"""Shared benchmark utilities: pair groups, timing, result records."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.exact.graph import Graph
from repro.data.graphs import graph_pair_groups

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"

# CPU-feasible stand-ins for the paper's sizes (paper: |V| up to 30 in C++;
# our exact reference is pure python on one core, so groups are smaller —
# the *orderings* the paper claims are what we reproduce).
QUICK_SIZES = (8, 10, 12)
FULL_SIZES = (8, 10, 12, 14)
OPS = (1, 2, 3, 4, 5)


def groups(quick: bool = True, pairs_per_group: int = 5,
           sizes: Optional[Tuple[int, ...]] = None, seed: int = 42):
    sz = sizes or (QUICK_SIZES if quick else FULL_SIZES)
    return graph_pair_groups(seed, sizes=sz, ops=OPS,
                             pairs_per_group=pairs_per_group)


def timed(fn: Callable, *args, **kw) -> Tuple[Any, float]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def timed_best(fn: Callable, *args, repeats: int = 3, **kw
               ) -> Tuple[Any, float]:
    """Like :func:`timed`, but the *minimum* wall over ``repeats`` calls.

    Shared-runner interference is one-sided — preemption only ever adds
    time — so the min is the stable cross-PR estimator for steady-state
    timings (``BENCH_engine.json`` rows).  Callers are expected to have
    warmed/compiled ``fn`` already.
    """
    out, best = None, float("inf")
    for _ in range(repeats):
        out, dt = timed(fn, *args, **kw)
        best = min(best, dt)
    return out, best


def record(name: str, rows: List[Dict[str, Any]]) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(rows, indent=1))


def record_section(name: str, section: str, rows: List[Dict[str, Any]]
                   ) -> None:
    """Merge one named section into ``results/bench/{name}.json``.

    The file holds ``{section: rows, ...}`` so benchmark functions that
    run at different times (backend throughput, escalation overlap)
    contribute to one trajectory record without clobbering each other.
    """
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    data: Dict[str, Any] = {}
    if path.exists():
        try:
            existing = json.loads(path.read_text())
            if isinstance(existing, dict):
                data = existing
        except ValueError:
            pass                      # unreadable/legacy layout: rewrite
    data[section] = rows
    path.write_text(json.dumps(data, indent=1))


def print_table(title: str, rows: List[Dict[str, Any]],
                cols: List[str]) -> None:
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def geometric_mean(xs: List[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))
