"""Batched JAX engine benchmarks: agreement with the exact reference,
throughput, strategy/bound ablations, and Pallas-kernel validation.

This is the beyond-paper half of the harness: the paper's AStar+ is a
sequential heap algorithm; the engine runs thousands of pairs in lockstep
on one device (and data-parallel across the mesh at scale — see the
``ged-verify`` dry-run rows).  Everything here goes through the public
``repro.ged`` facade — the same door serving traffic uses.
"""

from __future__ import annotations

import itertools
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (groups, print_table, record, record_section,
                               timed, timed_best)
from repro.core.exact.search import ged as exact_ged
from repro.ged import GedEngine


def _flat_pairs(gs, max_pairs=60):
    pairs = list(itertools.chain.from_iterable(gs.values()))
    return pairs[:max_pairs]


def _engine(**overrides) -> GedEngine:
    # cache=False: benchmarks re-run identical pair sets to measure
    # steady-state throughput — the result cache would answer the repeat
    # from memory and time nothing.
    opts = dict(slots=16, pool=512, expand=8, max_iters=512,
                bound="hybrid", strategy="astar", cache=False)
    opts.update(overrides)
    return GedEngine(opts.pop("backend", "jax"), **opts)


def _mean_stat(outs, key) -> float:
    return float(np.mean([o.stats[key] for o in outs]))


def engine_agreement_and_throughput(quick=True) -> List[Dict]:
    """Certified-exact agreement with the reference + pairs/s."""
    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs)
    truth = [exact_ged(q, g, bound="BMa").ged for q, g in pairs]

    rows = []
    for strategy in ("astar", "dfs"):
        eng = _engine(strategy=strategy)
        outs, dt_warm = timed(eng.compute, pairs)          # includes compile
        outs, dt = timed(eng.compute, pairs)               # steady state
        certified = np.array([o.certified for o in outs])
        agree = [int(round(o.ged)) == t
                 for o, t in zip(outs, truth) if o.certified]
        rows.append({
            "strategy": strategy,
            "pairs": len(pairs),
            "certified_frac": float(np.mean(certified)),
            "agree_frac_of_certified": float(np.mean(agree)) if agree else 0.0,
            "pairs_per_s": len(pairs) / dt,
            "compile_s": dt_warm - dt,
            "mean_iters": _mean_stat(outs, "iterations"),
        })
        assert all(agree), "certified engine answers must match the oracle"
    print_table("Engine vs exact (computation)", rows,
                ["strategy", "pairs", "certified_frac",
                 "agree_frac_of_certified", "pairs_per_s", "mean_iters"])
    record("engine_agreement", rows)
    return rows


def engine_verification(quick=True) -> List[Dict]:
    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs)
    truth = [exact_ged(q, g, bound="BMa").ged for q, g in pairs]
    rows = []
    for tau in (3.0, 6.0, 9.0):
        eng = _engine()
        outs, _ = timed(eng.verify, pairs, tau)
        outs, dt = timed(eng.verify, pairs, tau)
        cert = np.array([o.certified for o in outs])
        ok = [o.similar == (t <= tau)
              for o, t in zip(outs, truth) if o.certified]
        rows.append({"tau": tau, "pairs_per_s": len(pairs) / dt,
                     "certified_frac": float(np.mean(cert)),
                     "agree": float(np.mean(ok)) if ok else 0.0,
                     "mean_iters": _mean_stat(outs, "iterations")})
        assert all(ok)
    print_table("Engine verification (vary tau)", rows,
                ["tau", "pairs_per_s", "certified_frac", "agree",
                 "mean_iters"])
    record("engine_verification", rows)
    return rows


def engine_bound_ablation(quick=True) -> List[Dict]:
    """LSa vs BMa-dual vs hybrid inside the batched engine: iterations =
    the tensor analogue of the paper's search-space metric."""
    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs, max_pairs=36)
    rows = []
    for bound in ("lsa", "bma", "hybrid"):
        eng = _engine(bound=bound)
        outs, _ = timed(eng.compute, pairs)
        outs, dt = timed(eng.compute, pairs)
        rows.append({"bound": bound,
                     "mean_iters": _mean_stat(outs, "iterations"),
                     "mean_expanded": _mean_stat(outs, "expanded"),
                     "pairs_per_s": len(pairs) / dt,
                     "certified_frac":
                         float(np.mean([o.certified for o in outs]))})
    by = {r["bound"]: r["mean_expanded"] for r in rows}
    assert by["hybrid"] <= by["lsa"] * 1.05, \
        "tighter bound must not expand more states"
    print_table("Engine bound ablation", rows,
                ["bound", "mean_iters", "mean_expanded", "pairs_per_s",
                 "certified_frac"])
    record("engine_bounds", rows)
    return rows


def engine_sweeps_ablation(quick=True) -> List[Dict]:
    """Auction sweeps: the bound-tightness dial.

    Finding (recorded in EXPERIMENTS.md §Perf as a refuted hypothesis):
    MORE sweeps does NOT monotonically shrink the search on paper-scale
    graphs — higher post-auction prices degrade the greedy-primal
    *incumbent* faster than the dual bound tightens, and the incumbent
    dominates pruning at these sizes.  What IS guaranteed (weak duality)
    and asserted here: every certified answer stays exact at any sweep
    count, and answers agree across sweep counts.
    """
    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs, max_pairs=36)
    truth = [exact_ged(q, g, bound="BMa").ged for q, g in pairs]
    rows = []
    for sweeps in (2, 6, 12):
        eng = _engine(bound="bma", sweeps=sweeps)
        outs, _ = timed(eng.compute, pairs)
        outs, dt = timed(eng.compute, pairs)
        agree = [int(round(o.ged)) == t
                 for o, t in zip(outs, truth) if o.certified]
        assert all(agree), f"sweeps={sweeps}: certified answer wrong"
        rows.append({"sweeps": sweeps,
                     "mean_expanded": _mean_stat(outs, "expanded"),
                     "pairs_per_s": len(pairs) / dt,
                     "certified_frac":
                         float(np.mean([o.certified for o in outs]))})
    print_table("Engine auction-sweeps ablation (admissible at every "
                "sweep count)", rows,
                ["sweeps", "mean_expanded", "pairs_per_s",
                 "certified_frac"])
    record("engine_sweeps", rows)
    return rows


def kernel_validation(quick=True) -> List[Dict]:
    """Pallas kernels vs pure-jnp oracles (interpret mode on CPU)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    from repro.kernels.bma_cost_matrix import bma_cost_matrix_pallas
    from repro.kernels.reduced_top2 import reduced_top2_pallas

    rng = np.random.default_rng(3)
    rows = []
    shapes = [(2, 8, 4), (3, 16, 6)] if quick else \
        [(2, 8, 4), (3, 16, 6), (2, 32, 8), (1, 64, 8)]
    for (b, n, le) in shapes:
        qv = jnp.asarray(rng.integers(0, 5, (b, n)), jnp.int32)
        gv = jnp.asarray(rng.integers(0, 5, (b, n)), jnp.int32)
        iq = jnp.asarray(rng.integers(0, 3, (b, n, le)), jnp.float32)
        ig = jnp.asarray(rng.integers(0, 3, (b, n, le)), jnp.float32)
        qa = jnp.asarray(rng.integers(0, 3, (b, n, n)), jnp.int32)
        gc = jnp.asarray(rng.integers(0, 3, (b, n, n)), jnp.int32)
        pa = jnp.asarray(rng.random((b, n)) < 0.3, jnp.float32)
        t0 = time.perf_counter()
        out_k = bma_cost_matrix_pallas(qv, gv, iq, ig, qa, gc, pa,
                                       interpret=True)
        dt_k = time.perf_counter() - t0
        out_r = ref.bma_cost_matrix_ref(qv, gv, iq, ig, qa, gc, pa)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-5)
        cost = jnp.asarray(rng.random((b, n, n)), jnp.float32)
        prices = jnp.asarray(rng.random((b, n)), jnp.float32)
        m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=True)
        r1, ra, r2 = ref.reduced_top2_ref(cost, prices)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(r1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(r2), atol=1e-6)
        from repro.kernels.lsa_children import lsa_children_pallas
        lsa_args = [
            jnp.asarray(rng.integers(0, 9, (b, n)) * 0.5, jnp.float32),
            jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32),
            jnp.asarray(rng.integers(0, le + 1, (b, n, n)), jnp.int32),
            jnp.asarray(rng.integers(0, le + 1, (b, n)), jnp.int32),
            jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, n)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, n)), jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, le)) * 0.5, jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, le)) * 0.5, jnp.float32),
            jnp.asarray(rng.integers(0, 4, (b, le)), jnp.float32),
        ]
        out_l = lsa_children_pallas(*lsa_args, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out_l), np.asarray(ref.lsa_children_ref(*lsa_args)))
        rows.append({"B": b, "N": n, "Le": le, "allclose": True,
                     "interpret_s": dt_k})
    print_table("Pallas kernels vs oracle (interpret mode)", rows,
                ["B", "N", "Le", "allclose", "interpret_s"])
    record("kernel_validation", rows)
    return rows


def engine_backend_throughput(quick=True) -> List[Dict]:
    """Single-device vs mesh-sharded executor throughput.

    Emits the ``backend_throughput`` section of
    ``results/bench/BENCH_engine.json`` — the perf-trajectory record the
    ROADMAP's scaling work is judged against.  On one CPU device the
    sharded path should roughly match ``jax`` (same compute + shard_map
    overhead); the row captures the device count so multi-chip runs are
    comparable.
    """
    import jax

    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs)
    rows = []
    for backend in ("jax", "sharded"):
        eng = _engine(backend=backend)
        outs, dt_warm = timed(eng.compute, pairs)          # includes compile
        outs, dt = timed_best(eng.compute, pairs)          # steady state
        rows.append({
            "backend": backend,
            "devices": jax.device_count(),
            "batch_multiple": int(eng.batch_multiple),
            "pairs": len(pairs),
            "pairs_per_s": len(pairs) / dt,
            "compile_s": dt_warm - dt,
            "certified_frac":
                float(np.mean([o.certified for o in outs])),
            "mean_wall_s": float(np.mean([o.wall_s for o in outs])),
        })
    a, b = (r["pairs_per_s"] for r in rows)
    assert min(a, b) > 0
    print_table("Engine backend throughput (single-device vs sharded)",
                rows, ["backend", "devices", "batch_multiple", "pairs",
                       "pairs_per_s", "compile_s", "certified_frac"])
    record_section("BENCH_engine", "backend_throughput", rows)
    return rows


def engine_escalation_overlap(quick=True) -> List[Dict]:
    """Sequential vs overlapped rung execution in the ``auto`` pipeline.

    A small first rung forces real escalation (and a host-solver tail),
    which is where overlap pays: while one batch is in flight the
    scheduler drains decided pairs, re-buckets survivors, and host-solves
    final-rung pairs behind the device work.  Outcomes must be identical
    in both modes; only the wall-clock differs.  The comparison lands in
    the ``escalation_overlap`` section of
    ``results/bench/BENCH_engine.json``.
    """
    import jax

    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs, max_pairs=36)

    def make(overlap: bool) -> GedEngine:
        eng = _engine(backend="auto", batch_size=8, overlap=overlap,
                      max_in_flight=4)
        # shrink the ladder so rung 0 leaves survivors and the host rung
        # actually engages on paper-scale pairs
        eng._backend.scheduler.rungs = ((8, 1, 4), (256, 4, 128))
        return eng

    rows, outcomes = [], {}
    for mode in ("sequential", "overlapped"):
        overlap = mode == "overlapped"
        make(overlap).compute(pairs)                   # compile warm-up
        eng = make(overlap)
        outs, dt = timed(eng.compute, pairs)
        outcomes[mode] = [(o.ged, o.certified) for o in outs]
        s = eng.stats
        rows.append({
            "mode": mode,
            "devices": jax.device_count(),
            "pairs": len(pairs),
            "pairs_per_s": len(pairs) / dt,
            "wall_s": dt,
            "escalated": s["escalated"],
            "host_solved": s["host_solved"],
            "dispatches": s["dispatches"],
            "overlap_saved_s": s["overlap_saved_s"],
            "certified_frac": float(np.mean([o.certified for o in outs])),
        })
    assert outcomes["sequential"] == outcomes["overlapped"], \
        "overlapped rung execution changed an answer"
    assert all(c for _, c in outcomes["overlapped"]), \
        "auto must certify every answer"
    print_table("Auto escalation: sequential vs overlapped rungs", rows,
                ["mode", "pairs", "pairs_per_s", "wall_s", "escalated",
                 "host_solved", "overlap_saved_s", "certified_frac"])
    record_section("BENCH_engine", "escalation_overlap", rows)
    return rows


def engine_similarity_search(quick=True) -> List[Dict]:
    """Corpus similarity search through ``ged.GraphStore``: the paper's
    filter-verify workload end to end.

    An AIDS-like molecule corpus (with planted near-duplicates of each
    query) is ingested once; ranged queries then run the staged pipeline
    — stage-0 resident-corpus scan, stage-1 anchor-aware engine bounds,
    stage-2 certified verification.  The row records the filter ratio,
    the per-stage candidate counts, queries/s, and the scan-vs-verify
    wall split; it lands in the ``similarity_search`` section of
    ``results/bench/BENCH_engine.json``.  ``cache=False`` keeps repeat
    timings honest (the store's result cache would answer the second
    pass from memory).
    """
    import jax

    from repro.data.graphs import aids_like_graph, perturb
    from repro.ged import GraphStore

    rng = np.random.default_rng(12)
    corpus_size = 120 if quick else 240
    n_queries = 4 if quick else 8
    tau = 4.0
    corpus = [aids_like_graph(rng, int(rng.integers(8, 15)))
              for _ in range(corpus_size)]
    queries = [corpus[int(rng.integers(0, corpus_size))]
               for _ in range(n_queries)]
    for query in queries:                      # planted near-duplicates
        for _ in range(3):
            corpus.append(perturb(rng, query, int(rng.integers(1, 4)),
                                  n_vlabels=62, n_elabels=3))

    def make() -> GraphStore:
        return GraphStore(corpus, batch_size=32, pool=512, expand=8,
                          max_iters=512, cache=False)

    make().search_batch(queries, tau)          # compile warm-up
    store = make()
    _, dt = timed(store.search_batch, queries, tau)
    s = store.stats
    row = {
        "devices": jax.device_count(),
        "corpus": len(corpus),
        "queries": len(queries),
        "tau": tau,
        "candidates": s["candidates"],
        "stage0_pruned": s["stage0_pruned"],
        "stage1_decided": s["stage1_decided"],
        "stage2_verified": s["stage2_verified"],
        "filter_ratio": s["filter_ratio"],
        "hits": s["hits"],
        "queries_per_s": len(queries) / dt,
        "scan_wall_s": s["scan_wall_s"] + s["bound_wall_s"],
        "verify_wall_s": s["verify_wall_s"],
        "wall_s": dt,
    }
    assert s["index_pruned"] + s["stage0_pruned"] > 0.5 * s["candidates"], \
        "the cheap stages must prune most of the corpus"
    assert row["hits"] >= len(queries), "planted duplicates must be found"
    print_table("Corpus similarity search (filter-verify pipeline)", [row],
                ["corpus", "queries", "tau", "candidates", "stage0_pruned",
                 "stage1_decided", "stage2_verified", "filter_ratio",
                 "hits", "queries_per_s", "scan_wall_s", "verify_wall_s"])
    record_section("BENCH_engine", "similarity_search", [row])
    # the corpus-size sweep for the stage −1 candidate index rides along:
    # it emits its own ``candidate_index`` section (and, in full mode,
    # validates the >=100k-corpus selectivity acceptance bar)
    engine_candidate_index(quick=quick)
    return [row]


def engine_candidate_index(quick=True) -> List[Dict]:
    """Corpus-size sweep for the stage −1 ``CandidateIndex``.

    For each corpus size an AIDS-like database (with planted
    near-duplicates of every query) is ingested twice — once with
    ``index=None`` (the previous full-scan pipeline, which doubles as the
    recall oracle) and once with the banded WL-sketch index — and the
    same ranged queries run through both.  Each row records the ingest
    wall, the stage funnel, ``examined_frac`` (the corpus fraction stage
    −1 leaves for the linear stages — smaller is better, and
    ``tools/bench_diff.py`` flags it when it rises), measured recall
    against the oracle, and steady-state queries/s; rows land in the
    ``candidate_index`` section of ``results/bench/BENCH_engine.json``.

    In full mode the sweep reaches a >=100k-graph corpus and enforces
    the acceptance bar: exact mode examines <=10% of the database per
    query at *zero* recall loss.  A probabilistic row (``recall=0.9``)
    shows the explicit exactness opt-out at the smallest size.
    ``digest="exact"`` keeps ingest about hashing, not WL dedup probes;
    ``cache=False`` keeps the repeat timings honest.
    """
    import jax

    from repro.data.graphs import aids_like_graph, perturb
    from repro.ged import GraphStore

    sizes = [1_500] if quick else [20_000, 100_000]
    tau, n_queries = 2.0, 3
    opts = dict(batch_size=32, pool=512, expand=8, max_iters=512,
                cache=False, digest="exact")
    rows = []
    for size in sizes:
        rng = np.random.default_rng(13)
        corpus = [aids_like_graph(rng, int(rng.integers(8, 15)))
                  for _ in range(size)]
        queries = [corpus[int(rng.integers(0, size))]
                   for _ in range(n_queries)]
        for query in queries:              # planted near-duplicates
            for _ in range(2):
                corpus.append(perturb(rng, query, int(rng.integers(1, 3)),
                                      n_vlabels=62, n_elabels=3))
        flat = GraphStore(corpus, index=None, **opts)
        truth = [sorted(h.graph_id for h in flat.range_search(q, tau))
                 for q in queries]
        assert all(truth), "every query must have planted hits"

        modes = [("exact", "auto")]
        if size == sizes[0]:
            modes.append(("recall90", {"recall": 0.9}))
        for mode, index in modes:
            store, ingest_s = timed(GraphStore, corpus, index=index, **opts)
            per_q, _ = timed(store.search_batch, queries, tau)  # + compile
            s = dict(store.stats)          # funnel of exactly one pass
            got = [sorted(h.graph_id for h in qhits) for qhits in per_q]
            want = sum(len(t) for t in truth)
            found = sum(len(set(g) & set(t)) for g, t in zip(got, truth))
            _, dt = timed_best(store.search_batch, queries, tau)
            examined = (s["candidates"] - s["index_pruned"]) \
                / max(s["candidates"], 1)
            row = {
                "case": f"{mode}/{len(corpus)}",
                "mode": mode,
                "devices": jax.device_count(),
                "corpus": len(corpus),
                "queries": n_queries,
                "tau": tau,
                "ingest_s": ingest_s,
                "examined_frac": examined,
                "index_pruned": s["index_pruned"],
                "stage0_pruned": s["stage0_pruned"],
                "stage1_decided": s["stage1_decided"],
                "stage2_verified": s["stage2_verified"],
                "hits": s["hits"],
                "recall": found / want,
                "queries_per_s": n_queries / dt,
                "index_wall_s": s["index_wall_s"],
            }
            if mode == "exact":
                assert got == truth, \
                    f"exact index changed a result set at |DB|={len(corpus)}"
                if len(corpus) >= 100_000:
                    assert examined <= 0.10, \
                        f"stage -1 examined {examined:.2%} of the corpus"
            rows.append(row)
    print_table("Candidate index corpus-size sweep (stage -1)", rows,
                ["case", "corpus", "queries", "tau", "examined_frac",
                 "index_pruned", "stage0_pruned", "stage1_decided",
                 "stage2_verified", "recall", "queries_per_s", "ingest_s"])
    record_section("BENCH_engine", "candidate_index", rows)
    return rows


def engine_store_persistence(quick=True) -> List[Dict]:
    """Warm-start serving economics: cold ingest vs ``GraphStore.save``
    vs warm ``GraphStore.open`` vs incremental ``add``.

    One AIDS-like corpus is ingested from scratch (the cold path every
    process pays without persistence), persisted, and reopened from the
    snapshot; a small batch is then journal-appended to the open store.
    Result parity between the fresh and the reopened store is a
    *blocking* assertion — a persisted store that answers differently is
    a bug, not a slow path — and the warm open must not re-pack
    (``filter_packed_rows`` / ``index_signatures_built`` stay zero).
    The timings themselves are informational; ``warm_open_speedup``
    (bigger is better — ``tools/bench_diff.py`` treats the ``_speedup``
    suffix as such) lands in the ``store_persistence`` section of
    ``results/bench/BENCH_engine.json``.
    """
    import shutil
    import tempfile

    import jax

    from repro.data.graphs import aids_like_graph, perturb
    from repro.ged import GraphStore

    rng = np.random.default_rng(23)
    corpus_size = 120 if quick else 240
    n_queries = 4 if quick else 8
    n_append = 8 if quick else 16
    tau = 4.0
    corpus = [aids_like_graph(rng, int(rng.integers(8, 15)))
              for _ in range(corpus_size)]
    queries = [corpus[int(rng.integers(0, corpus_size))]
               for _ in range(n_queries)]
    extra = [perturb(rng, queries[i % n_queries], int(rng.integers(1, 4)),
                     n_vlabels=62, n_elabels=3) for i in range(n_append)]

    def make() -> GraphStore:
        return GraphStore(corpus, batch_size=32, pool=512, expand=8,
                          max_iters=512, cache=False)

    make().search_batch(queries, tau)          # compile warm-up
    fresh, cold_s = timed(make)
    truth = fresh.search_batch(queries, tau)

    store_dir = tempfile.mkdtemp(prefix="bench-graphstore-")
    try:
        _, save_s = timed(fresh.save, store_dir)
        warm, open_s = timed(
            GraphStore.open, store_dir, batch_size=32, pool=512,
            expand=8, max_iters=512, cache=False)
        got = warm.search_batch(queries, tau)
        assert [[(h.graph_id, h.ged) for h in hs] for hs in got] \
            == [[(h.graph_id, h.ged) for h in hs] for hs in truth], \
            "reopened store changed a result set"
        s = warm.stats
        assert s["filter_packed_rows"] == 0, "warm open re-packed features"
        assert s["index_signatures_built"] == 0, "warm open re-sketched"
        _, append_s = timed(warm.add, extra)
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)

    row = {
        "devices": jax.device_count(),
        "corpus": len(corpus),
        "appended": n_append,
        "queries": n_queries,
        "tau": tau,
        "cold_ingest_s": cold_s,
        "save_s": save_s,
        "warm_open_s": open_s,
        "append_s": append_s,
        "warm_open_speedup": cold_s / max(open_s, 1e-9),
    }
    print_table("GraphStore persistence (cold vs warm vs append)", [row],
                ["corpus", "appended", "cold_ingest_s", "save_s",
                 "warm_open_s", "append_s", "warm_open_speedup"])
    record_section("BENCH_engine", "store_persistence", [row])
    return [row]


def engine_deadline(quick=True) -> List[Dict]:
    """Anytime bound quality vs wall-clock budget (``docs/robustness.md``).

    Runs the ``auto`` pipeline over one fixed pair set at several
    deadline budgets (warm — compiles are paid before the clock starts)
    and reports, per budget: certified fraction, timed-out fraction,
    measured overshoot, and the bound-quality curve ``lb_quality`` =
    mean(lower_bound / true GED) with certified pairs counting 1.0 — the
    number that should climb monotonically toward 1.0 as the budget
    grows.  Soundness (``lb <= true GED <= ub``) is asserted at every
    budget; overshoot must stay within 20% of budgets >= 0.25s.
    """
    gs = groups(quick, pairs_per_group=3)
    pairs = _flat_pairs(gs, max_pairs=24 if quick else 48)
    truth = [exact_ged(q, g, bound="BMa").ged for q, g in pairs]

    def make() -> GedEngine:
        eng = _engine(backend="auto", batch_size=8, max_in_flight=4)
        # small first rung: forces escalation + a host tail, so budgets
        # actually bite on paper-scale pairs
        eng._backend.scheduler.rungs = ((8, 1, 4), (256, 4, 128))
        return eng

    make().compute(pairs)                              # compile warm-up
    budgets = ([0.001, 0.005, 0.02, 0.25] if quick
               else [0.001, 0.005, 0.02, 0.05, 0.25, 1.0])
    rows = []
    for budget in budgets + [None]:
        eng = make()
        outs, dt = timed(eng.compute, pairs, deadline_s=budget)
        lbq = []
        for o, t in zip(outs, truth):
            if not o.certified:
                assert o.lower_bound <= t + 1e-9, (budget, o.lower_bound, t)
                assert o.upper_bound >= t - 1e-9, (budget, o.upper_bound, t)
            lbq.append(1.0 if o.certified
                       else min(o.lower_bound / t, 1.0) if t else 1.0)
        overshoot = 0.0 if budget is None else max(dt - budget, 0.0) / budget
        if budget is not None and budget >= 0.25:
            assert overshoot <= 0.20, \
                f"deadline overshoot {overshoot:.0%} at budget {budget}s"
        rows.append({
            "case": "no-deadline" if budget is None else f"{budget:g}s",
            "budget_s": 0.0 if budget is None else budget,
            "pairs": len(pairs),
            "wall_s": dt,
            "overshoot_frac": overshoot,
            "certified_frac": float(np.mean([o.certified for o in outs])),
            "timed_out_frac": float(np.mean([o.timed_out for o in outs])),
            "lb_quality": float(np.mean(lbq)),
        })
    assert rows[-1]["certified_frac"] == 1.0, \
        "no-deadline run must certify everything"
    print_table("Anytime contract: bound quality vs deadline budget", rows,
                ["case", "pairs", "wall_s", "overshoot_frac",
                 "certified_frac", "timed_out_frac", "lb_quality"])
    record_section("BENCH_engine", "deadline", rows)
    return rows


ALL = (engine_agreement_and_throughput, engine_verification,
       engine_bound_ablation, engine_sweeps_ablation,
       engine_backend_throughput, engine_escalation_overlap,
       engine_similarity_search, engine_deadline, kernel_validation)


def scheduler_cost_model(quick=True) -> List[Dict]:
    """Does the straggler scheduler's difficulty model predict real work?

    Rank correlation between ``runtime.scheduler.difficulty`` and the
    engine's measured per-pair iteration count, plus the wall-time
    balance of LPT-packed batches vs naive contiguous batches under a
    work-proportional cost model.
    """
    from repro.runtime.scheduler import GedScheduler, difficulty

    gs = groups(quick, pairs_per_group=4)
    pairs = _flat_pairs(gs, max_pairs=48)
    eng = _engine()
    outs, _ = timed(eng.compute, pairs)
    iters = np.asarray([o.stats["iterations"] for o in outs], np.float64)

    diffs = [difficulty(q.n, g.n, q.m, g.m, q.vlabels, g.vlabels)
             for q, g in pairs]
    # Spearman rank correlation (no scipy in this image)
    def ranks(v):
        order = np.argsort(v)
        r = np.empty_like(order, dtype=np.float64)
        r[order] = np.arange(len(v))
        return r
    rd, ri = ranks(np.asarray(diffs)), ranks(iters)
    rho = float(np.corrcoef(rd, ri)[0, 1])

    sched = GedScheduler(batch_size=8)
    batches = sched.pack(diffs)
    lpt_worst = max(sum(iters[i] for i in b.indices) for b in batches)
    naive_worst = max(sum(iters[k:k + 8]) for k in range(0, len(pairs), 8))
    rows = [{"pairs": len(pairs), "spearman_rho": rho,
             "lpt_worst_batch_iters": float(lpt_worst),
             "naive_worst_batch_iters": float(naive_worst),
             "straggler_gain": float(naive_worst / max(lpt_worst, 1e-9))}]
    assert rho > 0.2, f"difficulty model uncorrelated with work (rho={rho})"
    print_table("Scheduler cost model vs measured engine work", rows,
                ["pairs", "spearman_rho", "lpt_worst_batch_iters",
                 "naive_worst_batch_iters", "straggler_gain"])
    record("scheduler_cost_model", rows)
    return rows
