"""Kernel micro-benchmark rail: the search inner loop's device primitives.

Three hot-path comparisons, each timed at engine-realistic shapes across
``N in {32, 64, 128}`` and recorded as the ``kernel_hotpath`` section of
``results/bench/BENCH_engine.json`` (so ``tools/bench_diff.py`` tracks
kernel regressions across PRs):

* **lsa** — fused Pallas LSa child-bound kernel vs the unfused einsum
  chain (``bounds.lsa_children`` with ``use_kernel`` on/off).
* **bma** — fused Pallas BMa branch-cost kernel vs the pure-jnp path
  (``bounds.bma_cost_matrix``).
* **merge** — sorted-pool frontier maintenance (child-only sort +
  ``parallel.ops.merge_sorted_topk`` rank merge) vs the old full-pool
  ``top_k`` pop + ``(P + B*N)`` argsort merge.

On CPU the Pallas kernels execute in interpret mode (recorded in the
``pallas`` column) — the fused-vs-unfused ratio there tracks *lowering*
regressions, not real silicon; on TPU the same rows measure Mosaic
kernels.  The merge rows are backend-honest everywhere (both variants are
plain XLA).

A fourth section, ``compile_cache``, measures warm-vs-cold first-call
latency across two fresh subprocesses sharing one persistent compilation
cache directory (``GedEngine(compile_cache_dir=...)``).
"""

from __future__ import annotations

import functools
import os
import re
import subprocess
import sys
import tempfile
from typing import Dict, List

import numpy as np

from benchmarks.common import print_table, record_section

_NS = {True: (32, 64), False: (32, 64, 128)}       # quick -> sizes


def _time(fn, *args, iters: int = 5, blocks: int = 4) -> float:
    """Steady-state seconds per call of a jitted ``fn`` (compiles first).

    ``common.timed_best`` (min over repeats — the least-interference
    estimator for one-sided shared-runner noise) over ``blocks`` timing
    blocks of ``iters`` back-to-back calls each.
    """
    import jax

    from benchmarks.common import timed_best
    jax.block_until_ready(fn(*args))               # compile + warm

    def block():
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)

    _, best = timed_best(block, repeats=blocks)
    return best / iters


def _pallas_mode() -> str:
    import jax
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return "disabled"
    return "mosaic" if jax.default_backend() == "tpu" else "interpret"


def _packed_pair(rng, n: int):
    """One dense random pair packed at ``slots == n`` (full occupancy)."""
    from repro.core.engine.tensor_graphs import pack_pairs
    from repro.data.graphs import perturb, random_graph

    q = random_graph(rng, n, density=0.3, n_vlabels=5, n_elabels=3)
    g = perturb(rng, q, 4, n_vlabels=5, n_elabels=3)
    return pack_pairs([(q, g)], slots=n)


def _states(rng, n: int, b: int):
    """A batch of ``b`` random expansion states (img, level, gcost)."""
    imgs = np.full((b, n), -1, np.int32)
    levels = rng.integers(1, max(2, n // 2), b).astype(np.int32)
    for i, lvl in enumerate(levels):
        imgs[i, :lvl] = rng.permutation(n)[:lvl]
    gcosts = (rng.integers(0, 8, b) * 0.5).astype(np.float32)
    return imgs, levels, gcosts


def kernel_bound_fusion(quick=True) -> List[Dict]:
    """Fused vs unfused LSa/BMa child scoring at engine shapes."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import bounds as eb

    rng = np.random.default_rng(7)
    b = 8                                           # states per expansion
    rows = []
    for n in _NS[quick]:
        t = _packed_pair(rng, n)
        args = tuple(jnp.asarray(x[0]) for x in
                     (t.qv, t.gv, t.qa, t.ga, t.order)) + (jnp.asarray(t.n[0]),)
        imgs, levels, gcosts = (jnp.asarray(a) for a in _states(rng, n, b))

        def run(kernel_fn, use_kernel):
            @functools.partial(jax.jit, static_argnames=("uk",))
            def f(qv, gv, qa, ga, order, nn, im, lv, gc, uk):
                pc = eb.make_pair_consts(qv, gv, qa, ga, order, nn,
                                         t.n_vlabels, t.n_elabels)

                def one(img, level, gcost):
                    sm = eb.state_masks(pc, img, level)
                    return kernel_fn(pc, sm, level, gcost, uk)

                return jax.vmap(one)(im, lv, gc)

            return _time(lambda: f(*args, imgs, levels, gcosts, uk=use_kernel))

        lsa = lambda pc, sm, level, gcost, uk: \
            eb.lsa_children(pc, sm, level, gcost, use_kernel=uk)
        bma = lambda pc, sm, level, gcost, uk: \
            eb.bma_cost_matrix(pc, sm, use_kernel=uk)
        for name, fn in (("lsa", lsa), ("bma", bma)):
            fused_s = run(fn, True)
            unfused_s = run(fn, False)
            rows.append({
                "case": f"{name}/N={n}",
                "kernel": name, "N": n, "B": b,
                "fused_us": fused_s * 1e6,
                "unfused_us": unfused_s * 1e6,
                "fused_speedup": unfused_s / fused_s,
                "pallas": _pallas_mode(),
            })
    print_table("Kernel fusion: fused vs unfused child scoring", rows,
                ["case", "B", "fused_us", "unfused_us", "fused_speedup",
                 "pallas"])
    return rows


def kernel_merge_vs_argsort(quick=True) -> List[Dict]:
    """Sorted-pool frontier step vs the old full-pool argsort merge.

    Payload mirrors the engine's pool state (an ``(N,)`` int32 image per
    entry plus level/gcost/lb/valid); both variants are vmapped over a
    pair batch, like the real loop.
    """
    import jax
    import jax.numpy as jnp

    from repro.parallel.ops import merge_sorted_topk, sort_by_key, \
        top_k_sorted

    rng = np.random.default_rng(11)
    batch, bexp = 32, 8                            # pair batch, expand B
    rows = []
    for n in _NS[quick]:
        pool = 2048 if n >= 64 else 512
        bn = bexp * n                              # children per iteration

        def payload(rows_, keys):
            return {"img": jnp.asarray(
                        rng.integers(0, n, (batch, rows_, n)), jnp.int32),
                    "lb": keys / 256.0}

        pool_keys = jnp.asarray(
            np.sort(rng.random((batch, pool)), axis=1), jnp.float32)
        ch_keys = jnp.asarray(rng.random((batch, bn)), jnp.float32)
        pool_pl = payload(pool, pool_keys)
        ch_pl = payload(bn, ch_keys)

        @jax.jit
        def old_step(pk, pp, ck, cp):
            def one(pk, pimg, plb, ck, cimg, clb):
                _, idx = top_k_sorted(-pk, bexp)   # pop: full-pool top_k
                popped = (pimg[idx], plb[idx])
                ak = jnp.concatenate([pk, ck])
                ai = jnp.concatenate([pimg, cimg])
                al = jnp.concatenate([plb, clb])
                order = jnp.argsort(ak)            # full (P + B*N) argsort
                keep = order[:pool]
                return popped, ak[keep], ai[keep], al[keep], \
                    jnp.min(al[order[pool:]])
            return jax.vmap(one)(pk, pp["img"], pp["lb"], ck, cp["img"],
                                 cp["lb"])

        @jax.jit
        def new_step(pk, pp, ck, cp):
            def one(pk, pimg, plb, ck, cimg, clb):
                popped = (pimg[:bexp], plb[:bexp])   # pop: a slice
                rk, (rimg, rlb) = pk[bexp:], (pimg[bexp:], plb[bexp:])
                cks, co = sort_by_key(                # keys only
                    ck, jnp.arange(bn, dtype=jnp.int32))
                keys, (img, lb), dropped = merge_sorted_topk(
                    rk, cks, (rimg, rlb), (cimg, clb), pool,
                    drop_a=rlb, drop_b=clb, perm_b=co)
                return popped, keys, img, lb, dropped
            return jax.vmap(one)(pk, pp["img"], pp["lb"], ck, cp["img"],
                                 cp["lb"])

        old_s = _time(lambda: old_step(pool_keys, pool_pl, ch_keys, ch_pl))
        new_s = _time(lambda: new_step(pool_keys, pool_pl, ch_keys, ch_pl))
        rows.append({
            "case": f"merge/P={pool},BN={bn}",
            "kernel": "merge", "N": n, "pool": pool, "children": bn,
            "pairs": batch,
            "argsort_us": old_s * 1e6,
            "merge_us": new_s * 1e6,
            "merge_speedup": old_s / new_s,
        })
    print_table("Frontier maintenance: rank merge vs full-pool argsort",
                rows, ["case", "pairs", "argsort_us", "merge_us",
                       "merge_speedup"])
    return rows


def kernel_hotpath(quick=True) -> List[Dict]:
    """The full rail -> ``kernel_hotpath`` section of BENCH_engine.json."""
    rows = kernel_bound_fusion(quick) + kernel_merge_vs_argsort(quick)
    record_section("BENCH_engine", "kernel_hotpath", rows)
    return rows


_CACHE_PROBE = """
import sys, time
from repro import ged
pairs = [(([0, 1, 1], [(0, 1, 1), (1, 2, 2)]),
          ([0, 1, 2], [(0, 1, 1), (0, 2, 1)]))]
eng = ged.GedEngine("jax", cache=False, pool=64, max_iters=64,
                    compile_cache_dir=sys.argv[1])
t0 = time.perf_counter(); eng.compute(pairs)
first = time.perf_counter() - t0
t0 = time.perf_counter(); eng.compute(pairs)
steady = time.perf_counter() - t0
s = eng.stats
print(f"RESULT first={first} steady={steady} "
      f"hits={s['persistent_cache_hits']} "
      f"misses={s['persistent_cache_misses']}")
"""


def kernel_compile_cache(quick=True) -> List[Dict]:
    """Warm-vs-cold first-call compile across processes.

    Two fresh subprocesses run the same tiny engine workload against one
    persistent compilation cache directory: the first pays the XLA
    compile and serialises it, the second deserialises.  The remaining
    warm first-call time is tracing + dispatch, which the persistent
    cache cannot remove.
    """
    rows = []
    with tempfile.TemporaryDirectory() as d:
        for run in ("cold", "warm"):
            env = dict(os.environ)
            env.setdefault("JAX_PLATFORMS", "cpu")
            root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            env["PYTHONPATH"] = os.path.join(root, "src") + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
            out = subprocess.run(
                [sys.executable, "-c", _CACHE_PROBE, d],
                capture_output=True, text=True, env=env, check=True)
            m = re.search(r"RESULT first=(\S+) steady=(\S+) hits=(\S+) "
                          r"misses=(\S+)", out.stdout)
            assert m, out.stdout + out.stderr
            rows.append({
                "run": run,
                "first_call_s": float(m.group(1)),
                "steady_s": float(m.group(2)),
                "persistent_cache_hits": float(m.group(3)),
                "persistent_cache_misses": float(m.group(4)),
            })
    assert rows[0]["persistent_cache_misses"] >= 1, rows
    assert rows[1]["persistent_cache_hits"] >= 1, rows
    print_table("Persistent compile cache: cold vs warm process", rows,
                ["run", "first_call_s", "steady_s",
                 "persistent_cache_hits", "persistent_cache_misses"])
    record_section("BENCH_engine", "compile_cache", rows)
    return rows


ALL = (kernel_hotpath, kernel_compile_cache)
