"""Kernel micro-benchmark rail: the search inner loop's device primitives.

Four sections in ``results/bench/BENCH_engine.json`` (tracked across PRs
by ``tools/bench_diff.py``):

* ``kernel_hotpath`` — fused-vs-unfused LSa/BMa child scoring swept over
  ``N in {32, 64, 128} x B in {8, 32, 128}`` **through the autotuner**
  (``repro.kernels.autotune.tune_shape``), so every row records the
  measured winner the ``use_kernel="auto"`` dispatch would pick: the
  ``auto_*`` columns are the tuned rows, and ``auto_speedup >= 1.0`` by
  construction (dispatch can never pick a variant that measured slower
  than both alternatives).  Plus the rank-merge-vs-argsort frontier
  comparison and the fused merge-ranks kernel at pool shapes.
* ``roofline`` — bytes/FLOPs attribution for both bound kernels, the
  rank merge and a whole lowered search step, via
  ``launch/hlo_analysis.analyze_hlo`` over the compiled unfused HLO next
  to the analytic minimum traffic of the fused form — *why* a shape
  wins, not just that it does (``benchmarks/roofline.py --ged`` renders
  it).
* ``autotune`` — the CI smoke: sweep -> persist -> reload -> dispatch on
  a tuning table in a temp dir, with engine-outcome parity between
  ``use_kernel="auto"`` and the unfused baseline asserted (blocking);
  the timings are informational.
* ``compile_cache`` — warm-vs-cold first-call latency across two fresh
  subprocesses sharing one persistent compilation cache directory.

On CPU the Pallas kernels execute in interpret mode (the ``pallas`` and
``device_kind`` columns say so on every row) — fused-vs-unfused ratios
there track *lowering* regressions, not real silicon; on TPU the same
rows measure Mosaic kernels, and the tuning table keyed by
``device_kind`` keeps the two worlds from contaminating each other.
"""

from __future__ import annotations

import functools
import os
import re
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import print_table, record_section

_NS = {True: (32, 64), False: (32, 64, 128)}       # quick -> N sweep
_BS = {True: (8, 32), False: (8, 32, 128)}         # quick -> B sweep
_MERGE_SHAPES = {True: ((512, 256), (2048, 512)),  # (pool, children)
                 False: ((512, 256), (2048, 512), (2048, 1024))}
_BUDGET = {True: 0.08, False: 0.15}                # per-variant timing budget

# Machine balance (FLOP/byte) separating memory- from compute-bound in the
# roofline verdicts: ~TPU-class HBM (e.g. 275 TF/s / 1.2 TB/s ~= 230).
# CPU balances are far lower, so a kernel memory-bound at 240 is
# memory-bound everywhere this repo runs.
_BALANCE = 240.0


def _time(fn, *args, iters: int = 5, blocks: int = 4) -> float:
    """Steady-state seconds per call of a jitted ``fn`` (compiles first).

    ``common.timed_best`` (min over repeats — the least-interference
    estimator for one-sided shared-runner noise) over ``blocks`` timing
    blocks of ``iters`` back-to-back calls each.
    """
    import jax

    from benchmarks.common import timed_best
    jax.block_until_ready(fn(*args))               # compile + warm

    def block():
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)

    _, best = timed_best(block, repeats=blocks)
    return best / iters


def _pallas_mode() -> str:
    import jax
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return "disabled"
    return "mosaic" if jax.default_backend() == "tpu" else "interpret"


def _device_kind() -> str:
    from repro.kernels.autotune import device_kind
    return device_kind()


def _packed_pair(rng, n: int):
    """One dense random pair packed at ``slots == n`` (full occupancy)."""
    from repro.core.engine.tensor_graphs import pack_pairs
    from repro.data.graphs import perturb, random_graph

    q = random_graph(rng, n, density=0.3, n_vlabels=5, n_elabels=3)
    g = perturb(rng, q, 4, n_vlabels=5, n_elabels=3)
    return pack_pairs([(q, g)], slots=n)


def _states(rng, n: int, b: int):
    """A batch of ``b`` random expansion states (img, level, gcost)."""
    imgs = np.full((b, n), -1, np.int32)
    levels = rng.integers(1, max(2, n // 2), b).astype(np.int32)
    for i, lvl in enumerate(levels):
        imgs[i, :lvl] = rng.permutation(n)[:lvl]
    gcosts = (rng.integers(0, 8, b) * 0.5).astype(np.float32)
    return imgs, levels, gcosts


def kernel_bound_fusion(quick=True) -> List[Dict]:
    """Fused vs unfused LSa/BMa child scoring, measured by the autotuner.

    Every ``(kernel, N, B)`` cell runs ``autotune.tune_shape`` — the
    exact measurement ``use_kernel="auto"`` dispatches on — so the rail
    and the dispatch can never disagree.  ``fused_us`` is the fused
    kernel at its *default* tiles (the PR 5 comparison), ``auto_us`` the
    tuned winner's own time; ``auto_speedup`` compares the winner to the
    better of {fused-default, unfused} and is >= 1.0 by construction.
    """
    from repro.kernels import autotune

    rows = []
    for name in ("lsa", "bma"):
        for n in _NS[quick]:
            for b in _BS[quick]:
                ent = autotune.tune_shape(name, n, b,
                                          budget_s=_BUDGET[quick])
                fused = ent["fused_default_us"]
                unfused = ent["unfused_us"]
                auto = ent["us"]
                rows.append({
                    "case": f"{name}/N={n}/B={b}",
                    "kernel": name, "N": n, "B": b,
                    "fused_us": fused,
                    "unfused_us": unfused,
                    "fused_speedup": unfused / fused,
                    "auto_us": auto,
                    "auto_impl": ent["impl"],
                    "tile_v": ent["tile_v"], "tile_u": ent["tile_u"],
                    "auto_speedup": min(fused, unfused) / auto,
                    "tuned": True,
                    "pallas": ent["pallas"],
                    "device_kind": ent["device_kind"],
                })
    print_table("Kernel fusion: fused vs unfused child scoring (tuned)",
                rows, ["case", "fused_us", "unfused_us", "fused_speedup",
                       "auto_impl", "tile_u", "auto_speedup", "pallas"])
    return rows


def kernel_merge_fusion(quick=True) -> List[Dict]:
    """Pallas rank-count merge kernel vs the searchsorted rank passes.

    The same sorted-pool merge step the engine runs (pop-slice remainder
    + freshly sorted children, payload gather, floor), with only the two
    rank computations swapped — bit-identical outputs either way.
    """
    from repro.kernels import autotune

    rows = []
    for pool, children in _MERGE_SHAPES[quick]:
        ent = autotune.tune_shape("merge", pool, children,
                                  budget_s=_BUDGET[quick])
        fused = ent["fused_us"]
        unfused = ent["unfused_us"]
        rows.append({
            "case": f"merge_ranks/P={pool},BN={children}",
            "kernel": "merge", "pool": pool, "children": children,
            "fused_us": fused,
            "unfused_us": unfused,
            "fused_speedup": unfused / fused,
            "auto_us": ent["us"],
            "auto_impl": ent["impl"],
            "auto_speedup": min(fused, unfused) / ent["us"],
            "tuned": True,
            "pallas": ent["pallas"],
            "device_kind": ent["device_kind"],
        })
    print_table("Frontier merge: Pallas rank counts vs binary search",
                rows, ["case", "fused_us", "unfused_us", "fused_speedup",
                       "auto_impl", "auto_speedup", "pallas"])
    return rows


def kernel_merge_vs_argsort(quick=True) -> List[Dict]:
    """Sorted-pool frontier step vs the old full-pool argsort merge.

    Payload mirrors the engine's pool state (an ``(N,)`` int32 image per
    entry plus level/gcost/lb/valid); both variants are vmapped over a
    pair batch, like the real loop.
    """
    import jax
    import jax.numpy as jnp

    from repro.parallel.ops import merge_sorted_topk, sort_by_key, \
        top_k_sorted

    rng = np.random.default_rng(11)
    batch, bexp = 32, 8                            # pair batch, expand B
    rows = []
    for n in _NS[quick]:
        pool = 2048 if n >= 64 else 512
        bn = bexp * n                              # children per iteration

        def payload(rows_, keys):
            return {"img": jnp.asarray(
                        rng.integers(0, n, (batch, rows_, n)), jnp.int32),
                    "lb": keys / 256.0}

        pool_keys = jnp.asarray(
            np.sort(rng.random((batch, pool)), axis=1), jnp.float32)
        ch_keys = jnp.asarray(rng.random((batch, bn)), jnp.float32)
        pool_pl = payload(pool, pool_keys)
        ch_pl = payload(bn, ch_keys)

        @jax.jit
        def old_step(pk, pp, ck, cp):
            def one(pk, pimg, plb, ck, cimg, clb):
                _, idx = top_k_sorted(-pk, bexp)   # pop: full-pool top_k
                popped = (pimg[idx], plb[idx])
                ak = jnp.concatenate([pk, ck])
                ai = jnp.concatenate([pimg, cimg])
                al = jnp.concatenate([plb, clb])
                order = jnp.argsort(ak)            # full (P + B*N) argsort
                keep = order[:pool]
                return popped, ak[keep], ai[keep], al[keep], \
                    jnp.min(al[order[pool:]])
            return jax.vmap(one)(pk, pp["img"], pp["lb"], ck, cp["img"],
                                 cp["lb"])

        @jax.jit
        def new_step(pk, pp, ck, cp):
            def one(pk, pimg, plb, ck, cimg, clb):
                popped = (pimg[:bexp], plb[:bexp])   # pop: a slice
                rk, (rimg, rlb) = pk[bexp:], (pimg[bexp:], plb[bexp:])
                cks, co = sort_by_key(                # keys only
                    ck, jnp.arange(bn, dtype=jnp.int32))
                keys, (img, lb), dropped = merge_sorted_topk(
                    rk, cks, (rimg, rlb), (cimg, clb), pool,
                    drop_a=rlb, drop_b=clb, perm_b=co)
                return popped, keys, img, lb, dropped
            return jax.vmap(one)(pk, pp["img"], pp["lb"], ck, cp["img"],
                                 cp["lb"])

        old_s = _time(lambda: old_step(pool_keys, pool_pl, ch_keys, ch_pl))
        new_s = _time(lambda: new_step(pool_keys, pool_pl, ch_keys, ch_pl))
        rows.append({
            "case": f"merge/P={pool},BN={bn}",
            "kernel": "merge", "N": n, "pool": pool, "children": bn,
            "pairs": batch,
            "argsort_us": old_s * 1e6,
            "merge_us": new_s * 1e6,
            "merge_speedup": old_s / new_s,
            "device_kind": _device_kind(),
        })
    print_table("Frontier maintenance: rank merge vs full-pool argsort",
                rows, ["case", "pairs", "argsort_us", "merge_us",
                       "merge_speedup"])
    return rows


def kernel_hotpath(quick=True) -> List[Dict]:
    """The full rail -> ``kernel_hotpath`` section of BENCH_engine.json."""
    rows = kernel_bound_fusion(quick) + kernel_merge_vs_argsort(quick) \
        + kernel_merge_fusion(quick)
    # acceptance: dispatch never picks a loser (auto >= best alternative;
    # tiny epsilon for float division noise — the winner's us IS the min)
    for r in rows:
        if "auto_speedup" in r:
            assert r["auto_speedup"] >= 0.999, r
    record_section("BENCH_engine", "kernel_hotpath", rows)
    return rows


# ---------------------------------------------------------------- roofline

def _fused_min_bytes(kernel: str, n: int, b: int, le: int = 3) -> float:
    """Analytic minimum HBM traffic of the fused kernel: every operand
    read once + the output written once (f32/int32 = 4 bytes each).

    lsa operands (see ``kernels/lsa_children.py``): 5x (B,N) f32 + qrow
    (B,N) i32 + 3x (B,N,Le) f32 + 3x (B,Le) f32 + a_ju (B,N,N) i32,
    out (B,N) f32.  bma (``kernels/bma_cost_matrix.py``): qv/gv (B,N)
    i32 + inner hists 2x (B,N,Le) f32 + qa_ord/gcross (B,N,N) i32 +
    pos_anch (B,N) f32, out (B,N,N) f32.
    """
    if kernel == "lsa":
        words = b * (7 * n + 3 * n * le + 3 * le + n * n)
    elif kernel == "bma":
        words = b * (3 * n + 2 * n * le + 3 * n * n)
    else:
        raise ValueError(kernel)
    return 4.0 * words


def _lowered_bound_cost(kernel: str, n: int, b: int) -> Dict[str, float]:
    """flops/bytes of the *unfused* bound at (N, B) from compiled HLO.

    The unfused path is pure XLA (no interpret-mode pallas noise in the
    module), so ``analyze_hlo`` over ``.compile().as_text()`` attributes
    the real einsum-chain traffic the fused kernel replaces.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.engine import bounds as eb
    from repro.launch.hlo_analysis import analyze_hlo

    rng = np.random.default_rng(7)
    t = _packed_pair(rng, n)
    args = tuple(jnp.asarray(x[0]) for x in
                 (t.qv, t.gv, t.qa, t.ga, t.order)) + (jnp.asarray(t.n[0]),)
    imgs, levels, gcosts = (jnp.asarray(a) for a in _states(rng, n, b))

    @functools.partial(jax.jit, static_argnames=("uk",))
    def f(qv, gv, qa, ga, order, nn, im, lv, gc, uk):
        pc = eb.make_pair_consts(qv, gv, qa, ga, order, nn,
                                 t.n_vlabels, t.n_elabels)

        def one(img, level, gcost):
            sm = eb.state_masks(pc, img, level)
            if kernel == "lsa":
                return eb.lsa_children(pc, sm, level, gcost, use_kernel=uk)
            return eb.bma_cost_matrix(pc, sm, use_kernel=uk)

        return jax.vmap(one)(im, lv, gc)

    text = f.lower(*args, imgs, levels, gcosts, uk=False) \
        .compile().as_text()
    c = analyze_hlo(text)
    return {"flops": float(c["flops"]),
            "bytes_accessed": float(c["bytes_accessed"])}


def _lowered_merge_cost(pool: int, children: int, pairs: int = 32
                        ) -> Dict[str, float]:
    """flops/bytes of one sorted-pool merge step (rank passes + payload
    gather + floor) from compiled HLO."""
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze_hlo
    from repro.parallel.ops import merge_sorted_topk, sort_by_key

    rng = np.random.default_rng(11)
    na = pool - 8
    ka = jnp.asarray(np.sort(rng.random((pairs, na)), axis=1), jnp.float32)
    kb = jnp.asarray(rng.random((pairs, children)), jnp.float32)
    pa = jnp.asarray(rng.integers(0, 64, (pairs, na, 16)), jnp.int32)
    pb = jnp.asarray(rng.integers(0, 64, (pairs, children, 16)), jnp.int32)

    @jax.jit
    def f(ka, kb, pa, pb):
        def one(ka, kb, pa, pb):
            kbs, order = sort_by_key(
                kb, jnp.arange(children, dtype=jnp.int32))
            return merge_sorted_topk(ka, kbs, (pa,), (pb,), pool,
                                     drop_a=ka, drop_b=kbs, perm_b=order)
        return jax.vmap(one)(ka, kb, pa, pb)

    text = f.lower(ka, kb, pa, pb).compile().as_text()
    c = analyze_hlo(text)
    return {"flops": float(c["flops"]),
            "bytes_accessed": float(c["bytes_accessed"])}


def _lowered_search_step_cost(n: int, batch: int = 8) -> Dict[str, float]:
    """flops/bytes of the whole jitted search (``_run_batch``) at a
    bucket shape, lowered from abstract inputs with kernels off (pure
    XLA, so the HLO walk sees everything)."""
    from repro.core.engine import api as engine_api
    from repro.core.engine.search import EngineConfig
    from repro.launch.hlo_analysis import analyze_hlo

    ab = engine_api.batch_abstract_inputs(batch, n)
    cfg = EngineConfig(pool=256, expand=4, max_iters=64, use_kernel=False)
    lowered = engine_api._run_batch.lower(
        ab["qv"], ab["gv"], ab["qa"], ab["ga"], ab["order"], ab["n"],
        ab["taus"], cfg, False, 5, 3)
    c = analyze_hlo(lowered.compile().as_text())
    return {"flops": float(c["flops"]),
            "bytes_accessed": float(c["bytes_accessed"])}


def kernel_roofline(quick=True) -> List[Dict]:
    """Bytes/FLOPs attribution -> ``roofline`` section of BENCH_engine.

    For each bound kernel at the swept N (B = 8; both costs scale ~
    linearly in B so the intensity verdict is B-independent): the
    unfused einsum chain's measured HLO traffic next to the fused form's
    analytic minimum.  ``intensity_fused_ideal < balance`` means the
    kernel stays memory-bound even with perfect fusion — the win comes
    from the traffic it deletes, which is exactly what the table shows.
    The rank-merge row is what justifies the fused merge kernel: its
    intensity sits far below any machine balance (it is a comparison
    count — almost no FLOPs per byte), i.e. memory-bound, so fusing the
    two rank passes into one VMEM-resident kernel is the only lever.
    """
    rows = []
    b = 8
    for kernel in ("lsa", "bma"):
        for n in _NS[quick]:
            c = _lowered_bound_cost(kernel, n, b)
            fused_bytes = _fused_min_bytes(kernel, n, b)
            intensity = c["flops"] / max(c["bytes_accessed"], 1.0)
            ideal = c["flops"] / fused_bytes
            rows.append({
                "case": f"{kernel}/N={n}/B={b}",
                "kernel": kernel, "N": n, "B": b,
                "flops": c["flops"],
                "bytes_unfused": c["bytes_accessed"],
                "bytes_fused_min": fused_bytes,
                "traffic_ratio": c["bytes_accessed"] / fused_bytes,
                "intensity_unfused": intensity,
                "intensity_fused_ideal": ideal,
                "memory_bound": bool(ideal < _BALANCE),
                "balance": _BALANCE,
                "device_kind": _device_kind(),
            })
    pool, children = _MERGE_SHAPES[quick][-1]
    c = _lowered_merge_cost(pool, children)
    intensity = c["flops"] / max(c["bytes_accessed"], 1.0)
    rows.append({
        "case": f"merge/P={pool},BN={children}",
        "kernel": "merge", "N": pool, "B": children,
        "flops": c["flops"],
        "bytes_unfused": c["bytes_accessed"],
        "intensity_unfused": intensity,
        "intensity_fused_ideal": intensity,   # fusion deletes no FLOPs
        "memory_bound": bool(intensity < _BALANCE),
        "balance": _BALANCE,
        "device_kind": _device_kind(),
    })
    n0 = _NS[quick][0]
    c = _lowered_search_step_cost(n0)
    rows.append({
        "case": f"search_step/N={n0}/B=8",
        "kernel": "search_step", "N": n0, "B": 8,
        "flops": c["flops"],
        "bytes_unfused": c["bytes_accessed"],
        "intensity_unfused": c["flops"] / max(c["bytes_accessed"], 1.0),
        "memory_bound": bool(
            c["flops"] / max(c["bytes_accessed"], 1.0) < _BALANCE),
        "balance": _BALANCE,
        "device_kind": _device_kind(),
    })
    print_table("GED kernel roofline (unfused HLO vs fused minimum "
                "traffic)", rows,
                ["case", "flops", "bytes_unfused", "bytes_fused_min",
                 "intensity_unfused", "intensity_fused_ideal",
                 "memory_bound"])
    record_section("BENCH_engine", "roofline", rows)
    return rows


# ---------------------------------------------------------------- autotune

def kernel_autotune(quick=True) -> List[Dict]:
    """CI smoke: sweep -> persist -> reload -> dispatch, parity-gated.

    Runs a tiny tuning sweep into a temp directory, drops the in-memory
    table, reloads it from disk, and computes a small workload with
    ``use_kernel="auto"`` against the unfused baseline.  Outcome parity
    and table round-trip are *asserted* (blocking); the recorded timings
    are informational.  Engine/global tuning state is snapshotted and
    restored, so this probe never contaminates the other rails.
    """
    from repro import ged
    from repro.data.graphs import perturb, random_graph
    from repro.kernels import autotune

    rows: List[Dict] = []
    saved = autotune.snapshot()
    try:
        with tempfile.TemporaryDirectory() as d:
            autotune.reset()
            autotune.enable_autotune(d)
            t0 = time.perf_counter()
            entries = autotune.tune(ns=(8, 16), bs=(8,),
                                    kernels=("lsa", "bma"),
                                    merge_shapes=((128, 64),),
                                    tiles=((0, 0),), budget_s=0.02)
            sweep_s = time.perf_counter() - t0
            assert len(entries) == 5, entries
            rows.append({"run": "sweep", "entries": len(entries),
                         "sweep_s": sweep_s,
                         "pallas": _pallas_mode(),
                         "device_kind": _device_kind()})

            # persist -> reload: a fresh table must serve the same rows
            autotune.reset()
            autotune.enable_autotune(d)
            reloaded = autotune.lookup("lsa", 8, 8, count=False)
            assert reloaded is not None and reloaded["impl"] in \
                ("fused", "unfused"), reloaded
            rows.append({"run": "reload",
                         "entries": len(autotune._AUTOTUNE["table"])})

            # dispatch + parity (blocking): auto must match the baseline
            rng = np.random.default_rng(5)
            pairs = [(random_graph(rng, int(rng.integers(4, 9)),
                                   density=0.4, n_vlabels=3, n_elabels=2),
                      perturb(rng, random_graph(rng, 6, density=0.4,
                                                n_vlabels=3, n_elabels=2),
                              2, n_vlabels=3, n_elabels=2))
                     for _ in range(8)]
            ea = ged.GedEngine("jax", use_kernel="auto", cache=False,
                               autotune_dir=d, pool=128, max_iters=128)
            eb_ = ged.GedEngine("jax", cache=False, pool=128,
                                max_iters=128)
            t0 = time.perf_counter()
            oa = ea.compute(pairs)
            auto_s = time.perf_counter() - t0
            ob = eb_.compute(pairs)
            for a, b in zip(oa, ob):
                assert (a.ged, a.certified, a.lower_bound, a.upper_bound) \
                    == (b.ged, b.certified, b.lower_bound, b.upper_bound), \
                    (a, b)
                assert np.array_equal(a.mapping, b.mapping)
            s = ea.stats
            assert s["autotune_hits"] >= 1, s
            rows.append({"run": "dispatch", "pairs": len(pairs),
                         "auto_s": auto_s, "parity_ok": 1.0,
                         "autotune_hits": s["autotune_hits"],
                         "autotune_misses": s["autotune_misses"],
                         "pallas_interpret": bool(s["pallas_interpret"])})
    finally:
        autotune.restore(saved)
    print_table("Autotune smoke: sweep -> persist -> reload -> dispatch",
                rows, ["run", "entries", "sweep_s", "pairs", "auto_s",
                       "parity_ok", "autotune_hits"])
    record_section("BENCH_engine", "autotune", rows)
    return rows


# ------------------------------------------------------------ compile cache

_CACHE_PROBE = """
import sys, time
from repro import ged
pairs = [(([0, 1, 1], [(0, 1, 1), (1, 2, 2)]),
          ([0, 1, 2], [(0, 1, 1), (0, 2, 1)]))]
eng = ged.GedEngine("jax", cache=False, pool=64, max_iters=64,
                    compile_cache_dir=sys.argv[1])
t0 = time.perf_counter(); eng.compute(pairs)
first = time.perf_counter() - t0
t0 = time.perf_counter(); eng.compute(pairs)
steady = time.perf_counter() - t0
s = eng.stats
print(f"RESULT first={first} steady={steady} "
      f"hits={s['persistent_cache_hits']} "
      f"misses={s['persistent_cache_misses']}")
"""


def kernel_compile_cache(quick=True) -> List[Dict]:
    """Warm-vs-cold first-call compile across processes.

    Two fresh subprocesses run the same tiny engine workload against one
    persistent compilation cache directory: the first pays the XLA
    compile and serialises it, the second deserialises.  The remaining
    warm first-call time is tracing + dispatch, which the persistent
    cache cannot remove.
    """
    rows = []
    with tempfile.TemporaryDirectory() as d:
        for run in ("cold", "warm"):
            env = dict(os.environ)
            env.setdefault("JAX_PLATFORMS", "cpu")
            root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            env["PYTHONPATH"] = os.path.join(root, "src") + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
            out = subprocess.run(
                [sys.executable, "-c", _CACHE_PROBE, d],
                capture_output=True, text=True, env=env, check=True)
            m = re.search(r"RESULT first=(\S+) steady=(\S+) hits=(\S+) "
                          r"misses=(\S+)", out.stdout)
            assert m, out.stdout + out.stderr
            rows.append({
                "run": run,
                "first_call_s": float(m.group(1)),
                "steady_s": float(m.group(2)),
                "persistent_cache_hits": float(m.group(3)),
                "persistent_cache_misses": float(m.group(4)),
            })
    assert rows[0]["persistent_cache_misses"] >= 1, rows
    assert rows[1]["persistent_cache_hits"] >= 1, rows
    print_table("Persistent compile cache: cold vs warm process", rows,
                ["run", "first_call_s", "steady_s",
                 "persistent_cache_hits", "persistent_cache_misses"])
    record_section("BENCH_engine", "compile_cache", rows)
    return rows


ALL = (kernel_hotpath, kernel_roofline, kernel_autotune,
       kernel_compile_cache)
