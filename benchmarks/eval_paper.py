"""Paper Evals I–IX (one per figure of §6 / App. A.4), on the exact
paper-faithful reference implementation.

Metrics follow the paper: processing time and *search space* = number of
best-extension computations.  Sizes are CPU-scaled (see common.py); each
eval asserts the paper's qualitative claim and records the measured rows.
"""

from __future__ import annotations

import itertools
from typing import Dict, List

import numpy as np

from benchmarks.common import (geometric_mean, groups, print_table, record,
                               timed)
from repro.core.exact.search import ged, ged_verify

DEFAULT_X = 4            # perturbation group (paper defaults to GED=9)


def _run_group(pairs, bound: str, strategy: str, expand_all: bool = True,
               tau=None) -> Dict[str, float]:
    times, space, expanded = [], [], []
    for q, g in pairs:
        if tau is None:
            res, dt = timed(ged, q, g, bound=bound, strategy=strategy,
                            expand_all=expand_all)
        else:
            res, dt = timed(ged_verify, q, g, tau, bound=bound,
                            strategy=strategy, expand_all=expand_all)
        times.append(dt)
        space.append(res.stats.best_extension_calls)
        expanded.append(res.stats.expanded)
    return {"time_s": float(np.mean(times)),
            "space": float(np.mean(space)),
            "expanded": float(np.mean(expanded))}


def _sweep(gs, algos, x: int = DEFAULT_X, tau=None) -> List[Dict]:
    rows = []
    sizes = sorted({k[0] for k in gs})
    for n in sizes:
        pairs = gs[(n, x)]
        for name, (bound, strategy, expand_all) in algos.items():
            r = _run_group(pairs, bound, strategy, expand_all, tau=tau)
            rows.append({"algo": name, "V": n, **r})
    return rows


def eval_1_against_existing(quick=True) -> List[Dict]:
    """Fig. 6: AStar+-BMa / DFS+-LSa / AStar+-LS vs DF_GED (= DFS+-LS)."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "DFS+-LSa": ("LSa", "dfs", True),
        "AStar+-LS": ("LS", "astar", True),
        "DF_GED(DFS+-LS)": ("LS", "dfs", True),
    }
    rows = _sweep(gs, algos)
    by = {r["algo"]: [] for r in rows}
    for r in rows:
        by[r["algo"]].append(r["space"])
    assert geometric_mean(by["AStar+-BMa"]) < geometric_mean(
        by["DF_GED(DFS+-LS)"]), "paper: AStar+-BMa beats DF_GED"
    print_table("Eval-I processing time / search space vs existing "
                "(x=4 group)", rows, ["algo", "V", "time_s", "space"])
    record("eval1_against_existing", rows)
    return rows


def eval_2_anchor_aware(quick=True) -> List[Dict]:
    """Fig. 7/15: anchor-aware bounds vs their plain counterparts."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "AStar+-BM": ("BM", "astar", True),
        "AStar+-LSa": ("LSa", "astar", True),
        "AStar+-LS": ("LS", "astar", True),
    }
    rows = _sweep(gs, algos)
    sp = lambda a: geometric_mean([r["space"] for r in rows
                                  if r["algo"] == a])
    assert sp("AStar+-BMa") <= sp("AStar+-BM")
    assert sp("AStar+-LSa") <= sp("AStar+-LS")
    print_table("Eval-II anchor-aware vs plain bounds", rows,
                ["algo", "V", "time_s", "space"])
    record("eval2_anchor_aware", rows)
    return rows


def eval_3_lower_bounds(quick=True) -> List[Dict]:
    """Fig. 8/16: BMaN <= BMa <= LSa <= SMa search-space ordering."""
    gs = groups(quick)
    algos = {
        "AStar+-BMaN": ("BMaN", "astar", True),
        "AStar+-BMa": ("BMa", "astar", True),
        "AStar+-LSa": ("LSa", "astar", True),
        "AStar+-SMa": ("SMa", "astar", True),
    }
    rows = _sweep(gs, algos)
    # search space = EXTENDED STATES here: BMaN's per-child naive bound
    # makes one "best extension computation" score each child separately,
    # so the state-count is the comparable metric (paper Figs. 8/16).
    sp = lambda a: geometric_mean([r["expanded"] for r in rows
                                  if r["algo"] == a])
    assert sp("AStar+-BMaN") <= sp("AStar+-BMa") * 1.05
    assert sp("AStar+-BMa") <= sp("AStar+-LSa") * 1.05
    assert sp("AStar+-LSa") <= sp("AStar+-SMa") * 1.05
    # the paper's time trade-off: BMaN has the smallest space but runs
    # SLOWER than BMa (per-child cubic solves)
    t = lambda a: geometric_mean([r["time_s"] for r in rows
                                 if r["algo"] == a])
    assert t("AStar+-BMaN") > t("AStar+-BMa")
    print_table("Eval-III lower bounds within AStar+", rows,
                ["algo", "V", "time_s", "space", "expanded"])
    record("eval3_lower_bounds", rows)
    return rows


def eval_4_expand_all(quick=True) -> List[Dict]:
    """Fig. 9: expand-all strategy vs -EO (best-child-only)."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "AStar+-BMa-EO": ("BMa", "astar", False),
        "AStar+-LSa": ("LSa", "astar", True),
        "AStar+-LSa-EO": ("LSa", "astar", False),
    }
    rows = _sweep(gs, algos)
    t = lambda a: geometric_mean([r["time_s"] for r in rows
                                  if r["algo"] == a])
    # paper: expand-all helps LSa consistently, BMa little
    assert t("AStar+-LSa") <= t("AStar+-LSa-EO") * 1.1
    print_table("Eval-IV expand-all strategy", rows,
                ["algo", "V", "time_s", "space"])
    record("eval4_expand_all", rows)
    return rows


def eval_5_astar_vs_dfs(quick=True) -> List[Dict]:
    """Fig. 10/17: AStar+ vs DFS+ for computation (same bound)."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "DFS+-BMa": ("BMa", "dfs", True),
        "AStar+-LSa": ("LSa", "astar", True),
        "DFS+-LSa": ("LSa", "dfs", True),
    }
    rows = _sweep(gs, algos)
    sp = lambda a: geometric_mean([r["space"] for r in rows
                                  if r["algo"] == a])
    assert sp("AStar+-BMa") <= sp("DFS+-BMa")
    assert sp("AStar+-LSa") <= sp("DFS+-LSa")
    print_table("Eval-V AStar+ vs DFS+ (computation)", rows,
                ["algo", "V", "time_s", "space"])
    record("eval5_astar_vs_dfs", rows)
    return rows


def eval_6_scalability(quick=True) -> List[Dict]:
    """Fig. 11/18: scalability in |V| for AStar+-BMa / AStar+-LSa."""
    sizes = (8, 12, 16) if quick else (8, 12, 16, 20)
    gs = groups(quick, sizes=sizes, pairs_per_group=3)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "AStar+-LSa": ("LSa", "astar", True),
    }
    rows = _sweep(gs, algos, x=2)
    print_table("Eval-VI scalability (x=2 group)", rows,
                ["algo", "V", "time_s", "space"])
    record("eval6_scalability", rows)
    return rows


def _tau_sweep(gs, algos, quick=True) -> List[Dict]:
    rows = []
    n = max(k[0] for k in gs)
    taus = (3, 5, 7, 9)
    for tau in taus:
        pairs = list(itertools.chain.from_iterable(
            gs[(n, x)] for x in (1, 3, 5)))
        for name, (bound, strategy, expand_all) in algos.items():
            r = _run_group(pairs, bound, strategy, expand_all, tau=tau)
            rows.append({"algo": name, "tau": tau, **r})
    return rows


def eval_7_verification_astar_vs_dfs(quick=True) -> List[Dict]:
    """Fig. 12/19: AStar+ vs DFS+ for verification (vary tau)."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "DFS+-BMa": ("BMa", "dfs", True),
        "AStar+-LSa": ("LSa", "astar", True),
        "DFS+-LSa": ("LSa", "dfs", True),
    }
    rows = _tau_sweep(gs, algos, quick)
    sp = lambda a: geometric_mean([r["space"] for r in rows
                                  if r["algo"] == a])
    # paper: the verification gap is small; AStar+ never meaningfully worse
    assert sp("AStar+-BMa") <= sp("DFS+-BMa") * 1.25
    print_table("Eval-VII AStar+ vs DFS+ (verification, vary tau)", rows,
                ["algo", "tau", "time_s", "space"])
    record("eval7_verify_astar_vs_dfs", rows)
    return rows


def eval_8_verification_vs_existing(quick=True) -> List[Dict]:
    """Fig. 13: AStar+-BMa / DFS+-BMa vs AStar+-LS (A*GED stand-in)."""
    gs = groups(quick)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "DFS+-BMa": ("BMa", "dfs", True),
        "AStar+-LS": ("LS", "astar", True),
    }
    rows = _tau_sweep(gs, algos, quick)
    sp = lambda a: geometric_mean([r["space"] for r in rows
                                  if r["algo"] == a])
    assert sp("AStar+-BMa") < sp("AStar+-LS")
    print_table("Eval-VIII verification vs existing", rows,
                ["algo", "tau", "time_s", "space"])
    record("eval8_verify_vs_existing", rows)
    return rows


def eval_9_verification_scalability(quick=True) -> List[Dict]:
    """Fig. 14: verification scalability in |V| (tau = 5)."""
    sizes = (8, 12, 16) if quick else (8, 12, 16, 20)
    gs = groups(quick, sizes=sizes, pairs_per_group=3)
    algos = {
        "AStar+-BMa": ("BMa", "astar", True),
        "DFS+-BMa": ("BMa", "dfs", True),
    }
    rows = _sweep(gs, algos, x=2, tau=5.0)
    print_table("Eval-IX verification scalability (tau=5)", rows,
                ["algo", "V", "time_s", "space"])
    record("eval9_verify_scalability", rows)
    return rows


ALL = (eval_1_against_existing, eval_2_anchor_aware, eval_3_lower_bounds,
       eval_4_expand_all, eval_5_astar_vs_dfs, eval_6_scalability,
       eval_7_verification_astar_vs_dfs, eval_8_verification_vs_existing,
       eval_9_verification_scalability)
