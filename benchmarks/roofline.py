"""Roofline table from the dry-run JSONs (results/dryrun/*.json).

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, peak bytes/device,
and the MFU upper bound implied by the dominant term.

Usage:  python -m benchmarks.roofline [--mesh single] [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"

COLS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
        "bottleneck", "useful_ratio", "peak_GiB", "mfu_ub")


def load(mesh: str = "all") -> List[Dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh != "all" and r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "bottleneck": r["reason"],
                         "skipped": True})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"],
                         "bottleneck": "ERROR: " + r.get("error", "?"),
                         "skipped": True})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"].replace("_s", ""),
            "useful_ratio": t.get("useful_flops_ratio"),
            "peak_GiB": r["memory"]["peak_bytes_per_device"] / 2 ** 30,
            "mfu_ub": t.get("mfu_upper_bound"),
            "skipped": False,
        })
    return rows


def _fmt(v, nd=4):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def markdown(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "bottleneck | useful | peak GiB/dev | MFU ub |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"— | — | — | {r['bottleneck']} | — | — | — |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} | "
                f"{_fmt(r['collective_s'])} | {r['bottleneck']} | "
                f"{_fmt(r['useful_ratio'], 3)} | {_fmt(r['peak_GiB'], 3)} | "
                f"{_fmt(r['mfu_ub'], 3)} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "all"))
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    if not rows:
        print("no dry-run results found — run "
              "`python -m repro.launch.dryrun` first")
        return
    if args.md:
        print(markdown(rows))
        return
    print(",".join(COLS))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in COLS))


if __name__ == "__main__":
    main()
