"""Roofline table from the dry-run JSONs (results/dryrun/*.json), plus
the GED kernel-attribution table from BENCH_engine.json.

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, peak bytes/device,
and the MFU upper bound implied by the dominant term.

``--ged`` renders the ``roofline`` section ``benchmarks/eval_kernels.py
kernel_roofline`` records instead: per bound kernel (and the rank merge
and whole search step), the unfused einsum chain's compiled-HLO
bytes/FLOPs next to the fused kernel's analytic minimum traffic — the
*why* behind each ``kernel_hotpath`` dispatch decision.

Usage:  python -m benchmarks.roofline [--mesh single] [--md] [--ged]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"
BENCH = Path(__file__).resolve().parent.parent / "results" / "bench" / \
    "BENCH_engine.json"

COLS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
        "bottleneck", "useful_ratio", "peak_GiB", "mfu_ub")

GED_COLS = ("case", "flops", "bytes_unfused", "bytes_fused_min",
            "traffic_ratio", "intensity_unfused", "intensity_fused_ideal",
            "memory_bound", "device_kind")


def load(mesh: str = "all") -> List[Dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh != "all" and r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "bottleneck": r["reason"],
                         "skipped": True})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"],
                         "bottleneck": "ERROR: " + r.get("error", "?"),
                         "skipped": True})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"].replace("_s", ""),
            "useful_ratio": t.get("useful_flops_ratio"),
            "peak_GiB": r["memory"]["peak_bytes_per_device"] / 2 ** 30,
            "mfu_ub": t.get("mfu_upper_bound"),
            "skipped": False,
        })
    return rows


def load_ged() -> List[Dict]:
    """Rows of the ``roofline`` section of BENCH_engine.json ([] when the
    kernel rail hasn't been run)."""
    try:
        data = json.loads(BENCH.read_text())
    except (OSError, ValueError):
        return []
    rows = data.get("roofline", []) if isinstance(data, dict) else []
    return [r for r in rows if isinstance(r, dict)]


def markdown_ged(rows: List[Dict]) -> str:
    out = ["| case | flops | bytes unfused | bytes fused min | traffic x | "
           "intensity | fused ideal | verdict |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        verdict = "memory" if r.get("memory_bound") else "compute"
        out.append(
            f"| {r.get('case')} | {_fmt(r.get('flops'), 3)} | "
            f"{_fmt(r.get('bytes_unfused'), 3)} | "
            f"{_fmt(r.get('bytes_fused_min'), 3)} | "
            f"{_fmt(r.get('traffic_ratio'), 3)} | "
            f"{_fmt(r.get('intensity_unfused'), 3)} | "
            f"{_fmt(r.get('intensity_fused_ideal'), 3)} | {verdict} |")
    return "\n".join(out)


def _fmt(v, nd=4):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def markdown(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "bottleneck | useful | peak GiB/dev | MFU ub |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"— | — | — | {r['bottleneck']} | — | — | — |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} | "
                f"{_fmt(r['collective_s'])} | {r['bottleneck']} | "
                f"{_fmt(r['useful_ratio'], 3)} | {_fmt(r['peak_GiB'], 3)} | "
                f"{_fmt(r['mfu_ub'], 3)} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "all"))
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--ged", action="store_true",
                    help="render the GED kernel attribution from "
                         "BENCH_engine.json instead of the dry-run table")
    args = ap.parse_args()
    if args.ged:
        rows = load_ged()
        if not rows:
            print("no GED roofline section — run "
                  "`python -m benchmarks.run --only eval_kernels` first")
            return
        if args.md:
            print(markdown_ged(rows))
            return
        print(",".join(GED_COLS))
        for r in rows:
            print(",".join(_fmt(r.get(c)) for c in GED_COLS))
        return
    rows = load(args.mesh)
    if not rows:
        print("no dry-run results found — run "
              "`python -m repro.launch.dryrun` first")
        return
    if args.md:
        print(markdown(rows))
        return
    print(",".join(COLS))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in COLS))


if __name__ == "__main__":
    main()
