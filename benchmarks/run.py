"""Benchmark harness entry point: one eval per paper figure (Evals I–IX on
the paper-faithful reference), the batched-engine suite, kernel validation,
and the roofline summary from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run            # quick (default)
  PYTHONPATH=src python -m benchmarks.run --quick    # same, explicit (CI)
  PYTHONPATH=src python -m benchmarks.run --full     # larger sizes
  PYTHONPATH=src python -m benchmarks.run --only eval5,engine
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import eval_engine, eval_kernels, eval_paper
from benchmarks.roofline import load as roofline_load, load_ged, \
    markdown, markdown_ged


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; CI smoke "
                         "steps pass it so intent reads in the workflow)")
    ap.add_argument("--only", default="",
                    help="comma list: eval1..eval9, engine, index, "
                         "deadline, persistence, kernels, eval_kernels, "
                         "roofline")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    quick = not args.full
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    # tags subsumed by a broader one in a default (no --only) run:
    # "engine" already runs the candidate-index sweep via
    # engine_similarity_search and the anytime-deadline curve via
    # engine_deadline, so those tags only fire when asked for (the CI
    # index-smoke and chaos-smoke steps run `--only index` / `--only
    # deadline`).
    implied = {"index", "deadline"}

    def want(tag: str) -> bool:
        return tag in only if only else tag not in implied

    t0 = time.time()
    failures = []

    paper_map = {f"eval{i+1}": fn for i, fn in enumerate(eval_paper.ALL)}
    for tag, fn in paper_map.items():
        if not want(tag):
            continue
        try:
            fn(quick=quick)
        except Exception as e:
            failures.append((tag, e))
            traceback.print_exc()

    engine_map = {
        "engine": (eval_engine.engine_agreement_and_throughput,
                   eval_engine.engine_verification,
                   eval_engine.engine_bound_ablation,
                   eval_engine.engine_sweeps_ablation,
                   eval_engine.engine_backend_throughput,
                   eval_engine.engine_escalation_overlap,
                   eval_engine.engine_similarity_search,
                   eval_engine.engine_deadline,
                   eval_engine.scheduler_cost_model),
        "index": (eval_engine.engine_candidate_index,),
        "deadline": (eval_engine.engine_deadline,),
        # "persistence" is the CI smoke tag for the durable-store rail:
        # cold ingest vs save vs warm open vs journal append (fresh/warm
        # result parity asserted inside, timings informational)
        "persistence": (eval_engine.engine_store_persistence,),
        # "kernels" is the CI smoke tag: oracle validation plus the
        # autotune sweep -> persist -> reload -> dispatch probe (parity
        # asserted inside, timings informational)
        "kernels": (eval_engine.kernel_validation,
                    eval_kernels.kernel_autotune),
        "eval_kernels": eval_kernels.ALL,
    }
    for tag, fns in engine_map.items():
        if not want(tag):
            continue
        for fn in fns:
            try:
                fn(quick=quick)
            except Exception as e:
                failures.append((tag, e))
                traceback.print_exc()

    if want("roofline"):
        rows = roofline_load("single")
        if rows:
            print("\n== Roofline (single-pod, from dry-run artifacts) ==")
            print(markdown(rows))
        ged_rows = load_ged()
        if ged_rows:
            print("\n== GED kernel roofline (from BENCH_engine.json) ==")
            print(markdown_ged(ged_rows))

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures")
    for tag, e in failures:
        print(f"  FAIL {tag}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
