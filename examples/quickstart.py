"""Quickstart: GED computation and verification with both engines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.exact.graph import Graph
from repro.core.exact.search import ged, ged_verify
from repro.core.engine.api import ged_batch, verify_batch
from repro.core.engine.search import EngineConfig
from repro.core.engine.tensor_graphs import pack_pairs

# --- build the paper's Figure 3 pair ---------------------------------------
A, B, C = 0, 1, 2
a, b = 1, 2
q = Graph.from_edges([A, B, B, B],
                     [(0, 1, a), (1, 2, a), (2, 3, b), (1, 3, b)])
g = Graph.from_edges([B, B, B, B, C],
                     [(0, 1, a), (1, 2, b), (2, 3, b), (1, 3, b),
                      (0, 4, b), (3, 4, a)])

# --- paper-faithful reference: AStar+-BMa (Alg. 2 + §4 bounds) --------------
res = ged(q, g, bound="BMa", strategy="astar")
print(f"exact engine  : delta(q, g) = {res.ged}  "
      f"(search space = {res.stats.best_extension_calls} best-extension calls)")

res_v = ged_verify(q, g, tau=5.0, bound="BMa")
print(f"verification  : delta(q, g) <= 5 ? {res_v.similar}")

# --- batched JAX engine: same answers, thousands of pairs at once ----------
rng = np.random.default_rng(0)
from repro.data.graphs import perturb, random_graph
pairs = [(q, g)]
for _ in range(15):
    qq = random_graph(rng, 10)
    pairs.append((qq, perturb(rng, qq, 3)))

packed = pack_pairs(pairs, slots=16)
out = ged_batch(packed, EngineConfig(pool=512, expand=8, use_kernel=False))
print(f"\nbatched engine: {len(pairs)} pairs in one jit call")
print("  ged      :", [int(x) for x in out["ged"][:8]], "...")
print("  certified:", [bool(x) for x in out["exact"][:8]], "...")

taus = [4.0] * len(pairs)
ver = verify_batch(packed, taus, EngineConfig(pool=256, expand=4,
                                              use_kernel=False))
print("  <= 4?    :", [bool(x) for x in ver["similar"][:8]], "...")
assert int(out["ged"][0]) == res.ged
print("\nbatched engine agrees with the paper-faithful reference ✓")
