"""Quickstart: one front door for GED — ``repro.ged``.

    PYTHONPATH=src python examples/quickstart.py   # or pip install -e .

Every entry point — module-level one-shots, a configured ``GedEngine``,
or streaming ``submit``/``flush`` — returns the same ``GedOutcome``
schema, whichever backend answered.
"""

import numpy as np

from repro import ged
from repro.core.exact.graph import Graph

# --- build the paper's Figure 3 pair ---------------------------------------
A, B, C = 0, 1, 2
a, b = 1, 2
q = Graph.from_edges([A, B, B, B],
                     [(0, 1, a), (1, 2, a), (2, 3, b), (1, 3, b)])
g = Graph.from_edges([B, B, B, B, C],
                     [(0, 1, a), (1, 2, b), (2, 3, b), (1, 3, b),
                      (0, 4, b), (3, 4, a)])

# --- one-shot, paper-faithful host solver (AStar+-BMa, Alg. 2 + §4) --------
[ref] = ged.compute([(q, g)], backend="exact")
print(f"exact backend : delta(q, g) = {ref.ged}  "
      f"(certified={ref.certified}, mapping={ref.mapping})")

[ver] = ged.verify([(q, g)], tau=5.0, backend="exact")
print(f"verification  : delta(q, g) <= 5 ? {ver.similar}")

# --- graphs don't have to be Graph objects ---------------------------------
# (vlabels, edges) tuples and adjacency dicts are ingested automatically
q_edges = ([A, B, B, B], [(0, 1, a), (1, 2, a), (2, 3, b), (1, 3, b)])
[same] = ged.compute([(q_edges, g)], backend="exact")
assert same.ged == ref.ged

# --- batched JAX engine: same answers, thousands of pairs at once ----------
rng = np.random.default_rng(0)
from repro.data.graphs import perturb, random_graph
pairs = [(q, g)]
for _ in range(15):
    qq = random_graph(rng, 10)
    pairs.append((qq, perturb(rng, qq, 3)))

engine = ged.GedEngine(backend="jax", pool=512, expand=8)
outs = engine.compute(pairs)
print(f"\njax backend   : {len(pairs)} pairs, bucketed into power-of-two "
      f"shapes ({engine.stats})")
print("  ged      :", [int(o.ged) for o in outs[:8]], "...")
print("  certified:", [o.certified for o in outs[:8]], "...")

vers = engine.verify(pairs, tau=4.0)
print("  <= 4?    :", [o.similar for o in vers[:8]], "...")

# --- mesh-sharded execution: same policy, shard_map placement --------------
# The sharded backend shards the pair batch over every local device
# (or a mesh you pass via ``mesh=``); batches are padded to shard
# multiples automatically, and outcomes are identical to the jax backend.
import jax
sharded = ged.GedEngine(backend="sharded", pool=512, expand=8)
outs_sh = sharded.compute(pairs)
assert [o.ged for o in outs_sh] == [o.ged for o in outs]
print(f"\nsharded       : {len(pairs)} pairs over {jax.device_count()} "
      f"device(s), batch multiple {sharded.batch_multiple}")

# --- engine-level result cache: duplicates never re-execute ----------------
again = sharded.compute(pairs)              # same pairs -> pure cache hits
assert all(o.stats.get("cached") for o in again)
print(f"result cache  : {sharded.stats['result_cache_hits']} hits, "
      f"{sharded.stats['result_cache_misses']} misses")

# --- streaming: mix computation and verification, flush once ---------------
engine.submit(q, g)                  # computation ticket 0
engine.submit(q, g, tau=4.0)         # verification ticket 1
t0, t1 = engine.flush()
print(f"\nstreaming     : ged={t0.ged}, <=4? {t1.similar}")

# --- the escalating production pipeline (always certified) -----------------
auto = ged.GedEngine(backend="auto", batch_size=8)
assert all(o.certified for o in auto.compute(pairs))

assert int(outs[0].ged) == ref.ged
print("\nall backends agree through one facade ✓")
