"""Serve a small LM with batched requests: prefill once, decode with a
donated KV cache (steady-state decode allocates nothing).  Exercises three
cache families: dense GQA ring/global (gemma3), pure-SSM state (rwkv6),
and hybrid mamba+shared-attention (zamba2).

    PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import numpy as np

from repro.configs import get_arch
from repro.models.config import reduced
from repro.models.params import init_params, param_count
from repro.serving import generate

for arch in ("gemma3-1b", "rwkv6-3b", "zamba2-7b"):
    base = get_arch(arch)
    cfg = reduced(base, layers=3 if base.window_pattern else 2)
    cfg = dataclasses.replace(cfg, remat="none")
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    batch, prompt_len, max_new = 4, 24, 12
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    t0 = time.time()
    out = generate(params, prompt, cfg, max_new=max_new, impl="naive")
    dt = time.time() - t0
    print(f"{arch:12s} ({param_count(cfg)/1e6:5.1f}M reduced) "
          f"batch={batch} prompt={prompt_len} new={max_new}  "
          f"{batch*max_new/dt:6.1f} tok/s   sample={out[0][:6].tolist()}")
