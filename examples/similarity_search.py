"""Graph similarity search over a database — the paper's target application
(§1, §5.3), end to end through the ``repro.ged`` facade.

A query graph is checked against a database of molecules via
``GedEngine(backend="auto")``: the pipeline predicts per-pair difficulty,
LPT-packs batches (straggler mitigation), runs the batched AStar+ engine,
and escalates uncertified pairs up to the paper-faithful host solver.
Every returned verdict is certified exact.

    PYTHONPATH=src python examples/similarity_search.py
"""

import time

import numpy as np

from repro.data.graphs import aids_like_graph, perturb
from repro.ged import GedEngine

rng = np.random.default_rng(1)

# --- database: 80 AIDS-like molecules, some of them near-copies of others --
DB = []
for i in range(60):
    DB.append(aids_like_graph(rng, int(rng.integers(8, 14))))
query = DB[0]
for _ in range(20):                       # planted near-duplicates
    DB.append(perturb(rng, query, int(rng.integers(1, 5)),
                      n_vlabels=62, n_elabels=3))

TAU = 4.0
engine = GedEngine(backend="auto", batch_size=32, slots=16)

t0 = time.time()
results = engine.verify([(query, g) for g in DB], tau=TAU)
dt = time.time() - t0

hits = [i for i, r in enumerate(results) if r.similar]
print(f"database size  : {len(DB)}")
print(f"tau            : {TAU}")
print(f"similar graphs : {len(hits)} -> indices {hits[:12]}{'...' if len(hits) > 12 else ''}")
print(f"wall time      : {dt:.2f}s ({len(DB)/dt:.1f} pairs/s, single CPU)")
print(f"all certified  : {all(r.certified for r in results)}")
print(f"engine stats   : {engine.stats}")

# sanity: the planted near-duplicates with few edits should be among hits
planted = set(range(60, 80))
found_planted = planted & set(hits)
print(f"planted near-duplicates found: {len(found_planted)}/20")
assert 0 in hits, "query vs itself must be similar"
