"""Graph similarity search over a database — the paper's target application
(§1, §5.3), end to end through ``repro.ged.GraphStore``.

A molecule corpus is ingested once (shared label vocab, resident stage-0
feature arrays, WL-digest dedup, a banded WL-sketch candidate index);
queries then run the staged filter-verify pipeline: the sound sketch
index prunes most of the corpus without scanning it (``docs/index.md``),
a vectorized corpus scan prunes the survivors with label/degree/size
bounds, the anchor-aware engine bounds decide most of the rest at a tiny
budget, and only the remainder pays full certified verification
(``docs/search.md``).

    PYTHONPATH=src python examples/similarity_search.py
"""

import time

import numpy as np

from repro.data.graphs import aids_like_graph, perturb
from repro.ged import GraphStore

rng = np.random.default_rng(1)

# --- database: 80 AIDS-like molecules, some of them near-copies of others --
DB = []
for i in range(60):
    DB.append(aids_like_graph(rng, int(rng.integers(8, 14))))
query = DB[0]
for _ in range(20):                       # planted near-duplicates
    DB.append(perturb(rng, query, int(rng.integers(1, 5)),
                      n_vlabels=62, n_elabels=3))

TAU = 4.0
store = GraphStore(DB, batch_size=32, slots=16)

t0 = time.time()
hits = store.range_search(query, TAU)
dt = time.time() - t0

stats = store.stats
print(f"database size  : {len(DB)}")
print(f"tau            : {TAU}")
print(f"similar graphs : {len(hits)} -> ids "
      f"{[h.graph_id for h in hits[:12]]}{'...' if len(hits) > 12 else ''}")
print(f"wall time      : {dt:.2f}s "
      f"(scan {stats['scan_wall_s'] + stats['bound_wall_s']:.2f}s, "
      f"verify {stats['verify_wall_s']:.2f}s)")
print(f"all certified  : {all(h.certified for h in hits)}")
print(f"filter ratio   : {stats['filter_ratio']:.2%} of "
      f"{int(stats['candidates'])} candidates decided before verification "
      f"(index pruned {int(stats['index_pruned'])}, "
      f"stage 0 pruned {int(stats['stage0_pruned'])})")

# the same ingested corpus answers nearest-neighbour queries: visit
# candidates in lower-bound order, stop once the bound passes the k-th best
top = store.top_k(query, k=5)
print(f"top-5 by GED   : {[(h.graph_id, h.ged) for h in top]}")

# sanity: the planted near-duplicates with few edits should be among hits
planted = set(range(60, 80))
found_planted = planted & {h.graph_id for h in hits}
print(f"planted near-duplicates found: {len(found_planted)}/20")
assert any(h.graph_id == 0 for h in hits), "query vs itself must be similar"
assert top[0].graph_id == 0 and top[0].ged == 0.0
