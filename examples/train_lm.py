"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with the production stack — deterministic data pipeline,
AdamW, async atomic checkpoints, and an injected node failure at step 120
that the loop recovers from with exact replay.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: 8 layers x d_model 512 x ff 2048, vocab 32k.)
"""

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.params import init_params, param_count
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultInjector, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fault-step", type=int, default=120)
    args = ap.parse_args()

    cfg = reduced(get_arch("qwen3-8b"), layers=8, d_model=512,
                  vocab=32_768, d_ff=2048, heads=8, kv_heads=4)
    cfg = dataclasses.replace(cfg, remat="none")
    print(f"model: {cfg.name}-reduced  params={param_count(cfg)/1e6:.1f}M")

    params = init_params(cfg, seed=0)
    opt_cfg = AdamWConfig(lr=3e-4, warmup=20, total_steps=args.steps)
    opt = adamw_init(params)
    raw_step = T.make_train_step(cfg, opt_cfg, accum=1, impl="naive")
    jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch):
        p, o = state
        tokens, labels = batch
        p, o, m = jit_step(p, o, {"tokens": jnp.asarray(tokens),
                                  "labels": jnp.asarray(labels)})
        return (p, o), m

    def make_pipeline(start):
        return TokenPipeline(0, args.batch, args.seq, cfg.vocab,
                             start_step=start)

    ckpt_every = max(10, min(50, args.steps // 3))
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep_last_k=2)
        injector = FaultInjector(
            [args.fault_step]
            if args.fault_step and args.fault_step > ckpt_every else [])
        t0 = time.time()
        (params, opt), hist = train_loop(
            step_fn, (params, opt), make_pipeline, ckpt,
            total_steps=args.steps, ckpt_every=ckpt_every, injector=injector,
            log_every=20,
            on_metrics=lambda s, m: print(
                f"step {s:4d}  loss {m['loss']:.4f}  "
                f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}"))
        dt = time.time() - t0

    losses = [h["loss"] for h in hist]
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(injected fault at step {args.fault_step}, recovered)")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
