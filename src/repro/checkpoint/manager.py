"""Sharded, async, atomic checkpoints with elastic restore.

Layout (two-phase commit — a crash mid-write can never corrupt a step):

    <dir>/step_00000100.tmp-<nonce>/     # written first
        manifest.json                    # tree structure, global shapes,
                                         # dtypes, mesh info, extra metadata
        host0000.npz                     # this host's addressable shards
    <dir>/step_00000100/                 # atomic rename on completion

Each host writes ONLY its addressable shards (``arr.addressable_shards``),
so checkpoint bandwidth scales with host count.  The manifest stores the
*global* shape/dtype of every leaf, so restore is **elastic**: any later
mesh re-assembles global arrays host-side and ``jax.device_put``s them with
the new shardings (tested 8 -> 4 -> 8 devices in ``tests/test_checkpoint``).

``save(..., block=False)`` hands the host-side serialisation to a
background thread; the train loop overlaps the next steps with the write.
``keep_last_k`` garbage-collects old steps after each commit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last_k = keep_last_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        self.wait()                       # one in-flight save at a time
        # Snapshot to host memory synchronously (cheap vs serialisation);
        # device buffers may be donated away by the next step.
        items, _ = _flatten(tree)
        host_items = []
        for key, leaf in items:
            arr = jax.device_get(leaf) if isinstance(leaf, jax.Array) \
                else np.asarray(leaf)
            host_items.append((key, np.asarray(arr)))
        meta = {
            "step": int(step),
            "keys": [k for k, _ in host_items],
            "shapes": {k: list(v.shape) for k, v in host_items},
            "dtypes": {k: str(v.dtype) for k, v in host_items},
            "extra": extra or {},
            "time": time.time(),
            "n_hosts": jax.process_count(),
        }

        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_items, meta),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_items, meta)

    def _write(self, step: int, host_items, meta) -> None:
        try:
            tmp = self.dir / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
            tmp.mkdir(parents=True)
            (tmp / "manifest.json").write_text(json.dumps(meta, indent=1))
            shard_file = tmp / f"host{jax.process_index():04d}.npz"
            np.savez(shard_file, **{k: v for k, v in host_items})
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)         # atomic commit
            self._gc()
        except BaseException as e:        # surfaced on next wait()
            self._error = e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last_k] if self.keep_last_k else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err}")

    # ---------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and ".tmp-" not in p.name:
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any, Dict]:
        """Rebuild ``template``-structured tree.  ``shardings`` (same
        structure, or None = commit to default device placement) enables
        elastic restore onto any mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        data: Dict[str, np.ndarray] = {}
        for f in sorted(d.glob("host*.npz")):
            with np.load(f) as z:
                for k in z.files:
                    data[k] = z[k]

        items, treedef = _flatten(template)
        leaves = []
        for (key, leaf) in items:
            if key not in data:
                raise KeyError(f"checkpoint {step} missing leaf {key!r}")
            arr = data[key]
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != "
                    f"template {want}")
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return step, tree, meta.get("extra", {})
