"""Registry of assigned architectures (``--arch <id>``)."""

from typing import Dict, List

from repro.models.config import ArchConfig

from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.gemma3_1b import CONFIG as _gemma3
from repro.configs.qwen2_72b import CONFIG as _qwen2_72b
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.qwen2_moe_a27b import CONFIG as _qwen2_moe
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.zamba2_7b import CONFIG as _zamba2

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        _qwen3_8b, _nemotron, _gemma3, _qwen2_72b, _qwen2_vl,
        _moonshot, _qwen2_moe, _rwkv6, _whisper, _zamba2,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)
