"""gemma3-1b [dense] — 26L d1152 4H (MQA kv=1, hd=256) ff6912 vocab 262144.

5:1 local(512):global attention pattern, qk-norm, sandwich norms,
rmsnorm(+1), tied embeddings, embed scaling, global layers rope theta 1e6.
Sub-quadratic at 500k: local layers hold 512-slot ring buffers; only every
6th layer keeps a full-length KV cache.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    qk_norm=True,
    rope_theta=1e4,
    global_rope_theta=1e6,
    window_pattern=(512, 512, 512, 512, 512, 0),
    mlp="gelu",
    norm="rmsnorm1p",
    sandwich_norm=True,
    tied_embeddings=True,
    embed_scale=True,
    subquadratic=True,
    train_accum=4,
)
