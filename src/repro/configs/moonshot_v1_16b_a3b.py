"""moonshot-v1-16b-a3b [moe] — 48L d2048 16H (kv=16) MoE 64e top-6 ff1408.

kimi/moonlight family: 64 routed experts, top-6, expert ff 1408, vocab
163840.  The assignment spec lists no shared expert, so none is added
(DESIGN.md notes the deviation risk).  EP: experts shard over ``model``.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""

from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    head_dim=128,
    rope_theta=5e4,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoECfg(num_experts=64, top_k=6, expert_ff=1408),
    train_accum=8,
)
