"""nemotron-4-15b [dense] — 32L d6144 48H (GQA kv=8) ff24576 vocab 256000.

GQA, squared-ReLU (non-gated) MLP, LayerNorm1p, partial RoPE (50%).
[arXiv:2402.16819; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    head_dim=128,
    rope_theta=1e4,
    rope_pct=0.5,
    mlp="squared_relu",
    norm="layernorm1p",
    train_accum=8,
)
