"""qwen2-72b [dense] — 80L d8192 64H (GQA kv=8) ff29568 vocab 152064.

GQA, QKV bias, SwiGLU, RoPE(1e6).  The largest assigned arch: the dry-run
must show FSDP(data) x TP(model) fits 16 GB/chip with AdamW state.
[arXiv:2407.10671; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mlp="swiglu",
    norm="rmsnorm",
    train_accum=16,
)
