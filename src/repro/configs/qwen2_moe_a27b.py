"""qwen2-moe-a2.7b [moe] — 24L d2048 16H (kv=16) MoE 60e top-4 ff1408.

Qwen1.5-MoE-A2.7B: 60 routed experts top-4 + 4 shared experts (shared ff =
4 x 1408 = 5632).  Experts padded 60 -> 64 for even EP-16 sharding; the 4
padded experts are masked out of the router (never win top-k) and FLOP
accounting uses 60.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoECfg(num_experts=60, top_k=4, expert_ff=1408,
               shared_experts=4, shared_ff=5632, padded_experts=64),
    train_accum=8,
)
