"""qwen2-vl-2b [vlm] — 28L d1536 12H (GQA kv=2) ff8960 vocab 151936.

M-RoPE (t/h/w 3-section rotary), dynamic resolution.  The vision frontend
is a STUB per the brief: ``input_specs()`` supplies 1024 precomputed patch
embeddings that are prepended to the text stream; the position input is the
(3, B, S) t/h/w stream driving M-RoPE.
[arXiv:2409.12191; hf]
"""

from repro.models.config import ArchConfig, VLMCfg

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mlp="swiglu",
    norm="rmsnorm",
    vlm=VLMCfg(num_patches=1024, mrope_sections=(16, 24, 24)),
    train_accum=4,
)
