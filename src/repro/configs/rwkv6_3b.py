"""rwkv6-3b [ssm] — 32L d2560 (attention-free) ff8960 vocab 65536.

Finch: token-shift, data-dependent per-channel decay (low-rank), bonus u,
chunked WKV6 for train/prefill, O(1) recurrent state for decode — the
canonical ``long_500k`` arch (state size is independent of context).
[arXiv:2404.05892; hf]
"""

from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # informational: wkv heads = d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    mlp="squared_relu",    # rwkv channel-mix uses relu^2
    norm="layernorm",
    ssm=SSMCfg(kind="rwkv6", head_dim=64, chunk=128),
    subquadratic=True,
    train_accum=8,
)
