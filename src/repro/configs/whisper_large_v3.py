"""whisper-large-v3 [audio] — 32+32L d1280 20H ff5120 vocab 51866.

Encoder-decoder; the conv audio frontend is a STUB per the brief:
``input_specs()`` provides (B, 1500, 1280) precomputed frame embeddings.
Sinusoidal positions on both stacks (deviation: real Whisper uses learned
decoder positions capped at 448 — the 4k/32k decode shapes are synthetic
backbone stress, so the unbounded sinusoid is used instead; DESIGN.md §4).
Vocab padded 51866 -> 51872 for even 16-way TP.
[arXiv:2212.04356; unverified]
"""

from repro.models.config import ArchConfig, EncDecCfg

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers; encoder in encdec
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    rope_pct=0.0,           # absolute (sinusoidal) positions, no rotary
    mlp="gelu",
    mlp_bias=True,
    attn_out_bias=True,
    norm="layernorm",
    encdec=EncDecCfg(enc_layers=32, enc_seq=1500),
    vocab_pad_to=32,
    train_accum=4,
)
