"""zamba2-7b [hybrid] — 81 slots d3584 32H kv32 ff14336 vocab 32000 state 64.

Mamba2 (SSD: headdim 64, state 64, expand 2) backbone with ONE weight-shared
full-attention+MLP block applied every 6th slot (zamba2's signature weight
reuse): 81 slots = 13 x (5 mamba + shared attn) + 3 mamba.  Sub-quadratic at
500k: mamba state is O(1); only the 13 shared-attn applications hold KV.
[arXiv:2411.15242; unverified]
"""

from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    rope_theta=1e4,
    mlp="swiglu",
    norm="rmsnorm",
    ssm=SSMCfg(kind="mamba2", d_state=64, head_dim=64, expand=2),
    hybrid_attn_every=6,
    subquadratic=True,
    train_accum=8,
)
