"""Batched JAX GED engine — the TPU-native adaptation of the paper.

The paper's pointer-chasing branch-and-bound is re-expressed as fixed-shape
tensor programs (see DESIGN.md §2):

* ``tensor_graphs`` — padded dense pair representation + host converters
* ``bounds``        — batched anchor-aware bound components (histogram algebra)
* ``auction``       — Bertsekas auction with LP-dual *admissible* lower bounds
* ``search``        — device-resident frontier search (``lax.while_loop``)
* ``corpus``        — corpus-wide stage-0 filter bounds (label-multiset /
  degree-sequence / size) for graph-database similarity search
* ``api``           — deprecated ``ged_batch`` / ``verify_batch`` shims; the
  public entry point is the ``repro.ged`` facade
"""

from repro.core.engine.tensor_graphs import GraphPairTensors, pack_pairs
from repro.core.engine.search import EngineConfig
from repro.core.engine.api import ged_batch, verify_batch

__all__ = [
    "GraphPairTensors",
    "pack_pairs",
    "EngineConfig",
    "ged_batch",
    "verify_batch",
]
