"""Batched engine entry points.

.. deprecated::
    ``ged_batch`` / ``verify_batch`` are kept as thin shims for existing
    callers; new code should go through the facade in :mod:`repro.ged`
    (``repro.ged.GedEngine`` / ``repro.ged.compute``), which adds input
    adapters, slot bucketing with compile-cache reuse, backend selection and
    the unified ``GedOutcome`` result schema.

Pairs are data-parallel: ``vmap`` on one device; ``shard_map`` over the mesh
(``pod`` x ``data`` x ``model`` all carry pairs) at scale — the placement
layer lives in :mod:`repro.ged.exec` (``Executor`` / ``ShardedExecutor``);
see also ``repro/serving/ged_service.py`` and ``launch/dryrun.py``.
"""

from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.search import EngineConfig, run_pair
from repro.core.engine.tensor_graphs import GraphPairTensors, pack_pairs

# Number of times ``_run_batch`` has been *traced* (compiled) this process.
# The increment below runs only while JAX traces the function, so bucketed
# workloads that reuse a compilation do not bump it — ``repro.ged.plan``'s
# bucketing tests assert on this.
_RUN_BATCH_TRACES = 0


def run_batch_traces() -> int:
    """How many distinct compilations of the batch kernel exist."""
    return _RUN_BATCH_TRACES


def pair_tuple(t: GraphPairTensors):
    """Device-array argument tuple for ``_run_batch``."""
    return (jnp.asarray(t.qv), jnp.asarray(t.gv), jnp.asarray(t.qa),
            jnp.asarray(t.ga), jnp.asarray(t.order), jnp.asarray(t.n))


_pair_tuple = pair_tuple  # backwards-compatible private alias


@functools.partial(jax.jit, static_argnames=("cfg", "verification",
                                             "n_vlabels", "n_elabels"))
def _run_batch(qv, gv, qa, ga, order, n, taus, cfg: EngineConfig,
               verification: bool, n_vlabels: int, n_elabels: int):
    global _RUN_BATCH_TRACES
    _RUN_BATCH_TRACES += 1  # trace-time side effect: counts compilations

    def one(qv, gv, qa, ga, order, n, tau):
        return run_pair((qv, gv, qa, ga, order, n, n_vlabels, n_elabels),
                        cfg, tau, verification)

    return jax.vmap(one)(qv, gv, qa, ga, order, n, taus)


def dispatch_packed(packed: GraphPairTensors, taus, cfg: EngineConfig,
                    verification: bool) -> Dict[str, jax.Array]:
    """Enqueue one engine invocation; return un-materialised device arrays.

    The raw compute step under :mod:`repro.ged.exec` — no deprecation
    shimming, no rounding policy, just pack-in / futures-out.  JAX
    dispatches asynchronously: this returns as soon as the computation is
    queued on the device, with every value still a ``jax.Array`` future.
    ``repro.ged.exec.PendingBatch`` wraps the dict (blocking ``result()``
    converts to numpy); the overlapped ``auto`` scheduler in
    :mod:`repro.ged.backends` does useful work before reading the numbers.
    """
    args = pair_tuple(packed)
    return _run_batch(*args, jnp.asarray(np.asarray(taus, dtype=np.float32)),
                      cfg, bool(verification), packed.n_vlabels,
                      packed.n_elabels)


def ged_batch(pairs: GraphPairTensors, cfg: EngineConfig = EngineConfig()
              ) -> Dict[str, np.ndarray]:
    """Exact-with-certificate GED for a batch of pairs.

    .. deprecated:: use ``repro.ged.GedEngine(backend="jax").compute``.
    """
    warnings.warn(
        "ged_batch is deprecated and will be removed in repro-ged 0.3; "
        "use repro.ged.GedEngine / repro.ged.compute (corpus workloads: "
        "repro.ged.GraphStore)",
        DeprecationWarning, stacklevel=2)
    args = pair_tuple(pairs)
    taus = jnp.zeros((pairs.batch,), dtype=jnp.float32)
    out = _run_batch(*args, taus, cfg, False, pairs.n_vlabels, pairs.n_elabels)
    out = {k: np.asarray(v) for k, v in out.items()}
    out["ged"] = np.where(out["exact"], np.rint(out["ged"]), out["ged"])
    return out


def verify_batch(pairs: GraphPairTensors, taus: Sequence[float],
                 cfg: EngineConfig = EngineConfig()) -> Dict[str, np.ndarray]:
    """Batched GED verification: ``delta(q, g) <= tau``? per pair.

    .. deprecated:: use ``repro.ged.GedEngine(backend="jax").verify``.
    """
    warnings.warn(
        "verify_batch is deprecated and will be removed in repro-ged 0.3; "
        "use repro.ged.GedEngine / repro.ged.verify (corpus workloads: "
        "repro.ged.GraphStore.range_search)",
        DeprecationWarning, stacklevel=2)
    args = pair_tuple(pairs)
    taus = jnp.asarray(np.asarray(taus, dtype=np.float32))
    out = _run_batch(*args, taus, cfg, True, pairs.n_vlabels, pairs.n_elabels)
    return {k: np.asarray(v) for k, v in out.items()}


def batch_abstract_inputs(batch: int, slots: int):
    """ShapeDtypeStruct stand-ins for a verification batch (for dry-runs)."""
    f = jax.ShapeDtypeStruct
    return dict(
        qv=f((batch, slots), jnp.int32),
        gv=f((batch, slots), jnp.int32),
        qa=f((batch, slots, slots), jnp.int32),
        ga=f((batch, slots, slots), jnp.int32),
        order=f((batch, slots), jnp.int32),
        n=f((batch,), jnp.int32),
        taus=f((batch,), jnp.float32),
    )
