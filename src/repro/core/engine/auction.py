"""Batched auction assignment with LP-dual admissible lower bounds.

The paper computes ``delta^BMa`` with the Hungarian algorithm — sequential
augmenting paths that do not map to a systolic machine.  The TPU-native
replacement (DESIGN.md §2) rests on two facts:

1. **Weak LP duality.**  For *any* price vector ``p``,

       dual(p) = sum_i min_j (c_ij + p_j) - sum_j p_j  <=  OPT(c),

   so a fixed number of auction sweeps yields a *valid* lower bound whose
   tightness is a dial (sweep count), never a correctness requirement.

2. **Forced-edge minors.**  ``OPT(c | row r -> col u) = c[r, u] + OPT(minor)``
   and the same ``p`` restricted to the minor is dual-feasible there, giving
   Alg. 3's "score every child with one solve" in O(N^2) total:

       forced_lb[u] = c[r, u] + sum_{i != r} min_{j != u} (c_ij + p_j)
                      - (sum_j p_j - p_u).

Sweeps are Jacobi (all unassigned rows bid in parallel): a row's bid is a
masked top-2 reduction — pure VPU work, batchable over thousands of search
states.  ``kernels/auction.py`` provides the fused Pallas version of one
sweep; this module is the reference/jnp implementation and the host of the
dual/forced-bound algebra.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

BIG = 1e7


class AuctionState(NamedTuple):
    prices: jnp.ndarray     # (..., N) float32 column prices
    row_to_col: jnp.ndarray  # (..., N) int32, -1 if unassigned
    col_to_row: jnp.ndarray  # (..., N) int32, -1 if unowned


def init_auction(cost: jnp.ndarray) -> AuctionState:
    shape = cost.shape[:-1]
    n = cost.shape[-1]
    return AuctionState(
        prices=jnp.zeros(shape, dtype=jnp.float32),
        row_to_col=jnp.full(shape[:-1] + (n,), -1, dtype=jnp.int32),
        col_to_row=jnp.full(shape[:-1] + (n,), -1, dtype=jnp.int32),
    )


def _top2_min(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(min, argmin, second-min) along the last axis."""
    m1 = jnp.min(x, axis=-1)
    a1 = jnp.argmin(x, axis=-1)
    masked = x + jax.nn.one_hot(a1, x.shape[-1], dtype=x.dtype) * BIG
    m2 = jnp.min(masked, axis=-1)
    return m1, a1, m2


def auction_sweep(cost: jnp.ndarray, st: AuctionState, eps: float) -> AuctionState:
    """One Jacobi sweep: every unassigned row bids; highest bid wins the col.

    ``cost``: (..., N, N).  Works for any leading batch dims.
    """
    n = cost.shape[-1]
    unassigned = st.row_to_col < 0                     # (..., N)
    m1, a1, m2 = kops.reduced_top2(cost, st.prices)    # fused kernel
    incr = (m2 - m1) + eps                             # bid increment per row
    incr = jnp.where(unassigned, incr, -BIG)           # only unassigned bid

    # Resolve conflicts: per column, the bidding row with the largest
    # increment wins (one-hot scatter + argmax over rows).
    bid_onehot = jax.nn.one_hot(a1, n, dtype=cost.dtype)          # (..., N, N)
    bids = jnp.where(unassigned[..., None], bid_onehot * incr[..., None]
                     + (1.0 - bid_onehot) * (-BIG), -BIG)
    win_incr = jnp.max(bids, axis=-2)                 # (..., N) per col
    win_row = jnp.argmax(bids, axis=-2).astype(jnp.int32)
    has_bid = win_incr > -BIG / 2

    new_prices = jnp.where(has_bid, st.prices + win_incr, st.prices)

    # Ownership transfer: winning rows take their columns; displaced owners
    # become unassigned.
    old_owner = st.col_to_row
    new_col_to_row = jnp.where(has_bid, win_row, old_owner)
    # row_to_col: invert, preferring the new ownership map.
    cols = jnp.arange(n, dtype=jnp.int32)
    onehot_owner = (new_col_to_row[..., None, :]
                    == jnp.arange(n, dtype=jnp.int32)[..., :, None])  # (..., row, col)
    any_col = jnp.any(onehot_owner, axis=-1)
    new_row_to_col = jnp.where(
        any_col, jnp.argmax(onehot_owner, axis=-1).astype(jnp.int32), -1
    )
    del cols
    return AuctionState(new_prices, new_row_to_col, new_col_to_row)


def run_auction(cost: jnp.ndarray, n_sweeps: int, phases: Tuple[float, ...]
                = (1.0, 0.25, 0.125)) -> AuctionState:
    """Fixed-budget auction with epsilon-scaling.

    Standard forward-auction scaling: each phase halves eps, *unassigns all
    rows* and warm-starts from the previous phase's prices.  Without the
    reset the assignment freezes under coarse-phase price overshoot and the
    dual can stall arbitrarily far from OPT (observed in tests); with it the
    final phase's dual is within ~n*eps_final of OPT.
    """
    st = init_auction(cost)
    per_phase = max(n_sweeps // max(len(phases), 1), 1)

    for eps in phases:
        # phase reset: keep prices, drop the assignment
        st = AuctionState(st.prices,
                          jnp.full_like(st.row_to_col, -1),
                          jnp.full_like(st.col_to_row, -1))

        def body(_k, s, eps=eps):
            return auction_sweep(cost, s, eps)

        st = jax.lax.fori_loop(0, per_phase, body, st)
    return st


def dual_bound(cost: jnp.ndarray, prices: jnp.ndarray) -> jnp.ndarray:
    """Weak-duality lower bound on OPT(cost) for any price vector."""
    reduced = cost + prices[..., None, :]
    return jnp.sum(jnp.min(reduced, axis=-1), axis=-1) - jnp.sum(prices, axis=-1)


def forced_dual_bounds(cost: jnp.ndarray, prices: jnp.ndarray, row: jnp.ndarray
                       ) -> jnp.ndarray:
    """Lower bound on OPT(cost | row -> u) for **every** column u at once.

    ``row`` may be a scalar or a batch of per-problem row indices
    (shape = cost.shape[:-2]).  Returns (..., N).
    """
    n = cost.shape[-1]
    m1, a1, m2 = kops.reduced_top2(cost, prices)        # (..., N) per row
    # Row minima over columns != u: m2 where the argmin was u, else m1.
    u_ids = jnp.arange(n, dtype=jnp.int32)
    # (..., N rows, N u): rowmin excluding column u
    excl = jnp.where(a1[..., :, None] == u_ids, m2[..., :, None], m1[..., :, None])
    total_excl = jnp.sum(excl, axis=-2)                 # (..., N u)
    row_b = jnp.asarray(row, dtype=jnp.int32)
    row_excl = jnp.take_along_axis(
        excl, row_b[..., None, None].astype(jnp.int32), axis=-2
    )[..., 0, :]                                        # (..., N u)
    minors = total_excl - row_excl                      # sum_{i != row}
    p_tot = jnp.sum(prices, axis=-1, keepdims=True)
    c_row = jnp.take_along_axis(
        cost, row_b[..., None, None].astype(jnp.int32), axis=-2
    )[..., 0, :]
    return c_row + minors - (p_tot - prices)


def greedy_primal(cost: jnp.ndarray, prices: jnp.ndarray) -> jnp.ndarray:
    """A full (not necessarily optimal) assignment for upper-bound updates.

    Sequential greedy over rows on the reduced costs; O(N^2), fori_loop.
    Returns col index per row, shape (..., N).

    Prices are clipped before use: auction bids against forbidden (BIG)
    second-best columns legitimately inflate a price to ~BIG, which would
    invert the dummy/free class separation of the GED cost matrices and let
    a real vertex grab a PAD column.  Clipped price guidance keeps the
    near-optimal ordering where it matters (contested cheap columns) without
    ever overpowering the BIG structure.
    """
    n = cost.shape[-1]
    reduced = cost + jnp.clip(prices, 0.0, 1e3)[..., None, :]

    def body(i, carry):
        used, out = carry
        rowc = reduced[..., i, :] + jnp.where(used, BIG, 0.0)
        j = jnp.argmin(rowc, axis=-1).astype(jnp.int32)
        used = used | (jnp.arange(n, dtype=jnp.int32) == j[..., None])
        out = out.at[..., i].set(j)
        return used, out

    used0 = jnp.zeros(cost.shape[:-2] + (n,), dtype=bool)
    out0 = jnp.zeros(cost.shape[:-2] + (n,), dtype=jnp.int32)
    _, out = jax.lax.fori_loop(0, n, body, (used0, out0))
    return out
