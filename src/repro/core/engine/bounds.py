"""Batched anchor-aware bound components (histogram algebra).

Everything here scores **all children of one search state at once** — the
tensor formulation of the paper's Alg. 3 / Alg. 4.  Multiset edit distances
become dense histogram operations:

    Y(S1, S2) = max(|S1|, |S2|) - sum_l min(h1[l], h2[l])

and the inner/cross partitions of the anchor-aware bounds become einsums of
one-hot adjacency tensors against free-vertex masks.  Functions take a single
pair + a single state and are ``vmap``-ed over the expansion batch and over
pairs by the search loop.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import auction as auc
from repro.kernels import ops as kops

BIG = 1e7


class PairConsts(NamedTuple):
    """Static per-pair tensors, computed once outside the search loop."""

    qv: jnp.ndarray        # (N,) int32
    gv: jnp.ndarray        # (N,) int32
    qa: jnp.ndarray        # (N, N) int32
    ga: jnp.ndarray        # (N, N) int32
    order: jnp.ndarray     # (N,) int32
    n: jnp.ndarray         # () int32
    oh_q: jnp.ndarray      # (Le, N, N) f32 one-hot edge labels
    oh_g: jnp.ndarray      # (Le, N, N) f32
    qa_ord: jnp.ndarray    # (N, N) int32 = qa[:, order] (cols by order position)
    oh_q_ord: jnp.ndarray  # (N, Le, N) f32 = oh_q[:, order[j], :] by position j
    n_vlabels: int
    n_elabels: int


def make_pair_consts(qv, gv, qa, ga, order, n, n_vlabels: int, n_elabels: int
                     ) -> PairConsts:
    le = n_elabels
    labels = jnp.arange(1, le + 1, dtype=jnp.int32)
    oh_q = (qa[None, :, :] == labels[:, None, None]).astype(jnp.float32)
    oh_g = (ga[None, :, :] == labels[:, None, None]).astype(jnp.float32)
    qa_ord = qa[:, order]
    oh_q_ord = jnp.transpose(oh_q, (1, 0, 2))[order]  # (N, Le, N)
    return PairConsts(qv, gv, qa, ga, order, n, oh_q, oh_g, qa_ord, oh_q_ord,
                      n_vlabels, n_elabels)


class StateMasks(NamedTuple):
    vi: jnp.ndarray          # () int32 next q vertex
    anchored_q: jnp.ndarray  # (N,) bool
    used_g: jnp.ndarray      # (N,) bool
    free_q: jnp.ndarray      # (N,) f32 (includes v_i)
    free_q2: jnp.ndarray     # (N,) f32 (excludes v_i)
    free_g: jnp.ndarray      # (N,) f32
    img_cl: jnp.ndarray      # (N,) int32 img clamped to [0, N)
    pos_anch: jnp.ndarray    # (N,) f32 1.0 where position j < level


def state_masks(pc: PairConsts, img: jnp.ndarray, level: jnp.ndarray) -> StateMasks:
    N = pc.qv.shape[0]
    ids = jnp.arange(N, dtype=jnp.int32)
    vmask = ids < pc.n
    pos_anch = (ids < level)
    vi = pc.order[jnp.minimum(level, pc.n - 1)]
    anchored_q = jnp.zeros(N, dtype=bool).at[pc.order].set(pos_anch)
    img_cl = jnp.clip(img, 0, N - 1)
    used_g = jnp.any((img[None, :] == ids[:, None]) & pos_anch[None, :], axis=1)
    free_q = (~anchored_q) & vmask
    free_q2 = free_q & (ids != vi)
    free_g = (~used_g) & vmask
    return StateMasks(vi, anchored_q, used_g, free_q.astype(jnp.float32),
                      free_q2.astype(jnp.float32), free_g.astype(jnp.float32),
                      img_cl, pos_anch.astype(jnp.float32))


def child_exact_delta(pc: PairConsts, sm: StateMasks) -> jnp.ndarray:
    """Exact editorial-cost increment of (v_i -> u) for every u: (N,)."""
    dv = (pc.qv[sm.vi] != pc.gv).astype(jnp.float32)
    qrow = pc.qa_ord[sm.vi]                      # (N,) labels by position
    grow = pc.ga[:, sm.img_cl]                   # (N u, N pos)
    de = jnp.sum((qrow[None, :] != grow).astype(jnp.float32) * sm.pos_anch[None, :],
                 axis=1)
    return dv + de


def lsa_children(pc: PairConsts, sm: StateMasks, level: jnp.ndarray,
                 g_cost: jnp.ndarray, use_kernel: bool = False,
                 tile_u: int = 0) -> jnp.ndarray:
    """delta^LSa(f u {v_i -> u}) for every u; +BIG where u is not free.

    ``use_kernel=True`` routes the (N, N)-shaped work — inner-edge
    upsilons, per-(anchor, u) cross adjustments, exact-delta edge
    mismatches — through the fused Pallas kernel
    (``kernels/lsa_children.py``); only cheap (N, Le)-sized histogram
    contractions and row gathers run as XLA ops outside it.  Both paths
    compute the identical bound (small-half float arithmetic is exact, so
    re-association cannot change a bit — asserted by the parity tests).
    """
    N = pc.qv.shape[0]
    lv_bins = pc.n_vlabels + 2

    # ---- vertex component ---------------------------------------------------
    voh_q = jax.nn.one_hot(pc.qv, lv_bins, dtype=jnp.float32)
    voh_g = jax.nn.one_hot(pc.gv, lv_bins, dtype=jnp.float32)
    hq_v = jnp.einsum("vl,v->l", voh_q, sm.free_q2)
    hg_v = jnp.einsum("vl,v->l", voh_g, sm.free_g)
    inter_v = jnp.sum(jnp.minimum(hq_v, hg_v))
    max_v = (pc.n - level - 1).astype(jnp.float32)
    # removing label gv[u] from the g side
    surplus_u = (hg_v - hq_v)[pc.gv]             # (N,)
    ups_v = max_v - (inter_v - (surplus_u <= 0).astype(jnp.float32))

    if use_kernel:
        # Pre-reduced histograms: (N, Le) contractions + row gathers; the
        # (N, N)-shaped accumulation loops stay fused inside the kernel.
        rowhist_g = jnp.einsum("luw,w->ul", pc.oh_g, sm.free_g)   # (N, Le)
        rowhist_q2 = jnp.einsum("lvw,w->vl", pc.oh_q, sm.free_q2)
        hq_i = 0.5 * jnp.einsum("vl,v->l", rowhist_q2, sm.free_q2)
        hg_i = 0.5 * jnp.einsum("ul,u->l", rowhist_g, sm.free_g)
        cq = rowhist_q2[pc.order]                 # (N pos, Le)
        cg = rowhist_g[sm.img_cl]
        s1 = jnp.sum(cq, axis=1)
        s2 = jnp.sum(cg, axis=1)
        inter_j = jnp.sum(jnp.minimum(cq, cg), axis=1)
        base_j = jnp.maximum(s1, s2) - inter_j
        adjb_j = jnp.maximum(s1, s2 - 1.0) - inter_j
        a_ju = pc.ga[sm.img_cl]                   # (N pos, N u)
        qrow = pc.qa_ord[sm.vi]
        cq_vi = rowhist_q2[sm.vi]
        dv = (pc.qv[sm.vi] != pc.gv).astype(jnp.float32)
        base = g_cost + dv + ups_v
        return kops.lsa_children(base, sm.free_g, rowhist_g, a_ju, qrow,
                                 sm.pos_anch, cq, cg, base_j, adjb_j,
                                 hq_i, hg_i, cq_vi, tile_u=tile_u)

    # ---- inner edges --------------------------------------------------------
    hq_i = 0.5 * jnp.einsum("lvw,v,w->l", pc.oh_q, sm.free_q2, sm.free_q2)
    hg_i = 0.5 * jnp.einsum("lvw,v,w->l", pc.oh_g, sm.free_g, sm.free_g)
    rowhist_g = jnp.einsum("luw,w->ul", pc.oh_g, sm.free_g)  # (N, Le)
    hg_i_u = hg_i[None, :] - rowhist_g                        # (N u, Le)
    n_i1 = jnp.sum(hq_i)
    n_i2 = jnp.sum(hg_i_u, axis=1)
    inter_i = jnp.sum(jnp.minimum(hq_i[None, :], hg_i_u), axis=1)
    ups_i = jnp.maximum(n_i1, n_i2) - inter_i

    # ---- old-anchor cross components ---------------------------------------
    cq = jnp.einsum("jlw,w->jl", pc.oh_q_ord, sm.free_q2)     # (N pos, Le)
    oh_g_img = jnp.transpose(pc.oh_g, (1, 0, 2))[sm.img_cl]   # (N pos, Le, N)
    cg = jnp.einsum("jlw,w->jl", oh_g_img, sm.free_g)         # (N pos, Le)
    s1 = jnp.sum(cq, axis=1)
    s2 = jnp.sum(cg, axis=1)
    inter_j = jnp.sum(jnp.minimum(cq, cg), axis=1)
    base_j = jnp.maximum(s1, s2) - inter_j                    # (N pos,)
    a_ju = pc.ga[sm.img_cl]                                   # (N pos, N u)
    le = pc.n_elabels
    aoh = (a_ju[:, :, None] == jnp.arange(1, le + 1, dtype=jnp.int32)).astype(
        jnp.float32)                                           # (pos, u, Le)
    cg_at = jnp.einsum("jul,jl->ju", aoh, cg)
    cq_at = jnp.einsum("jul,jl->ju", aoh, cq)
    d_ju = (cg_at <= cq_at).astype(jnp.float32)
    adj_j = jnp.maximum(s1[:, None], s2[:, None] - 1.0) - (inter_j[:, None] - d_ju)
    ups_ju = jnp.where(a_ju > 0, adj_j, base_j[:, None])      # (pos, u)
    cross_sum = jnp.einsum("ju,j->u", ups_ju, sm.pos_anch)

    # ---- v_i's own cross component ------------------------------------------
    cq_vi = jnp.einsum("lw,w->l", pc.oh_q[:, sm.vi, :], sm.free_q2)  # (Le,)
    s1_vi = jnp.sum(cq_vi)
    s2_u = jnp.sum(rowhist_g, axis=1)
    inter_vi = jnp.sum(jnp.minimum(cq_vi[None, :], rowhist_g), axis=1)
    ups_vi = jnp.maximum(s1_vi, s2_u) - inter_vi

    delta = child_exact_delta(pc, sm)
    lb = g_cost + delta + ups_v + ups_i + cross_sum + ups_vi
    return jnp.where(sm.free_g > 0, lb, BIG)


def bma_cost_matrix(pc: PairConsts, sm: StateMasks, use_kernel: bool = True,
                    tile_v: int = 0, tile_u: int = 0) -> jnp.ndarray:
    """lambda^BMa over all (v, u) with dummy structure for non-free slots.

    Dummy rows (anchored / PAD q-slots) pair with dummy columns at cost 0 and
    with free columns at BIG, so the NxN optimum equals the free-free optimum.
    """
    inner_q = jnp.einsum("lvw,w->vl", pc.oh_q, sm.free_q)    # (N, Le)
    inner_g = jnp.einsum("luw,w->ul", pc.oh_g, sm.free_g)
    if use_kernel:
        lam_free = kops.bma_cost_matrix(
            pc.qv, pc.gv, inner_q, inner_g,
            pc.qa_ord, pc.ga, sm.img_cl, sm.pos_anch,
            tile_v=tile_v, tile_u=tile_u,
        )
    else:
        sq = jnp.sum(inner_q, axis=1)
        sg = jnp.sum(inner_g, axis=1)
        inter = jnp.sum(
            jnp.minimum(inner_q[:, None, :], inner_g[None, :, :]), axis=2
        )
        ups = jnp.maximum(sq[:, None], sg[None, :]) - inter
        qcross = pc.qa_ord                                    # (N v, N pos)
        gcross = pc.ga[:, sm.img_cl]                          # (N u, N pos)
        mism = jnp.einsum(
            "vuj,j->vu",
            (qcross[:, None, :] != gcross[None, :, :]).astype(jnp.float32),
            sm.pos_anch,
        )
        vmis = (pc.qv[:, None] != pc.gv[None, :]).astype(jnp.float32)
        lam_free = vmis + 0.5 * ups + mism

    fq = sm.free_q[:, None] > 0
    fg = sm.free_g[None, :] > 0
    return jnp.where(fq & fg, lam_free, jnp.where(fq == fg, 0.0, BIG))


class BmaChildren(NamedTuple):
    lb: jnp.ndarray            # (N,) forced dual bounds (+BIG where not free)
    full_img: jnp.ndarray      # (N,) heuristic full mapping by order position
    full_cost: jnp.ndarray     # () editorial cost of the heuristic mapping


def editorial_cost_tensor(pc: PairConsts, fmap: jnp.ndarray) -> jnp.ndarray:
    """Exact editorial cost of a full mapping given *by vertex* (N,)."""
    N = pc.qv.shape[0]
    ids = jnp.arange(N, dtype=jnp.int32)
    vmask = (ids < pc.n).astype(jnp.float32)
    vterm = jnp.sum((pc.qv != pc.gv[fmap]).astype(jnp.float32) * vmask)
    gmap = pc.ga[fmap][:, fmap]
    pairm = vmask[:, None] * vmask[None, :]
    upper = (ids[:, None] < ids[None, :]).astype(jnp.float32)
    eterm = jnp.sum((pc.qa != gmap).astype(jnp.float32) * pairm * upper)
    return vterm + eterm


def bma_children(pc: PairConsts, sm: StateMasks, img: jnp.ndarray,
                 level: jnp.ndarray, g_cost: jnp.ndarray, sweeps: int,
                 use_kernel: bool = True, tile_v: int = 0,
                 tile_u: int = 0) -> BmaChildren:
    """Alg. 3 on TPU: one auction, dual forced bounds for every child."""
    N = pc.qv.shape[0]
    lam = bma_cost_matrix(pc, sm, use_kernel=use_kernel,
                          tile_v=tile_v, tile_u=tile_u)
    st = auc.run_auction(lam, sweeps)
    forced = auc.forced_dual_bounds(lam, st.prices, sm.vi)
    lb = g_cost + jnp.maximum(forced, 0.0)
    lb = jnp.where(sm.free_g > 0, lb, BIG)

    # Heuristic full mapping (paper §4.2 remark): greedy primal completion.
    assign = auc.greedy_primal(lam, st.prices)           # (N,) col per row v
    pos = jnp.arange(N, dtype=jnp.int32)
    img_full = jnp.where(pos < level, img, assign[pc.order])
    fmap = jnp.zeros(N, dtype=jnp.int32).at[pc.order].set(img_full)
    full_cost = editorial_cost_tensor(pc, fmap)
    # Defence in depth: a mapping sending a real vertex to a PAD slot is not
    # a valid editorial script — poison its cost so it can never become the
    # incumbent upper bound.
    invalid = jnp.any((fmap >= pc.n) & (pos < pc.n))
    full_cost = full_cost + invalid.astype(jnp.float32) * BIG
    return BmaChildren(lb, img_full, full_cost)
