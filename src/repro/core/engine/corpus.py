"""Corpus-wide stage-0 lower-bound kernels for graph-database search.

The paper frames GED *verification* as the primitive of graph similarity
search: a cheap filter phase prunes the database, and only survivors reach
the expensive verifier.  This module is the filter phase's compute kernel —
per-graph **features** extracted once at ingest, and a single vectorized
pass that scores a query against an entire packed corpus with sound lower
bounds, no per-pair planning or packing:

* ``Y_v`` — vertex-label multiset bound ``max(n_q, n_g) - sum_l min(h_q, h_g)``
  (the paper's label-set bound at the root state, vertex half);
* ``Y_e`` — same over edge-label multisets;
* ``D``  — degree-sequence bound ``ceil(L1(sorted degrees) / 2)``: every
  edge insertion/deletion changes the sorted degree sequence's L1 distance
  by at most 2, and relabels change it not at all.

``Y_e`` and ``D`` both lower-bound the number of *edge* operations, so the
combined per-pair bound is ``Y_v + max(Y_e, D)`` — vertex and edge costs
are disjoint, hence the sum stays admissible:

    stage0 <= delta(q, g)   for every corpus graph g.

Everything is histogram algebra on fixed-width arrays (one shared label
vocabulary, one "other" bin for labels outside it), so a whole slot-bucket
of the corpus is scored by one fused jit call — and the arrays shard over
a device mesh by their leading (corpus) axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.exact.graph import Graph

# Number of times the stage-0 scan has been traced this process (compile
# reuse is observable, mirroring ``api.run_batch_traces``).
_SCAN_TRACES = 0


def scan_traces() -> int:
    """How many distinct compilations of the stage-0 scan kernel exist."""
    return _SCAN_TRACES


@dataclasses.dataclass
class CorpusFeatures:
    """Stage-0 feature arrays for a batch of corpus graphs.

    ``vhist``/``ehist`` use the shared vocabulary plus one trailing
    "other" bin; corpus graphs never populate "other" when the vocab was
    built from the corpus, so query-only labels intersect nothing (the
    bound stays sound either way).  ``degs`` holds descending-sorted
    degree sequences zero-padded to a common width.
    """

    vhist: np.ndarray   # (B, Lv + 1) float32 vertex-label counts
    ehist: np.ndarray   # (B, Le + 1) float32 edge-label counts
    degs: np.ndarray    # (B, K) float32 degree sequence, sorted desc
    n: np.ndarray       # (B,) float32 vertex counts
    m: np.ndarray       # (B,) float32 edge counts

    @property
    def batch(self) -> int:
        return self.vhist.shape[0]

    @property
    def width(self) -> int:
        return self.degs.shape[1]


def graph_features(
    graphs: Sequence[Graph],
    vocab: Tuple[Sequence[int], Sequence[int]],
    width: Optional[int] = None,
) -> CorpusFeatures:
    """Extract :class:`CorpusFeatures` for ``graphs`` under ``vocab``.

    ``width`` — degree-sequence padding width (defaults to the largest
    ``g.n`` in the batch).  Labels outside the vocabulary land in the
    trailing "other" bin.

    >>> g = Graph.from_edges([0, 1], [(0, 1, 1)])
    >>> f = graph_features([g], vocab=((0, 1), (1,)))
    >>> f.vhist[0].tolist(), f.ehist[0].tolist(), f.degs[0].tolist()
    ([1.0, 1.0, 0.0], [1.0, 0.0], [1.0, 1.0])
    """
    vmap = {int(a): i for i, a in enumerate(vocab[0])}
    emap = {int(a): i for i, a in enumerate(vocab[1])}
    lv, le = len(vmap), len(emap)
    if width is None:
        width = max((g.n for g in graphs), default=1)
    B = len(graphs)
    vhist = np.zeros((B, lv + 1), dtype=np.float32)
    ehist = np.zeros((B, le + 1), dtype=np.float32)
    degs = np.zeros((B, width), dtype=np.float32)
    ns = np.zeros((B,), dtype=np.float32)
    ms = np.zeros((B,), dtype=np.float32)
    for b, g in enumerate(graphs):
        if g.n > width:
            raise ValueError(f"graph with {g.n} vertices exceeds width {width}")
        for a in g.vlabels.tolist():
            vhist[b, vmap.get(int(a), lv)] += 1.0
        for _, _, a in g.edges():
            ehist[b, emap.get(int(a), le)] += 1.0
        d = np.sort(g.degrees())[::-1].astype(np.float32)
        degs[b, : g.n] = d
        ns[b] = g.n
        ms[b] = g.m
    return CorpusFeatures(vhist, ehist, degs, ns, ms)


def stage0_lower_bounds(qvh, qeh, qdeg, qn, qm, cvh, ceh, cdeg, cn, cm):
    """Sound per-graph GED lower bounds for one query against a packed corpus.

    Query arrays are rank-1 (replicated); corpus arrays carry the batch on
    their leading axis (and may be mesh-sharded along it).  Pure ``jnp`` —
    callers jit (and optionally ``shard_map``) it.
    """
    import jax.numpy as jnp

    global _SCAN_TRACES
    _SCAN_TRACES += 1  # trace-time side effect: counts compilations

    inter_v = jnp.sum(jnp.minimum(qvh[None, :], cvh), axis=-1)
    y_v = jnp.maximum(qn, cn) - inter_v
    inter_e = jnp.sum(jnp.minimum(qeh[None, :], ceh), axis=-1)
    y_e = jnp.maximum(qm, cm) - inter_e
    l1 = jnp.sum(jnp.abs(qdeg[None, :] - cdeg), axis=-1)
    d = jnp.ceil(l1 * 0.5)
    return y_v + jnp.maximum(y_e, d)


def stage0_reference(q: Graph, g: Graph) -> float:
    """Host-side oracle for :func:`stage0_lower_bounds` on one pair.

    Used by property tests to pin the vectorized kernel and to document
    the math in plain numpy.

    >>> a = Graph.from_edges([0, 0], [(0, 1, 1)])
    >>> b = Graph.from_edges([0, 1, 1], [(0, 1, 1), (1, 2, 1)])
    >>> stage0_reference(a, b)
    3.0
    """
    from collections import Counter

    cqv, cgv = Counter(q.vlabels.tolist()), Counter(g.vlabels.tolist())
    y_v = max(q.n, g.n) - sum(min(cqv[k], cgv[k]) for k in cqv.keys() & cgv)
    cqe = Counter(a for _, _, a in q.edges())
    cge = Counter(a for _, _, a in g.edges())
    y_e = max(q.m, g.m) - sum(min(cqe[k], cge[k]) for k in cqe.keys() & cge)
    k = max(q.n, g.n)
    dq = np.zeros(k)
    dq[: q.n] = np.sort(q.degrees())[::-1]
    dg = np.zeros(k)
    dg[: g.n] = np.sort(g.degrees())[::-1]
    d = np.ceil(np.sum(np.abs(dq - dg)) / 2.0)
    return float(y_v + max(y_e, d))
