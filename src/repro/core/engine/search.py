"""Device-resident frontier search (the tensorised Alg. 2).

One ``lax.while_loop`` per pair (``vmap``-ed across pairs) owns a fixed
capacity pool of search states kept **sorted by the strategy pop key**
(AStar+: ``(lb, -level)``; DFS+: ``(-level, lb)`` — the paper's pop rule
as a scalar key).  Per iteration:

  1. **pop**: the best ``expand`` states are the first ``B`` rows of the
     sorted pool — a free static slice, no per-iteration ``top_k``.
  2. **expand**: score all children of each popped state at once (LSa via
     histogram algebra, BMa via one auction + dual forced bounds — Alg. 3/4;
     both Pallas-fused under ``EngineConfig.use_kernel``).
  3. **bound**: update the incumbent from (a) exact leaf children and (b) the
     greedy-primal full-mapping extension (Alg. 2 line 13).
  4. **merge**: sort only the ``B*N`` children, then rank-merge the two
     sorted runs (surviving pool + children) and truncate to ``pool``
     rows (``parallel.ops.merge_sorted_topk``) — no full-pool ``argsort``.
     The smallest lower bound ever dropped is remembered — the result is
     certified **exact** iff the final answer is <= that floor (it is, for
     paper-scale inputs; overflowing pairs are re-queued to the exact host
     solver by the serving layer).

States whose lower bound has been overtaken by the incumbent are pruned
*lazily* (the old loop bulk-invalidated them at every merge, which a sorted
pool cannot do without re-sorting): they are discarded at pop time (Alg. 2
line 6), and when truncation drops them they are excluded from the floor —
exactly the old accounting.  Under the AStar+ key they sort to the tail and
fall off first; under the DFS+ key (depth-first) stale deep states sort to
the *head*, so they drain through the next pops instead — at worst they
occupy pool slots for a few iterations, which on a near-capacity DFS pool
can evict (and floor-account) shallow states the eager-pruning loop would
have kept.  That only makes the certificate more conservative, never
unsound: ``exact`` still means the answer is at or below every unexplored
bound ever discarded.  Verification mode initialises the incumbent to
``tau + 0.5`` and stops early on accept (incumbent <= tau) or reject (pool
min lb > tau) — paper §5.3.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.engine import bounds as eb
from repro.core.engine.tensor_graphs import GraphPairTensors
from repro.kernels.autotune import KernelDispatch, concrete_dispatch
from repro.parallel.ops import merge_sorted_topk, sort_by_key

INF = 3.0e8
BIG = eb.BIG


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    pool: int = 1024          # state-pool capacity P
    expand: int = 8           # states expanded per iteration B
    max_iters: int = 512
    sweeps: int = 8           # auction sweeps per expansion
    bound: str = "hybrid"     # "lsa" | "bma" | "hybrid" (max of both)
    strategy: str = "astar"   # "astar" | "dfs"
    # True/False force the Pallas kernels on/off globally; "auto" resolves
    # per bucket shape through the measured tuning table (see
    # kernels/autotune.py).  ``dispatch`` is the resolved per-bucket plan
    # the executor pins before jit — the config (dispatch included) is a
    # static jit arg, so every compile cache keys on the decision while
    # outcomes stay bit-identical across all dispatch paths.
    use_kernel: Union[bool, str] = True
    dispatch: Optional[KernelDispatch] = None

    def __post_init__(self):
        if self.use_kernel not in (True, False, "auto"):
            raise ValueError(
                f"use_kernel must be True, False or 'auto', "
                f"got {self.use_kernel!r}")


class PoolState(NamedTuple):
    img: jnp.ndarray       # (P, N) int32 images by order position (-1 = unset)
    level: jnp.ndarray     # (P,) int32
    gcost: jnp.ndarray     # (P,) f32
    lb: jnp.ndarray        # (P,) f32
    valid: jnp.ndarray     # (P,) bool


class Carry(NamedTuple):
    pool: PoolState
    ub: jnp.ndarray          # () f32 incumbent
    best_img: jnp.ndarray    # (N,) int32 incumbent mapping (by position)
    floor: jnp.ndarray       # () f32 min lower bound ever dropped
    it: jnp.ndarray          # () int32
    expanded: jnp.ndarray    # () int32 total states expanded
    done: jnp.ndarray        # () bool


def _pop_key(cfg: EngineConfig, lb, level, valid, n):
    if cfg.strategy == "astar":
        key = lb * 256.0 + (n.astype(jnp.float32) - level.astype(jnp.float32))
    else:  # dfs: deepest first, then smallest bound
        key = (n.astype(jnp.float32) - level.astype(jnp.float32)) * 1.0e5 + lb
    return jnp.where(valid, key, INF)


def _expand_one(pc: eb.PairConsts, cfg: EngineConfig, img, level, gcost,
                state_valid):
    """Score all children of one state.  Returns per-child arrays (N,)."""
    sm = eb.state_masks(pc, img, level)
    delta = eb.child_exact_delta(pc, sm)
    child_gcost = gcost + delta

    d = concrete_dispatch(cfg, img.shape[-1])
    lb_parts = []
    if cfg.bound in ("lsa", "hybrid"):
        lb_parts.append(eb.lsa_children(pc, sm, level, gcost,
                                        use_kernel=d.lsa_fused,
                                        tile_u=d.lsa_tile_u))
    if cfg.bound in ("bma", "hybrid"):
        bma = eb.bma_children(pc, sm, img, level, gcost, cfg.sweeps,
                              use_kernel=d.bma_fused,
                              tile_v=d.bma_tile_v, tile_u=d.bma_tile_u)
        lb_parts.append(bma.lb)
        heur_img, heur_cost = bma.full_img, bma.full_cost
    else:
        heur_img = img
        heur_cost = jnp.float32(INF)
    lb = lb_parts[0]
    for p in lb_parts[1:]:
        lb = jnp.maximum(lb, p)

    free = sm.free_g > 0
    ok = free & state_valid
    lb = jnp.where(ok, lb, INF)
    child_gcost = jnp.where(ok, child_gcost, INF)
    heur_cost = jnp.where(state_valid, heur_cost, INF)
    return lb, child_gcost, heur_img, heur_cost


def run_pair(pair: Tuple, cfg: EngineConfig, tau: jnp.ndarray,
             verification: bool):
    """Search one pair.  ``pair`` = (qv, gv, qa, ga, order, n) jnp arrays."""
    qv, gv, qa, ga, order, n, n_vlabels, n_elabels = pair
    N = qv.shape[0]
    P, B = cfg.pool, cfg.expand
    pc = eb.make_pair_consts(qv, gv, qa, ga, order, n, n_vlabels, n_elabels)

    nf = n.astype(jnp.float32)

    pool0 = PoolState(
        img=jnp.full((P, N), -1, dtype=jnp.int32),
        level=jnp.zeros((P,), dtype=jnp.int32),
        gcost=jnp.full((P,), INF, dtype=jnp.float32).at[0].set(0.0),
        lb=jnp.full((P,), INF, dtype=jnp.float32).at[0].set(0.0),
        valid=jnp.zeros((P,), dtype=bool).at[0].set(True),
    )
    ub0 = (tau + 0.5).astype(jnp.float32) if verification else jnp.float32(INF)
    carry0 = Carry(pool0, ub0, jnp.full((N,), -1, jnp.int32),
                   jnp.float32(INF), jnp.int32(0), jnp.int32(0),
                   jnp.asarray(n == 0))

    expand_v = jax.vmap(
        lambda img, lvl, gc, sv: _expand_one(pc, cfg, img, lvl, gc, sv)
    )

    def cond(c: Carry):
        return ~c.done

    def body(c: Carry) -> Carry:
        pool = c.pool
        # ---- pop: the pool is key-sorted, so the best B states are the
        # first B rows — a free static slice, no top_k / per-pool sort.
        sel_img = pool.img[:B]
        sel_level = pool.level[:B]
        sel_gcost = pool.gcost[:B]
        sel_lb = pool.lb[:B]
        # prune-at-pop (Alg. 2 line 6)
        sel_valid = pool.valid[:B] & (sel_lb < c.ub)

        # the unpopped remainder (rows B..P) stays sorted: nothing below
        # mutates its fields, so its keys are unchanged since the last merge
        rem = PoolState(pool.img[B:], pool.level[B:], pool.gcost[B:],
                        pool.lb[B:], pool.valid[B:])

        # ---- expand ---------------------------------------------------------
        clb, cgc, heur_img, heur_cost = expand_v(
            sel_img, sel_level, sel_gcost, sel_valid
        )                                                     # (B, N) each
        # monotone bounds along root-leaf paths (§5.1)
        clb = jnp.maximum(clb, sel_lb[:, None])
        child_level = sel_level + 1                           # (B,)
        is_leaf = (child_level[:, None] == n)                 # (B, N)

        # ---- incumbent update ----------------------------------------------
        leaf_costs = jnp.where(is_leaf & (cgc < INF / 2), cgc, INF)
        l_flat = leaf_costs.reshape(-1)
        l_best = jnp.argmin(l_flat)
        l_cost = l_flat[l_best]
        lb_state, lu = l_best // N, l_best % N
        pos = jnp.arange(N, dtype=jnp.int32)
        leaf_img = jnp.where(pos == sel_level[lb_state], lu,
                             sel_img[lb_state])

        h_best = jnp.argmin(heur_cost)
        h_cost = heur_cost[h_best]

        new_ub = jnp.minimum(c.ub, jnp.minimum(l_cost, h_cost))
        best_img = jnp.where(
            (l_cost < c.ub) & (l_cost <= h_cost), leaf_img,
            jnp.where(h_cost < c.ub, heur_img[h_best], c.best_img),
        )

        # ---- children to insert ---------------------------------------------
        ins_mask = (~is_leaf) & (clb < new_ub) & (clb < INF / 2)
        child_imgs = jnp.where(
            pos[None, None, :] == sel_level[:, None, None],
            jnp.broadcast_to(pos[None, :, None], (B, N, N)),
            sel_img[:, None, :],
        )                                                      # (B, N, N)
        ch_img = child_imgs.reshape(B * N, N)
        ch_level = jnp.broadcast_to(child_level[:, None], (B, N)).reshape(-1)
        ch_gcost = cgc.reshape(-1)
        ch_lb = jnp.where(ins_mask, clb, INF).reshape(-1)
        ch_valid = ins_mask.reshape(-1)

        # ---- merge: keep best P by pop key ----------------------------------
        # Only the B*N child *keys* are sorted; the remainder run is already
        # sorted (invariant), so the merge is two binary-search rank passes
        # + one payload gather instead of a full (P + B*N) argsort.  The
        # child payload rows never pre-sort: the sort permutation composes
        # into the merge's source-index map (perm_b).
        ch = PoolState(ch_img, ch_level, ch_gcost, ch_lb, ch_valid)
        ch_keys = _pop_key(cfg, ch_lb, ch_level, ch_valid, n)
        ch_keys, ch_order = sort_by_key(
            ch_keys, jnp.arange(B * N, dtype=jnp.int32))
        rem_keys = _pop_key(cfg, rem.lb, rem.level, rem.valid, n)
        # Floor accounting matches the old bulk-pruning merge exactly:
        # dropped states whose bound the incumbent already beat (lb >=
        # new_ub) contribute nothing — they are discarded as pruned, not
        # as unexplored.  (Children are pre-filtered by ins_mask, so
        # their lb is < new_ub wherever valid.)
        _, kept, dropped_lb = merge_sorted_topk(
            rem_keys, ch_keys, rem, ch, P,
            drop_a=jnp.where(rem.valid & (rem.lb < new_ub), rem.lb, INF),
            drop_b=jnp.where(ch.valid, ch.lb, INF),
            perm_b=ch_order,
            use_kernel=concrete_dispatch(cfg, N).merge_fused)
        new_pool = kept._replace(lb=jnp.where(kept.valid, kept.lb, INF))
        new_floor = jnp.minimum(c.floor, dropped_lb)

        # ---- termination -----------------------------------------------------
        min_lb = jnp.min(jnp.where(new_pool.valid, new_pool.lb, INF))
        it = c.it + 1
        exhausted = min_lb >= INF / 2
        # min_lb >= ub means every remaining state is prunable (Alg. 2
        # line 6 would discard each at pop), i.e. the incumbent is optimal.
        # The pre-sorted-pool loop reached the same stop by bulk-invalidating
        # lb >= ub entries at merge time; pruning is lazy now (at pop and by
        # tail truncation), so both strategies stop on the bound condition.
        opt_done = min_lb >= new_ub
        done = exhausted | opt_done | (it >= cfg.max_iters)
        if verification:
            done = done | (new_ub <= tau) | (jnp.minimum(min_lb, new_floor) > tau)

        new_c = Carry(new_pool, new_ub, best_img, new_floor, it,
                      c.expanded + jnp.sum(sel_valid.astype(jnp.int32)), done)
        # mask the whole carry when already done (vmap lockstep safety)
        return jax.tree.map(
            lambda new, old: jnp.where(c.done, old, new), new_c, c
        )

    final = jax.lax.while_loop(cond, body, carry0)

    min_lb_end = jnp.min(jnp.where(final.pool.valid, final.pool.lb, INF))
    truncated = (final.it >= cfg.max_iters) & (min_lb_end < final.ub)
    ged_val = final.ub
    exact = (ged_val <= final.floor) & ~truncated
    if verification:
        similar = final.ub <= tau
        exact = jnp.where(
            similar, jnp.asarray(True),
            (jnp.minimum(min_lb_end, final.floor) > tau) & ~truncated,
        )
        return {
            "similar": similar,
            "exact": exact,
            "lower_bound": jnp.where(similar, jnp.float32(0.0),
                                     jnp.minimum(min_lb_end, final.floor)),
            "upper_bound": final.ub,
            "iterations": final.it,
            "expanded": final.expanded,
            "best_img": final.best_img,
        }
    return {
        "ged": ged_val,
        "exact": exact,
        "lower_bound": jnp.minimum(jnp.minimum(min_lb_end, final.floor),
                                   final.ub),
        "upper_bound": final.ub,
        "iterations": final.it,
        "expanded": final.expanded,
        "best_img": final.best_img,
        "floor": final.floor,
    }
