"""Dense padded tensor representation of (q, g) pairs.

Label conventions (compact, per *batch*):
* vertex labels ``0 .. Lv-1`` are real, ``Lv`` is the BOTTOM padding label
  (paper's ``_|_``), ``Lv+1`` marks PAD slots (non-vertices beyond ``n``).
* edge labels ``1 .. Le`` real, ``0`` = no edge.  PAD slots have no edges.

All pairs in a batch share the static size ``N`` (max vertices) and the label
vocabularies ``Lv`` / ``Le``; the per-pair true size ``n`` is data.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.exact.graph import BOTTOM, Graph, pad_pair
from repro.core.exact.order import matching_order


@dataclasses.dataclass
class GraphPairTensors:
    """A batch of B graph pairs, padded to N slots."""

    qv: np.ndarray      # (B, N) int32 vertex labels of q (compact)
    gv: np.ndarray      # (B, N) int32 vertex labels of g
    qa: np.ndarray      # (B, N, N) int32 edge labels of q (0 = absent)
    ga: np.ndarray      # (B, N, N) int32 edge labels of g
    order: np.ndarray   # (B, N) int32 matching order of q (PAD slots at end)
    n: np.ndarray       # (B,) int32 true vertex count per pair
    n_vlabels: int      # Lv (real labels); BOTTOM = Lv, PAD = Lv + 1
    n_elabels: int      # Le (real labels); absent = 0

    @property
    def batch(self) -> int:
        return self.qv.shape[0]

    @property
    def slots(self) -> int:
        return self.qv.shape[1]

    def pair(self, i: int) -> "GraphPairTensors":
        return GraphPairTensors(
            self.qv[i : i + 1], self.gv[i : i + 1], self.qa[i : i + 1],
            self.ga[i : i + 1], self.order[i : i + 1], self.n[i : i + 1],
            self.n_vlabels, self.n_elabels,
        )


def label_vocab(
    pairs: Sequence[Tuple[Graph, Graph]],
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Joint (vertex, edge) label vocabularies across a set of pairs.

    Sharing one vocabulary across several ``pack_pairs`` calls keeps the
    static ``n_vlabels`` / ``n_elabels`` arguments of the jitted engine
    identical between batches, so bucketed workloads reuse compilations.
    """
    vset = sorted(
        {int(a) for q, g in pairs for a in q.vlabels if a != BOTTOM}
        | {int(a) for q, g in pairs for a in g.vlabels if a != BOTTOM}
    )
    eset = sorted(
        {int(a) for q, g in pairs for a in np.unique(q.adj) if a != 0}
        | {int(a) for q, g in pairs for a in np.unique(g.adj) if a != 0}
    )
    return tuple(vset), tuple(eset)


def pack_pairs(
    pairs: Sequence[Tuple[Graph, Graph]],
    slots: int | None = None,
    vocab: Tuple[Sequence[int], Sequence[int]] | None = None,
) -> GraphPairTensors:
    """Pad, relabel and stack a list of (q, g) pairs into batch tensors.

    ``vocab`` — optional ``(vertex_labels, edge_labels)`` from
    :func:`label_vocab`; when given it must cover every label in the batch
    and is used verbatim so batches packed with the same vocab share the
    compact label space (and hence jit compilations).
    """
    padded: List[Tuple[Graph, Graph]] = []
    for q, g in pairs:
        qp, gp, _ = pad_pair(q, g)
        padded.append((qp, gp))

    # Joint compact label maps across the batch (or the caller's vocab).
    if vocab is not None:
        vset, eset = sorted(int(a) for a in vocab[0]), sorted(int(a) for a in vocab[1])
        observed_v, observed_e = label_vocab(padded)
        missing = (set(observed_v) - set(vset)) | (set(observed_e) - set(eset))
        if missing:
            raise ValueError(f"vocab does not cover batch labels: {sorted(missing)}")
    else:
        vset, eset = (list(s) for s in label_vocab(padded))
    vmap = {a: i for i, a in enumerate(vset)}
    emap = {a: i + 1 for i, a in enumerate(eset)}
    emap[0] = 0
    lv, le = len(vset), len(eset)
    bottom, pad = lv, lv + 1

    nmax = max(gp.n for _, gp in padded)
    if slots is None:
        slots = max(4, int(2 ** np.ceil(np.log2(max(nmax, 1)))))
    if nmax > slots:
        raise ValueError(f"pair with {nmax} vertices does not fit {slots} slots")

    B = len(padded)
    qv = np.full((B, slots), pad, dtype=np.int32)
    gv = np.full((B, slots), pad, dtype=np.int32)
    qa = np.zeros((B, slots, slots), dtype=np.int32)
    ga = np.zeros((B, slots, slots), dtype=np.int32)
    order = np.zeros((B, slots), dtype=np.int32)
    ns = np.zeros((B,), dtype=np.int32)

    for b, (qp, gp) in enumerate(padded):
        n = gp.n
        ns[b] = n
        qv[b, :n] = [bottom if int(a) == BOTTOM else vmap[int(a)] for a in qp.vlabels]
        gv[b, :n] = [bottom if int(a) == BOTTOM else vmap[int(a)] for a in gp.vlabels]
        qa[b, :n, :n] = np.vectorize(lambda a: emap[int(a)])(qp.adj)
        ga[b, :n, :n] = np.vectorize(lambda a: emap[int(a)])(gp.adj)
        ordv = matching_order(qp, gp)
        order[b, :n] = ordv
        order[b, n:] = np.arange(n, slots)  # PAD positions map to themselves

    return GraphPairTensors(qv, gv, qa, ga, order, ns, lv, le)
