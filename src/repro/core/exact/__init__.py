"""Paper-faithful exact GED algorithms (Chang et al., 2017).

This subpackage is the reference implementation of the paper:
  - ``graph``      : labeled undirected graphs, padding simplifications (§2.1)
  - ``multiset``   : multiset edit distance ``Y`` (App. A.2)
  - ``assignment`` : exact Hungarian (Jonker-Volgenant style) + forced variants
  - ``bounds``     : LS / LSa / BM / BMa / BMaN / SM / SMa child scoring (§4, A.3)
  - ``order``      : frequency-aware connected matching order (App. A.1)
  - ``search``     : unified framework (Alg. 2) -> AStar+ / DFS+ (§3, §5)
  - ``brute``      : brute-force oracle for tests

Everything here is plain python/numpy and serves both as the paper-faithful
baseline recorded in EXPERIMENTS.md and as the oracle for the batched JAX
engine in ``repro.core.engine``.
"""

from repro.core.exact.graph import Graph, BOTTOM, pad_pair, editorial_cost
from repro.core.exact.multiset import multiset_edit_distance
from repro.core.exact.assignment import hungarian, solve_forced_all
from repro.core.exact.order import matching_order
from repro.core.exact.search import ged, ged_verify, SearchResult, BOUNDS

__all__ = [
    "Graph",
    "BOTTOM",
    "pad_pair",
    "editorial_cost",
    "multiset_edit_distance",
    "hungarian",
    "solve_forced_all",
    "matching_order",
    "ged",
    "ged_verify",
    "SearchResult",
    "BOUNDS",
]
