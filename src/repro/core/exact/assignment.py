"""Exact minimum-cost perfect matching (Hungarian / Jonker-Volgenant style).

Used by the BM/BMa/SM/SMa lower bounds (paper §4, Alg. 3).  The solver keeps
explicit dual potentials so that the *forced* variants needed by Alg. 3 —
"cost of the optimal assignment with row ``r`` forced to column ``c``, for
every ``c``" — run in one full solve plus one O(n^2) re-augmentation per
column (O(n^3) total), instead of |V(g)| independent solves.
"""

from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

_INF = float("inf")


class _JVState:
    """Dual potentials + partial assignment supporting row-by-row augmenting."""

    def __init__(self, cost: np.ndarray):
        cost = np.asarray(cost, dtype=np.float64)
        if cost.ndim != 2 or cost.shape[0] != cost.shape[1]:
            raise ValueError("cost must be a square matrix")
        if not np.all(np.isfinite(cost)):
            raise ValueError("cost entries must be finite (use a large BIG)")
        self.cost = cost
        n = cost.shape[0]
        self.n = n
        # 1-indexed potentials / assignment, index 0 is the virtual column.
        self.u = np.zeros(n + 1)
        self.v = np.zeros(n + 1)
        self.p = np.zeros(n + 1, dtype=np.int64)  # p[j] = row (1-idx) on col j

    def clone(self) -> "_JVState":
        s = _JVState.__new__(_JVState)
        s.cost = self.cost
        s.n = self.n
        s.u = self.u.copy()
        s.v = self.v.copy()
        s.p = self.p.copy()
        return s

    def augment(self, row: int, banned_col: int | None = None) -> None:
        """Insert ``row`` (0-indexed) via one shortest-augmenting-path sweep.

        ``banned_col`` (0-indexed) is treated as permanently occupied and can
        never appear on the alternating path.
        """
        n = self.n
        cost, u, v, p = self.cost, self.u, self.v, self.p
        p[0] = row + 1
        j0 = 0
        minv = np.full(n + 1, _INF)
        way = np.zeros(n + 1, dtype=np.int64)
        used = np.zeros(n + 1, dtype=bool)
        if banned_col is not None:
            used[banned_col + 1] = True
        while True:
            used[j0] = True
            i0 = p[j0]
            free = ~used[1:]
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            upd = free & (cur < minv[1:])
            if np.any(upd):
                minv1 = minv[1:]
                way1 = way[1:]
                minv1[upd] = cur[upd]
                way1[upd] = j0
            masked = np.where(free, minv[1:], _INF)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            if not np.isfinite(delta):  # pragma: no cover - defensive
                raise RuntimeError("infeasible assignment problem")
            used_js = np.nonzero(used)[0]
            u[p[used_js]] += delta
            v[used_js] -= delta
            minv[1:][free] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1

    def col_of_row(self) -> np.ndarray:
        out = np.full(self.n, -1, dtype=np.int64)
        for j in range(1, self.n + 1):
            if self.p[j] > 0:
                out[self.p[j] - 1] = j - 1
        return out

    def total(self, skip_row: int | None = None) -> float:
        tot = 0.0
        for j in range(1, self.n + 1):
            i = self.p[j]
            if i > 0 and (skip_row is None or i - 1 != skip_row):
                tot += self.cost[i - 1, j - 1]
        return tot


def hungarian(cost: np.ndarray) -> Tuple[np.ndarray, float]:
    """Solve min-cost perfect matching.  Returns ``(col_of_row, total)``."""
    st = _JVState(cost)
    for i in range(st.n):
        st.augment(i)
    col = st.col_of_row()
    return col, st.total()


def solve_forced_all(cost: np.ndarray, row: int) -> Tuple[np.ndarray, np.ndarray, float]:
    """For every column ``c``: optimal total with ``row -> c`` forced.

    Returns ``(forced_totals, col_of_row, total)`` where ``col_of_row`` /
    ``total`` describe the *unforced* optimum (the matching ``M`` of Alg. 3,
    also used by the paper's full-mapping upper-bound heuristic).

    Strategy: one full JV solve; for each other column ``c`` displace the row
    currently holding ``c``, free ``row``'s own column, and re-augment the
    displaced row with ``c`` banned — O(n^2) per column, O(n^3) total.
    """
    base = _JVState(cost)
    for i in range(base.n):
        base.augment(i)
    col = base.col_of_row()
    total = base.total()
    n = base.n
    forced = np.empty(n, dtype=np.float64)
    c0 = int(col[row])
    forced[c0] = total
    for c in range(n):
        if c == c0:
            continue
        st = base.clone()
        displaced = int(st.p[c + 1]) - 1  # row currently on column c
        # Remove `row` (it pins column c outside the reduced problem) and
        # free its old column c0; re-insert the displaced row.
        st.p[c0 + 1] = 0
        st.p[c + 1] = 0
        if displaced == row:
            # `row` already sat on c in the optimum; reduced problem unchanged.
            forced[c] = total
            continue
        st.augment(displaced, banned_col=c)
        forced[c] = cost[row, c] + st.total(skip_row=row)
    return forced, col, total


def brute_force_assignment(cost: np.ndarray) -> Tuple[np.ndarray, float]:
    """O(n!) oracle for tests."""
    cost = np.asarray(cost, dtype=np.float64)
    n = cost.shape[0]
    best = None
    best_cost = _INF
    for perm in itertools.permutations(range(n)):
        c = float(sum(cost[i, perm[i]] for i in range(n)))
        if c < best_cost:
            best_cost = c
            best = perm
    return np.asarray(best, dtype=np.int64), best_cost
