"""Lower bounds for partial mappings (paper §4 and App. A.3).

Every bound is exposed through a *children scorer*: given a partial mapping
``f`` at level ``i`` (images ``img`` of ``order[:i]``), score **all**
extensions ``f u {v_i -> u}`` at once — the paper's "expand all" /
Alg. 3 / Alg. 4 formulation:

=========  =============================================================
``LS``     label-set bound, Alg. 4 (surplus counters, O(size(q)+size(g)))
``LSa``    anchor-aware label-set bound (inner/cross partition)
``BM``     branch-match bound [31] via one forced-all assignment solve
``BMa``    anchor-aware branch-match bound, Alg. 3 (one O(n^3) solve)
``BMaN``   naive anchor-aware branch match (one solve per child; O(n^4))
``SM``     star-match bound [28] extended to edge labels (App. A.3)
``SMa``    anchor-aware star-match bound (App. A.3)
=========  =============================================================

Scorers return ``ChildScores`` with, per candidate ``u`` of ``V(g)``:
``lb[u]`` (``inf`` if ``u`` is already used), ``g_cost[u]`` (the exact
``delta_f'(q[f'], g[f'])`` of the child), and optionally a heuristic full
mapping (the assignment ``M`` of Alg. 3) for upper-bound updates.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.exact.assignment import hungarian, solve_forced_all
from repro.core.exact.graph import Graph
from repro.core.exact.multiset import multiset_edit_distance

_INF = float("inf")


@dataclasses.dataclass
class ChildScores:
    lb: np.ndarray                     # (n,) float; inf where u is used
    g_cost: np.ndarray                 # (n,) float; exact child editorial cost so far
    full_mapping: Optional[np.ndarray]  # (n,) int or None — heuristic extension


class PairContext:
    """Static per-(q, g) data shared by every bound evaluation."""

    def __init__(self, q: Graph, g: Graph, order: np.ndarray):
        if q.n != g.n:
            raise ValueError("PairContext requires padded equal-size graphs")
        self.q = q
        self.g = g
        self.n = q.n
        self.order = np.asarray(order, dtype=np.int64)
        self.qv = q.vlabels
        self.gv = g.vlabels
        self.qa = q.adj
        self.ga = g.adj


def _labels_of(adj_row: np.ndarray, mask: np.ndarray) -> List[int]:
    vals = adj_row[mask]
    return vals[vals > 0].tolist()


class _Frame:
    """Per-expansion scratch (anchors/free sets, exact child deltas)."""

    def __init__(self, ctx: PairContext, img: Tuple[int, ...]):
        self.ctx = ctx
        n = ctx.n
        i = len(img)
        self.i = i
        self.vi = int(ctx.order[i]) if i < n else -1
        self.anchors_q = ctx.order[:i]
        self.anchors_g = np.asarray(img, dtype=np.int64)
        fq = np.ones(n, dtype=bool)
        fq[self.anchors_q] = False
        fg = np.ones(n, dtype=bool)
        fg[self.anchors_g] = False
        self.free_q_mask = fq                   # includes v_i
        self.free_g_mask = fg
        self.free_q = np.nonzero(fq)[0]
        self.free_g = np.nonzero(fg)[0]
        # q-side free set once v_i is anchored:
        fq2 = fq.copy()
        if self.vi >= 0:
            fq2[self.vi] = False
        self.free_q2_mask = fq2
        self.free_q2 = np.nonzero(fq2)[0]

        if self.vi < 0:  # full mapping: no next vertex, no children
            self.delta_exact = np.zeros(n)
            return
        # Exact editorial-cost increment of child (v_i -> u), for every u.
        dv = (ctx.qv[self.vi] != ctx.gv).astype(np.float64)
        if i > 0:
            aq = ctx.qa[self.vi, self.anchors_q]          # (i,)
            ag = ctx.ga[:, self.anchors_g]                # (n, i)
            de = np.count_nonzero(aq[None, :] != ag, axis=1).astype(np.float64)
        else:
            de = np.zeros(n)
        self.delta_exact = dv + de


def _upsilon_counters(cq: Counter, cg: Counter) -> Tuple[int, int, int]:
    """(|S1|, |S2|, |S1 /\\ S2|) for Counters."""
    s1 = sum(cq.values())
    s2 = sum(cg.values())
    inter = sum(min(cq[k], cg[k]) for k in cq.keys() & cg.keys())
    return s1, s2, inter


class BoundEvaluator:
    """Children scorers for all seven bounds."""

    def __init__(self, ctx: PairContext):
        self.ctx = ctx

    # ------------------------------------------------------------------ LS
    def children_ls(self, img: Tuple[int, ...], g_cost: float,
                    cand_mask: Optional[np.ndarray] = None) -> ChildScores:
        """Alg. 4: label-set bound for all children with surplus counters."""
        ctx, fr = self.ctx, _Frame(self.ctx, img)
        n = ctx.n

        # --- q side (fixed across children) --------------------------------
        # Vertex labels of q \ f' (free vertices minus v_i).
        cqv = Counter(ctx.qv[fr.free_q2].tolist())
        # Edge labels of q \ f' = edges with >= 1 endpoint in free_q2.
        # Equivalently: all edges of q\f minus edges (v_i -> anchors_q).
        he_q = Counter()
        sub = ctx.qa[np.ix_(fr.free_q, np.arange(n))]
        # edges with >=1 endpoint free, before anchoring v_i:
        for a_idx, v in enumerate(fr.free_q):
            row = ctx.qa[v]
            for w in np.nonzero(row)[0]:
                if w > v or not fr.free_q_mask[w]:
                    # count each inner edge once (v < w), each cross edge once
                    # (free endpoint side).
                    if fr.free_q_mask[w] and w < v:
                        continue
                    he_q[int(row[w])] += 1
        del sub
        # remove edges (v_i -> anchors_q): they leave q\f' entirely
        for w in fr.anchors_q:
            a = int(ctx.qa[fr.vi, w])
            if a:
                he_q[a] -= 1
                if he_q[a] == 0:
                    del he_q[a]
        n1 = sum(he_q.values())

        # --- g side base ----------------------------------------------------
        cgv = Counter(ctx.gv[fr.free_g].tolist())
        he_g = Counter()
        for u in fr.free_g:
            row = ctx.ga[u]
            for w in np.nonzero(row)[0]:
                if fr.free_g_mask[w] and w < u:
                    continue
                he_g[int(row[w])] += 1
        n2_base = sum(he_g.values())

        # Surplus counters (Alg. 4 lines 3-6): n_E(a) = count_g - count_q.
        nE: Dict[int, int] = {}
        for a in set(he_q) | set(he_g):
            nE[a] = he_g.get(a, 0) - he_q.get(a, 0)
        cE_base = sum(min(he_q[a], he_g[a]) for a in he_q.keys() & he_g.keys())
        nV: Dict[int, int] = {}
        for a in set(cqv) | set(cgv):
            nV[a] = cgv.get(a, 0) - cqv.get(a, 0)
        cV_base = sum(min(cqv[a], cgv[a]) for a in cqv.keys() & cgv.keys())
        max_v = max(n - fr.i - 1, n - fr.i - 1)

        lbs = np.full(n, _INF)
        for u in fr.free_g:
            if cand_mask is not None and not cand_mask[u]:
                continue
            # remove edges (u -> anchors_g) from the g-side edge multiset
            n2, cE = n2_base, cE_base
            touched: List[int] = []
            for w in fr.anchors_g:
                a = int(ctx.ga[u, w])
                if a:
                    n2 -= 1
                    if nE.get(a, 0) <= 0:
                        cE -= 1
                    nE[a] = nE.get(a, 0) - 1
                    touched.append(a)
            ups_e = max(n1, n2) - cE
            dv = 1 if nV.get(int(ctx.gv[u]), 0) <= 0 else 0
            ups_v = max_v - (cV_base - dv)
            lbs[u] = g_cost + fr.delta_exact[u] + ups_v + ups_e
            for a in touched:  # restore surplus (Alg. 4 lines 21-23)
                nE[a] += 1
        return ChildScores(lbs, g_cost + fr.delta_exact, None)

    # ----------------------------------------------------------------- LSa
    def children_lsa(self, img: Tuple[int, ...], g_cost: float,
                     cand_mask: Optional[np.ndarray] = None) -> ChildScores:
        """Anchor-aware label-set bound for all children.

        Components per child ``f' = f u {v_i -> u}``:
          Y(vertex labels) + Y(inner edges) + sum_anchors Y(cross edges).
        """
        ctx, fr = self.ctx, _Frame(self.ctx, img)
        n = ctx.n

        # Vertex component: identical bookkeeping to LS.
        cqv = Counter(ctx.qv[fr.free_q2].tolist())
        cgv = Counter(ctx.gv[fr.free_g].tolist())
        nV = {a: cgv.get(a, 0) - cqv.get(a, 0) for a in set(cqv) | set(cgv)}
        cV_base = sum(min(cqv[a], cgv[a]) for a in cqv.keys() & cgv.keys())
        max_v = n - fr.i - 1

        # Inner edges: q side fixed = edges with both endpoints in free_q2.
        heI_q = Counter()
        for a_i, v in enumerate(fr.free_q2):
            row = ctx.qa[v]
            for w in np.nonzero(row)[0]:
                if fr.free_q2_mask[w] and w > v:
                    heI_q[int(row[w])] += 1
        nI1 = sum(heI_q.values())
        # g side base = edges with both endpoints free_g.
        heI_g = Counter()
        for u in fr.free_g:
            row = ctx.ga[u]
            for w in np.nonzero(row)[0]:
                if fr.free_g_mask[w] and w > u:
                    heI_g[int(row[w])] += 1
        nI2_base = sum(heI_g.values())
        nIE = {a: heI_g.get(a, 0) - heI_q.get(a, 0) for a in set(heI_q) | set(heI_g)}
        cIE_base = sum(min(heI_q[a], heI_g[a]) for a in heI_q.keys() & heI_g.keys())

        # Old-anchor cross components. q side (fixed): edges anchor -> free_q2.
        # g side base: edges f(anchor) -> free_g; per child remove (f(anchor), u).
        anchor_data = []  # (s1, s2, inter, cq, cg) per anchor j
        base_cross_sum = 0.0
        for j in range(fr.i):
            vq, ug = int(fr.anchors_q[j]), int(fr.anchors_g[j])
            cq = Counter(_labels_of(ctx.qa[vq], fr.free_q2_mask))
            cg = Counter(_labels_of(ctx.ga[ug], fr.free_g_mask))
            s1, s2, inter = _upsilon_counters(cq, cg)
            anchor_data.append((s1, s2, inter, cq, cg))
            base_cross_sum += max(s1, s2) - inter

        # v_i's own cross component (q side fixed).
        cq_vi = Counter(_labels_of(ctx.qa[fr.vi], fr.free_q2_mask))

        # anchors adjacent to u (g side) for fast per-child adjustment
        lbs = np.full(n, _INF)
        for u in fr.free_g:
            if cand_mask is not None and not cand_mask[u]:
                continue
            # inner edges: remove u's free-neighbor edges from g inner multiset
            nI2, cIE = nI2_base, cIE_base
            touched: List[int] = []
            for w in np.nonzero(ctx.ga[u])[0]:
                if fr.free_g_mask[w]:
                    a = int(ctx.ga[u, w])
                    nI2 -= 1
                    if nIE.get(a, 0) <= 0:
                        cIE -= 1
                    nIE[a] = nIE.get(a, 0) - 1
                    touched.append(a)
            ups_inner = max(nI1, nI2) - cIE
            for a in touched:
                nIE[a] += 1

            # old anchors: only those adjacent to u change from base
            cross_sum = base_cross_sum
            for j in range(fr.i):
                a = int(ctx.ga[int(fr.anchors_g[j]), u])
                if a:
                    s1, s2, inter, cq, cg = anchor_data[j]
                    d = 1 if cg[a] <= cq[a] else 0
                    cross_sum += (max(s1, s2 - 1) - (inter - d)) - (max(s1, s2) - inter)

            # v_i component vs u's free neighbours (minus u itself)
            cg_u = Counter(
                int(ctx.ga[u, w]) for w in np.nonzero(ctx.ga[u])[0]
                if fr.free_g_mask[w] and w != u
            )
            ups_vi = multiset_edit_distance(cq_vi.elements(), cg_u.elements())

            dv = 1 if nV.get(int(ctx.gv[u]), 0) <= 0 else 0
            ups_v = max_v - (cV_base - dv)
            lbs[u] = g_cost + fr.delta_exact[u] + ups_v + ups_inner + cross_sum + ups_vi
        return ChildScores(lbs, g_cost + fr.delta_exact, None)

    # ---------------------------------------------------------- BM family
    def _branch_hists(self, fr: _Frame, inner_only: bool) -> Tuple[np.ndarray, ...]:
        """Per-free-vertex edge-label Counters for q and g sides."""
        ctx = self.ctx
        if inner_only:
            qmask, gmask = fr.free_q_mask, fr.free_g_mask
        else:
            qmask = np.ones(ctx.n, dtype=bool)
            gmask = np.ones(ctx.n, dtype=bool)
        cq = [Counter(_labels_of(ctx.qa[v], qmask)) for v in fr.free_q]
        cg = [Counter(_labels_of(ctx.ga[u], gmask)) for u in fr.free_g]
        return cq, cg

    def _pairwise_upsilon(self, cq: List[Counter], cg: List[Counter]) -> np.ndarray:
        k = len(cq)
        out = np.zeros((k, k))
        for a in range(k):
            for b in range(k):
                s1, s2, inter = _upsilon_counters(cq[a], cg[b])
                out[a, b] = max(s1, s2) - inter
        return out

    def _cross_mismatch(self, fr: _Frame) -> np.ndarray:
        """sum_j 1[l(v, order_j) != l(u, img_j)] over free (v, u) pairs."""
        ctx = self.ctx
        if fr.i == 0:
            return np.zeros((len(fr.free_q), len(fr.free_g)))
        mq = ctx.qa[np.ix_(fr.free_q, fr.anchors_q)]   # (k, i)
        mg = ctx.ga[np.ix_(fr.free_g, fr.anchors_g)]   # (k, i)
        return np.count_nonzero(mq[:, None, :] != mg[None, :, :], axis=2).astype(float)

    def _lambda_matrix(self, fr: _Frame, kind: str) -> np.ndarray:
        """lambda^{BM|BMa|SM|SMa} over free_q x free_g (v_i treated as free)."""
        ctx = self.ctx
        vmis = (ctx.qv[fr.free_q][:, None] != ctx.gv[fr.free_g][None, :]).astype(float)
        if kind in ("BM", "SM"):
            cq, cg = self._branch_hists(fr, inner_only=False)
            lam = vmis + 0.5 * self._pairwise_upsilon(cq, cg)
        else:  # BMa / SMa
            cq, cg = self._branch_hists(fr, inner_only=True)
            lam = vmis + 0.5 * self._pairwise_upsilon(cq, cg) + self._cross_mismatch(fr)
        if kind in ("SM", "SMa"):
            nq = [Counter(ctx.qv[np.nonzero(ctx.qa[v] * fr.free_q_mask)[0]].tolist())
                  for v in fr.free_q]
            ng = [Counter(ctx.gv[np.nonzero(ctx.ga[u] * fr.free_g_mask)[0]].tolist())
                  for u in fr.free_g]
            lam = lam + self._pairwise_upsilon(nq, ng)
        return lam

    def _star_denominator(self, fr: _Frame) -> float:
        ctx = self.ctx
        # degree within q\f of free vertices (inner + cross edges)
        dq = max((int(np.count_nonzero(ctx.qa[v])) for v in fr.free_q), default=0)
        dg = max((int(np.count_nonzero(ctx.ga[u])) for u in fr.free_g), default=0)
        return float(max(4, dq + 1, dg + 1))

    def _children_assignment(self, img: Tuple[int, ...], g_cost: float, kind: str,
                             cand_mask: Optional[np.ndarray] = None) -> ChildScores:
        ctx, fr = self.ctx, _Frame(self.ctx, img)
        n = ctx.n
        k = len(fr.free_q)
        lam = self._lambda_matrix(fr, kind)
        if cand_mask is not None:
            vi_row = int(np.nonzero(fr.free_q == fr.vi)[0][0])
            banned = ~cand_mask[fr.free_g]
            lam = lam.copy()
            lam[vi_row, banned] = 1e7  # Alg. 3 line 3 (large finite BIG)
        vi_row = int(np.nonzero(fr.free_q == fr.vi)[0][0])
        forced, mcol, _total = solve_forced_all(lam, vi_row)
        denom = self._star_denominator(fr) if kind in ("SM", "SMa") else 1.0

        lbs = np.full(n, _INF)
        lbs[fr.free_g] = g_cost + forced / denom
        if cand_mask is not None:
            lbs[~cand_mask] = _INF

        # Heuristic full mapping from the matching M (paper §4.2 remark).
        full = np.full(n, -1, dtype=np.int64)
        full[fr.anchors_q] = fr.anchors_g
        full[fr.free_q] = fr.free_g[mcol]
        return ChildScores(lbs, g_cost + fr.delta_exact, full)

    def children_bm(self, img, g_cost, cand_mask=None) -> ChildScores:
        return self._children_assignment(img, g_cost, "BM", cand_mask)

    def children_bma(self, img, g_cost, cand_mask=None) -> ChildScores:
        return self._children_assignment(img, g_cost, "BMa", cand_mask)

    def children_sm(self, img, g_cost, cand_mask=None) -> ChildScores:
        return self._children_assignment(img, g_cost, "SM", cand_mask)

    def children_sma(self, img, g_cost, cand_mask=None) -> ChildScores:
        return self._children_assignment(img, g_cost, "SMa", cand_mask)

    # ---------------------------------------------------------------- BMaN
    def children_bman(self, img: Tuple[int, ...], g_cost: float,
                      cand_mask: Optional[np.ndarray] = None) -> ChildScores:
        """Naive anchor-aware branch match: one assignment solve per child.

        ``delta^BMaN(f') = delta_f'(q[f'], g[f']) + delta^BMa(q\\f', g\\f')``
        with ``v_i`` *anchored* — tighter than BMa, |V(g)| x costlier.
        """
        ctx, fr = self.ctx, _Frame(self.ctx, img)
        n = ctx.n
        lbs = np.full(n, _INF)
        gc = g_cost + fr.delta_exact
        best_full, best_lb = None, _INF
        for u in fr.free_g:
            if cand_mask is not None and not cand_mask[u]:
                continue
            img2 = img + (int(u),)
            fr2 = _Frame(ctx, img2)
            if len(fr2.free_q) == 0:
                lbs[u] = gc[u]
                continue
            lam = self._lambda_matrix(fr2, "BMa")
            mcol, total = hungarian(lam)
            lbs[u] = gc[u] + total
            if lbs[u] < best_lb:
                # heuristic full mapping from this child's matching M
                # (paper §4.2 remark, same as Alg. 3's extension)
                best_lb = lbs[u]
                full = np.full(n, -1, dtype=np.int64)
                full[fr2.anchors_q] = fr2.anchors_g
                full[fr2.free_q] = fr2.free_g[mcol]
                best_full = full
        return ChildScores(lbs, gc, best_full)


# Naive whole-state bounds, used as oracles in property tests ----------------

def remaining_lower_bound(ctx: PairContext, img: Tuple[int, ...], kind: str) -> float:
    """``delta_lower(q\\f, g\\f)`` computed from scratch for a *given* state."""
    if len(img) >= ctx.n:
        return 0.0
    fr = _Frame(ctx, img)
    # For a state (not children): free sets exclude nothing extra; rebuild a
    # frame "as if" v_i were not special by using the raw anchor sets.
    n = ctx.n
    free_q = np.nonzero(fr.free_q_mask)[0]
    free_g = np.nonzero(fr.free_g_mask)[0]
    ev = BoundEvaluator(ctx)
    if kind == "LS":
        lq = Counter(ctx.qv[free_q].tolist())
        lg = Counter(ctx.gv[free_g].tolist())
        he_q = Counter()
        for v in free_q:
            for w in np.nonzero(ctx.qa[v])[0]:
                if fr.free_q_mask[w] and w < v:
                    continue
                he_q[int(ctx.qa[v, w])] += 1
        he_g = Counter()
        for u in free_g:
            for w in np.nonzero(ctx.ga[u])[0]:
                if fr.free_g_mask[w] and w < u:
                    continue
                he_g[int(ctx.ga[u, w])] += 1
        return (multiset_edit_distance(lq.elements(), lg.elements())
                + multiset_edit_distance(he_q.elements(), he_g.elements()))
    if kind == "LSa":
        lq = Counter(ctx.qv[free_q].tolist())
        lg = Counter(ctx.gv[free_g].tolist())
        tot = multiset_edit_distance(lq.elements(), lg.elements())
        heI_q, heI_g = Counter(), Counter()
        for v in free_q:
            for w in np.nonzero(ctx.qa[v])[0]:
                if fr.free_q_mask[w] and w > v:
                    heI_q[int(ctx.qa[v, w])] += 1
        for u in free_g:
            for w in np.nonzero(ctx.ga[u])[0]:
                if fr.free_g_mask[w] and w > u:
                    heI_g[int(ctx.ga[u, w])] += 1
        tot += multiset_edit_distance(heI_q.elements(), heI_g.elements())
        for j in range(fr.i):
            vq, ug = int(fr.anchors_q[j]), int(fr.anchors_g[j])
            cq = _labels_of(ctx.qa[vq], fr.free_q_mask)
            cg = _labels_of(ctx.ga[ug], fr.free_g_mask)
            tot += multiset_edit_distance(cq, cg)
        return float(tot)
    if kind in ("BM", "BMa", "SM", "SMa"):
        if len(free_q) == 0:
            return 0.0
        lam = ev._lambda_matrix(fr, kind)
        _, total = hungarian(lam)
        if kind in ("SM", "SMa"):
            total /= ev._star_denominator(fr)
        return float(total)
    raise ValueError(kind)


SCORERS = {
    "LS": BoundEvaluator.children_ls,
    "LSa": BoundEvaluator.children_lsa,
    "BM": BoundEvaluator.children_bm,
    "BMa": BoundEvaluator.children_bma,
    "BMaN": BoundEvaluator.children_bman,
    "SM": BoundEvaluator.children_sm,
    "SMa": BoundEvaluator.children_sma,
}
