"""Brute-force GED oracles for tests (Lemma 2.2: min editorial cost)."""

from __future__ import annotations

import itertools
from typing import Optional, Tuple

import numpy as np

from repro.core.exact.graph import Graph, editorial_cost, pad_pair


def brute_force_ged(q: Graph, g: Graph, limit: int = 9) -> int:
    """Exact GED by enumerating all |V(g)|! mappings.  Tiny graphs only."""
    q, g, _ = pad_pair(q, g)
    if q.n > limit:
        raise ValueError(f"brute force limited to n <= {limit}")
    best = np.inf
    for perm in itertools.permutations(range(g.n)):
        best = min(best, editorial_cost(q, g, np.asarray(perm)))
    return int(best)


def brute_force_extension_cost(
    q: Graph, g: Graph, order: np.ndarray, img: Tuple[int, ...],
) -> int:
    """Min editorial cost over all full mappings extending a partial mapping.

    Oracle for admissibility property tests: any lower bound ``lb(f)`` must
    satisfy ``lb(f) <= brute_force_extension_cost(f)``.
    """
    n = g.n
    used = set(img)
    free_g = [u for u in range(n) if u not in used]
    rest_q = [int(v) for v in order[len(img):]]
    f = np.full(n, -1, dtype=np.int64)
    for v, u in zip(order[: len(img)], img):
        f[int(v)] = int(u)
    best = np.inf
    for perm in itertools.permutations(free_g):
        for v, u in zip(rest_q, perm):
            f[v] = u
        best = min(best, editorial_cost(q, g, f))
    return int(best)
