"""Labeled undirected graphs and the paper's §2.1 simplifications.

Conventions
-----------
* Vertex labels are integers ``>= 0``; the special label ``BOTTOM = -1`` marks
  padding vertices (the paper's unique label ``_|_`` not in Sigma).
* Edges are stored in a dense symmetric adjacency matrix ``adj`` where
  ``adj[i, j] == 0`` means "no edge" and ``adj[i, j] == a >= 1`` means an edge
  with label ``a``.  No self loops.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

import numpy as np

BOTTOM = -1  # label of padding (inserted isolated) vertices


@dataclasses.dataclass
class Graph:
    """A labeled undirected graph."""

    vlabels: np.ndarray  # (n,) int64
    adj: np.ndarray      # (n, n) int64; 0 = absent, >=1 edge label

    def __post_init__(self) -> None:
        self.vlabels = np.asarray(self.vlabels, dtype=np.int64)
        self.adj = np.asarray(self.adj, dtype=np.int64)
        n = self.vlabels.shape[0]
        if self.adj.shape != (n, n):
            raise ValueError(f"adj shape {self.adj.shape} != ({n},{n})")
        if not np.array_equal(self.adj, self.adj.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(self.adj) != 0):
            raise ValueError("self loops are not supported")

    # -- basic accessors ---------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.vlabels.shape[0])

    @property
    def m(self) -> int:
        return int(np.count_nonzero(self.adj) // 2)

    @property
    def size(self) -> int:
        """``size(g) = |V(g)| + |E(g)|`` (paper §2)."""
        return self.n + self.m

    def degree(self, v: int) -> int:
        return int(np.count_nonzero(self.adj[v]))

    def degrees(self) -> np.ndarray:
        return np.count_nonzero(self.adj, axis=1)

    def edges(self) -> Iterable[Tuple[int, int, int]]:
        """Yield ``(i, j, label)`` with ``i < j``."""
        ii, jj = np.nonzero(np.triu(self.adj, k=1))
        for i, j in zip(ii.tolist(), jj.tolist()):
            yield i, j, int(self.adj[i, j])

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_edges(
        vlabels: Sequence[int],
        edges: Iterable[Tuple[int, int, int]],
    ) -> "Graph":
        n = len(vlabels)
        adj = np.zeros((n, n), dtype=np.int64)
        for i, j, a in edges:
            if i == j:
                raise ValueError("self loop")
            if a <= 0:
                raise ValueError("edge labels must be >= 1")
            adj[i, j] = a
            adj[j, i] = a
        return Graph(np.asarray(vlabels, dtype=np.int64), adj)

    def copy(self) -> "Graph":
        return Graph(self.vlabels.copy(), self.adj.copy())

    def induced(self, keep: Sequence[int]) -> "Graph":
        keep = np.asarray(keep, dtype=np.int64)
        return Graph(self.vlabels[keep], self.adj[np.ix_(keep, keep)])

    def __repr__(self) -> str:  # pragma: no cover - debug helper
        return f"Graph(n={self.n}, m={self.m})"


def pad_pair(q: Graph, g: Graph) -> Tuple[Graph, Graph, bool]:
    """Apply the paper's §2.1 simplifications.

    Ensures ``|V(q)| <= |V(g)|`` (swapping if necessary; GED is symmetric) and
    pads ``q`` with isolated ``BOTTOM``-labeled vertices so both graphs have
    the same vertex count.  Returns ``(q', g', swapped)``.
    """
    swapped = False
    if q.n > g.n:
        q, g = g, q
        swapped = True
    if q.n < g.n:
        pad = g.n - q.n
        vlabels = np.concatenate([q.vlabels, np.full(pad, BOTTOM, dtype=np.int64)])
        adj = np.zeros((g.n, g.n), dtype=np.int64)
        adj[: q.n, : q.n] = q.adj
        q = Graph(vlabels, adj)
    return q, g, swapped


def editorial_cost(q: Graph, g: Graph, f: Sequence[int]) -> int:
    """Algorithm 1: editorial cost of a full mapping ``f`` (uniform costs).

    ``q`` and ``g`` must have the same number of vertices (use :func:`pad_pair`
    first); ``f[v]`` is the vertex of ``g`` that ``v`` maps to.

    Vertex relabels + (edge delete / insert / relabel), where an edge pair
    ``(v, v') -> (f(v), f(v'))`` costs 1 iff the labels differ (absence is
    label 0, so delete/insert fall out of the same comparison).
    """
    f = np.asarray(f, dtype=np.int64)
    if q.n != g.n or f.shape[0] != q.n:
        raise ValueError("editorial_cost requires padded, equal-size graphs")
    cost = int(np.count_nonzero(q.vlabels != g.vlabels[f]))
    mapped = g.adj[np.ix_(f, f)]
    cost += int(np.count_nonzero(np.triu(q.adj != mapped, k=1)))
    return cost


def relabel_compact(q: Graph, g: Graph) -> Tuple[Graph, Graph, int, int]:
    """Jointly re-map vertex/edge labels of a pair to compact ranges.

    Vertex labels become ``0..Lv-1`` (``BOTTOM`` stays ``BOTTOM``); edge
    labels become ``1..Le``.  Returns ``(q', g', Lv, Le)``.  Used by the JAX
    engine, which wants dense histogram bins.
    """
    vset = sorted(set(q.vlabels.tolist() + g.vlabels.tolist()) - {BOTTOM})
    vmap = {a: i for i, a in enumerate(vset)}
    vmap[BOTTOM] = BOTTOM
    eset = sorted(
        (set(np.unique(q.adj).tolist()) | set(np.unique(g.adj).tolist())) - {0}
    )
    emap = {0: 0}
    emap.update({a: i + 1 for i, a in enumerate(eset)})

    def remap(gr: Graph) -> Graph:
        vl = np.array([vmap[int(a)] for a in gr.vlabels], dtype=np.int64)
        adj = np.vectorize(lambda a: emap[int(a)])(gr.adj).astype(np.int64)
        return Graph(vl, adj)

    return remap(q), remap(g), len(vset), len(eset)
