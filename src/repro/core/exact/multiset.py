"""Multiset edit distance (paper App. A.2).

``Y(S1, S2) = max(|S1|, |S2|) - |S1 /\\ S2|`` where ``/\\`` is multiset
intersection.  Metric; computable in ``O(|S1| + |S2|)`` with hashing.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterable

import numpy as np


def multiset_edit_distance(s1: Iterable, s2: Iterable) -> int:
    """``Y(S1, S2)`` for arbitrary hashable elements."""
    c1, c2 = Counter(s1), Counter(s2)
    inter = sum(min(c1[k], c2[k]) for k in c1.keys() & c2.keys())
    return max(sum(c1.values()), sum(c2.values())) - inter


def hist_edit_distance(h1: np.ndarray, h2: np.ndarray) -> int:
    """``Y`` over dense label histograms (same binning)."""
    n1 = int(h1.sum())
    n2 = int(h2.sum())
    inter = int(np.minimum(h1, h2).sum())
    return max(n1, n2) - inter


def counter_intersection_size(c1: Counter, c2: Counter) -> int:
    return sum(min(c1[k], c2[k]) for k in c1.keys() & c2.keys())
