"""Frequency-aware connected matching order (paper App. A.1).

Infrequency weight of a vertex/edge of ``q`` = 1 - frequency of its label in
``g``.  Greedy: start from the vertex with the largest total weight (vertex +
adjacent edges), then repeatedly append the vertex with the largest total
weight of (its own label + edges connecting it to the chosen prefix),
preferring vertices connected to the prefix.  Padding (``BOTTOM``) vertices
are structureless and are deferred to the end of the order.
"""

from __future__ import annotations

from collections import Counter
from typing import List

import numpy as np

from repro.core.exact.graph import BOTTOM, Graph


def matching_order(q: Graph, g: Graph) -> np.ndarray:
    n = q.n
    vfreq = Counter(g.vlabels.tolist())
    efreq: Counter = Counter()
    for _, _, a in g.edges():
        efreq[a] += 1
    n_g = max(g.n, 1)
    m_g = max(g.m, 1)

    wv = np.array([1.0 - vfreq.get(int(a), 0) / n_g for a in q.vlabels])
    we = np.where(q.adj > 0,
                  1.0 - np.vectorize(lambda a: efreq.get(int(a), 0))(q.adj) / m_g,
                  0.0)

    is_pad = q.vlabels == BOTTOM
    chosen: List[int] = []
    in_order = np.zeros(n, dtype=bool)

    def total_weight_initial(v: int) -> float:
        return wv[v] + float(we[v].sum())

    def total_weight_to_prefix(v: int) -> float:
        return wv[v] + float(we[v, in_order].sum())

    while len(chosen) < n:
        cands = [v for v in range(n) if not in_order[v] and not is_pad[v]]
        if not cands:
            cands = [v for v in range(n) if not in_order[v]]
        if chosen:
            connected = [v for v in cands if np.any(q.adj[v, in_order] > 0)]
            pool = connected if connected else cands
            best = max(pool, key=total_weight_to_prefix)
        else:
            best = max(cands, key=total_weight_initial)
        chosen.append(best)
        in_order[best] = True
    return np.asarray(chosen, dtype=np.int64)
