"""The unified GED search framework (paper Alg. 2, §3/§5).

One loop, instantiated by the priority-queue pop rule:

* ``strategy="astar"`` — pop minimum lower bound, tie-break larger level
  (**AStar+**, §5.1); terminates as soon as the popped bound reaches the
  incumbent upper bound.
* ``strategy="dfs"``  — pop largest level, tie-break smaller bound
  (**DFS+**, §5.2).

Memory model follows the paper: each queue entry stores one partial mapping
plus its *ungenerated siblings* — with the **expand-all** strategy (§5.1)
siblings are materialised (scored once) and attached; without it
(``expand_all=False``, the ``-EO`` variants of Eval-IV) only the candidate
set is kept and the best-extension computation re-runs per sibling request.

Verification (§5.3): initialise the incumbent to ``tau + eps`` and return as
soon as a full mapping with editorial cost <= ``tau`` is found.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.exact.bounds import BoundEvaluator, PairContext, SCORERS
from repro.core.exact.graph import Graph, editorial_cost, pad_pair
from repro.core.exact.order import matching_order

BOUNDS = tuple(SCORERS.keys())  # ("LS", "LSa", "BM", "BMa", "BMaN", "SM", "SMa")

_INF = float("inf")


@dataclasses.dataclass
class SearchStats:
    best_extension_calls: int = 0
    expanded: int = 0
    generated: int = 0
    pops: int = 0
    max_queue: int = 0
    full_mappings_seen: int = 0
    wall_time_s: float = 0.0


@dataclasses.dataclass
class SearchResult:
    ged: Optional[int]            # exact GED (computation mode)
    similar: Optional[bool]       # verification verdict (verification mode)
    best_mapping: Optional[np.ndarray]
    upper_bound: float
    stats: SearchStats
    # Anytime fields (appended with defaults so completed searches are
    # unchanged): on deadline expiry the search stops cooperatively and
    # reports the admissible floor over everything still open.
    lower_bound: Optional[float] = None
    timed_out: bool = False


class _Entry:
    """One queue entry: a partial mapping + its ungenerated siblings."""

    __slots__ = ("img", "level", "g_cost", "lb", "siblings", "cand", "parent_g_cost")

    def __init__(self, img, level, g_cost, lb, siblings, cand, parent_g_cost=0.0):
        self.img = img              # tuple of images of order[:level]
        self.level = level
        self.g_cost = g_cost
        self.lb = lb
        self.siblings = siblings    # sorted [(lb, u, g_cost), ...] or None
        self.cand = cand            # frozenset of remaining candidates (EO mode)
        self.parent_g_cost = parent_g_cost


def _key(strategy: str, lb: float, level: int, n: int) -> Tuple:
    if strategy == "astar":
        return (lb, n - level)
    if strategy == "dfs":
        return (-level, lb)
    raise ValueError(f"unknown strategy {strategy!r}")


def _search(
    q: Graph,
    g: Graph,
    bound: str = "BMa",
    strategy: str = "astar",
    tau: Optional[float] = None,
    expand_all: bool = True,
    order: Optional[np.ndarray] = None,
    deadline=None,
) -> SearchResult:
    t0 = time.perf_counter()
    q, g, _swapped = pad_pair(q, g)
    n = q.n
    stats = SearchStats()
    if n == 0:
        stats.wall_time_s = time.perf_counter() - t0
        verdict = True if tau is not None else None
        return SearchResult(0 if tau is None else None, verdict,
                            np.zeros(0, dtype=np.int64), 0.0, stats)

    if order is None:
        order = matching_order(q, g)
    ctx = PairContext(q, g, order)
    ev = BoundEvaluator(ctx)
    scorer = SCORERS[bound].__get__(ev)

    verification = tau is not None
    ub = (tau + 0.5) if verification else _INF
    best_map: Optional[np.ndarray] = None

    heap: List[Tuple[Tuple, int, _Entry]] = []
    tick = itertools.count()

    def push(entry: _Entry) -> None:
        heapq.heappush(heap, (_key(strategy, entry.lb, entry.level, n), next(tick), entry))
        stats.max_queue = max(stats.max_queue, len(heap))

    def full_mapping_from_order(img: Tuple[int, ...]) -> np.ndarray:
        f = np.full(n, -1, dtype=np.int64)
        for v, u in zip(order, img):
            f[int(v)] = int(u)
        return f

    def try_update_ub(f: np.ndarray, cost: Optional[float] = None) -> Optional[bool]:
        """Update incumbent from a full mapping; returns True on early accept."""
        nonlocal ub, best_map
        if cost is None:
            cost = editorial_cost(q, g, f)
        stats.full_mappings_seen += 1
        if cost < ub:
            ub = float(cost)
            best_map = f.copy()
        if verification and cost <= tau:
            return True
        return None

    def score_children(entry: _Entry, cand_mask: Optional[np.ndarray]):
        stats.best_extension_calls += 1
        return scorer(entry.img, entry.g_cost, cand_mask)

    # -- root ---------------------------------------------------------------
    push(_Entry((), 0, 0.0, 0.0, [], None))
    accepted = False
    timed_out = False
    open_lb = 0.0               # admissible floor over open work at expiry

    while heap:
        key, _, entry = heapq.heappop(heap)
        stats.pops += 1
        # Cooperative deadline check (anytime contract, docs/robustness.md):
        # the first pop and then every 16 keeps the overhead unmeasurable
        # on completed searches while bounding overshoot to a handful of
        # expansions — and guarantees an already-expired deadline stops
        # even a tiny search before real work.  ``deadline`` is duck-typed
        # (anything with ``expired()``) so the core layer stays
        # independent of repro.ged.
        if deadline is not None and (stats.pops & 0xF) == 1 \
                and deadline.expired():
            timed_out = True
            # Every not-yet-enumerated full mapping descends from an open
            # entry (cost >= its lb) or from one pruned at lb >= the ub
            # threshold, so this min is a sound global lower bound.
            open_lb = min(min(e.lb for _, _, e in heap),
                          entry.lb, ub) if heap else min(entry.lb, ub)
            break
        if entry.lb >= ub:
            if strategy == "astar":
                break  # everything left has lb >= this lb >= ub
            continue
        stats.expanded += 1

        # (a) regenerate the best ungenerated sibling (Alg. 2 line 7)
        if entry.level > 0:
            sib = None
            if expand_all:
                while entry.siblings:
                    lb_s, u_s, gc_s = entry.siblings[0]
                    if lb_s >= ub:
                        entry.siblings = []  # sorted: all following are >= ub
                        break
                    entry.siblings = entry.siblings[1:]
                    sib = _Entry(entry.img[:-1] + (u_s,), entry.level, gc_s,
                                 max(lb_s, entry.lb), entry.siblings, None)
                    break
            else:
                if entry.cand:
                    parent_img = entry.img[:-1]
                    mask = np.zeros(n, dtype=bool)
                    mask[list(entry.cand)] = True
                    sc = scorer(parent_img, entry.parent_g_cost, mask)
                    stats.best_extension_calls += 1
                    u_s = int(np.argmin(sc.lb))
                    if np.isfinite(sc.lb[u_s]) and sc.lb[u_s] < ub:
                        sib = _Entry(parent_img + (u_s,), entry.level,
                                     float(sc.g_cost[u_s]),
                                     max(float(sc.lb[u_s]), entry.lb),
                                     None, entry.cand - {u_s},
                                     parent_g_cost=entry.parent_g_cost)
            if sib is not None:
                stats.generated += 1
                push(sib)

        # (b) extend: children of this entry (Alg. 2 line 8)
        if entry.level == n:
            # full mapping reached via the queue: already accounted
            continue
        if entry.level == n - 1:
            # children are leaves: compute exact editorial costs directly
            fr_scores = score_children(entry, None)  # for stats parity
            used = set(entry.img)
            best_cost, best_u = _INF, None
            for u in range(n):
                if u in used:
                    continue
                c = float(fr_scores.g_cost[u])
                if c < best_cost:
                    best_cost, best_u = c, u
            if best_u is not None:
                f = full_mapping_from_order(entry.img + (best_u,))
                if try_update_ub(f, best_cost):
                    accepted = True
                    break
            continue

        scores = score_children(entry, None)
        # Heuristic full-mapping extension (Alg. 2 line 13 / §4.2 remark):
        # only for assignment-based bounds (paper: not for LS/LSa).
        if scores.full_mapping is not None:
            if try_update_ub(scores.full_mapping):
                accepted = True
                break

        lbs = scores.lb
        finite = np.isfinite(lbs)
        if not np.any(finite):
            continue
        # lower bounds are non-decreasing along a root-leaf path (§5.1 note)
        lbs = np.where(finite, np.maximum(lbs, entry.lb), _INF)
        u_best = int(np.argmin(lbs))
        lb_best = float(lbs[u_best])
        if lb_best >= ub:
            continue
        if expand_all:
            sib_list = sorted(
                (float(lbs[u]), u, float(scores.g_cost[u]))
                for u in range(n)
                if finite[u] and u != u_best and lbs[u] < ub
            )
            child = _Entry(entry.img + (u_best,), entry.level + 1,
                           float(scores.g_cost[u_best]), lb_best, sib_list, None,
                           parent_g_cost=entry.g_cost)
        else:
            cand = frozenset(u for u in range(n) if finite[u] and u != u_best)
            child = _Entry(entry.img + (u_best,), entry.level + 1,
                           float(scores.g_cost[u_best]), lb_best, None, cand,
                           parent_g_cost=entry.g_cost)
        stats.generated += 1
        push(child)

    stats.wall_time_s = time.perf_counter() - t0
    if timed_out:
        # Best-so-far result: a real incumbent (if any) is the upper
        # bound; in verification mode the initial ``tau + 0.5`` is only a
        # pruning threshold, not a mapping, so without an incumbent the
        # true upper bound is unknown.
        true_ub = ub if best_map is not None else _INF
        if verification:
            similar: Optional[bool] = None
            if open_lb > tau:
                similar = False     # all remaining possibilities exceed tau
            elif true_ub <= tau:
                similar = True      # an incumbent at or below tau exists
            return SearchResult(None, similar, best_map, true_ub, stats,
                                lower_bound=float(open_lb), timed_out=True)
        return SearchResult(None, None, best_map, true_ub, stats,
                            lower_bound=float(open_lb), timed_out=True)
    if verification:
        similar = accepted or (ub <= tau)
        return SearchResult(None, bool(similar), best_map, ub, stats)
    ged_val = int(round(ub)) if np.isfinite(ub) else None
    return SearchResult(ged_val, None, best_map, ub, stats)


def ged(
    q: Graph,
    g: Graph,
    bound: str = "BMa",
    strategy: str = "astar",
    expand_all: bool = True,
    order: Optional[np.ndarray] = None,
    deadline=None,
) -> SearchResult:
    """GED computation: ``delta(q, g)`` with the chosen bound/strategy."""
    return _search(q, g, bound=bound, strategy=strategy, tau=None,
                   expand_all=expand_all, order=order, deadline=deadline)


def ged_verify(
    q: Graph,
    g: Graph,
    tau: float,
    bound: str = "BMa",
    strategy: str = "astar",
    expand_all: bool = True,
    order: Optional[np.ndarray] = None,
    deadline=None,
) -> SearchResult:
    """GED verification: is ``delta(q, g) <= tau``? (§5.3)."""
    return _search(q, g, bound=bound, strategy=strategy, tau=float(tau),
                   expand_all=expand_all, order=order, deadline=deadline)
