from repro.data.graphs import (
    random_graph,
    perturb,
    graph_pair_groups,
    aids_like_graph,
)
from repro.data.tokens import synthetic_token_batches, TokenPipeline

__all__ = [
    "random_graph",
    "perturb",
    "graph_pair_groups",
    "aids_like_graph",
    "synthetic_token_batches",
    "TokenPipeline",
]
