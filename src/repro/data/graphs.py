"""Labeled-graph generators mirroring the paper's §6 experimental setup.

* ``random_graph``  — GraphGen-equivalent: |V| vertices, target edge density,
  ``n_vlabels`` vertex labels, ``n_elabels`` edge labels (paper: density 20%,
  5 vertex labels, 2 edge labels).
* ``perturb``       — apply ``x`` random edit operations to a graph (the
  paper builds each synthetic group by perturbing a seed graph).
* ``aids_like_graph`` — sparse molecule-like graphs (tree + few extra edges,
  skewed label distribution) approximating the AIDS dataset statistics.
* ``graph_pair_groups`` — pair sampler grouped by (|V|, GED-perturbation).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.exact.graph import Graph


def random_graph(
    rng: np.random.Generator,
    n: int,
    density: float = 0.2,
    n_vlabels: int = 5,
    n_elabels: int = 2,
) -> Graph:
    vlabels = rng.integers(0, n_vlabels, size=n)
    adj = np.zeros((n, n), dtype=np.int64)
    iu = np.triu_indices(n, k=1)
    present = rng.random(len(iu[0])) < density
    labels = rng.integers(1, n_elabels + 1, size=len(iu[0]))
    vals = np.where(present, labels, 0)
    adj[iu] = vals
    adj = adj + adj.T
    return Graph(vlabels, adj)


def aids_like_graph(
    rng: np.random.Generator,
    n: int,
    n_vlabels: int = 62,
    n_elabels: int = 3,
) -> Graph:
    """Sparse, molecule-like: random spanning tree + ~8% extra edges, Zipfian
    vertex labels (a few heavy atoms dominate, like C/N/O in AIDS)."""
    # Zipf-ish label distribution over n_vlabels
    ranks = np.arange(1, n_vlabels + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    vlabels = rng.choice(n_vlabels, size=n, p=probs)
    adj = np.zeros((n, n), dtype=np.int64)
    for v in range(1, n):
        u = int(rng.integers(0, v))
        a = int(rng.integers(1, n_elabels + 1))
        adj[u, v] = adj[v, u] = a
    extra = max(0, int(0.08 * n))
    for _ in range(extra):
        u, v = rng.integers(0, n, size=2)
        if u != v and adj[u, v] == 0:
            a = int(rng.integers(1, n_elabels + 1))
            adj[u, v] = adj[v, u] = a
    return Graph(vlabels, adj)


def perturb(
    rng: np.random.Generator,
    g: Graph,
    n_ops: int,
    n_vlabels: int = 5,
    n_elabels: int = 2,
) -> Graph:
    """Apply ``n_ops`` random edit operations (paper's group construction).

    Operations: vertex relabel, edge relabel, edge insert, edge delete.
    (Vertex insert/delete changes |V|; the paper's groups keep |V| within
    +-2, we keep it fixed for determinism of the group's nominal GED.)
    """
    g = g.copy()
    n = g.n
    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0 and n > 0:  # vertex relabel
            v = int(rng.integers(0, n))
            old = g.vlabels[v]
            new = int(rng.integers(0, n_vlabels))
            if new == old:
                new = (new + 1) % max(n_vlabels, 2)
            g.vlabels[v] = new
        elif op == 1:  # edge relabel
            ii, jj = np.nonzero(np.triu(g.adj, k=1))
            if len(ii) == 0:
                continue
            k = int(rng.integers(0, len(ii)))
            u, v = int(ii[k]), int(jj[k])
            old = int(g.adj[u, v])
            new = int(rng.integers(1, n_elabels + 1))
            if new == old:
                new = 1 + (new % max(n_elabels, 2))
            g.adj[u, v] = g.adj[v, u] = new
        elif op == 2 and n >= 2:  # edge insert
            for _attempt in range(8):
                u, v = rng.integers(0, n, size=2)
                if u != v and g.adj[u, v] == 0:
                    a = int(rng.integers(1, n_elabels + 1))
                    g.adj[u, v] = g.adj[v, u] = a
                    break
        else:  # edge delete
            ii, jj = np.nonzero(np.triu(g.adj, k=1))
            if len(ii) == 0:
                continue
            k = int(rng.integers(0, len(ii)))
            u, v = int(ii[k]), int(jj[k])
            g.adj[u, v] = g.adj[v, u] = 0
    return g


def graph_pair_groups(
    seed: int,
    sizes: Tuple[int, ...] = (10, 15, 20),
    ops: Tuple[int, ...] = (1, 2, 3, 4, 5),
    pairs_per_group: int = 10,
    density: float = 0.2,
    n_vlabels: int = 5,
    n_elabels: int = 2,
) -> Dict[Tuple[int, int], List[Tuple[Graph, Graph]]]:
    """Paper §6 synthetic setup: per (|V|, x) group, ``pairs_per_group``
    pairs of (seed graph, x-edit perturbation)."""
    rng = np.random.default_rng(seed)
    groups: Dict[Tuple[int, int], List[Tuple[Graph, Graph]]] = {}
    for n in sizes:
        for x in ops:
            pairs = []
            for _ in range(pairs_per_group):
                base = random_graph(rng, n, density, n_vlabels, n_elabels)
                other = perturb(rng, base, x, n_vlabels, n_elabels)
                pairs.append((base, other))
            groups[(n, x)] = pairs
    return groups
