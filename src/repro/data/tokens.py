"""Deterministic synthetic token pipeline for LM training.

Deterministic-by-step: batch ``k`` is a pure function of ``(seed, k)``, so a
restart-from-checkpoint replays the exact same stream (required for the
fault-tolerant loop in ``repro.runtime``).  A background prefetch thread
keeps ``prefetch`` batches ready (host-side overlap with device compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


def _batch_at(seed: int, step: int, batch: int, seq_len: int, vocab: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Markov-ish stream so the loss actually decreases: next token depends on
    # the previous token through a fixed random permutation + noise.
    perm = np.random.default_rng(seed).permutation(vocab)
    toks = np.empty((batch, seq_len + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    noise = rng.random((batch, seq_len))
    rand_tok = rng.integers(0, vocab, size=(batch, seq_len))
    for t in range(seq_len):
        nxt = perm[toks[:, t]]
        toks[:, t + 1] = np.where(noise[:, t] < 0.85, nxt, rand_tok[:, t])
    return toks


def synthetic_token_batches(
    seed: int, batch: int, seq_len: int, vocab: int, start_step: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield ``(tokens, labels)`` of shapes (batch, seq_len)."""
    step = start_step
    while True:
        toks = _batch_at(seed, step, batch, seq_len, vocab)
        yield toks[:, :-1], toks[:, 1:]
        step += 1


class TokenPipeline:
    """Prefetching wrapper with exact resume: ``TokenPipeline(..., start_step=k)``."""

    def __init__(self, seed: int, batch: int, seq_len: int, vocab: int,
                 start_step: int = 0, prefetch: int = 2):
        self._it = synthetic_token_batches(seed, batch, seq_len, vocab, start_step)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        for item in self._it:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        item = self._q.get()
        self.step += 1
        return item

    def __iter__(self) -> "TokenPipeline":
        return self

    def close(self) -> None:
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
