"""``repro.ged`` — the public GED API.

One facade (:class:`GedEngine` / :func:`compute` / :func:`verify`) over
pluggable policy backends (``exact`` host solver, ``jax`` vmap engine,
``pallas`` kernel engine, ``sharded`` mesh-parallel engine, ``auto``
escalation pipeline), with bucketed planning for mixed-size workloads and
a single :class:`GedOutcome` result schema.

Corpus-scale similarity search goes through the same door:
:class:`GraphStore` ingests a graph database once (shared label vocab,
resident stage-0 feature arrays, canonical-digest dedup, a sublinear
:class:`CandidateIndex` — banded WL-sketch LSH plus distance-reuse pivot
pruning) and answers ``range_search`` / ``top_k`` / ``search_batch``
queries via a staged filter-verify pipeline, returning ranked
:class:`SearchHit` results.

Policies ride on the executor layer (:mod:`repro.ged.exec`): an
:class:`Executor` owns device placement, compile caching, packing and
unpacking; :class:`ShardedExecutor` ``shard_map``-s the search over the
device mesh; :class:`PendingBatch` is the async-dispatch future the
overlapped ``auto`` escalation scheduler rides; and an engine-level
:class:`ResultCache` answers duplicate pairs without re-execution (keyed
on exact or Weisfeiler-Leman canonical digests — see :func:`wl_digest`).

Robustness primitives live in :mod:`repro.ged.faults`: the anytime
:class:`Deadline` contract (``GedEngine(deadline_s=...)`` — every pair
answers with admissible best-so-far bounds when the budget expires), the
:class:`RetryPolicy`/degradation ladder under faults, and the
deterministic :class:`FaultInjector` chaos hook — see
``docs/robustness.md``.

The layers underneath (``repro.core.exact``, ``repro.core.engine``,
``repro.serving``) remain importable, but new code — and all future
sharding/caching/async work — should come through this door.

>>> from repro import ged
>>> [o.ged for o in ged.compute([(([0], []), ([1], []))],
...                             backend="exact")]
[1.0]
"""

from repro.ged.api import GedEngine, compute, verify
from repro.ged.backends import (available_backends, make_backend,
                                register_backend)
from repro.ged.exec import (Executor, PendingBatch, ResultCache,
                            ShardedExecutor, SketchSpec, batch_signatures,
                            graph_digest, wl_digest, wl_signature)
from repro.ged.faults import (Deadline, FaultInjector, InjectedFault,
                              Overloaded, RetryPolicy)
from repro.ged.index import CandidateIndex, sketch_damage
from repro.ged.plan import as_graph, build_plan, slot_bucket
from repro.ged.results import GedOutcome, SearchHit
from repro.ged.store import GraphStore

__all__ = [
    "GedEngine",
    "GedOutcome",
    "GraphStore",
    "CandidateIndex",
    "SearchHit",
    "SketchSpec",
    "sketch_damage",
    "wl_signature",
    "batch_signatures",
    "compute",
    "verify",
    "register_backend",
    "available_backends",
    "make_backend",
    "as_graph",
    "build_plan",
    "slot_bucket",
    "Executor",
    "ShardedExecutor",
    "PendingBatch",
    "ResultCache",
    "graph_digest",
    "wl_digest",
    "Deadline",
    "RetryPolicy",
    "FaultInjector",
    "InjectedFault",
    "Overloaded",
]
