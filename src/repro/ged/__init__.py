"""``repro.ged`` — the public GED API.

One facade (:class:`GedEngine` / :func:`compute` / :func:`verify`) over
pluggable backends (``exact`` host solver, ``jax`` vmap engine, ``pallas``
kernel engine, ``auto`` escalation pipeline), with bucketed planning for
mixed-size workloads and a single :class:`GedOutcome` result schema.

The layers underneath (``repro.core.exact``, ``repro.core.engine``,
``repro.serving``) remain importable, but new code — and all future
sharding/caching work — should come through this door.
"""

from repro.ged.api import GedEngine, compute, verify
from repro.ged.backends import (available_backends, make_backend,
                                register_backend)
from repro.ged.plan import as_graph, build_plan, slot_bucket
from repro.ged.results import GedOutcome

__all__ = [
    "GedEngine",
    "GedOutcome",
    "compute",
    "verify",
    "register_backend",
    "available_backends",
    "make_backend",
    "as_graph",
    "build_plan",
    "slot_bucket",
]
