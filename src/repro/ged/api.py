"""The one front door for GED: ``repro.ged.GedEngine``.

    from repro import ged

    outcomes = ged.compute([(q, g), ...])                 # module-level
    engine = ged.GedEngine(backend="jax", pool=512)
    outcomes = engine.verify(pairs, tau=4.0)              # batch
    engine.submit(q, g); engine.submit(q2, g2, tau=3.0)
    outcomes = engine.flush()                             # streaming

Inputs are anything :func:`repro.ged.plan.as_graph` understands (``Graph``
objects, ``(vlabels, edges)`` tuples, adjacency dicts); every entry point
returns :class:`repro.ged.results.GedOutcome` per pair, whichever backend
ran.  Mixed-size workloads are bucketed to power-of-two shapes so the
jitted engine compiles once per bucket, not once per odd batch.

In front of every backend sits an engine-level result cache
(:class:`repro.ged.exec.ResultCache`): queries are keyed on canonical pair
digests (label-vocab-independent; tau-aware for verification), so
duplicate pairs — within one batch or across calls — are answered without
re-planning, re-compiling, or re-executing.  ``GedEngine(cache=False)``
opts out (benchmarks do, to time real work).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine.search import EngineConfig
from repro.kernels.autotune import autotune_stats, enable_autotune
from repro.ged.backends import Backend, make_backend
from repro.ged.exec import (DIGESTS, ResultCache, detached,
                            enable_compile_cache, pair_key,
                            pair_key_from_digests, persistent_cache_stats)
from repro.ged.faults import (Deadline, FaultInjector, RetryPolicy,
                              RunContext)
from repro.ged.plan import Vocab, as_graph, as_pairs, build_plan
from repro.ged.results import GedOutcome

Taus = Union[float, Sequence[float]]

_CONFIG_FIELDS = {f.name for f in dataclasses.fields(EngineConfig)}


class GedEngine:
    """Facade over the pluggable GED backends.

    Parameters
    ----------
    backend : ``"auto"`` (default) | ``"exact"`` | ``"jax"`` | ``"pallas"``
        | ``"sharded"`` or any name registered via
        :func:`repro.ged.register_backend`.
    slots : pin every batch to this slot count instead of per-pair
        power-of-two bucketing (bucketing is the default).
    vocab : optional ``(vertex_labels, edge_labels)`` universe.  Pin it when
        issuing many calls over the same label alphabet so the engine's
        static shapes — and hence its compilations — are stable across
        calls.
    batch_size : scheduler batch size (``auto`` backend only).
    mesh : device mesh for the ``"sharded"`` and ``"auto"`` backends
        (``"sharded"`` defaults to a 1-D mesh over every local device;
        ``"auto"`` runs single-device unless a mesh is given, in which
        case every escalation rung's batches are ``shard_map``-ed over
        it).  Ignored by the other backends.
    overlap : overlapped rung execution (``auto`` backend only, default
        True): batches dispatch asynchronously, decided pairs drain while
        the next rung is in flight, and host-solver pairs run behind
        device work.  ``overlap=False`` is the strictly sequential rung
        loop.  Outcomes are identical either way.
    max_in_flight : how many rung buckets may be dispatched but not yet
        drained at once (``auto`` backend, overlap mode only).
    cache : keep an engine-level result cache (default True): duplicate
        pairs — within one batch or across calls — are answered from the
        cache instead of re-executing.  ``cache_size`` bounds it (LRU).
    shared_cache_dir : directory for the *cross-process* result-cache
        tier (default: the ``REPRO_GED_SHARED_CACHE_DIR`` environment
        variable; unset means off).  An on-disk, file-locked LRU of
        certified scalars (:class:`repro.store_io.SharedResultCache`)
        layered *behind* the in-memory cache: probed on in-memory
        misses (hits are promoted back into memory), written through
        with every certified outcome, shared safely between concurrent
        processes.  Counters appear in :attr:`stats` as
        ``shared_cache_*``.
    compile_cache_dir : directory for jax's *persistent* compilation
        cache (default: the ``REPRO_GED_COMPILE_CACHE_DIR`` environment
        variable; unset means off).  Compiled engine executables are
        serialised there and re-loaded by later processes, so the
        multi-second first-call compile is paid once per machine rather
        than once per process.  Process-global (jax has one cache);
        hit/miss/entry counters appear in :attr:`stats` as
        ``persistent_cache_*``.
    autotune_dir : directory for the measured kernel-tuning table
        (default: the ``REPRO_GED_AUTOTUNE_DIR`` environment variable;
        unset means in-memory only).  ``use_kernel="auto"`` resolves each
        bucket's ``(slots, batch)`` shape to fused/unfused kernels plus
        tuned tile sizes through the table — pre-warm it with
        :func:`repro.kernels.autotune.tune`.  Process-global like the
        compile cache; counters appear in :attr:`stats` as
        ``autotune_*`` alongside ``pallas_interpret`` (True when Pallas
        kernels would run in interpret mode, i.e. timings here are not
        accelerator numbers).
    digest : graph-hash family for the result-cache keys.  ``"exact"``
        (default) keys on byte-identical graphs, so cached mappings stay
        index-compatible; ``"wl"`` keys on Weisfeiler-Leman canonical
        digests, so *isomorphic* duplicates also hit.  ``"wl"`` is a
        deliberate precision trade for duplicate-heavy graph-DB traffic:
        WL refinement is an incomplete isomorphism test, so WL-equivalent
        non-isomorphic pairs (rare outside uniform-label regular graphs)
        alias to one cache entry and the second pair is answered with the
        first pair's distance.  Cache copies also drop their vertex
        mappings.  :class:`repro.ged.GraphStore` gets the same hit-rate
        win soundly instead — WL dedup confirmed by certified
        zero-distance checks at ingest — and keeps its engine on
        ``"exact"``.
    deadline_s : wall-clock budget per ``compute``/``verify``/``flush``
        call (default ``None`` = unbounded, bit-identical to an engine
        without the robustness layer).  On expiry, in-flight device work
        drains, remaining rungs are skipped, and *every* pair still
        returns a :class:`GedOutcome` carrying best-so-far admissible
        ``lower_bound``/``upper_bound`` with ``certified=False`` and
        ``timed_out`` set — never an exception, never a missing result.
        Each entry point takes a per-call override.  See
        ``docs/robustness.md``.
    per_pair_deadline_s : additional per-pair budget for host-solver
        searches (cooperative check inside the search loop), capped by
        whatever remains of ``deadline_s``.
    fault_inject : deterministic fault spec (string for
        :class:`repro.ged.faults.FaultInjector`, or an injector
        instance) scoped to this engine; the ``REPRO_GED_FAULT_INJECT``
        environment variable injects process-wide instead.
    retry : :class:`repro.ged.faults.RetryPolicy` for transient dispatch
        failures (default: 2 retries, exponential backoff + jitter).
    Remaining keyword arguments (``pool``, ``expand``, ``max_iters``,
    ``sweeps``, ``bound``, ``strategy``, ``use_kernel``) override
    :class:`EngineConfig` defaults.  ``use_kernel`` is implied by the
    ``"jax"``/``"sharded"`` (False) and ``"pallas"`` (True) backend names —
    passing a contradicting boolean there raises, while
    ``use_kernel="auto"`` is accepted on *every* backend: it defers the
    fused/unfused choice to the measured per-bucket dispatch
    (:mod:`repro.kernels.autotune`), which never changes outcomes, only
    which bit-identical implementation runs.

    Examples
    --------
    >>> from repro import ged
    >>> q = ([0, 1], [(0, 1, 1)])           # (vlabels, edges) adapter form
    >>> g = ([0, 2], [(0, 1, 1)])
    >>> eng = ged.GedEngine("exact")
    >>> [o.ged for o in eng.compute([(q, g)])]
    [1.0]
    >>> [o.similar for o in eng.verify([(q, g)], tau=1.0)]
    [True]

    The anytime deadline contract — an exhausted budget still answers
    every pair, with sound (here: cheap stage-0-style) bounds:

    >>> eng = ged.GedEngine("exact", deadline_s=0.0)    # expires on arrival
    >>> out, = eng.compute([(q, g)])
    >>> out.timed_out, out.certified, out.lower_bound, out.upper_bound
    (True, False, 1.0, inf)
    >>> out, = eng.compute([(q, g)], deadline_s=60.0)   # per-call override
    >>> out.ged, out.certified
    (1.0, True)

    Deterministic fault injection — an injected host-solver failure
    degrades (uncertified, admissible bounds), never errors:

    >>> eng = ged.GedEngine("exact", fault_inject="host@times=1")
    >>> out, = eng.compute([(q, g)])
    >>> out.degraded, out.certified, out.lower_bound <= 1.0
    (True, False, True)
    """

    def __init__(self, backend: str = "auto", *,
                 slots: Optional[int] = None,
                 vocab: Optional[Vocab] = None,
                 batch_size: int = 256,
                 mesh=None,
                 overlap: bool = True,
                 max_in_flight: int = 4,
                 cache: bool = True,
                 cache_size: int = 4096,
                 shared_cache_dir: Optional[str] = None,
                 compile_cache_dir: Optional[str] = None,
                 autotune_dir: Optional[str] = None,
                 digest: str = "exact",
                 deadline_s: Optional[float] = None,
                 per_pair_deadline_s: Optional[float] = None,
                 fault_inject: Union[None, str, FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 config: Optional[EngineConfig] = None,
                 **config_overrides):
        unknown = set(config_overrides) - _CONFIG_FIELDS
        if unknown:
            raise TypeError(f"unknown GedEngine options: {sorted(unknown)}")
        self.deadline_s = deadline_s
        self.per_pair_deadline_s = per_pair_deadline_s
        self._injector = (FaultInjector(fault_inject)
                          if isinstance(fault_inject, str)
                          else fault_inject)
        self._retry = retry if retry is not None else RetryPolicy()
        self._fault_stats: Dict[str, float] = {}
        if digest not in DIGESTS:
            raise ValueError(f"unknown digest {digest!r}; "
                             f"expected one of {sorted(DIGESTS)}")
        self.digest = digest
        self.compile_cache_dir = enable_compile_cache(compile_cache_dir)
        self.autotune_dir = enable_autotune(autotune_dir)
        if config is None:
            config = EngineConfig(**{"use_kernel": False, **config_overrides})
        elif config_overrides:
            config = dataclasses.replace(config, **config_overrides)
        self.slots = slots
        self.vocab = vocab
        self._cache = ResultCache(cache_size) if cache else None
        self._shared = None
        if shared_cache_dir is None:
            # repro.store_io.shared_cache.SHARED_CACHE_ENV; lazily
            # imported below so the leaf modules stay cycle-free
            shared_cache_dir = os.environ.get(
                "REPRO_GED_SHARED_CACHE_DIR") or None
        if shared_cache_dir:
            from repro.store_io.shared_cache import SharedResultCache
            self._shared = SharedResultCache(str(shared_cache_dir))
        self.shared_cache_dir = shared_cache_dir
        self._backend: Backend = make_backend(
            backend, batch_size=batch_size, mesh=mesh, overlap=overlap,
            max_in_flight=max_in_flight)
        self.backend = self._backend.name
        # "jax" means pure-jnp and "pallas" means kernels; default the flag
        # from the backend name and refuse a contradicting boolean.
        # "auto" is welcome everywhere: measured dispatch picks among
        # bit-identical implementations, so it cannot contradict what a
        # backend name promises about outcomes.
        self._kernel_default = getattr(self._backend, "kernel_default", None)
        if self._kernel_default is not None:
            asked = config_overrides.get("use_kernel")
            if asked == "auto":
                pass
            elif asked is not None and asked != self._kernel_default:
                raise ValueError(
                    f"backend {backend!r} implies use_kernel="
                    f"{self._kernel_default}; use the "
                    f"{'pallas' if asked else 'jax'!r} backend instead")
            else:
                config = dataclasses.replace(config,
                                             use_kernel=self._kernel_default)
        self.config = config
        # backends registered before the robustness layer may not take
        # ``ctx``; only pass it when the run() signature names it
        import inspect
        try:
            self._backend_takes_ctx = "ctx" in inspect.signature(
                self._backend.run).parameters
        except (TypeError, ValueError):            # pragma: no cover
            self._backend_takes_ctx = False
        self._pending: List[Tuple[object, object, Optional[float]]] = []

    # ------------------------------------------------------------ batch

    def compute(self, pairs, vocab: Optional[Vocab] = None,
                deadline_s: Union[None, float, Deadline] = None,
                per_pair_deadline_s: Optional[float] = None,
                **config_overrides) -> List[GedOutcome]:
        """Exact-with-certificate GED for every pair.

        ``vocab`` overrides the engine's label universe for this call
        only (callers with a known corpus vocabulary — e.g.
        :class:`repro.ged.GraphStore` — keep compile keys stable without
        mutating shared engine state).  ``deadline_s`` /
        ``per_pair_deadline_s`` override the engine-level budgets for
        this call (anytime contract: an expired budget yields
        uncertified best-so-far bounds, never an exception).

        >>> from repro import ged
        >>> outs = ged.GedEngine("exact").compute(
        ...     [(([0], []), ([0], []))])           # identical graphs
        >>> outs[0].ged, outs[0].certified
        (0.0, True)
        """
        return self._run(pairs, None, verification=False,
                         overrides=config_overrides, vocab=vocab,
                         deadline_s=deadline_s,
                         per_pair_deadline_s=per_pair_deadline_s)

    def verify(self, pairs, tau: Taus, vocab: Optional[Vocab] = None,
               deadline_s: Union[None, float, Deadline] = None,
               per_pair_deadline_s: Optional[float] = None,
               **config_overrides) -> List[GedOutcome]:
        """Certified ``delta(q, g) <= tau``? for every pair.

        ``tau`` is a scalar (broadcast) or one threshold per pair;
        ``vocab`` is a per-call label-universe override (see
        :meth:`compute`); ``deadline_s`` / ``per_pair_deadline_s`` are
        the per-call anytime budgets (see :meth:`compute`).

        >>> from repro import ged
        >>> pair = (([0], []), ([1], []))           # distance 1
        >>> [o.similar for o in ged.GedEngine("exact").verify(
        ...     [pair, pair], tau=[0.5, 1.5])]
        [False, True]
        """
        return self._run(pairs, tau, verification=True,
                         overrides=config_overrides, vocab=vocab,
                         deadline_s=deadline_s,
                         per_pair_deadline_s=per_pair_deadline_s)

    # -------------------------------------------------------- streaming

    def submit(self, q, g, tau: Optional[float] = None) -> int:
        """Enqueue one pair (verification when ``tau`` is given, otherwise
        computation); returns its ticket — the index into ``flush()``'s
        result list.

        >>> from repro import ged
        >>> eng = ged.GedEngine("exact")
        >>> eng.submit(([0], []), ([1], []))        # computation
        0
        >>> eng.submit(([0], []), ([0], []), tau=0.5)   # verification
        1
        >>> [(o.ged, o.similar) for o in eng.flush()]
        [(1.0, None), (None, True)]
        """
        self._pending.append((q, g, None if tau is None else float(tau)))
        return len(self._pending) - 1

    def flush(self, deadline_s: Union[None, float, Deadline] = None,
              per_pair_deadline_s: Optional[float] = None
              ) -> List[GedOutcome]:
        """Answer every submitted pair, in submission order.

        Mixed computation/verification submissions come back as one list
        aligned with the tickets :meth:`submit` returned (see the example
        there); a drained engine flushes to ``[]``.  ``deadline_s`` is
        one shared budget for the whole flush (the computation and
        verification sub-batches draw from the same clock).
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        dl = deadline_s if deadline_s is not None else self.deadline_s
        # one Deadline for both sub-batches, so a flush-level budget is a
        # single clock, not one-per-mode
        shared = dl if isinstance(dl, Deadline) or dl is None \
            else Deadline(dl)
        results: List[Optional[GedOutcome]] = [None] * len(pending)
        comp = [i for i, (_, _, tau) in enumerate(pending) if tau is None]
        veri = [i for i, (_, _, tau) in enumerate(pending) if tau is not None]
        if comp:
            outs = self.compute([(pending[i][0], pending[i][1])
                                 for i in comp], deadline_s=shared,
                                per_pair_deadline_s=per_pair_deadline_s)
            for i, o in zip(comp, outs):
                results[i] = o
        if veri:
            outs = self.verify([(pending[i][0], pending[i][1])
                                for i in veri],
                               [pending[i][2] for i in veri],
                               deadline_s=shared,
                               per_pair_deadline_s=per_pair_deadline_s)
            for i, o in zip(veri, outs):
                results[i] = o
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------ stats

    @property
    def batch_multiple(self) -> int:
        """Shard count every batch is padded to (1 on a single device).

        >>> from repro import ged
        >>> ged.GedEngine("jax").batch_multiple
        1
        """
        return getattr(self._backend, "batch_multiple", 1)

    @property
    def stats(self) -> Dict[str, float]:
        """Backend + executor counters plus cache hit/miss totals.

        Per backend: the ``auto`` pipeline reports ``pairs`` /
        ``escalated`` / ``host_solved`` / ``batches`` / ``dispatches``,
        per-rung survivor counts (``survivors_rung_0``, ...) and
        ``overlap_saved_s`` — device seconds hidden behind host-solver
        and drain work by overlapped rung execution.  Every engine adds
        ``executor_*``, ``compile_cache_*`` and ``result_cache_*``
        counters where applicable, plus the kernel-dispatch telemetry:
        ``autotune_hits`` / ``autotune_misses`` / ``autotune_sweep_s`` /
        ``autotune_entries`` and ``pallas_interpret`` (True when Pallas
        kernels fall back to interpret mode — CPU — so bench rows cannot
        masquerade as accelerator numbers).  Robustness counters
        (``retries``, ``degraded_kernel``, ``degraded_host``,
        ``fault_*``, ``timed_out_pairs``,
        ``shared_cache_lock_timeouts``) appear once the corresponding
        event has happened — see ``docs/robustness.md``.

        >>> from repro import ged
        >>> eng = ged.GedEngine("exact")
        >>> _ = eng.compute([(([0], []), ([1], []))])
        >>> eng.stats["result_cache_misses"]
        1
        """
        out: Dict[str, float] = dict(getattr(self._backend, "stats", {}))
        executor = getattr(self._backend, "executor", None)
        if executor is not None:
            out.update({f"executor_{k}": v
                        for k, v in executor.stats.items()})
        cache = getattr(self._backend, "cache", None)
        if cache is not None:
            out["compile_cache_hits"] = cache.stats.hits
            out["compile_cache_misses"] = cache.stats.misses
        if self._cache is not None:
            out["result_cache_hits"] = self._cache.hits
            out["result_cache_misses"] = self._cache.misses
            out["result_cache_entries"] = len(self._cache)
            out["index_pivot_hits"] = self._cache.pivot_hits
            out["index_pivot_misses"] = self._cache.pivot_misses
        if self._shared is not None:
            out["shared_cache_hits"] = self._shared.hits
            out["shared_cache_misses"] = self._shared.misses
            out["shared_cache_evictions"] = self._shared.evictions
            out["shared_cache_entries"] = self._shared.entries()
            out["shared_cache_lock_timeouts"] = self._shared.lock_timeouts
        # robustness counters accumulated across runs (retries, degraded_*,
        # fault_*, timed_out_pairs) — absent keys mean nothing happened
        out.update(self._fault_stats)
        out.update(persistent_cache_stats())
        out.update(autotune_stats())
        return out

    def cached_distance(self, q=None, g=None, *,
                        digests: Optional[Tuple[bytes, bytes]] = None
                        ) -> Optional[float]:
        """A certified exact distance for one pair straight from the result
        cache — no planning, no execution, ``None`` on a miss.

        This is the distance-reuse hook :class:`repro.ged.CandidateIndex`
        prunes through: DB–DB distances that earlier traffic (top-k walks,
        pivot probes, ingest seeding) left in the cache are read back by
        digest and fed into the triangle bound
        ``|d(q,p) - d(p,y)| <= d(q,y)``.  Pass ``digests=(dq, dg)`` when
        the graphs are already hashed (the index pre-digests its corpus);
        both orientations of the pair are probed.  Only *certified
        computation* entries answer — verification entries carry no exact
        distance, uncertified ones no guarantee — and only the scalar
        comes back, never the cached outcome (so a WL-aliased entry's
        dropped mapping stays dropped).  Lookups count into
        ``stats["index_pivot_hits"]`` / ``["index_pivot_misses"]``, not
        the query-path ``result_cache_*`` totals.

        >>> from repro import ged
        >>> eng = ged.GedEngine("exact")
        >>> a, b = ([0], []), ([1], [])
        >>> eng.cached_distance(a, b) is None       # nothing cached yet
        True
        >>> _ = eng.compute([(a, b)])
        >>> eng.cached_distance(b, a)               # either orientation
        1.0
        """
        if self._cache is None:
            return None
        if digests is None:
            fn = DIGESTS[self.digest]
            digests = (fn(as_graph(q)), fn(as_graph(g)))
        for dq, dg in (digests, digests[::-1]):
            key = pair_key_from_digests(dq, dg, False, None, self.config,
                                        self.backend, digest=self.digest)
            out = self._cache.peek(key)
            if out is not None and out.certified and out.ged is not None:
                self._cache.pivot_hits += 1
                return float(out.ged)
        self._cache.pivot_misses += 1
        return None

    # --------------------------------------------------------- internal

    def _run(self, pairs, tau: Optional[Taus], verification: bool,
             overrides: dict,
             vocab: Optional[Vocab] = None,
             deadline_s: Union[None, float, Deadline] = None,
             per_pair_deadline_s: Optional[float] = None
             ) -> List[GedOutcome]:
        unknown = set(overrides) - _CONFIG_FIELDS
        if unknown:
            raise TypeError(f"unknown engine options: {sorted(unknown)}")
        asked = overrides.get("use_kernel")
        if (asked is not None and asked != "auto"
                and self._kernel_default is not None
                and asked != self._kernel_default):
            raise ValueError(
                f"backend {self.backend!r} implies use_kernel="
                f"{self._kernel_default}")
        cfg = dataclasses.replace(self.config, **overrides) \
            if overrides else self.config
        pairs = as_pairs(pairs)
        n = len(pairs)
        if verification:
            taus = np.broadcast_to(
                np.asarray(tau, dtype=np.float32), (n,)).copy()
        else:
            taus = np.zeros((n,), dtype=np.float32)

        results: List[Optional[GedOutcome]] = [None] * n
        run_idx = list(range(n))
        keys: List[Optional[tuple]] = [None] * n
        dup_of: Dict[int, int] = {}
        if self._cache is not None or self._shared is not None:
            run_idx, seen = [], {}
            for i, (q, g) in enumerate(pairs):
                keys[i] = pair_key(
                    q, g, verification,
                    float(taus[i]) if verification else None, cfg,
                    self.backend, digest=self.digest)
                if keys[i] in seen:
                    # duplicate within this batch: runs once, answers twice
                    dup_of[i] = seen[keys[i]]
                    if self._cache is not None:
                        self._cache.hits += 1
                    continue
                hit = self._cache.get(keys[i]) \
                    if self._cache is not None else None
                if hit is None and self._shared is not None:
                    # the cross-process tier answers in-memory misses;
                    # promote hits so this process stops paying disk
                    hit = self._shared.get(keys[i])
                    if hit is not None and self._cache is not None:
                        self._cache.put(keys[i], self._cache_view(hit))
                if hit is not None:
                    results[i] = hit
                else:
                    seen[keys[i]] = i
                    run_idx.append(i)

        if run_idx:
            plan = build_plan(
                [pairs[i] for i in run_idx], slots=self.slots,
                vocab=vocab if vocab is not None else self.vocab,
                batch_multiple=self.batch_multiple)
            dl = deadline_s if deadline_s is not None else self.deadline_s
            pp = (per_pair_deadline_s if per_pair_deadline_s is not None
                  else self.per_pair_deadline_s)
            ctx = RunContext(
                deadline=dl if isinstance(dl, Deadline) else Deadline(dl),
                per_pair_deadline_s=pp,
                injector=self._injector, retry=self._retry)
            if self._backend_takes_ctx:
                outs = self._backend.run(plan, taus[run_idx], verification,
                                         cfg, ctx=ctx)
            else:
                outs = self._backend.run(plan, taus[run_idx], verification,
                                         cfg)
            for k, v in ctx.stats.items():
                self._fault_stats[k] = self._fault_stats.get(k, 0) + v
            for i, o in zip(run_idx, outs):
                results[i] = o
                # never cache a timed-out or fault-degraded *uncertified*
                # answer: a later, unconstrained run must not be poisoned
                # by this run's budget or faults (degraded-but-certified
                # answers are bit-identical, so they stay cacheable)
                if o.timed_out or (not o.certified
                                   and o.stats.get("degraded")):
                    continue
                if self._cache is not None:
                    self._cache.put(keys[i], self._cache_view(o))
                if self._shared is not None:
                    self._shared.put(keys[i], o)   # certified-only inside
        for i, j in dup_of.items():
            # a distinct outcome per position, so mutating one entry
            # cannot leak into its duplicates (or the cache)
            results[i] = detached(self._cache_view(results[j]),
                                  {**results[j].stats, "cached": True})
        return results  # type: ignore[return-value]

    def _cache_view(self, outcome: GedOutcome) -> GedOutcome:
        """What a cache (or in-batch duplicate) may reuse of ``outcome``.

        Exact digests key byte-identical graphs, so everything is
        reusable; WL digests key isomorphism classes, so the vertex
        mapping — index-valid only for the graph that produced it — is
        dropped from what duplicates see.
        """
        if self.digest == "exact" or outcome.mapping is None:
            return outcome
        return dataclasses.replace(outcome, mapping=None)


# ------------------------------------------------- module-level helpers

def compute(pairs, backend: str = "auto", **options) -> List[GedOutcome]:
    """One-shot :meth:`GedEngine.compute` with a throwaway engine.

    Compiled executables persist in the process-wide jit cache, so repeated
    module-level calls stay cheap; hold a :class:`GedEngine` to accumulate
    stats or stream with ``submit``/``flush``.

    >>> from repro import ged
    >>> [o.ged for o in ged.compute([(([0], []), ([1], []))],
    ...                             backend="exact")]
    [1.0]
    """
    return GedEngine(backend, **options).compute(pairs)


def verify(pairs, tau: Taus, backend: str = "auto",
           **options) -> List[GedOutcome]:
    """One-shot :meth:`GedEngine.verify` with a throwaway engine.

    >>> from repro import ged
    >>> [o.similar for o in ged.verify([(([0], []), ([1], []))], tau=2.0,
    ...                                backend="exact")]
    [True]
    """
    return GedEngine(backend, **options).verify(pairs, tau)
