"""Pluggable *policy* backends behind the ``repro.ged`` facade.

Every backend implements one protocol — ``run(plan, taus, verification,
cfg) -> List[GedOutcome]`` — over the bucketed :class:`repro.ged.plan.Plan`.
Backends decide *what* runs (which rungs, which bounds, when to escalate);
*how* a bucket reaches a device — placement, jit/compile caching, packing,
unpacking — is the executor layer's job (:mod:`repro.ged.exec`), so a
policy composes with any placement:

* ``"exact"``   — the paper-faithful host solver (AStar+/DFS+ with BMa),
  one pair at a time.  Always certified; produces mappings.
* ``"jax"``     — the batched vmap engine, one jit call per shape bucket,
  compile-cache aware.  Pure-jnp bound math (``use_kernel=False``).
* ``"pallas"``  — same engine with the Pallas kernels enabled on the hot
  path (interpret mode on CPU, real kernels on TPU).
* ``"sharded"`` — same policy as ``"jax"`` on a
  :class:`~repro.ged.exec.ShardedExecutor`: the pair batch ``shard_map``-ed
  over the device mesh, buckets padded to shard multiples.
* ``"auto"``    — the production pipeline: difficulty prediction, LPT
  batch packing, escalation through growing engine rungs, host-solver
  final rung.  Every answer it returns is certified.  Rungs execute
  *overlapped* by default (async dispatch; while rung *k* is in flight,
  decided pairs drain into results, survivors re-bucket for rung *k+1*,
  and host-solver pairs run behind the device work), and the policy rides
  any executor — ``GedEngine(backend="auto", mesh=...)`` runs every rung
  ``shard_map``-ed over the mesh.

New backends (remote, multi-host, ...) register with
:func:`register_backend` and become constructible via
``GedEngine(backend="name")`` with no facade changes.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Protocol

import numpy as np

from repro.core.engine.search import EngineConfig
from repro.core.exact.search import ged as exact_ged
from repro.core.exact.search import ged_verify
from repro.ged.exec import (Executor, PendingBatch, ShardedExecutor,
                            engine_outcome)
from repro.ged.plan import Bucket, Plan
from repro.ged.results import GedOutcome
from repro.runtime.scheduler import Batch, GedScheduler, difficulty


class Backend(Protocol):
    """What the facade requires of an execution-policy backend.

    A minimal conforming implementation (see
    :func:`register_backend` to plug one in)::

        class EchoBackend:
            name = "echo"
            kernel_default = None

            def run(self, plan, taus, verification, cfg):
                return [some_outcome(q, g) for q, g in plan.pairs]
    """

    name: str
    # What ``EngineConfig.use_kernel`` must be for this backend; ``None``
    # means the backend honors whatever the config says.  ``GedEngine``
    # applies the default and rejects contradicting user settings.
    kernel_default: Optional[bool]

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig) -> List[GedOutcome]:
        """Answer every pair in ``plan`` (in order).  ``taus`` is aligned
        with ``plan.pairs`` (zeros in computation mode).

        A backend may additionally accept ``ctx`` (keyword,
        :class:`repro.ged.faults.RunContext`) to honor deadlines and the
        fault-injection/retry machinery; the facade only passes it when
        the signature names it, so third-party backends registered before
        the robustness layer keep working unchanged.
        """
        ...


# ----------------------------------------------------------- host solver

class ExactBackend:
    """Paper-faithful host solver: always certified, yields mappings.

    >>> import numpy as np
    >>> from repro.core.engine.search import EngineConfig
    >>> from repro.ged.plan import build_plan
    >>> plan = build_plan([(([0], []), ([1], []))])   # 1-vertex relabel
    >>> out, = ExactBackend().run(plan, np.zeros(1, np.float32), False,
    ...                           EngineConfig())
    >>> out.ged, out.certified
    (1.0, True)
    """

    name = "exact"
    kernel_default = None  # host solver: kernels irrelevant
    batch_multiple = 1     # host solver: no device batch shape to satisfy

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig, ctx=None) -> List[GedOutcome]:
        from repro.ged import faults as _faults
        outcomes: List[GedOutcome] = []
        for i, (q, g) in enumerate(plan.pairs):
            tau = float(taus[i]) if verification else None
            if ctx is not None and ctx.expired():
                # budget already spent: cheap admissible floor, no search
                ctx.bump("timed_out_pairs")
                outcomes.append(_faults.fallback_outcome(
                    q, g, verification, tau, self.name,
                    stats={"rung": 0}))
                continue
            outcomes.append(_robust_host_solve(
                q, g, tau, verification, cfg, self.name, 0, ctx))
        return outcomes


def _host_compute_outcome(res, backend: str, wall_s: float,
                          rung: int = 0) -> GedOutcome:
    ged = float(res.ged)
    return GedOutcome(ged=ged, similar=None, certified=True,
                      lower_bound=ged, upper_bound=ged,
                      mapping=res.best_mapping, backend=backend,
                      wall_s=wall_s, stats={"rung": rung,
                                            "expanded": res.stats.expanded})


def _host_verify_outcome(res, tau: float, backend: str, wall_s: float,
                         rung: int = 0) -> GedOutcome:
    similar = bool(res.similar)
    return GedOutcome(
        ged=None, similar=similar, certified=True,
        lower_bound=0.0 if similar else float(np.nextafter(tau, np.inf)),
        upper_bound=float(res.upper_bound) if similar else float("inf"),
        mapping=res.best_mapping if similar else None,
        backend=backend, wall_s=wall_s, tau=tau,
        stats={"rung": rung, "expanded": res.stats.expanded})


def _robust_host_solve(q, g, tau: Optional[float], verification: bool,
                       cfg: EngineConfig, backend: str, rung: int,
                       ctx=None) -> GedOutcome:
    """One host-solver pair under the robustness context.

    ``ctx=None`` is exactly the legacy certified path.  With a context:
    the pair runs under :meth:`RunContext.pair_deadline` (cooperative
    check inside the search loop); a timed-out search becomes a sound
    uncertified best-so-far outcome; the ``host`` fault-injection site
    simulates a solver failure, which — since the host solver is the
    ladder's last step — degrades to the cheap admissible floor.
    """
    from repro.ged import faults as _faults

    t0 = time.perf_counter()
    inj = _faults.get_injector(ctx)
    if inj is not None:
        try:
            inj.check("host", rung)
        except Exception:
            if ctx is not None:
                ctx.bump("fault_host")
            _faults.warn_once(
                "host-fault",
                "host solver failed (injected or real); answering from "
                "the cheap admissible floor, uncertified")
            out = _faults.fallback_outcome(
                q, g, verification, tau, backend, timed_out=False,
                stats={"rung": rung, "degraded": True})
            out.wall_s = time.perf_counter() - t0
            return out
    deadline = None
    if ctx is not None and (ctx.has_deadline
                            or ctx.per_pair_deadline_s is not None):
        deadline = ctx.pair_deadline()
    if verification:
        res = ged_verify(q, g, float(tau), bound="BMa",
                         strategy=cfg.strategy, deadline=deadline)
    else:
        res = exact_ged(q, g, bound="BMa", strategy=cfg.strategy,
                        deadline=deadline)
    wall = time.perf_counter() - t0
    if getattr(res, "timed_out", False):
        if ctx is not None:
            ctx.bump("timed_out_pairs")
        out = _faults.fallback_outcome(
            q, g, verification, tau, backend,
            lower_bound=res.lower_bound, upper_bound=res.upper_bound,
            stats={"rung": rung, "expanded": res.stats.expanded})
        out.wall_s = wall
        return out
    if verification:
        return _host_verify_outcome(res, float(tau), backend, wall,
                                    rung=rung)
    return _host_compute_outcome(res, backend, wall, rung=rung)


# --------------------------------------------------------- batched engine

class EngineBackend:
    """Bucket-at-a-time policy over an :class:`~repro.ged.exec.Executor`.

    ``cfg.use_kernel`` is taken as-is — ``GedEngine`` defaults it per
    backend name (``jax``/``sharded`` -> False, ``pallas`` -> True) and
    rejects contradictions, so the flag always matches what the user asked
    for.

    Example (normally reached through the facade)::

        eng = ged.GedEngine("jax", pool=512)
        outs = eng.compute(pairs)       # one jit call per shape bucket
    """

    name = "jax"
    kernel_default = False

    def __init__(self, executor: Optional[Executor] = None) -> None:
        self.executor = executor or Executor()

    @property
    def cache(self):
        return self.executor.cache

    @property
    def batch_multiple(self) -> int:
        return self.executor.batch_multiple

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig, ctx=None) -> List[GedOutcome]:
        from repro.ged import faults as _faults
        results: List[Optional[GedOutcome]] = [None] * len(plan.pairs)
        for bucket in plan.buckets:
            t0 = time.perf_counter()
            if ctx is not None and ctx.expired():
                # deadline gone: remaining buckets answer from the cheap
                # admissible floor (bucket granularity — one dispatch is
                # the engine's unit of work)
                for gi in bucket.indices:
                    ctx.bump("timed_out_pairs")
                    q, g = plan.pairs[gi]
                    results[gi] = _faults.fallback_outcome(
                        q, g, verification,
                        float(taus[gi]) if verification else None,
                        self.name, stats={"rung": 0})
                continue
            try:
                pending = self.executor.run_bucket_async(
                    bucket, taus, cfg, verification, ctx=ctx, rung=0)
                out = pending.result()
            except Exception as exc:
                # the engine rung is permanently gone for this bucket
                # (kernel AND unfused dispatch failed): the degradation
                # ladder's last step is the certified host solver
                _faults.warn_once(
                    f"degrade-host-{self.name}",
                    f"{self.name} backend: engine bucket failed "
                    f"({exc!r}); degrading its pairs to the host solver")
                for gi in bucket.indices:
                    if ctx is not None:
                        ctx.bump("degraded_host")
                    q, g = plan.pairs[gi]
                    o = _robust_host_solve(
                        q, g, float(taus[gi]) if verification else None,
                        verification, cfg, self.name, 0, ctx)
                    o.stats["degraded"] = True
                    results[gi] = o
                continue
            wall = time.perf_counter() - t0
            for bi, gi in enumerate(bucket.indices):
                o = engine_outcome(
                    out, bucket.packed, bi, verification,
                    float(taus[gi]) if verification else None,
                    self.name, wall, rung=0)
                if pending.flags:
                    o.stats.update(pending.flags)
                results[gi] = o
        return results  # type: ignore[return-value]


class PallasBackend(EngineBackend):
    """Engine policy with Pallas kernels on the hot path.

    Interpret mode on CPU, real kernels on TPU — same policy, same
    outcomes as ``"jax"``::

        outs = ged.GedEngine("pallas").compute(pairs)
    """

    name = "pallas"
    kernel_default = True


class ShardedBackend(EngineBackend):
    """Engine policy on a mesh-sharded executor (``shard_map`` over pairs).

    Identical policy (and therefore identical outcomes) to ``"jax"``; only
    the placement differs.  ``mesh`` defaults to a 1-D mesh over every
    local device.  Example::

        mesh = jax.make_mesh((8,), ("data",))
        outs = ged.GedEngine("sharded", mesh=mesh).verify(pairs, 4.0)
    """

    name = "sharded"
    kernel_default = False

    def __init__(self, mesh=None) -> None:
        super().__init__(ShardedExecutor(mesh))


# ------------------------------------------------------------ escalation

@dataclasses.dataclass
class _InFlight:
    """One dispatched rung bucket awaiting its device results."""
    bucket: Bucket
    rung: int
    pending: PendingBatch
    t_dispatch: float


class AutoBackend:
    """Difficulty-scheduled escalation: engine rungs, then the host solver.

    This is the serving pipeline (previously private to
    ``GedVerificationService``): predict per-pair difficulty, LPT-pack
    equalised batches, run the batched engine, and re-queue uncertified
    pairs through bigger-pool rungs down to the exact host solver — so
    every answer is certified.

    Rung execution is *overlapped* by default: batches are dispatched
    asynchronously (JAX async dispatch, up to ``max_in_flight`` at once),
    and while rung *k* is still crunching on the device the scheduler
    drains rung *k-1*'s finished batches — decided pairs become outcomes,
    survivors are re-bucketed (:meth:`repro.ged.plan.Plan.subset_buckets`)
    and queued for rung *k+1* — and chews final-rung host-solver pairs,
    which run on the Python side and therefore hide entirely behind
    in-flight device work.  ``overlap=False`` restores the strictly
    sequential rung loop (the benchmark baseline).

    The policy composes with any executor: the default is a local
    single-device :class:`~repro.ged.exec.Executor`; pass ``mesh=`` (or an
    explicit ``executor=``) to run every rung's batches ``shard_map``-ed
    over the device mesh via :class:`~repro.ged.exec.ShardedExecutor` —
    that is what ``GedEngine(backend="auto", mesh=...)`` constructs.
    Outcomes are identical whatever the placement or overlap setting; only
    the wall-clock changes.

    Example::

        eng = ged.GedEngine("auto", mesh=jax.make_mesh((8,), ("data",)),
                            max_in_flight=4)
        outs = eng.verify(pairs, tau=4.0)       # certified, mesh-sharded
        eng.stats["overlap_saved_s"]            # device time hidden
    """

    name = "auto"
    kernel_default = None  # honors cfg.use_kernel on the engine rungs

    def __init__(self, batch_size: int = 256,
                 executor: Optional[Executor] = None,
                 mesh=None, overlap: bool = True, max_in_flight: int = 4):
        if executor is None:
            executor = ShardedExecutor(mesh) if mesh is not None \
                else Executor()
        self.scheduler = GedScheduler(batch_size)
        self.executor = executor
        self.overlap = bool(overlap)
        self.max_in_flight = max(1, int(max_in_flight))
        self.stats: Dict[str, float] = {"pairs": 0, "escalated": 0,
                                        "host_solved": 0, "batches": 0,
                                        "dispatches": 0,
                                        "overlap_saved_s": 0.0}

    @property
    def cache(self):
        return self.executor.cache

    @property
    def batch_multiple(self) -> int:
        return self.executor.batch_multiple

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig, ctx=None) -> List[GedOutcome]:
        from repro.ged import faults as _faults
        results: List[Optional[GedOutcome]] = [None] * len(plan.pairs)
        diffs = [difficulty(q.n, g.n, q.m, g.m, q.vlabels, g.vlabels,
                            tau=float(taus[i]) if verification else None)
                 for i, (q, g) in enumerate(plan.pairs)]
        queue = self.scheduler.pack(diffs, rung=0)
        self.stats["pairs"] += len(plan.pairs)
        host_queue: List[int] = []          # pairs awaiting the final rung
        dispatchable: "collections.deque" = collections.deque()  # (bucket, rung)
        inflight: "collections.deque[_InFlight]" = collections.deque()
        last_block_end: Optional[float] = None  # end of last blocking drain
        has_deadline = ctx is not None and ctx.has_deadline
        # Best-so-far admissible bounds per surviving pair, merged across
        # rungs (anytime contract) — maintained only under a deadline so
        # the no-deadline path does zero extra work.
        best: Dict[int, tuple] = {}
        degraded: set = set()               # pairs routed around a fault

        def merge_best(gi: int, lb: float, ub: float) -> None:
            plb, pub = best.get(gi, (0.0, float("inf")))
            best[gi] = (max(plb, lb), min(pub, ub))

        def solve_host(gi: int) -> None:
            # final rung: exact host solver (paper-faithful AStar+-BMa)
            q, g = plan.pairs[gi]
            self.stats["host_solved"] += 1
            o = _robust_host_solve(
                q, g, float(taus[gi]) if verification else None,
                verification, cfg, f"{self.name}/exact", -1, ctx)
            if gi in degraded:
                o.stats["degraded"] = True
            if not o.certified and gi in best:
                # fold the engine rungs' best-so-far bounds into an
                # uncertified answer (both sides admissible -> still sound)
                lb, ub = best[gi]
                o.lower_bound = max(o.lower_bound, lb)
                o.upper_bound = min(o.upper_bound, ub)
                o.lower_bound = min(o.lower_bound, o.upper_bound)
                if verification and o.similar is None:
                    if o.lower_bound > float(taus[gi]):
                        o.similar = False
                    elif o.upper_bound <= float(taus[gi]):
                        o.similar = True
            results[gi] = o

        def degrade_bucket(bucket: Bucket, exc: Exception) -> None:
            # the engine rung is gone for these pairs (kernel AND unfused
            # dispatch failed): route them to the ladder's last step, the
            # host solver, instead of failing the whole run
            fresh = [gi for gi in bucket.indices if results[gi] is None]
            degraded.update(fresh)
            host_queue.extend(fresh)
            self.stats["degraded_host"] = \
                self.stats.get("degraded_host", 0) + len(fresh)
            if ctx is not None:
                ctx.bump("degraded_host", len(fresh))
            _faults.warn_once(
                "degrade-host-auto",
                f"auto backend: engine rung failed ({exc!r}); routing "
                f"{len(fresh)} pairs to the host solver")

        def refill() -> None:
            # turn scheduler batches into dispatchable rung buckets:
            # shard-aware re-bucketing groups each batch by slot bucket
            # and pads to the executor's shard multiple, so the
            # max_in_flight cap applies to what actually hits the device
            while not dispatchable and queue:
                batch = queue.pop(0)
                self.stats["batches"] += 1
                if self.scheduler.engine_params(batch.rung) is None:
                    host_queue.extend(batch.indices)
                    continue
                for bucket in plan.subset_buckets(batch.indices,
                                                  self.executor.pack):
                    dispatchable.append((bucket, batch.rung))

        def dispatch(bucket: Bucket, rung: int) -> None:
            pool, expand, max_iters = self.scheduler.engine_params(rung)
            rcfg = dataclasses.replace(cfg, pool=pool, expand=expand,
                                       max_iters=max_iters)
            self.stats["dispatches"] += 1
            try:
                pending = self.executor.run_packed_async(
                    bucket.packed, bucket.pad_values(taus), rcfg,
                    verification, real=bucket.real, ctx=ctx, rung=rung)
            except Exception as exc:
                degrade_bucket(bucket, exc)
                return
            item = _InFlight(bucket, rung, pending, time.perf_counter())
            if self.overlap:
                inflight.append(item)
            else:
                drain(item)             # sequential baseline: block now

        def drain(item: _InFlight) -> None:
            # Never raises: a batch that fails at materialisation is
            # degraded to the host solver, so callers (including the
            # cleanup path below) can always drain in-flight work.
            nonlocal last_block_end
            t_drain = time.perf_counter()
            try:
                out = item.pending.result()  # blocks until the batch lands
            except Exception as exc:
                last_block_end = time.perf_counter()
                degrade_bucket(item.bucket, exc)
                return
            now = time.perf_counter()
            # per-batch wall, not cumulative-since-run-start: a pair's
            # reported wall_s is the cost of the batch that answered it.
            wall = now - item.t_dispatch
            # overlap credit: host-side time this batch spent in flight
            # while we were NOT blocked in another drain — windows are
            # clipped at the previous blocking call so concurrent batches
            # never double-count; ~0 in sequential mode.
            start = item.t_dispatch if last_block_end is None \
                else max(item.t_dispatch, last_block_end)
            self.stats["overlap_saved_s"] += max(0.0, t_drain - start)
            last_block_end = now
            survivors = []
            for bi, gi in enumerate(item.bucket.indices):
                if bool(out["exact"][bi]):
                    o = engine_outcome(
                        out, item.bucket.packed, bi, verification,
                        float(taus[gi]) if verification else None,
                        self.name, wall, rung=item.rung)
                    if item.pending.flags:
                        o.stats.update(item.pending.flags)
                    results[gi] = o
                else:
                    survivors.append(bi)
                    if has_deadline:
                        # pool floor is admissible; the compute-mode raw
                        # ged is the engine's incumbent full mapping
                        merge_best(
                            gi, float(out["lower_bound"][bi]),
                            float("inf") if verification
                            else float(out["ged"][bi]))
            skey = f"survivors_rung_{item.rung}"
            self.stats[skey] = self.stats.get(skey, 0) + len(survivors)
            if survivors:
                self.stats["escalated"] += len(survivors)
                nxt = self.scheduler.escalate(
                    Batch(list(item.bucket.indices), 0.0, item.rung),
                    survivors)
                if nxt is not None:
                    queue.append(nxt)

        expired = False
        try:
            while queue or dispatchable or inflight or host_queue:
                if ctx is not None and ctx.expired():
                    expired = True
                    break
                refill()
                # keep the device fed: dispatch while there's work & room
                while dispatchable and len(inflight) < self.max_in_flight:
                    dispatch(*dispatchable.popleft())
                    refill()
                if inflight:
                    # overlap: host-solve while oldest batch is in flight
                    while host_queue and not inflight[0].pending.ready():
                        if ctx is not None and ctx.expired():
                            break
                        solve_host(host_queue.pop(0))
                    drain(inflight.popleft())
                elif host_queue:
                    solve_host(host_queue.pop(0))
        finally:
            # Never strand dispatched device work or lose its survivor
            # bounds — on deadline expiry or a mid-flight error, drain
            # everything still in flight (drain() itself cannot raise).
            while inflight:
                drain(inflight.popleft())
        if expired or any(r is None for r in results):
            # Anytime tail: every pair the budget never reached answers
            # with its best-so-far admissible bounds, uncertified.
            for gi, r in enumerate(results):
                if r is not None:
                    continue
                q, g = plan.pairs[gi]
                lb, ub = best.get(gi, (0.0, float("inf")))
                o = _faults.fallback_outcome(
                    q, g, verification,
                    float(taus[gi]) if verification else None,
                    self.name, lower_bound=lb, upper_bound=ub)
                if gi in degraded:
                    o.stats["degraded"] = True
                results[gi] = o
                self.stats["timed_out_pairs"] = \
                    self.stats.get("timed_out_pairs", 0) + 1
                if ctx is not None:
                    ctx.bump("timed_out_pairs")
        return results  # type: ignore[return-value]


# -------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[..., Backend]] = {}


def register_backend(name: str, factory: Callable[..., Backend]) -> None:
    """Make ``GedEngine(backend=name)`` constructible.

    ``factory`` is called with keyword options the backend understands
    (unknown ones are not passed — see :func:`make_backend`).

    >>> class NullBackend:
    ...     name = "null"
    ...     kernel_default = None
    ...     def run(self, plan, taus, verification, cfg): return []
    >>> register_backend("null", NullBackend)
    >>> "null" in available_backends()
    True
    >>> del _REGISTRY["null"]                  # tidy up the example
    """
    _REGISTRY[name] = factory


def available_backends() -> tuple:
    """Sorted names ``GedEngine(backend=...)`` accepts right now.

    >>> available_backends()
    ('auto', 'exact', 'jax', 'pallas', 'sharded')
    """
    return tuple(sorted(_REGISTRY))


def make_backend(name: str, **options) -> Backend:
    """Construct a registered backend, dropping options it doesn't take.

    This is what lets ``GedEngine`` pass every knob (``batch_size``,
    ``mesh``, ``overlap``, ...) to every backend: factories only receive
    the keywords their signature names (unless they take ``**kwargs``).

    >>> make_backend("exact").name
    'exact'
    >>> make_backend("exact", batch_size=64).name   # ignored, not an error
    'exact'
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    import inspect
    params = inspect.signature(factory).parameters
    if not any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
        options = {k: v for k, v in options.items() if k in params}
    return factory(**options)


register_backend("exact", ExactBackend)
register_backend("jax", EngineBackend)
register_backend("pallas", PallasBackend)
register_backend("sharded", ShardedBackend)
register_backend("auto", AutoBackend)
