"""Pluggable *policy* backends behind the ``repro.ged`` facade.

Every backend implements one protocol — ``run(plan, taus, verification,
cfg) -> List[GedOutcome]`` — over the bucketed :class:`repro.ged.plan.Plan`.
Backends decide *what* runs (which rungs, which bounds, when to escalate);
*how* a bucket reaches a device — placement, jit/compile caching, packing,
unpacking — is the executor layer's job (:mod:`repro.ged.exec`), so a
policy composes with any placement:

* ``"exact"``   — the paper-faithful host solver (AStar+/DFS+ with BMa),
  one pair at a time.  Always certified; produces mappings.
* ``"jax"``     — the batched vmap engine, one jit call per shape bucket,
  compile-cache aware.  Pure-jnp bound math (``use_kernel=False``).
* ``"pallas"``  — same engine with the Pallas kernels enabled on the hot
  path (interpret mode on CPU, real kernels on TPU).
* ``"sharded"`` — same policy as ``"jax"`` on a
  :class:`~repro.ged.exec.ShardedExecutor`: the pair batch ``shard_map``-ed
  over the device mesh, buckets padded to shard multiples.
* ``"auto"``    — the production pipeline: difficulty prediction, LPT
  batch packing, escalation through growing engine rungs, host-solver
  final rung.  Every answer it returns is certified.

New backends (async, remote, ...) register with :func:`register_backend`
and become constructible via ``GedEngine(backend="name")`` with no facade
changes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Protocol

import numpy as np

from repro.core.engine.search import EngineConfig
from repro.core.exact.search import ged as exact_ged
from repro.core.exact.search import ged_verify
from repro.ged.exec import (Executor, ShardedExecutor, engine_outcome)
from repro.ged.plan import Plan, pad_tail, slot_bucket
from repro.ged.results import GedOutcome
from repro.runtime.scheduler import GedScheduler, difficulty


class Backend(Protocol):
    """What the facade requires of an execution-policy backend."""

    name: str
    # What ``EngineConfig.use_kernel`` must be for this backend; ``None``
    # means the backend honors whatever the config says.  ``GedEngine``
    # applies the default and rejects contradicting user settings.
    kernel_default: Optional[bool]

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig) -> List[GedOutcome]:
        """Answer every pair in ``plan`` (in order).  ``taus`` is aligned
        with ``plan.pairs`` (zeros in computation mode)."""
        ...


# ----------------------------------------------------------- host solver

class ExactBackend:
    """Paper-faithful host solver: always certified, yields mappings."""

    name = "exact"
    kernel_default = None  # host solver: kernels irrelevant
    batch_multiple = 1     # host solver: no device batch shape to satisfy

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig) -> List[GedOutcome]:
        outcomes: List[GedOutcome] = []
        for i, (q, g) in enumerate(plan.pairs):
            t0 = time.perf_counter()
            if verification:
                res = ged_verify(q, g, float(taus[i]), bound="BMa",
                                 strategy=cfg.strategy)
                outcomes.append(_host_verify_outcome(
                    res, float(taus[i]), self.name,
                    time.perf_counter() - t0))
            else:
                res = exact_ged(q, g, bound="BMa", strategy=cfg.strategy)
                outcomes.append(_host_compute_outcome(
                    res, self.name, time.perf_counter() - t0))
        return outcomes


def _host_compute_outcome(res, backend: str, wall_s: float,
                          rung: int = 0) -> GedOutcome:
    ged = float(res.ged)
    return GedOutcome(ged=ged, similar=None, certified=True,
                      lower_bound=ged, upper_bound=ged,
                      mapping=res.best_mapping, backend=backend,
                      wall_s=wall_s, stats={"rung": rung,
                                            "expanded": res.stats.expanded})


def _host_verify_outcome(res, tau: float, backend: str, wall_s: float,
                         rung: int = 0) -> GedOutcome:
    similar = bool(res.similar)
    return GedOutcome(
        ged=None, similar=similar, certified=True,
        lower_bound=0.0 if similar else float(np.nextafter(tau, np.inf)),
        upper_bound=float(res.upper_bound) if similar else float("inf"),
        mapping=res.best_mapping if similar else None,
        backend=backend, wall_s=wall_s, tau=tau,
        stats={"rung": rung, "expanded": res.stats.expanded})


# --------------------------------------------------------- batched engine

class EngineBackend:
    """Bucket-at-a-time policy over an :class:`~repro.ged.exec.Executor`.

    ``cfg.use_kernel`` is taken as-is — ``GedEngine`` defaults it per
    backend name (``jax``/``sharded`` -> False, ``pallas`` -> True) and
    rejects contradictions, so the flag always matches what the user asked
    for.
    """

    name = "jax"
    kernel_default = False

    def __init__(self, executor: Optional[Executor] = None) -> None:
        self.executor = executor or Executor()

    @property
    def cache(self):
        return self.executor.cache

    @property
    def batch_multiple(self) -> int:
        return self.executor.batch_multiple

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig) -> List[GedOutcome]:
        results: List[Optional[GedOutcome]] = [None] * len(plan.pairs)
        for bucket in plan.buckets:
            t0 = time.perf_counter()
            out = self.executor.run_bucket(bucket, taus, cfg, verification)
            wall = time.perf_counter() - t0
            for bi, gi in enumerate(bucket.indices):
                results[gi] = engine_outcome(
                    out, bucket.packed, bi, verification,
                    float(taus[gi]) if verification else None,
                    self.name, wall, rung=0)
        return results  # type: ignore[return-value]


class PallasBackend(EngineBackend):
    """Engine policy with Pallas kernels on the hot path."""

    name = "pallas"
    kernel_default = True


class ShardedBackend(EngineBackend):
    """Engine policy on a mesh-sharded executor (``shard_map`` over pairs).

    Identical policy (and therefore identical outcomes) to ``"jax"``; only
    the placement differs.  ``mesh`` defaults to a 1-D mesh over every
    local device.
    """

    name = "sharded"
    kernel_default = False

    def __init__(self, mesh=None) -> None:
        super().__init__(ShardedExecutor(mesh))


# ------------------------------------------------------------ escalation

class AutoBackend:
    """Difficulty-scheduled escalation: engine rungs, then the host solver.

    This is the serving pipeline (previously private to
    ``GedVerificationService``): predict per-pair difficulty, LPT-pack
    equalised batches, run the batched engine, and re-queue uncertified
    pairs through bigger-pool rungs down to the exact host solver — so
    every answer is certified.
    """

    name = "auto"
    kernel_default = None  # honors cfg.use_kernel on the engine rungs

    def __init__(self, batch_size: int = 256,
                 executor: Optional[Executor] = None):
        self.scheduler = GedScheduler(batch_size)
        self.executor = executor or Executor()
        self.stats: Dict[str, float] = {"pairs": 0, "escalated": 0,
                                        "host_solved": 0, "batches": 0}

    @property
    def cache(self):
        return self.executor.cache

    @property
    def batch_multiple(self) -> int:
        return self.executor.batch_multiple

    def run(self, plan: Plan, taus: np.ndarray, verification: bool,
            cfg: EngineConfig) -> List[GedOutcome]:
        results: List[Optional[GedOutcome]] = [None] * len(plan.pairs)
        diffs = [difficulty(q.n, g.n, q.m, g.m, q.vlabels, g.vlabels,
                            tau=float(taus[i]) if verification else None)
                 for i, (q, g) in enumerate(plan.pairs)]
        queue = self.scheduler.pack(diffs, rung=0)
        self.stats["pairs"] += len(plan.pairs)

        while queue:
            batch = queue.pop(0)
            self.stats["batches"] += 1
            params = self.scheduler.engine_params(batch.rung)
            if params is None:
                # final rung: exact host solver (paper-faithful AStar+-BMa)
                for gi in batch.indices:
                    q, g = plan.pairs[gi]
                    self.stats["host_solved"] += 1
                    t0 = time.perf_counter()
                    if verification:
                        res = ged_verify(q, g, float(taus[gi]), bound="BMa",
                                         strategy=cfg.strategy)
                        results[gi] = _host_verify_outcome(
                            res, float(taus[gi]), f"{self.name}/exact",
                            time.perf_counter() - t0, rung=-1)
                    else:
                        res = exact_ged(q, g, bound="BMa",
                                        strategy=cfg.strategy)
                        results[gi] = _host_compute_outcome(
                            res, f"{self.name}/exact",
                            time.perf_counter() - t0, rung=-1)
                continue

            pool, expand, max_iters = params
            rcfg = dataclasses.replace(cfg, pool=pool, expand=expand,
                                       max_iters=max_iters)
            sub = [plan.pairs[gi] for gi in batch.indices]
            slots = plan.fixed_slots or slot_bucket(
                max(max(q.n, g.n) for q, g in sub))
            packed, _ = self.executor.pack(sub, slots, plan.vocab)
            sub_taus = pad_tail(
                np.asarray([taus[gi] for gi in batch.indices],
                           dtype=np.float32), packed.batch)
            t0 = time.perf_counter()
            out = self.executor.run_packed(packed, sub_taus, rcfg,
                                           verification, real=len(sub))
            # per-batch wall, not cumulative-since-run-start: a pair's
            # reported wall_s is the cost of the batch that answered it.
            wall = time.perf_counter() - t0

            uncertified = []
            for bi, gi in enumerate(batch.indices):
                if bool(out["exact"][bi]):
                    results[gi] = engine_outcome(
                        out, packed, bi, verification,
                        float(taus[gi]) if verification else None,
                        self.name, wall, rung=batch.rung)
                else:
                    uncertified.append(bi)
            if uncertified:
                self.stats["escalated"] += len(uncertified)
                nxt = self.scheduler.escalate(batch, uncertified)
                if nxt is not None:
                    queue.append(nxt)
        return results  # type: ignore[return-value]


# -------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[..., Backend]] = {}


def register_backend(name: str, factory: Callable[..., Backend]) -> None:
    """Make ``GedEngine(backend=name)`` constructible.

    ``factory`` is called with keyword options the backend understands
    (unknown ones are not passed — see :func:`make_backend`).
    """
    _REGISTRY[name] = factory


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def make_backend(name: str, **options) -> Backend:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    import inspect
    params = inspect.signature(factory).parameters
    if not any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
        options = {k: v for k, v in options.items() if k in params}
    return factory(**options)


register_backend("exact", ExactBackend)
register_backend("jax", EngineBackend)
register_backend("pallas", PallasBackend)
register_backend("sharded", ShardedBackend)
register_backend("auto", AutoBackend)
