"""The execution layer under the ``repro.ged`` facade.

Backends (:mod:`repro.ged.backends`) are pure *policies* — which pairs run
at which rung, with which bounds, when to escalate.  Everything about *how*
a packed bucket actually reaches silicon lives here:

* :class:`Executor` — default placement: one jit call per shape bucket on
  the default device, compile-cache bookkeeping, bucket packing and result
  unpacking.  Every backend drives one of these.
* :class:`ShardedExecutor` — ``shard_map`` the vmapped search over the
  device mesh's batch axes (``pod`` x ``data`` per
  :func:`repro.parallel.sharding.default_rules`), with bucket batches
  padded to shard multiples by :mod:`repro.ged.plan`.  The search's
  sorted-pool loop is built from batch-partitionable HLO — ``lax.sort``
  over the child keys, binary-search rank merges, gathers with explicit
  batch dims — so the pair batch stays sharded (a ``lax.top_k``
  custom-call would all-gather it — see ``repro/parallel/ops.py``).
  One-shard meshes skip ``shard_map`` entirely (the single-device fast
  path).
* :class:`PendingBatch` — the future returned by
  :meth:`Executor.run_packed_async`: a dispatched-but-not-yet-drained
  engine invocation, riding JAX's async dispatch.  The overlapped ``auto``
  escalation scheduler keeps several in flight and drains them as their
  device work lands.
* :class:`ResultCache` — engine-level outcome cache keyed on canonical
  pair digests (label-vocab-independent, tau-aware for verification) that
  :class:`repro.ged.GedEngine` consults before any executor runs.

Policy and placement compose freely: any backend policy runs unchanged on
any executor, which is what async / remote / multi-host work hangs off.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.engine import api as engine_api
from repro.core.engine.search import EngineConfig
from repro.core.exact.graph import Graph
from repro.kernels import autotune
from repro.ged.plan import Bucket, CompileCache, Vocab, pack_bucket
from repro.ged.results import GedOutcome, engine_mapping


# ------------------------------------------------- persistent compile cache

COMPILE_CACHE_ENV = "REPRO_GED_COMPILE_CACHE_DIR"

# Process-wide persistent-cache state: the enabled directory plus hit/miss
# counters fed by jax's monitoring events.  jax's compilation cache is a
# process-global switch, so this is module state rather than per-executor —
# every engine in the process shares the one cache (that is the point: the
# multi-second engine compile is paid once per *machine*, not per process).
# ``listener`` tracks the (unremovable) monitoring-listener registration
# separately from ``dir`` so disabling and re-enabling the cache can never
# register a second listener and double-count events.
_PERSISTENT_CACHE: Dict[str, object] = {"dir": None, "hits": 0, "misses": 0,
                                        "listener": False}


def _cache_event_listener(event: str, **kw) -> None:
    if event == "/jax/compilation_cache/cache_hits":
        _PERSISTENT_CACHE["hits"] += 1          # type: ignore[operator]
    elif event == "/jax/compilation_cache/cache_misses":
        _PERSISTENT_CACHE["misses"] += 1        # type: ignore[operator]


def enable_compile_cache(cache_dir: Optional[str] = None) -> Optional[str]:
    """Point jax's persistent compilation cache at ``cache_dir``.

    ``cache_dir`` defaults to the ``REPRO_GED_COMPILE_CACHE_DIR``
    environment variable; when neither is set this is a no-op returning
    ``None``.  Compiled engine executables are serialised into the
    directory and re-loaded by *later processes*, so the multi-second
    first-call compile is paid once per machine.  Idempotent — repeat
    calls (every ``GedEngine(compile_cache_dir=...)``) just re-point the
    directory.  Hit/miss counts land in :func:`persistent_cache_stats`
    (and therefore ``engine.stats``).

    >>> enable_compile_cache(None) is None     # no dir, no env: no-op
    True
    """
    path = cache_dir or os.environ.get(COMPILE_CACHE_ENV)
    if not path:
        return None
    import jax
    from jax import monitoring
    if not _PERSISTENT_CACHE["listener"]:
        monitoring.register_event_listener(_cache_event_listener)
        _PERSISTENT_CACHE["listener"] = True
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    # the engine's jit is exactly the compile worth persisting — don't let
    # the default 1s threshold skip mid-sized bucket shapes
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    if _PERSISTENT_CACHE["dir"] != str(path):
        # jax latches its cache-enabled check at the first compile of the
        # process; (re-)pointing the directory afterwards needs an explicit
        # reset or the new setting is silently ignored
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.reset_cache()
    _PERSISTENT_CACHE["dir"] = str(path)
    return str(path)


def persistent_cache_stats() -> Dict[str, float]:
    """Process-wide persistent compile-cache counters (empty when off).

    ``persistent_cache_hits`` / ``persistent_cache_misses`` count jax's
    disk-cache lookups this process; ``persistent_cache_entries`` is the
    number of serialised executables currently in the directory.
    """
    d = _PERSISTENT_CACHE["dir"]
    if d is None:
        return {}
    try:
        entries = len(os.listdir(str(d)))
    except OSError:
        entries = 0
    return {"persistent_cache_hits": float(_PERSISTENT_CACHE["hits"]),
            "persistent_cache_misses": float(_PERSISTENT_CACHE["misses"]),
            "persistent_cache_entries": float(entries)}


# ---------------------------------------------------------------- executors

class PendingBatch:
    """One dispatched-but-not-yet-drained engine invocation.

    Wraps the dict of ``jax.Array`` futures an executor's dispatch step
    produced.  Because JAX dispatches asynchronously, the device may still
    be crunching when a ``PendingBatch`` is handed out — :meth:`ready`
    polls without blocking, :meth:`result` blocks once and caches the
    numpy conversion.  The overlapped ``auto`` scheduler keeps a small
    queue of these in flight and does host-solver work while they cook.

    Plain numpy inputs (no ``is_ready`` method) count as always ready:

    >>> import numpy as np
    >>> p = PendingBatch({"ged": np.zeros(2)})
    >>> p.ready()
    True
    >>> p.result()["ged"]
    array([0., 0.])

    ``recover`` (optional) is the executor's degraded re-dispatch: JAX
    surfaces some runtime failures only at materialisation, so
    :meth:`result` catches them, runs ``recover()`` synchronously (the
    bit-identical unfused path) and marks ``flags["degraded"]``.
    ``check`` is the deterministic fault-injection hook for that same
    window (the ``result`` site).  ``flags`` records what the robust
    dispatch path did (``retries`` / ``degraded``) so backends can fold
    it into outcome stats.
    """

    def __init__(self, arrays, recover=None, check=None,
                 flags: Optional[Dict[str, float]] = None):
        self._arrays = arrays
        self._result: Optional[Dict[str, np.ndarray]] = None
        self._recover = recover
        self._check = check
        self.flags: Dict[str, float] = {} if flags is None else flags

    def ready(self) -> bool:
        """True when every output has landed (never blocks)."""
        if self._result is not None:
            return True
        for v in self._arrays.values():
            is_ready = getattr(v, "is_ready", None)
            if is_ready is not None and not is_ready():
                return False
        return True

    def result(self) -> Dict[str, np.ndarray]:
        """Block until the batch lands; numpy result dict (cached).

        A materialisation failure with a ``recover`` path re-runs the
        batch on the degraded config instead of raising; without one the
        failure propagates to the backend (which host-solves the pairs).
        """
        if self._result is None:
            try:
                if self._check is not None:
                    self._check()
                self._result = {k: np.asarray(v)
                                for k, v in self._arrays.items()}
            except Exception:
                if self._recover is None:
                    raise
                self.flags["degraded"] = True
                self._result = {k: np.asarray(v)
                                for k, v in self._recover().items()}
            self._arrays = None
        return self._result


class Executor:
    """Runs packed buckets on the default device.

    Owns the things backends used to hand-roll: the compile-cache mirror,
    batch-shape policy (``batch_multiple``), packing, and invocation
    counters (``stats``) — so a policy layer above never touches jit, jax
    arrays, or device placement.  Subclasses override :meth:`_dispatch`
    (and usually ``batch_multiple``) only; the sync/async entry points and
    the bookkeeping are shared.

    >>> ex = Executor()
    >>> ex.batch_multiple
    1
    >>> sorted(ex.stats)
    ['calls', 'pairs']
    """

    name = "local"

    def __init__(self) -> None:
        self.cache = CompileCache()
        self.stats: Dict[str, float] = {"calls": 0, "pairs": 0}

    @property
    def batch_multiple(self) -> int:
        """Every bucket batch must be a multiple of this (shard count)."""
        return 1

    def pack(self, pairs, slots: int, vocab: Optional[Vocab]):
        """Pack ``pairs`` with this executor's batch-shape policy.

        Returns ``(tensors, real_count)`` with the batch dimension padded
        to a power of two rounded up to :attr:`batch_multiple`::

            packed, real = executor.pack(pairs, slots=8, vocab=plan.vocab)
        """
        return pack_bucket(pairs, slots, vocab, self.batch_multiple)

    def run_packed_async(self, packed, taus: np.ndarray, cfg: EngineConfig,
                         verification: bool,
                         real: Optional[int] = None,
                         ctx=None, rung: Optional[int] = None
                         ) -> PendingBatch:
        """Dispatch one engine invocation without waiting for the result.

        Returns a :class:`PendingBatch` immediately — JAX queues the device
        work and hands back array futures — so callers can dispatch rung
        *k+1* or solve host pairs while rung *k* is in flight.  ``real`` —
        pairs before batch padding, for the ``pairs`` counter (defaults to
        the padded batch when the caller doesn't know).  ``ctx`` — the
        engine's :class:`repro.ged.faults.RunContext` (retry policy, fault
        injector, counters); ``rung`` labels the dispatch for rung-scoped
        fault specs.  Both default to off, which is the bit-identical
        legacy path.

        Example (the overlapped ``auto`` scheduler's inner loop)::

            pending = executor.run_packed_async(packed, taus, cfg, False)
            do_host_work_while(not pending.ready())
            out = pending.result()          # numpy dict, blocks if needed
        """
        self._check_batch(packed)
        # ``use_kernel="auto"`` resolves to a concrete per-bucket kernel
        # plan *here*, before anything jit-keyed sees the config: the
        # resolved dispatch (tuning-table lookup or static heuristic for
        # unmeasured shapes) is pinned on the config, so the jit cache,
        # the CompileCache ledger and the sharded executor's fn cache all
        # key on the actual decision.  Outcomes are bit-identical across
        # dispatch choices, so result caching upstream stays sound.
        cfg = autotune.resolve_config(cfg, packed.slots, packed.batch)
        self.cache.record(packed, cfg, verification)
        self.stats["calls"] += 1
        self.stats["pairs"] += packed.batch if real is None else int(real)
        return self._robust_dispatch(packed, taus, cfg, verification,
                                     ctx, rung)

    def _robust_dispatch(self, packed, taus, cfg, verification, ctx,
                         rung) -> PendingBatch:
        """Dispatch with the retry policy and kernel-degradation ladder.

        Transient failures retry with exponential backoff + jitter
        (:class:`repro.ged.faults.RetryPolicy`); permanent kernel
        compile/runtime failures fall back to the bit-identical unfused
        config (``use_kernel=False``); a failure of the unfused path too
        propagates, and the backend above degrades the bucket to the host
        solver.  On a clean dispatch this is exactly the legacy path —
        the try/except costs nothing unless something raises.
        """
        import time as _time

        from repro.ged import faults as _faults

        inj = _faults.get_injector(ctx)
        retry = ctx.retry if ctx is not None else _faults.RetryPolicy()

        def bump(key: str, by: float = 1) -> None:
            self.stats[key] = self.stats.get(key, 0) + by
            if ctx is not None:
                ctx.bump(key, by)

        ladder = [cfg]
        if bool(cfg.use_kernel):
            ladder.append(dataclasses.replace(cfg, use_kernel=False,
                                              dispatch=None))
        flags: Dict[str, float] = {}
        last_exc: Optional[Exception] = None
        for step, step_cfg in enumerate(ladder):
            if step > 0:
                bump("degraded_kernel")
                flags["degraded"] = True
                _faults.warn_once(
                    f"degrade-kernel-{self.name}",
                    f"{self.name} executor: kernel path failed "
                    f"({last_exc!r}); degrading to the bit-identical "
                    "unfused config for this and retried dispatches")
            attempt = 0
            while True:
                try:
                    if inj is not None:
                        inj.check("dispatch", rung)
                        if bool(step_cfg.use_kernel):
                            inj.check("kernel", rung)
                    arrays = self._dispatch(packed, taus, step_cfg,
                                            verification)
                    recover = None
                    if step + 1 < len(ladder):
                        nxt = ladder[step + 1]

                        def recover(_nxt=nxt):
                            bump("degraded_kernel")
                            _faults.warn_once(
                                f"degrade-kernel-{self.name}",
                                f"{self.name} executor: kernel batch "
                                "failed at materialisation; re-running "
                                "unfused")
                            return self._dispatch(packed, taus, _nxt,
                                                  verification)
                    check = None
                    if inj is not None:
                        check = (lambda: inj.check("result", rung))
                    return PendingBatch(arrays, recover=recover,
                                        check=check, flags=flags)
                except Exception as exc:
                    last_exc = exc
                    if (_faults.classify_transient(exc)
                            and attempt < retry.max_retries):
                        bump("retries")
                        flags["retries"] = flags.get("retries", 0) + 1
                        _time.sleep(retry.backoff_s(attempt))
                        attempt += 1
                        continue
                    bump("fault_dispatch")
                    break               # next ladder step (or give up)
        raise last_exc

    def run_packed(self, packed, taus: np.ndarray, cfg: EngineConfig,
                   verification: bool,
                   real: Optional[int] = None,
                   ctx=None, rung: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
        """One blocking engine invocation over a packed bucket; numpy dict.

        Sugar for :meth:`run_packed_async` + :meth:`PendingBatch.result`::

            out = executor.run_packed(packed, taus, cfg, verification)
            out["ged"], out["exact"]        # per-row engine results
        """
        return self.run_packed_async(packed, taus, cfg, verification,
                                     real=real, ctx=ctx, rung=rung).result()

    def run_bucket(self, bucket: Bucket, taus: np.ndarray, cfg: EngineConfig,
                   verification: bool) -> Dict[str, np.ndarray]:
        """Run one plan bucket; ``taus`` is the plan-global per-pair array.

        Example::

            for bucket in plan.buckets:
                out = executor.run_bucket(bucket, taus, cfg, verification)
        """
        return self.run_packed(bucket.packed, bucket.pad_values(taus), cfg,
                               verification, real=bucket.real)

    def run_bucket_async(self, bucket: Bucket, taus: np.ndarray,
                         cfg: EngineConfig, verification: bool,
                         ctx=None, rung: Optional[int] = None
                         ) -> PendingBatch:
        """Async :meth:`run_bucket` with the robustness context threaded
        through — the entry point fault-aware backends use (the returned
        batch's ``flags`` record retries/degradation for outcome stats)."""
        return self.run_packed_async(bucket.packed, bucket.pad_values(taus),
                                     cfg, verification, real=bucket.real,
                                     ctx=ctx, rung=rung)

    # ------------------------------------------------------------ internal

    def _check_batch(self, packed) -> None:
        mult = self.batch_multiple
        if packed.batch % mult:
            raise ValueError(
                f"batch {packed.batch} is not a multiple of the executor's "
                f"{mult} shards; pack with batch_multiple={mult} "
                "(GedEngine does this automatically)")

    def _dispatch(self, packed, taus, cfg, verification):
        """Enqueue the device work; dict of un-materialised jax arrays."""
        return engine_api.dispatch_packed(packed, taus, cfg, verification)


class ShardedExecutor(Executor):
    """``shard_map`` the vmapped search over the mesh's batch axes.

    ``mesh`` defaults to a 1-D ``("data",)`` mesh over every local device;
    production meshes from :mod:`repro.launch.mesh` work as-is — the shard
    axes come from the ``"pairs"`` row of
    :func:`repro.parallel.sharding.default_rules` (``pod`` + ``data``),
    matching how the serving dry-run places pair batches.

    Any policy backend composes with it — ``GedEngine(backend="sharded")``
    is the vmap policy on this executor, ``GedEngine(backend="auto",
    mesh=...)`` the escalation policy.  Example::

        mesh = jax.make_mesh((8,), ("data",))
        eng = ged.GedEngine("sharded", mesh=mesh)   # batches padded to 8

    On a one-shard mesh (one local device) the ``shard_map`` wrapper and
    shard-multiple batch padding are pure overhead — there is nothing to
    partition — so dispatch falls through to the plain single-device path
    (``stats["single_device_fastpath"]`` counts those dispatches) and
    ``batch_multiple`` stays 1.  Outcomes are identical either way.

    >>> ShardedExecutor().batch_multiple >= 1      # local device count
    True
    """

    name = "sharded"

    def __init__(self, mesh=None, axes: Optional[Sequence[str]] = None):
        super().__init__()
        import jax
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self.mesh = mesh
        if axes is None:
            from repro.parallel.sharding import pairs_axes
            axes = pairs_axes(mesh)
        self.axes = tuple(axes)
        self.stats["single_device_fastpath"] = 0
        self._fns: Dict[tuple, object] = {}

    @property
    def batch_multiple(self) -> int:
        from repro.parallel.sharding import default_rules
        return default_rules(self.mesh).mesh_size(self.axes)

    def _dispatch(self, packed, taus, cfg, verification):
        import jax
        import jax.numpy as jnp

        if self.batch_multiple == 1:
            # one shard = nothing to partition: skip the shard_map wrapper
            # (and its trace/lowering overhead) entirely
            self.stats["single_device_fastpath"] += 1
            return engine_api.dispatch_packed(packed, taus, cfg,
                                              verification)

        key = (cfg, bool(verification), packed.n_vlabels, packed.n_elabels)
        fn = self._fns.get(key)
        if fn is None:
            from jax.sharding import PartitionSpec as P

            from repro.parallel.ops import shard_map
            spec = P(self.axes)  # leading (batch) dim sharded, rest local

            def local_shard(qv, gv, qa, ga, order, n, t):
                return engine_api._run_batch(qv, gv, qa, ga, order, n, t,
                                             *key)

            fn = jax.jit(shard_map(local_shard, mesh=self.mesh,
                                   in_specs=(spec,) * 7, out_specs=spec,
                                   check=False))
            self._fns[key] = fn
        args = engine_api.pair_tuple(packed)
        return fn(*args, jnp.asarray(np.asarray(taus, dtype=np.float32)))


# ----------------------------------------------------------- result unpack

def engine_outcome(out: Dict[str, np.ndarray], packed, bi: int,
                   verification: bool, tau: Optional[float], backend: str,
                   wall_s: float, rung: int) -> GedOutcome:
    """One :class:`GedOutcome` from row ``bi`` of an executor result dict.

    The unpack half of the executor contract — backends call it once per
    answered pair::

        out = executor.run_bucket(bucket, taus, cfg, verification)
        for bi, gi in enumerate(bucket.indices):
            results[gi] = engine_outcome(out, bucket.packed, bi,
                                         verification, tau, "jax",
                                         wall_s, rung=0)
    """
    certified = bool(out["exact"][bi])
    n = int(packed.n[bi])
    mapping = engine_mapping(packed.order[bi], out["best_img"][bi], n)
    stats = {"rung": rung,
             "iterations": float(out["iterations"][bi]),
             "expanded": float(out["expanded"][bi])}
    lb = float(out["lower_bound"][bi])
    if verification:
        similar = bool(out["similar"][bi])
        ub = float(out["upper_bound"][bi])
        return GedOutcome(
            ged=None, similar=similar, certified=certified,
            lower_bound=lb, upper_bound=ub if similar else float("inf"),
            mapping=mapping if similar else None,
            backend=backend, wall_s=wall_s, tau=tau, stats=stats)
    raw = float(out["ged"][bi])
    ged = float(np.rint(raw)) if certified else raw
    return GedOutcome(
        ged=ged, similar=None, certified=certified,
        lower_bound=min(lb, ged), upper_bound=ged,
        mapping=mapping, backend=backend, wall_s=wall_s, stats=stats)


# ------------------------------------------------------------ result cache

def graph_digest(g: Graph) -> bytes:
    """Canonical digest of one graph, independent of any batch label vocab.

    Hashes the concrete representation (raw int64 labels + adjacency), so
    equality means *identical* graphs — mappings in cached outcomes stay
    index-compatible — and the digest never changes with whichever other
    pairs happened to share a batch.

    >>> from repro.ged.plan import as_graph
    >>> g = as_graph(([0, 1], [(0, 1, 1)]))
    >>> len(graph_digest(g))
    16
    >>> graph_digest(g) == graph_digest(as_graph(([0, 1], [(0, 1, 1)])))
    True
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.vlabels, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.adj, dtype=np.int64).tobytes())
    return h.digest()


def wl_digest(g: Graph, iters: int = 3) -> bytes:
    """Isomorphism-invariant digest: Weisfeiler-Leman color refinement.

    Vertex colors start from vertex labels and are refined ``iters`` times
    with the sorted multiset of ``(edge_label, neighbor_color)`` pairs; the
    digest hashes the *sorted* final color multiset plus an edge summary
    (sorted ``(color, color, edge_label)`` triples) and the graph sizes —
    every ingredient is permutation-invariant, so isomorphic graphs always
    collide, which is exactly what a graph-DB result cache wants.

    Caveat (why the exact digest stays the default for :func:`pair_key`):
    WL refinement is not a complete isomorphism test — WL-equivalent
    non-isomorphic graphs (a 6-cycle vs two triangles, regular graphs
    with uniform labels) share a digest.  A consumer must therefore
    either *confirm* a collision before trusting it
    (:class:`repro.ged.GraphStore` runs a certified GED == 0 check per
    candidate merge at ingest) or accept that an unconfirmed collision
    aliases two different pairs — ``GedEngine(digest="wl")`` is that
    opt-in trade: on WL-equivalent non-isomorphic pairs the cache can
    return the *other* pair's distance.  Cached mappings are dropped
    either way (index-valid only for the graph that produced them).

    >>> from repro.ged.plan import as_graph
    >>> g = as_graph(([0, 1, 2], [(0, 1, 1), (1, 2, 2)]))
    >>> p = as_graph(([2, 1, 0], [(1, 0, 2), (2, 1, 1)]))   # relabelled copy
    >>> wl_digest(g) == wl_digest(p)
    True
    >>> graph_digest(g) == graph_digest(p)
    False
    """
    def h8(*parts: bytes) -> bytes:
        hh = hashlib.blake2b(digest_size=8)
        for p in parts:
            hh.update(p)
        return hh.digest()

    adj = g.adj
    colors = [h8(np.int64(int(a)).tobytes()) for a in g.vlabels]
    for _ in range(iters):
        colors = [
            h8(colors[v], *(np.int64(int(adj[v, u])).tobytes() + colors[u]
                            for u in sorted(np.nonzero(adj[v])[0].tolist(),
                                            key=lambda u: (adj[v, u],
                                                           colors[u]))))
            for v in range(g.n)
        ]
    out = hashlib.blake2b(digest_size=16)
    out.update(np.int64(g.n).tobytes())
    out.update(np.int64(g.m).tobytes())
    for c in sorted(colors):
        out.update(c)
    ii, jj = np.nonzero(np.triu(adj, k=1))
    for t in sorted(
        h8(*sorted((colors[i], colors[j])),
           np.int64(int(adj[i, j])).tobytes())
        for i, j in zip(ii.tolist(), jj.tolist())
    ):
        out.update(t)
    return out.digest()


DIGESTS = {"exact": graph_digest, "wl": wl_digest}


# ------------------------------------------------------- sketch signatures

# Multiplicative uint32 hash constants (Knuth / murmur-style finalisers).
# The *same* wraparound arithmetic runs in numpy on the host (one query
# graph) and in jnp on device (the packed corpus), so signatures agree
# bit-for-bit whichever path produced them — CandidateIndex probes depend
# on that.
_H_VMUL = 2654435761        # vertex-label hash multiplier
_H_VADD = 0x9E3779B9
_H_EMUL = 0xC2B2AE35        # edge label inside the neighbor combine
_H_NMUL = 0x27D4EB2F        # per-neighbor contribution
_H_CMUL = 0x85EBCA6B        # self color between WL rounds
_H_CADD = 0x165667B1
_H_BMUL = 0x9E3779B1        # edge-label histogram bin
_H_BADD = 0x85EBCA77


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Shape of a WL-sketch signature (see :func:`wl_signature`).

    ``dims_v`` / ``dims_e`` are the hashed vertex- and edge-histogram
    widths; ``wl_iters`` rounds of Weisfeiler-Leman color refinement run
    before the vertex part is binned (0 = plain label histogram, the
    default — deeper sketches discriminate more but carry a larger
    admissible damage factor, see :func:`repro.ged.index.sketch_damage`).

    >>> SketchSpec().dims        # 64 vertex + 16 edge bins + (n, m)
    82
    """

    dims_v: int = 64
    dims_e: int = 16
    wl_iters: int = 0

    @property
    def dims(self) -> int:
        return self.dims_v + self.dims_e + 2


def wl_signature(g: Graph, spec: SketchSpec = SketchSpec()) -> np.ndarray:
    """Integer sketch of one graph: hashed WL-color histogram (``dims_v``
    bins) ⊕ hashed edge-label histogram (``dims_e`` bins) ⊕ ``(n, m)``.

    The sketch is built so one unit edit operation moves its L1 norm by a
    *bounded* amount (the damage factor — 2 at ``wl_iters=0``): a vertex
    relabel moves one unit between two vertex bins, an edge edit touches
    one edge bin plus the ``m`` entry, a vertex insert/delete one vertex
    bin plus ``n``.  Hashing labels into bins only ever *merges* histogram
    mass, which shrinks L1 — so ``ceil(L1 / damage)`` stays an admissible
    GED lower bound at any width.  Host path of the pair whose batched
    twin is :func:`batch_signatures`.

    >>> from repro.ged.plan import as_graph
    >>> s = wl_signature(as_graph(([0, 1], [(0, 1, 1)])))
    >>> int(s.sum() - s[-2] - s[-1]), int(s[-2]), int(s[-1])   # 2 vertices, 1 edge
    (3, 2, 1)
    """
    u32 = np.uint32
    c = np.asarray(g.vlabels, dtype=np.int64).astype(u32) * u32(_H_VMUL) \
        + u32(_H_VADD)
    adj = np.ascontiguousarray(g.adj, dtype=np.int64).astype(u32)
    present = g.adj > 0
    for _ in range(spec.wl_iters):
        h = (adj * u32(_H_EMUL) + c[None, :]) * u32(_H_NMUL)
        nsum = np.where(present, h, u32(0)).sum(axis=1, dtype=u32)
        c = c * u32(_H_CMUL) + nsum + u32(_H_CADD)
    sig = np.zeros(spec.dims, dtype=np.int32)
    sig[:spec.dims_v] = np.bincount(
        (c % u32(spec.dims_v)).astype(np.int64), minlength=spec.dims_v)
    iu, ju = np.nonzero(np.triu(g.adj, k=1))
    elabs = np.asarray(g.adj, dtype=np.int64)[iu, ju].astype(u32)
    ebin = ((elabs * u32(_H_BMUL) + u32(_H_BADD))
            % u32(spec.dims_e)).astype(np.int64)
    sig[spec.dims_v:spec.dims_v + spec.dims_e] = np.bincount(
        ebin, minlength=spec.dims_e)
    sig[-2] = g.n
    sig[-1] = g.m
    return sig


def _signature_fn(spec: SketchSpec, slots: int):
    """Pure-jnp single-graph signature over padded ``slots`` tensors,
    bit-identical to :func:`wl_signature` (same uint32 wraparound ops in
    the same order)."""
    import jax.numpy as jnp
    u32 = jnp.uint32

    def one(vlab, mask, adj):
        c = vlab.astype(u32) * u32(_H_VMUL) + u32(_H_VADD)
        present = adj > 0
        for _ in range(spec.wl_iters):
            h = (adj.astype(u32) * u32(_H_EMUL) + c[None, :]) * u32(_H_NMUL)
            nsum = jnp.sum(jnp.where(present, h, u32(0)), axis=1,
                           dtype=jnp.uint32)
            c = c * u32(_H_CMUL) + nsum + u32(_H_CADD)
        vbin = (c % u32(spec.dims_v)).astype(jnp.int32)
        vhist = jnp.zeros(spec.dims_v, jnp.int32).at[vbin].add(mask)
        tri = jnp.triu(jnp.ones((slots, slots), jnp.int32), k=1)
        w = present.astype(jnp.int32) * tri
        ebin = ((adj.astype(u32) * u32(_H_BMUL) + u32(_H_BADD))
                % u32(spec.dims_e)).astype(jnp.int32)
        ehist = jnp.zeros(spec.dims_e, jnp.int32) \
            .at[ebin.reshape(-1)].add(w.reshape(-1))
        return jnp.concatenate(
            [vhist, ehist, jnp.stack([jnp.sum(mask), jnp.sum(w)])])

    return one


def batch_signatures(graphs: Sequence[Graph],
                     spec: SketchSpec = SketchSpec(),
                     executor: Optional[Executor] = None,
                     fns: Optional[Dict[tuple, object]] = None,
                     chunk: int = 2048) -> np.ndarray:
    """:func:`wl_signature` for a whole corpus, batched on device.

    Graphs are grouped into power-of-two slot buckets (the planner's
    shapes, so compilations are shared with everything else at that
    width), packed into ``(batch, slots)`` label/mask and
    ``(batch, slots, slots)`` adjacency tensors in chunks of ``chunk``
    rows, and pushed through one vmapped jit per shape.  On a
    :class:`ShardedExecutor` the chunk's batch axis is ``shard_map``-ed
    over the executor's mesh axes — ingest-time signature builds ride
    whatever placement the store runs on.  ``fns`` is the caller's
    compiled-fn cache (keyed on shape), so repeated builds recompile
    nothing.  Returns ``(len(graphs), spec.dims)`` int32, row order =
    input order, bit-identical to the host path:

    >>> from repro.ged.plan import as_graph
    >>> g = as_graph(([0, 1, 0], [(0, 1, 1), (1, 2, 2)]))
    >>> bool((batch_signatures([g])[0] == wl_signature(g)).all())
    True
    """
    from repro.ged.plan import padded_batch, slot_bucket
    sigs = np.zeros((len(graphs), spec.dims), dtype=np.int32)
    if not len(graphs):
        return sigs
    import jax
    import jax.numpy as jnp
    executor = executor or Executor()
    fns = {} if fns is None else fns
    mult = executor.batch_multiple
    by_slots: Dict[int, list] = {}
    for i, g in enumerate(graphs):
        by_slots.setdefault(slot_bucket(g.n), []).append(i)
    for slots in sorted(by_slots):
        idxs = by_slots[slots]
        for lo in range(0, len(idxs), chunk):
            part = idxs[lo:lo + chunk]
            batch = padded_batch(len(part), mult)
            vlab = np.zeros((batch, slots), dtype=np.int32)
            mask = np.zeros((batch, slots), dtype=np.int32)
            adj = np.zeros((batch, slots, slots), dtype=np.int32)
            for r, gi in enumerate(part):
                g = graphs[gi]
                vlab[r, :g.n] = g.vlabels
                mask[r, :g.n] = 1
                adj[r, :g.n, :g.n] = g.adj
            key = (spec, slots, batch)
            fn = fns.get(key)
            if fn is None:
                one = _signature_fn(spec, slots)

                def batched(v, mk, a, _one=one):
                    return jax.vmap(_one)(v, mk, a)

                if isinstance(executor, ShardedExecutor) and mult > 1:
                    from jax.sharding import PartitionSpec as P

                    from repro.parallel.ops import shard_map
                    axes = executor.axes
                    fn = jax.jit(shard_map(
                        batched, mesh=executor.mesh,
                        in_specs=(P(axes),) * 3, out_specs=P(axes),
                        check=False))
                else:
                    fn = jax.jit(batched)
                fns[key] = fn
            out = np.asarray(fn(jnp.asarray(vlab), jnp.asarray(mask),
                                jnp.asarray(adj)))
            sigs[np.asarray(part, dtype=np.int64)] = out[:len(part)]
    return sigs


def pair_key_from_digests(dq: bytes, dg: bytes, verification: bool,
                          tau: Optional[float], cfg: EngineConfig,
                          backend: str, digest: str = "exact") -> tuple:
    """:func:`pair_key` when the graph digests are already in hand — the
    form :meth:`repro.ged.GedEngine.cached_distance` uses for pivot
    lookups over pre-digested corpus members (no re-hashing per probe)."""
    return (digest, dq, dg, bool(verification),
            None if tau is None else float(tau), cfg, backend)


def pair_key(q: Graph, g: Graph, verification: bool, tau: Optional[float],
             cfg: EngineConfig, backend: str, digest: str = "exact") -> tuple:
    """Cache key for one query: pair digests + mode (tau-aware) + config.

    The same pair in a different mode (or at a different tau) keys
    differently, so a verification answer can never shadow a computation:

    >>> from repro.ged.plan import as_graph
    >>> q = as_graph(([0], [])); g = as_graph(([1], []))
    >>> pair_key(q, g, True, 2.0, None, "jax") == \\
    ...     pair_key(q, g, False, None, None, "jax")
    False

    ``digest`` selects the graph-hash family: ``"exact"`` (default; equal
    keys mean byte-identical graphs, mappings stay index-compatible) or
    ``"wl"`` (:func:`wl_digest`; isomorphic duplicates share keys, raising
    hit rates on graph-DB workloads — cache copies drop their mappings):

    >>> p = as_graph(([1], []))                 # same graph, new object
    >>> pair_key(q, p, False, None, None, "jax", digest="wl") == \\
    ...     pair_key(q, g, False, None, None, "jax", digest="wl")
    True
    """
    fn = DIGESTS[digest]
    return pair_key_from_digests(fn(q), fn(g), verification, tau, cfg,
                                 backend, digest=digest)


def detached(outcome: GedOutcome, stats: Dict[str, float]) -> GedOutcome:
    """An independent copy of ``outcome`` — own stats dict, own mapping
    array — with ``stats`` swapped in.  Callers may mutate what they are
    handed without corrupting a cached entry (or a duplicate's answer).

    >>> from repro.ged.results import GedOutcome
    >>> a = GedOutcome(ged=1.0, similar=None, certified=True,
    ...                lower_bound=1.0, upper_bound=1.0, mapping=None,
    ...                backend="exact", wall_s=0.0, stats={"rung": 0})
    >>> b = detached(a, {**a.stats, "cached": True})
    >>> b.stats["cached"], "cached" in a.stats
    (True, False)
    """
    mapping = None if outcome.mapping is None else np.array(outcome.mapping)
    return dataclasses.replace(outcome, mapping=mapping, stats=stats)


class ResultCache:
    """LRU cache of :class:`GedOutcome` keyed by :func:`pair_key`.

    Sits in front of every executor (``GedEngine`` consults it before
    planning), so duplicate pairs — across calls or within one batch —
    never re-execute, whatever the backend.

    >>> from repro.ged.results import GedOutcome
    >>> cache = ResultCache(maxsize=2)
    >>> cache.get(("some", "key")) is None     # miss
    True
    >>> out = GedOutcome(ged=2.0, similar=None, certified=True,
    ...                  lower_bound=2.0, upper_bound=2.0, mapping=None,
    ...                  backend="jax", wall_s=0.01)
    >>> cache.put(("some", "key"), out)
    >>> hit = cache.get(("some", "key"))
    >>> hit.ged, hit.stats["cached"], (cache.hits, cache.misses)
    (2.0, True, (1, 1))
    """

    def __init__(self, maxsize: int = 4096):
        self.maxsize = int(maxsize)
        self._entries: "collections.OrderedDict[tuple, GedOutcome]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        # pivot-lookup traffic (CandidateIndex distance reuse) is counted
        # separately from query hits/misses: a pivot miss is expected and
        # must not skew the result-cache hit rate the serving layer reads.
        self.pivot_hits = 0
        self.pivot_misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key: tuple) -> Optional[GedOutcome]:
        """Read-only probe: no LRU bump, no hit/miss counting, and — unlike
        :meth:`get` — no detached copy.  Callers must treat the entry as
        frozen and may only read *scalars* off it (``ged``, ``certified``);
        in particular a peeked entry's ``mapping`` must never be handed
        out, because under WL digests the stored copy already dropped it
        and resurrecting one from a different orientation's entry would
        pair vertices of the wrong graph.  This is the lookup
        :meth:`repro.ged.GedEngine.cached_distance` builds pivot pruning
        on — thousands of probes per query, most missing, none of which
        should churn the LRU order."""
        return self._entries.get(key)

    def get(self, key: tuple) -> Optional[GedOutcome]:
        out = self._entries.get(key)
        if out is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        # wall_s stays the cost of the run that produced the entry
        return detached(out, {**out.stats, "cached": True})

    def put(self, key: tuple, outcome: GedOutcome) -> None:
        self._entries[key] = detached(outcome, dict(outcome.stats))
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
