"""The execution layer under the ``repro.ged`` facade.

Backends (:mod:`repro.ged.backends`) are pure *policies* — which pairs run
at which rung, with which bounds, when to escalate.  Everything about *how*
a packed bucket actually reaches silicon lives here:

* :class:`Executor` — default placement: one jit call per shape bucket on
  the default device, compile-cache bookkeeping, bucket packing and result
  unpacking.  Every backend drives one of these.
* :class:`ShardedExecutor` — ``shard_map`` the vmapped search over the
  device mesh's batch axes (``pod`` x ``data`` per
  :func:`repro.parallel.sharding.default_rules`), with bucket batches
  padded to shard multiples by :mod:`repro.ged.plan`.  The search's
  sort-based ``top_k_sorted`` path keeps the pair batch sharded (the
  ``lax.top_k`` custom-call would all-gather it — see
  ``repro/parallel/ops.py``).
* :class:`ResultCache` — engine-level outcome cache keyed on canonical
  pair digests (label-vocab-independent, tau-aware for verification) that
  :class:`repro.ged.GedEngine` consults before any executor runs.

Policy and placement compose freely: any backend policy runs unchanged on
any executor, which is what future async / remote / multi-host work hangs
off.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.engine import api as engine_api
from repro.core.engine.search import EngineConfig
from repro.core.exact.graph import Graph
from repro.ged.plan import Bucket, CompileCache, Vocab, pack_bucket
from repro.ged.results import GedOutcome, engine_mapping


# ---------------------------------------------------------------- executors

class Executor:
    """Runs packed buckets on the default device.

    Owns the things backends used to hand-roll: the compile-cache mirror,
    batch-shape policy (``batch_multiple``), packing, and invocation
    counters (``stats``) — so a policy layer above never touches jit, jax
    arrays, or device placement.
    """

    name = "local"

    def __init__(self) -> None:
        self.cache = CompileCache()
        self.stats: Dict[str, float] = {"calls": 0, "pairs": 0}

    @property
    def batch_multiple(self) -> int:
        """Every bucket batch must be a multiple of this (shard count)."""
        return 1

    def pack(self, pairs, slots: int, vocab: Optional[Vocab]):
        """Pack ``pairs`` with this executor's batch-shape policy."""
        return pack_bucket(pairs, slots, vocab, self.batch_multiple)

    def run_packed(self, packed, taus: np.ndarray, cfg: EngineConfig,
                   verification: bool,
                   real: Optional[int] = None) -> Dict[str, np.ndarray]:
        """One engine invocation over a packed bucket; numpy result dict.

        ``real`` — pairs before batch padding, for the ``pairs`` counter
        (defaults to the padded batch when the caller doesn't know)."""
        self._check_batch(packed)
        self.cache.record(packed, cfg, verification)
        self.stats["calls"] += 1
        self.stats["pairs"] += packed.batch if real is None else int(real)
        return self._invoke(packed, taus, cfg, verification)

    def run_bucket(self, bucket: Bucket, taus: np.ndarray, cfg: EngineConfig,
                   verification: bool) -> Dict[str, np.ndarray]:
        """Run one plan bucket; ``taus`` is the plan-global per-pair array."""
        return self.run_packed(bucket.packed, bucket.pad_values(taus), cfg,
                               verification, real=bucket.real)

    # ------------------------------------------------------------ internal

    def _check_batch(self, packed) -> None:
        mult = self.batch_multiple
        if packed.batch % mult:
            raise ValueError(
                f"batch {packed.batch} is not a multiple of the executor's "
                f"{mult} shards; pack with batch_multiple={mult} "
                "(GedEngine does this automatically)")

    def _invoke(self, packed, taus, cfg, verification):
        return engine_api.run_packed(packed, taus, cfg, verification)


class ShardedExecutor(Executor):
    """``shard_map`` the vmapped search over the mesh's batch axes.

    ``mesh`` defaults to a 1-D ``("data",)`` mesh over every local device;
    production meshes from :mod:`repro.launch.mesh` work as-is — the shard
    axes come from the ``"pairs"`` row of
    :func:`repro.parallel.sharding.default_rules` (``pod`` + ``data``),
    matching how the serving dry-run places pair batches.
    """

    name = "sharded"

    def __init__(self, mesh=None, axes: Optional[Sequence[str]] = None):
        super().__init__()
        import jax
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self.mesh = mesh
        if axes is None:
            from repro.parallel.sharding import pairs_axes
            axes = pairs_axes(mesh)
        self.axes = tuple(axes)
        self._fns: Dict[tuple, object] = {}

    @property
    def batch_multiple(self) -> int:
        from repro.parallel.sharding import default_rules
        return default_rules(self.mesh).mesh_size(self.axes)

    def _invoke(self, packed, taus, cfg, verification):
        import jax
        import jax.numpy as jnp

        key = (cfg, bool(verification), packed.n_vlabels, packed.n_elabels)
        fn = self._fns.get(key)
        if fn is None:
            from jax.sharding import PartitionSpec as P

            from repro.parallel.ops import shard_map
            spec = P(self.axes)  # leading (batch) dim sharded, rest local

            def local_shard(qv, gv, qa, ga, order, n, t):
                return engine_api._run_batch(qv, gv, qa, ga, order, n, t,
                                             *key)

            fn = jax.jit(shard_map(local_shard, mesh=self.mesh,
                                   in_specs=(spec,) * 7, out_specs=spec,
                                   check=False))
            self._fns[key] = fn
        args = engine_api.pair_tuple(packed)
        out = fn(*args, jnp.asarray(np.asarray(taus, dtype=np.float32)))
        return {k: np.asarray(v) for k, v in out.items()}


# ----------------------------------------------------------- result unpack

def engine_outcome(out: Dict[str, np.ndarray], packed, bi: int,
                   verification: bool, tau: Optional[float], backend: str,
                   wall_s: float, rung: int) -> GedOutcome:
    """One :class:`GedOutcome` from row ``bi`` of an executor result dict."""
    certified = bool(out["exact"][bi])
    n = int(packed.n[bi])
    mapping = engine_mapping(packed.order[bi], out["best_img"][bi], n)
    stats = {"rung": rung,
             "iterations": float(out["iterations"][bi]),
             "expanded": float(out["expanded"][bi])}
    lb = float(out["lower_bound"][bi])
    if verification:
        similar = bool(out["similar"][bi])
        ub = float(out["upper_bound"][bi])
        return GedOutcome(
            ged=None, similar=similar, certified=certified,
            lower_bound=lb, upper_bound=ub if similar else float("inf"),
            mapping=mapping if similar else None,
            backend=backend, wall_s=wall_s, tau=tau, stats=stats)
    raw = float(out["ged"][bi])
    ged = float(np.rint(raw)) if certified else raw
    return GedOutcome(
        ged=ged, similar=None, certified=certified,
        lower_bound=min(lb, ged), upper_bound=ged,
        mapping=mapping, backend=backend, wall_s=wall_s, stats=stats)


# ------------------------------------------------------------ result cache

def graph_digest(g: Graph) -> bytes:
    """Canonical digest of one graph, independent of any batch label vocab.

    Hashes the concrete representation (raw int64 labels + adjacency), so
    equality means *identical* graphs — mappings in cached outcomes stay
    index-compatible — and the digest never changes with whichever other
    pairs happened to share a batch.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.vlabels, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.adj, dtype=np.int64).tobytes())
    return h.digest()


def pair_key(q: Graph, g: Graph, verification: bool, tau: Optional[float],
             cfg: EngineConfig, backend: str) -> tuple:
    """Cache key for one query: pair digests + mode (tau-aware) + config."""
    return (graph_digest(q), graph_digest(g), bool(verification),
            None if tau is None else float(tau), cfg, backend)


def detached(outcome: GedOutcome, stats: Dict[str, float]) -> GedOutcome:
    """An independent copy of ``outcome`` — own stats dict, own mapping
    array — with ``stats`` swapped in.  Callers may mutate what they are
    handed without corrupting a cached entry (or a duplicate's answer)."""
    mapping = None if outcome.mapping is None else np.array(outcome.mapping)
    return dataclasses.replace(outcome, mapping=mapping, stats=stats)


class ResultCache:
    """LRU cache of :class:`GedOutcome` keyed by :func:`pair_key`.

    Sits in front of every executor (``GedEngine`` consults it before
    planning), so duplicate pairs — across calls or within one batch —
    never re-execute, whatever the backend.
    """

    def __init__(self, maxsize: int = 4096):
        self.maxsize = int(maxsize)
        self._entries: "collections.OrderedDict[tuple, GedOutcome]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> Optional[GedOutcome]:
        out = self._entries.get(key)
        if out is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        # wall_s stays the cost of the run that produced the entry
        return detached(out, {**out.stats, "cached": True})

    def put(self, key: tuple, outcome: GedOutcome) -> None:
        self._entries[key] = detached(outcome, dict(outcome.stats))
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
