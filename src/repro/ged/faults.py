"""Robustness primitives for the ``repro.ged`` engine.

The escalation structure (cheap admissible bounds -> tighter anchor-aware
bounds -> exact search) is naturally *anytime*: at every rung the engine
holds valid lower/upper bounds per pair.  This module supplies the pieces
that turn that shape into a contract:

* :class:`Deadline` — a wall-clock budget threaded from
  ``GedEngine(deadline_s=...)`` through the ``auto`` rung loop, the
  executors, and the host solver's cooperative iteration checks.  When it
  expires, every pair still returns a :class:`~repro.ged.results.GedOutcome`
  carrying its best-so-far admissible bounds with ``certified=False`` and
  ``timed_out`` in ``stats`` — never an exception, never a missing result.
* :class:`RetryPolicy` — bounded retries with exponential backoff plus
  deterministic jitter, and transient-vs-permanent error classification
  (:func:`classify_transient`).
* :class:`FaultInjector` — deterministic failure injection for every
  degradation path (``REPRO_GED_FAULT_INJECT`` or
  ``GedEngine(fault_inject=...)``), so the ladder is testable without
  flaky real faults.
* :class:`RunContext` — the per-call bundle (deadline + injector + retry
  policy) the facade hands to backends and executors; ``None`` everywhere
  means the bit-identical legacy path.
* :func:`cheap_lower_bound` / :func:`fallback_outcome` — the admissible
  stage-0-style floor used for pairs the budget never reached.

See ``docs/robustness.md`` for the full deadline/degradation contract.
"""

from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Deadline", "RetryPolicy", "RunContext", "FaultInjector",
    "InjectedFault", "Overloaded", "cheap_lower_bound", "fallback_outcome",
    "classify_transient", "get_injector", "install_injector", "warn_once",
    "FAULT_INJECT_ENV",
]

FAULT_INJECT_ENV = "REPRO_GED_FAULT_INJECT"

_LOG = logging.getLogger("repro.ged.faults")
_WARNED: set = set()


def warn_once(key: str, message: str) -> bool:
    """Log ``message`` at WARNING level once per process per ``key``.

    Degradation events (kernel fallback, host-solver ladder, lock
    timeouts) are expected to repeat under sustained faults; one line per
    failure *class* keeps the signal without flooding serving logs.
    Returns whether the message was emitted.

    >>> warn_once("doctest-demo", "something degraded")
    True
    >>> warn_once("doctest-demo", "something degraded")   # suppressed
    False
    """
    if key in _WARNED:
        return False
    _WARNED.add(key)
    _LOG.warning(message)
    return True


# ------------------------------------------------------------- deadlines

class Deadline:
    """A wall-clock budget: ``Deadline(0.5)`` expires 0.5s after creation.

    ``Deadline(None)`` never expires (every check is a cheap constant) —
    the facade builds one unconditionally so callers never branch on
    "is there a deadline".

    >>> d = Deadline(None)
    >>> d.expired(), d.remaining() == float("inf")
    (False, True)
    >>> Deadline(-1.0).expired()        # already spent on arrival
    True
    """

    __slots__ = ("t_end", "t_start")

    def __init__(self, seconds: Optional[float],
                 _now: Optional[float] = None):
        now = time.monotonic() if _now is None else _now
        self.t_start = now
        self.t_end = None if seconds is None else now + float(seconds)

    def expired(self) -> bool:
        """True once the budget is spent (never for ``Deadline(None)``)."""
        return self.t_end is not None and time.monotonic() >= self.t_end

    def remaining(self) -> float:
        """Seconds left (``inf`` for no deadline, clamped at 0)."""
        if self.t_end is None:
            return float("inf")
        return max(0.0, self.t_end - time.monotonic())

    def sub(self, seconds: Optional[float]) -> "Deadline":
        """A child deadline: ``seconds`` from now, capped by this one.

        This is how a per-pair budget composes with the call-level
        budget — the host-solver tail gives each pair
        ``min(per_pair, whatever the call has left)``.
        """
        if seconds is None:
            child = Deadline(None)
            child.t_end = self.t_end
            return child
        child = Deadline(float(seconds))
        if self.t_end is not None:
            child.t_end = min(child.t_end, self.t_end)
        return child


# ------------------------------------------------------ fault injection

class InjectedFault(RuntimeError):
    """A failure raised by :class:`FaultInjector` at a named site.

    ``transient`` drives :func:`classify_transient`: transient faults are
    retried by the :class:`RetryPolicy`, permanent ones degrade
    immediately (kernels -> unfused -> host solver).
    """

    def __init__(self, site: str, transient: bool = False):
        super().__init__(f"injected {'transient' if transient else 'permanent'}"
                         f" fault at {site!r}")
        self.site = site
        self.transient = transient


_SITES = frozenset({"dispatch", "kernel", "result", "lock", "host"})


@dataclasses.dataclass
class _FaultSpec:
    site: str                       # dispatch | kernel | result | lock | host
    times: float = 1                # how many matching calls fail (inf ok)
    rung: Optional[int] = None      # only fire at this escalation rung
    transient: bool = False

    def matches(self, site: str, rung: Optional[int]) -> bool:
        if self.site != site or self.times <= 0:
            return False
        if self.rung is not None and rung != self.rung:
            return False
        return True


class FaultInjector:
    """Deterministic failure injection at the engine's degradation sites.

    Specs are ``site[@key=value,...]`` joined by ``;``.  Sites:

    * ``dispatch`` — executor dispatch of a packed bucket;
    * ``kernel``   — Pallas kernel compile/runtime (fires only when the
      dispatched config has kernels enabled);
    * ``result``   — materialisation of a dispatched batch
      (``PendingBatch.result()``);
    * ``lock``     — shared-cache lock acquisition (raises the timeout
      path);
    * ``host``     — the exact host solver.

    Keys: ``times`` (how many matching calls fail, default 1, ``inf``
    allowed), ``rung`` (only that escalation rung), ``kind``
    (``transient`` | ``permanent``, default permanent).

    >>> inj = FaultInjector("dispatch@times=2,kind=transient")
    >>> inj.check("dispatch")   # doctest: +IGNORE_EXCEPTION_DETAIL
    Traceback (most recent call last):
    ...
    InjectedFault: injected transient fault at 'dispatch'
    >>> _ = inj.fired                           # one down, one to go
    >>> try: inj.check("dispatch")
    ... except InjectedFault: pass
    >>> inj.check("dispatch")                   # budget spent: no fault
    >>> inj.fired
    2
    """

    def __init__(self, spec: str = ""):
        self.specs: List[_FaultSpec] = []
        self.fired = 0
        for part in str(spec or "").split(";"):
            part = part.strip()
            if not part:
                continue
            site, _, opts = part.partition("@")
            site = site.strip()
            if site not in _SITES:
                # a typo'd site would otherwise never fire and the chaos
                # drill would silently test nothing
                raise ValueError(f"unknown fault site {site!r} in "
                                 f"{part!r}; expected one of "
                                 f"{sorted(_SITES)}")
            fs = _FaultSpec(site=site)
            for kv in opts.split(","):
                kv = kv.strip()
                if not kv:
                    continue
                k, _, v = kv.partition("=")
                k, v = k.strip(), v.strip()
                if k == "times":
                    fs.times = float("inf") if v == "inf" else int(v)
                elif k == "rung":
                    fs.rung = int(v)
                elif k == "kind":
                    fs.transient = v == "transient"
                else:
                    raise ValueError(f"unknown fault-spec key {k!r} in "
                                     f"{part!r}")
            self.specs.append(fs)

    def check(self, site: str, rung: Optional[int] = None) -> None:
        """Raise :class:`InjectedFault` when a live spec matches ``site``."""
        for fs in self.specs:
            if fs.matches(site, rung):
                fs.times -= 1
                self.fired += 1
                raise InjectedFault(site, transient=fs.transient)


# Process-global injector (environment-driven chaos testing); engine-level
# injectors ride the RunContext instead and take precedence.
_GLOBAL_INJECTOR: Optional[FaultInjector] = None
_GLOBAL_ENV: Optional[str] = None


def install_injector(injector: Optional[FaultInjector]) -> None:
    """Pin the process-global injector (``None`` restores env behavior)."""
    global _GLOBAL_INJECTOR, _GLOBAL_ENV
    _GLOBAL_INJECTOR = injector
    _GLOBAL_ENV = None if injector is None else "<installed>"


def get_injector(ctx: Optional["RunContext"] = None
                 ) -> Optional[FaultInjector]:
    """The injector in effect: the context's, the installed one, or the
    ``REPRO_GED_FAULT_INJECT`` environment spec (re-parsed when the
    variable changes, so subprocess tests can flip it per run)."""
    if ctx is not None and ctx.injector is not None:
        return ctx.injector
    global _GLOBAL_INJECTOR, _GLOBAL_ENV
    env = os.environ.get(FAULT_INJECT_ENV) or None
    if _GLOBAL_ENV == "<installed>":
        return _GLOBAL_INJECTOR
    if env != _GLOBAL_ENV:
        _GLOBAL_ENV = env
        _GLOBAL_INJECTOR = FaultInjector(env) if env else None
    return _GLOBAL_INJECTOR


# ----------------------------------------------------------- retry policy

def classify_transient(exc: BaseException) -> bool:
    """Is ``exc`` worth retrying verbatim (vs degrading immediately)?

    Injected faults carry their own kind; real-world transients are
    resource/communication shaped (OOM pressure, interrupted syscalls,
    runner hiccups).  Compile/lowering errors are permanent by
    construction — retrying the same trace cannot succeed, so they go
    straight to the degradation ladder.

    >>> classify_transient(InjectedFault("dispatch", transient=True))
    True
    >>> classify_transient(ValueError("bad shape"))
    False
    """
    if isinstance(exc, InjectedFault):
        return exc.transient
    if isinstance(exc, (OSError, TimeoutError, ConnectionError)):
        return True
    text = f"{type(exc).__name__}: {exc}"
    return any(tag in text for tag in (
        "RESOURCE_EXHAUSTED", "UNAVAILABLE", "DEADLINE_EXCEEDED",
        "ABORTED", "INTERNAL: Failed to"))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff and deterministic jitter.

    ``backoff_s(attempt)`` grows ``base * 2**attempt`` up to ``cap_s``,
    plus a small attempt-keyed jitter (golden-ratio hash — deterministic,
    so tests replay exactly, yet de-synchronised across attempt counts).

    >>> p = RetryPolicy(max_retries=2, base_s=0.1, cap_s=1.0)
    >>> 0.1 <= p.backoff_s(0) < 0.15
    True
    >>> p.backoff_s(5) <= 1.0 * 1.5
    True
    """

    max_retries: int = 2
    base_s: float = 0.05
    cap_s: float = 2.0

    def backoff_s(self, attempt: int) -> float:
        base = min(self.base_s * (2.0 ** attempt), self.cap_s)
        jitter = ((attempt * 0.6180339887498949) % 1.0) * 0.5
        return base * (1.0 + jitter)


# ------------------------------------------------------------ run context

@dataclasses.dataclass
class RunContext:
    """Per-call robustness bundle the facade threads through a run.

    ``deadline`` is the call-level budget (:class:`Deadline`, never
    ``None`` once built — a no-deadline call carries ``Deadline(None)``);
    ``per_pair_deadline_s`` caps each host-solver pair on top of it;
    ``injector``/``retry`` configure the fault path.  ``stats`` collects
    fault counters the facade folds into ``engine.stats``.
    """

    deadline: Deadline = dataclasses.field(
        default_factory=lambda: Deadline(None))
    per_pair_deadline_s: Optional[float] = None
    injector: Optional[FaultInjector] = None
    retry: RetryPolicy = RetryPolicy()
    stats: Dict[str, float] = dataclasses.field(default_factory=dict)

    def bump(self, key: str, by: float = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + by

    @property
    def has_deadline(self) -> bool:
        return self.deadline.t_end is not None

    def expired(self) -> bool:
        return self.deadline.expired()

    def pair_deadline(self) -> Deadline:
        """Budget for one host-solver pair: per-pair cap under the call
        budget (see :meth:`Deadline.sub`)."""
        return self.deadline.sub(self.per_pair_deadline_s)


# ------------------------------------------------- admissible fallbacks

def cheap_lower_bound(q, g) -> float:
    """Admissible O(n + m) GED floor for a pair the budget never reached.

    The host-side twin of the stage-0 corpus scan
    (:func:`repro.core.engine.corpus.stage0_reference`):
    ``Y_v + max(Y_e, ceil(L1(degree sequences) / 2))`` — vertex and edge
    costs are disjoint so the sum stays a sound lower bound.

    >>> from repro.ged.plan import as_graph
    >>> q = as_graph(([0, 0], [(0, 1, 1)]))
    >>> g = as_graph(([0, 1, 1], [(0, 1, 1), (1, 2, 1)]))
    >>> cheap_lower_bound(q, g)
    3.0
    """
    from collections import Counter

    cqv = Counter(np.asarray(q.vlabels).tolist())
    cgv = Counter(np.asarray(g.vlabels).tolist())
    y_v = max(q.n, g.n) - sum(min(cqv[k], cgv[k]) for k in cqv.keys() & cgv)
    cqe = Counter(a for _, _, a in q.edges())
    cge = Counter(a for _, _, a in g.edges())
    y_e = max(q.m, g.m) - sum(min(cqe[k], cge[k]) for k in cqe.keys() & cge)
    k = max(q.n, g.n, 1)
    dq = np.zeros(k)
    dq[: q.n] = np.sort(q.degrees())[::-1]
    dg = np.zeros(k)
    dg[: g.n] = np.sort(g.degrees())[::-1]
    d = np.ceil(np.sum(np.abs(dq - dg)) / 2.0)
    return float(y_v + max(y_e, d))


def fallback_outcome(q, g, verification: bool, tau: Optional[float],
                     backend: str, *, timed_out: bool = True,
                     lower_bound: Optional[float] = None,
                     upper_bound: float = float("inf"),
                     stats: Optional[Dict[str, float]] = None):
    """A sound, uncertified :class:`~repro.ged.results.GedOutcome` for a
    pair the run could not finish (deadline expiry, exhausted faults).

    ``lower_bound`` defaults to :func:`cheap_lower_bound` and is always
    raised to it (both floors are admissible, so the max is too);
    ``upper_bound`` is whatever best-so-far incumbent the caller has
    (``inf`` when no full mapping was ever found).  Verification answers
    stay ``similar=None`` — unknown — unless the surviving bounds already
    decide the question (floor above tau rejects; incumbent at or below
    tau accepts), in which case the verdict is sound even though the
    search never finished.
    """
    from repro.ged.results import GedOutcome

    lb = cheap_lower_bound(q, g)
    if lower_bound is not None:
        lb = max(lb, float(lower_bound))
    ub = float(upper_bound)
    lb = min(lb, ub)            # a real incumbent caps every floor
    out_stats = {"rung": -2, **(stats or {})}
    if timed_out:
        out_stats["timed_out"] = True
    similar: Optional[bool] = None
    if verification and tau is not None:
        if lb > tau:
            similar = False     # sound reject: floor already above tau
        elif ub <= tau:
            similar = True      # sound accept: a mapping at or below tau
    return GedOutcome(
        ged=None, similar=similar, certified=False,
        lower_bound=lb, upper_bound=ub, mapping=None,
        backend=backend, wall_s=0.0,
        tau=tau if verification else None, stats=out_stats)


# --------------------------------------------------------------- serving

class Overloaded(RuntimeError):
    """Load-shed response: the serving queue is full; retry later.

    Raised by the serving admission controller *before* any engine work
    runs, so an overloaded service answers in microseconds instead of
    queueing unboundedly.  ``retry_after_s`` is the caller's backoff
    hint, ``queue_depth``/``capacity`` the queue snapshot that shed it.
    """

    def __init__(self, retry_after_s: float, queue_depth: int,
                 capacity: int):
        super().__init__(
            f"serving queue full ({queue_depth}/{capacity} pending); "
            f"retry after {retry_after_s:.2f}s")
        self.retry_after_s = float(retry_after_s)
        self.queue_depth = int(queue_depth)
        self.capacity = int(capacity)
