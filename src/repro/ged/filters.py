"""The filter half of the corpus filter-verify pipeline.

:class:`FilterIndex` is what a :class:`repro.ged.GraphStore` builds at
ingest time: corpus graphs grouped per slot bucket, their stage-0 features
(:mod:`repro.core.engine.corpus`) packed into resident device arrays, and
one fused scan per bucket that scores a query against the whole bucket
with sound lower bounds — no per-pair planning, no per-pair packing.

The scan composes with the executor layer the same way backends do: on a
plain :class:`~repro.ged.exec.Executor` it is one jit call per bucket; on
a :class:`~repro.ged.exec.ShardedExecutor` the corpus axis is
``shard_map``-ed over the executor's mesh (bucket batches are padded to
the shard multiple at ingest), so ``GraphStore(mesh=...)`` shards the
filter scan exactly like it shards verification batches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine.corpus import (CorpusFeatures, graph_features,
                                      stage0_lower_bounds)
from repro.core.exact.graph import Graph
from repro.ged.exec import Executor, ShardedExecutor
from repro.ged.plan import Vocab, padded_batch, slot_bucket


@dataclasses.dataclass
class FeatureBucket:
    """One slot bucket of the corpus: ids + resident feature arrays."""

    slots: int
    ids: List[int]              # corpus positions, ingest order
    features: CorpusFeatures    # batch padded to the executor's multiple
    real: int                   # rows before batch padding


class FilterIndex:
    """Stage-0 scan over an ingested corpus.

    >>> from repro.ged.plan import as_graph, graphs_vocab
    >>> corpus = [as_graph(([0, 1], [(0, 1, 1)])), as_graph(([5], []))]
    >>> idx = FilterIndex(corpus, list(range(2)), graphs_vocab(corpus))
    >>> lbs = idx.scan(as_graph(([0, 1], [(0, 1, 1)])))
    >>> float(lbs[0]), bool(lbs[1] >= 2.0)   # identical graph; far singleton
    (0.0, True)
    """

    def __init__(self, graphs: Sequence[Graph], ids: Sequence[int],
                 vocab: Vocab, executor: Optional[Executor] = None,
                 features: Optional[Dict[int, Tuple[Sequence[int],
                                                    CorpusFeatures]]] = None):
        self.vocab = vocab
        self.executor = executor or Executor()
        self.buckets: List[FeatureBucket] = []
        self._fns: Dict[tuple, object] = {}
        self.stats: Dict[str, float] = {"scans": 0, "scanned": 0,
                                        "subset_scans": 0, "packed_rows": 0}
        if features is None:
            by_slots: Dict[int, List[int]] = {}
            for gid in ids:
                by_slots.setdefault(slot_bucket(graphs[gid].n),
                                    []).append(gid)
            for s in sorted(by_slots):
                bids = by_slots[s]
                feats = graph_features([graphs[i] for i in bids], vocab,
                                       width=s)
                self.stats["packed_rows"] += feats.batch
                self.buckets.append(self._bucket(s, bids, feats))
        else:
            # warm open: per-bucket arrays come off disk (mmap-backed,
            # unpadded — see repro.store_io.graphstore_io), so no
            # feature packing runs; padding to the executor's shard
            # multiple is the only per-open work
            for s in sorted(features):
                bids, feats = features[s]
                self.buckets.append(self._bucket(int(s), list(bids), feats))
        self._reindex()

    def _bucket(self, slots: int, bids: List[int],
                feats: CorpusFeatures) -> FeatureBucket:
        """Pad unpadded per-bucket arrays to the executor's shard multiple
        (a no-op copy-free pass-through on a single device)."""
        real = feats.batch
        pad = -real % max(self.executor.batch_multiple, 1)
        if pad:
            last = 1 if real else 0
            feats = CorpusFeatures(
                *(np.concatenate([a, np.repeat(a[-last:], pad, axis=0)])
                  for a in (feats.vhist, feats.ehist, feats.degs,
                            feats.n, feats.m)))
        return FeatureBucket(slots, bids, feats, real)

    def _reindex(self) -> None:
        # id order the scan output follows (bucket construction order)
        self.ids: List[int] = [gid for b in self.buckets for gid in b.ids]
        # id -> (bucket index, row within bucket), for subset gathers
        self._where: Dict[int, Tuple[int, int]] = {
            gid: (bi, ri) for bi, b in enumerate(self.buckets)
            for ri, gid in enumerate(b.ids)}

    def extend(self, graphs: Sequence[Graph], new_ids: Sequence[int]
               ) -> None:
        """Incrementally index ``new_ids``: pack only the new rows and
        append them to their slot buckets (creating buckets as needed) —
        the store's ``add()`` path, no full re-pack."""
        by_slots: Dict[int, List[int]] = {}
        for gid in new_ids:
            by_slots.setdefault(slot_bucket(graphs[gid].n), []).append(gid)
        at = {b.slots: bi for bi, b in enumerate(self.buckets)}
        for s in sorted(by_slots):
            bids = by_slots[s]
            feats = graph_features([graphs[i] for i in bids], self.vocab,
                                   width=s)
            self.stats["packed_rows"] += feats.batch
            bi = at.get(s)
            if bi is None:
                self.buckets.append(self._bucket(s, bids, feats))
                self.buckets.sort(key=lambda b: b.slots)
            else:
                old = self.buckets[bi]
                merged = CorpusFeatures(
                    *(np.concatenate([np.asarray(a)[:old.real], b])
                      for a, b in zip(
                          (old.features.vhist, old.features.ehist,
                           old.features.degs, old.features.n,
                           old.features.m),
                          (feats.vhist, feats.ehist, feats.degs,
                           feats.n, feats.m))))
                self.buckets[bi] = self._bucket(
                    s, old.ids[:old.real] + bids, merged)
        self._reindex()

    def __len__(self) -> int:
        return len(self.ids)

    # ------------------------------------------------------------- scan

    def scan(self, query: Graph) -> np.ndarray:
        """Stage-0 lower bound of ``delta(query, g)`` for every indexed id.

        Returns an array aligned with :attr:`ids` (bucket construction
        order).  One fused device call per bucket; the degree width is
        the max of the bucket's slots and the query's slot bucket, so
        repeated queries reuse compilations.
        """
        self.stats["scans"] += 1
        parts = []
        for b in self.buckets:
            width = max(b.slots, slot_bucket(query.n))
            qf = graph_features([query], self.vocab, width=width)
            parts.append(np.asarray(
                self._dispatch(qf, b.features, b.slots, width))[: b.real])
            self.stats["scanned"] += b.real
        return np.concatenate(parts) if parts \
            else np.zeros(0, dtype=np.float32)

    def scan_by_id(self, query: Graph) -> Dict[int, float]:
        """:meth:`scan` keyed by corpus id instead of position."""
        return dict(zip(self.ids, self.scan(query).tolist()))

    def scan_subset(self, query: Graph, ids: Sequence[int]
                    ) -> Dict[int, float]:
        """Stage-0 lower bounds for ``ids`` only — the scan a store runs
        after a candidate index already pruned the rest of the corpus.

        The requested rows are gathered out of the resident per-bucket
        feature arrays, padded to a power-of-two batch (rounded to the
        executor's shard multiple), and pushed through the same compiled
        scan functions the full pass uses — compile keys depend only on
        ``(slots, batch, widths)``, so subset scans at a given size reuse
        compilations across queries.  ``stats["scanned"]`` counts the
        *requested* rows, which is what makes the store's funnel ratios
        honest about index savings.
        """
        self.stats["scans"] += 1
        self.stats["subset_scans"] += 1
        out: Dict[int, float] = {}
        by_bucket: Dict[int, List[int]] = {}
        for gid in ids:
            by_bucket.setdefault(self._where[gid][0], []).append(gid)
        mult = max(self.executor.batch_multiple, 1)
        for bi in sorted(by_bucket):
            b = self.buckets[bi]
            gids = by_bucket[bi]
            rows = np.asarray([self._where[g][1] for g in gids],
                              dtype=np.int64)
            batch = padded_batch(len(rows), mult)
            take = np.concatenate(
                [rows, np.repeat(rows[-1:], batch - len(rows))])
            feats = CorpusFeatures(
                *(np.ascontiguousarray(a[take])
                  for a in (b.features.vhist, b.features.ehist,
                            b.features.degs, b.features.n, b.features.m)))
            width = max(b.slots, slot_bucket(query.n))
            qf = graph_features([query], self.vocab, width=width)
            vals = np.asarray(
                self._dispatch(qf, feats, b.slots, width))[:len(rows)]
            self.stats["scanned"] += len(rows)
            out.update(zip(gids, vals.tolist()))
        return out

    # --------------------------------------------------------- internal

    def _dispatch(self, qf: CorpusFeatures, cf: CorpusFeatures,
                  slots: int, width: int):
        import jax
        import jax.numpy as jnp

        key = (slots, cf.batch, width, cf.vhist.shape[1],
               cf.ehist.shape[1])
        fn = self._fns.get(key)
        if fn is None:
            pad_c = width - cf.degs.shape[1]

            def scan_fn(qvh, qeh, qdeg, qn, qm, cvh, ceh, cdeg, cn, cm):
                cdeg = jnp.pad(cdeg, ((0, 0), (0, pad_c)))
                return stage0_lower_bounds(qvh, qeh, qdeg, qn, qm,
                                           cvh, ceh, cdeg, cn, cm)

            if isinstance(self.executor, ShardedExecutor):
                from jax.sharding import PartitionSpec as P

                from repro.parallel.ops import shard_map
                axes = self.executor.axes
                fn = jax.jit(shard_map(
                    scan_fn, mesh=self.executor.mesh,
                    in_specs=(P(),) * 5 + (P(axes),) * 5,
                    out_specs=P(axes), check=False))
            else:
                fn = jax.jit(scan_fn)
            self._fns[key] = fn
        return fn(jnp.asarray(qf.vhist[0]), jnp.asarray(qf.ehist[0]),
                  jnp.asarray(qf.degs[0]), jnp.asarray(qf.n[0]),
                  jnp.asarray(qf.m[0]), jnp.asarray(cf.vhist),
                  jnp.asarray(cf.ehist), jnp.asarray(cf.degs),
                  jnp.asarray(cf.n), jnp.asarray(cf.m))
