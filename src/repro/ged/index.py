"""``ged.CandidateIndex`` — the sublinear stage −1 of the search pipeline.

Every stage the :class:`repro.ged.GraphStore` runs is O(|DB|) per query:
even the cheapest one, the stage-0 feature scan, touches every resident
row.  At the million-graph north star that linear factor *is* the query
cost, so this module puts a candidate index in front of the scan — stage
−1 — that generates candidates in (near-)sublinear time and hands the rest
of the pipeline only the survivors.  Two pruning families compose:

**Banded WL-sketch LSH.**  Every corpus graph gets an integer sketch
(:func:`repro.ged.exec.wl_signature` — hashed WL-color histogram ⊕ hashed
edge-label histogram ⊕ ``(n, m)``; the corpus side is JAX-batched and
mesh-sharded via :func:`repro.ged.exec.batch_signatures`).  The sketch is
built so one unit edit moves its L1 norm by at most a *damage factor*
(:func:`sketch_damage`; 2 at the default depth-0 sketch).  That single
inequality powers both probe modes:

* ``exact`` mode (the default) stays **sound** by widening bands from the
  admissible bound: if ``GED(q, g) <= tau`` then the sketches differ by at
  most ``budget = damage * tau`` in L1, so splitting the sketch into
  ``budget + 1`` bands pigeonholes at least one band into *exact*
  equality — probing only hash-colliding bands can never drop a true hit.
  Independent shuffled band partitions (``reps``) are intersected: each is
  individually sound, and the intersection is far more selective.
* probabilistic mode (``recall=r``) is the explicit opt-out of exactness:
  it keeps only ``ceil(r * (budget + 1))`` of the pigeonhole bands, so a
  true hit whose sketch damage spreads adversarially may be missed; pairs
  whose sketch L1 is below the kept band count are still always found.
  Rejections in this mode come back *uncertified*.

Colliding candidates are post-filtered by the full-sketch bound
``ceil(L1 / damage) > tau`` (admissible, so this prune is certified in
either mode).

**Distance-reuse pivot pruning** (Nass-style, PAPERS.md arXiv
2004.01124).  GED is a metric, so for any pivot ``p`` with known
distances, ``|GED(q, p) - GED(p, y)| <= GED(q, y)``.  DB–DB distances are
*not* kept in a second structure: they live in the engine's existing
:class:`~repro.ged.exec.ResultCache`, keyed on canonical digests — seeded
at ingest (``pivot_seeds``), and grown lazily by query traffic (top-k
walks and the per-query pivot probes themselves write cache entries; a
query that is a corpus member becomes a pivot).  At probe time the index
computes ``GED(q, p)`` for a handful of pivots and reads ``GED(p, y)``
back via :meth:`repro.ged.GedEngine.cached_distance`; candidates whose
triangle bound exceeds tau are rejected with a certificate.

``GraphStore(index=...)`` wires all of this in as stage −1 (see
``docs/index.md``); ``GraphStore(index=None)`` reproduces the previous
pipeline bit-for-bit.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.exact.graph import Graph
from repro.ged.exec import (DIGESTS, Executor, SketchSpec, batch_signatures,
                            wl_signature)

__all__ = ["CandidateIndex", "sketch_damage"]


def sketch_damage(spec: SketchSpec, max_degree: int = 0) -> float:
    """Max L1 movement of a :func:`~repro.ged.exec.wl_signature` sketch
    under one unit edit operation — the admissibility constant behind
    every bound the index certifies.

    At ``wl_iters=0`` the sketch is a plain (hashed) label histogram plus
    ``(n, m)``: a vertex relabel moves one unit between two vertex bins
    (2), an edge insert/delete touches one edge bin plus ``m`` (2), an
    edge relabel two edge bins (2), a vertex insert/delete one vertex bin
    plus ``n`` (2) — so the damage is 2 regardless of structure.

    At depth ``r >= 1`` an edit can recolor every vertex whose ``r``-hop
    ball sees it, so the factor grows with the degree bound ``max_degree``
    (callers pass the corpus/query max degree plus tau, covering every
    intermediate graph along an optimal edit path): a relabel recolors at
    most ``B_r`` vertices (ball volume), an edge edit at most ``2 B_{r-1}``
    plus its edge-part damage.

    >>> sketch_damage(SketchSpec())                    # depth 0
    2.0
    >>> sketch_damage(SketchSpec(wl_iters=1), max_degree=3)
    8.0
    """
    r = spec.wl_iters
    if r == 0:
        return 2.0
    d = max(int(max_degree), 1)

    def ball(k: int) -> int:
        return sum(d ** i for i in range(k + 1))

    return float(max(2 * ball(r), 4 * ball(r - 1) + 2))


class CandidateIndex:
    """Banded WL-sketch LSH + pivot pruning over an ingested corpus.

    Parameters
    ----------
    graphs : the store's corpus (full list; ``ids`` selects the indexed
        representatives).
    ids : corpus positions to index — the store passes its dedup
        representatives.
    executor : optional :class:`~repro.ged.exec.Executor`; a
        :class:`~repro.ged.exec.ShardedExecutor` shard-maps the ingest
        signature build over its mesh.
    dims_v / dims_e / wl_iters : sketch shape
        (:class:`~repro.ged.exec.SketchSpec`).
    reps : independent shuffled band partitions; candidates must collide
        in *every* rep (each rep is sound on its own, so the intersection
        is too).
    recall : ``None`` (default) = exact mode — band count comes from the
        admissible pigeonhole bound and a probe can never drop a graph
        within tau.  A float in (0, 1] opts out of exactness: only
        ``ceil(recall * (budget + 1))`` bands are probed and rejections
        are uncertified.  ``recall=1.0`` coincides with exact mode.
    max_pivots / pivot_seeds / pivot_coverage : distance-reuse knobs —
        how many pivots a probe consults, how many pivots to seed
        eagerly at ingest, and how many sketch-nearest neighbors each
        seeded pivot pre-computes distances to (through the engine, into
        its result cache).
    pivot_min_candidates : skip pivot probing (and its engine calls)
        when fewer candidates than this survive the sketch — the
        triangle bound can't pay for its ``GED(q, p)`` computations on a
        handful of survivors.
    seed : RNG seed for the band shuffles and pivot selection.

    >>> from repro.ged.plan import as_graph
    >>> corpus = [as_graph(([0, 1], [(0, 1, 1)])), as_graph(([5, 5], []))]
    >>> idx = CandidateIndex(corpus, [0, 1])
    >>> sorted(idx.probe(as_graph(([0, 1], [(0, 1, 1)])), tau=0.0))
    [0]
    """

    def __init__(self, graphs: Sequence[Graph], ids: Sequence[int], *,
                 executor: Optional[Executor] = None,
                 dims_v: int = 64, dims_e: int = 16, wl_iters: int = 0,
                 reps: int = 2, recall: Optional[float] = None,
                 max_pivots: int = 4, pivot_seeds: int = 0,
                 pivot_coverage: int = 32, pivot_min_candidates: int = 8,
                 seed: int = 7, sigs: Optional[np.ndarray] = None,
                 max_deg: Optional[int] = None):
        if recall is not None and not 0.0 < recall <= 1.0:
            raise ValueError(f"recall must be in (0, 1], got {recall!r}")
        if reps < 1:
            raise ValueError("reps must be >= 1")
        self.spec = SketchSpec(dims_v=int(dims_v), dims_e=int(dims_e),
                               wl_iters=int(wl_iters))
        self.recall = None if recall is None else float(recall)
        self.reps = int(reps)
        self.max_pivots = int(max_pivots)
        self.pivot_seeds = int(pivot_seeds)
        self.pivot_coverage = int(pivot_coverage)
        self.pivot_min_candidates = int(pivot_min_candidates)
        self.seed = int(seed)
        self._graphs = graphs
        self.ids: List[int] = [int(i) for i in ids]
        self._pos_of: Dict[int, int] = {g: i for i, g in enumerate(self.ids)}
        self._fns: Dict[tuple, object] = {}
        self.stats: Dict[str, float] = {
            "probes": 0, "probe_candidates": 0, "probe_fallbacks": 0,
            "tables_built": 0, "pivot_queries": 0, "pivot_lookups": 0,
            "pivots": 0, "seeded_pairs": 0, "nearest_calls": 0,
            "signatures_built": 0,
        }
        if sigs is not None:
            # restored from a persisted store (repro.store_io): the
            # signature matrix comes off disk — possibly mmap-backed —
            # so no device build runs; band tables rebuild lazily from
            # it, bit-identical (they are a deterministic function of
            # sigs + the seeded permutations)
            sigs = np.asarray(sigs)
            if sigs.shape != (len(self.ids), self.spec.dims):
                raise ValueError(
                    f"restored sigs shape {sigs.shape} does not match "
                    f"({len(self.ids)}, {self.spec.dims})")
            self.sigs = sigs
        else:
            self.sigs = batch_signatures([graphs[i] for i in self.ids],
                                         self.spec, executor, self._fns)
            self.stats["signatures_built"] += len(self.ids)
        if max_deg is not None:
            self._max_deg = int(max_deg)
        else:
            self._max_deg = max(
                (int(graphs[i].degrees().max()) for i in self.ids
                 if graphs[i].n), default=0)
        rng = np.random.default_rng(self.seed)
        self._perms = [rng.permutation(self.spec.dims)
                       for _ in range(self.reps)]
        self._rng = rng
        # band tables built lazily per (rep, band count) on probe traffic
        self._tables: Dict[Tuple[int, int], List[Dict[bytes, np.ndarray]]] \
            = {}
        # pivots in insertion order (most recent consulted first); their
        # distances live in the *engine's* result cache, nowhere else
        self._pivots: Dict[int, None] = {}
        self._engine = None
        self._digests: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self.ids)

    def extend(self, graphs: Sequence[Graph], new_ids: Sequence[int],
               executor: Optional[Executor] = None) -> None:
        """Incrementally index ``new_ids``: build signatures for the new
        rows only, append them to the resident matrix, and invalidate
        the lazily-built band tables (they rebuild on the next probe
        from the merged matrix — deterministic, so probes after an
        ``extend`` match a from-scratch build over the same ids)."""
        new_ids = [int(i) for i in new_ids]
        if not new_ids:
            return
        new_sigs = batch_signatures([graphs[i] for i in new_ids],
                                    self.spec, executor, self._fns)
        self.stats["signatures_built"] += len(new_ids)
        self.sigs = np.concatenate([np.asarray(self.sigs), new_sigs]) \
            if len(self.sigs) else new_sigs
        for gid in new_ids:
            self._pos_of[gid] = len(self.ids)
            self.ids.append(gid)
        self._tables.clear()
        deg = max((int(graphs[i].degrees().max()) for i in new_ids
                   if graphs[i].n), default=0)
        self._max_deg = max(self._max_deg, deg)

    @property
    def exact(self) -> bool:
        """True when probes are sound (no ``recall`` opt-out)."""
        return self.recall is None

    # ------------------------------------------------------------- probe

    def damage(self, query: Optional[Graph] = None,
               tau: float = 0.0) -> float:
        """Per-edit sketch damage for this corpus + ``query`` at ``tau``
        (degree bound covers intermediate graphs along the edit path)."""
        deg = self._max_deg
        if query is not None and query.n:
            deg = max(deg, int(query.degrees().max()))
        return sketch_damage(self.spec, deg + int(math.ceil(tau)))

    def probe(self, query: Graph, tau: float) -> Dict[int, float]:
        """Stage −1 candidate generation: surviving corpus ids with their
        admissible sketch lower bounds.

        In exact mode the result is a *superset* of every indexed graph
        within ``tau`` of ``query`` (pigeonhole over ``budget + 1`` bands;
        see the module docstring) — ids absent from the dict are proven
        to satisfy ``GED > tau``.  In probabilistic mode absence is only
        probable.  Either way, present ids carry
        ``lb = ceil(L1 / damage) <= tau``, a certified bound the caller
        may reuse against smaller per-job taus.
        """
        self.stats["probes"] += 1
        n_reps = len(self.sigs)
        if not n_reps:
            return {}
        sig = wl_signature(query, self.spec)
        damage = self.damage(query, tau)
        budget = int(math.floor(damage * float(tau) + 1e-9))
        need = budget + 1
        if need > self.spec.dims:
            # more bands than dims: banding cannot certify anything, so
            # fall back to the linear (still vectorized) sketch scan —
            # sound, just not sublinear at this tau/damage combination
            self.stats["probe_fallbacks"] += 1
            mask = np.ones(n_reps, dtype=bool)
        else:
            bands = need if self.recall is None \
                else max(1, int(math.ceil(self.recall * need)))
            mask = np.ones(n_reps, dtype=bool)
            for ri in range(self.reps):
                table = self._table(ri, bands)
                hit = np.zeros(n_reps, dtype=bool)
                for band, cols in zip(table,
                                      np.array_split(self._perms[ri],
                                                     bands)):
                    rows = band.get(
                        np.ascontiguousarray(sig[cols]).tobytes())
                    if rows is not None:
                        hit[rows] = True
                mask &= hit
                if not mask.any():
                    break
        cand = np.nonzero(mask)[0]
        if not len(cand):
            return {}
        l1 = np.abs(self.sigs[cand] - sig[None, :]).sum(axis=1)
        lb = np.ceil(l1 / damage - 1e-9)
        keep = lb <= float(tau) + 1e-9
        self.stats["probe_candidates"] += int(keep.sum())
        return {self.ids[int(i)]: float(b)
                for i, b in zip(cand[keep], lb[keep])}

    def nearest(self, query: Graph, limit: int) -> List[int]:
        """Corpus ids ordered by full-sketch L1 distance to ``query`` —
        the seed list a top-k walk verifies first to warm its k-th-best
        cutoff.  A linear (vectorized) pass over the resident signature
        matrix: candidate *ordering* needs no banding, and the caller's
        exactness never depends on it."""
        self.stats["nearest_calls"] += 1
        if not len(self.sigs):
            return []
        sig = wl_signature(query, self.spec)
        l1 = np.abs(self.sigs - sig[None, :]).sum(axis=1)
        order = np.argsort(l1, kind="stable")[:max(int(limit), 0)]
        return [self.ids[int(i)] for i in order]

    # ------------------------------------------------------------ pivots

    def bind_engine(self, engine, digests: Optional[Dict[int, bytes]] = None
                    ) -> None:
        """Attach the engine whose :class:`~repro.ged.exec.ResultCache`
        holds (and will keep accumulating) the DB–DB distances pivots
        prune with.  ``digests`` pre-seeds the per-id digest memo (the
        store passes its ingest-time exact digests, so pivot lookups
        never re-hash the corpus)."""
        self._engine = engine
        if digests:
            self._digests.update(digests)

    def note_pivot(self, rep_id: int) -> None:
        """Mark a corpus representative as a pivot — called by the store
        whenever a query turns out to be a corpus member, because that
        query's computed distances are now cache-resident and reusable."""
        if rep_id in self._pos_of and rep_id not in self._pivots:
            self._pivots[rep_id] = None
            self.stats["pivots"] = len(self._pivots)

    def seed_pivots(self, vocab=None) -> int:
        """Eager ingest-time pivot seeding: pick ``pivot_seeds`` spread-out
        representatives (greedy k-center on sketch L1) and compute each
        one's distance to its ``pivot_coverage`` sketch-nearest neighbors
        through the engine — the outcomes land in the engine's result
        cache, which *is* the index's distance store.  Returns the number
        of seeded DB–DB pairs; a cache-less engine seeds nothing."""
        if (self._engine is None or self._engine._cache is None
                or self.pivot_seeds <= 0 or len(self.sigs) < 2):
            return 0
        chosen: List[int] = [0]
        dist = np.abs(self.sigs - self.sigs[0][None, :]).sum(axis=1)
        while len(chosen) < min(self.pivot_seeds, len(self.sigs)):
            far = int(np.argmax(dist))
            if dist[far] <= 0:
                break
            chosen.append(far)
            dist = np.minimum(
                dist, np.abs(self.sigs - self.sigs[far][None, :])
                .sum(axis=1))
        seeded = 0
        for pos in chosen:
            l1 = np.abs(self.sigs - self.sigs[pos][None, :]).sum(axis=1)
            order = np.argsort(l1, kind="stable")
            near = [int(i) for i in order[:self.pivot_coverage + 1]
                    if int(i) != pos][:self.pivot_coverage]
            if near:
                p = self.ids[pos]
                self._engine.compute(
                    [(self._graphs[p], self._graphs[self.ids[i]])
                     for i in near], vocab=vocab)
                seeded += len(near)
            self.note_pivot(self.ids[pos])
        self.stats["seeded_pairs"] += seeded
        return seeded

    @property
    def use_pivots(self) -> bool:
        """Pivot pruning can run: an engine with a cache is bound, and at
        least one pivot exists."""
        return (self._engine is not None
                and self._engine._cache is not None
                and self.max_pivots > 0 and bool(self._pivots))

    def pivot_bounds(self, query: Graph, rep_ids: Sequence[int],
                     vocab=None) -> Dict[int, float]:
        """Certified triangle lower bounds ``|d(q,p) - d(p,y)|`` for the
        candidates in ``rep_ids``, via cached DB–DB distances.

        Computes ``GED(q, p)`` for up to ``max_pivots`` pivots (one
        engine batch — itself cached, so repeated queries pay nothing)
        and reads ``GED(p, y)`` back from the engine's result cache.
        Candidates with no cache-covered pivot simply get no bound; the
        returned dict only contains ids with a non-trivial bound.
        """
        if not self.use_pivots or len(rep_ids) < self.pivot_min_candidates:
            return {}
        pivots = list(self._pivots)[-self.max_pivots:]
        self.stats["pivot_queries"] += len(pivots)
        outs = self._engine.compute(
            [(query, self._graphs[p]) for p in pivots], vocab=vocab)
        dq = {p: float(o.ged) for p, o in zip(pivots, outs)
              if o.certified and o.ged is not None}
        if not dq:
            return {}
        bounds: Dict[int, float] = {}
        for y in rep_ids:
            dy = self._digest_of(y)
            best = 0.0
            for p, d in dq.items():
                if p == y:
                    continue
                self.stats["pivot_lookups"] += 1
                dpy = self._engine.cached_distance(
                    digests=(self._digest_of(p), dy))
                if dpy is not None:
                    best = max(best, abs(d - dpy))
            if best > 0.0:
                bounds[y] = best
        return bounds

    # ---------------------------------------------------------- internal

    def _digest_of(self, rep_id: int) -> bytes:
        d = self._digests.get(rep_id)
        if d is None:
            fn = DIGESTS[self._engine.digest if self._engine is not None
                         else "exact"]
            d = fn(self._graphs[rep_id])
            self._digests[rep_id] = d
        return d

    def _table(self, rep_idx: int, bands: int
               ) -> List[Dict[bytes, np.ndarray]]:
        key = (rep_idx, int(bands))
        table = self._tables.get(key)
        if table is None:
            table = self._build_table(rep_idx, int(bands))
            self._tables[key] = table
        return table

    def _build_table(self, rep_idx: int, bands: int
                     ) -> List[Dict[bytes, np.ndarray]]:
        """One banded hash table: for each band (a shuffled column slice
        of the signature matrix), group identical rows via a single
        ``np.unique(axis=0)`` sort — O(R log R) per band, no Python-level
        row hashing."""
        self.stats["tables_built"] += 1
        out: List[Dict[bytes, np.ndarray]] = []
        for cols in np.array_split(self._perms[rep_idx], bands):
            sub = np.ascontiguousarray(self.sigs[:, cols])
            uq, inv = np.unique(sub, axis=0, return_inverse=True)
            inv = inv.reshape(-1)
            order = np.argsort(inv, kind="stable")
            splits = np.searchsorted(inv[order], np.arange(1, len(uq)))
            groups = np.split(order, splits)
            out.append({np.ascontiguousarray(uq[k]).tobytes(): grp
                        for k, grp in enumerate(groups)})
        return out
