"""Workload planning for the ``repro.ged`` facade.

Three jobs, all shape-related:

1. **Ingestion** — :func:`as_graph` accepts the formats users actually have
   (``Graph`` objects, ``(vlabels, edges)`` tuples, adjacency dicts) so the
   facade never forces a manual conversion step.
2. **Bucketing** — :func:`build_plan` groups pairs by power-of-two slot
   count and pads each bucket's batch dimension to a power of two.  A
   mixed-size workload therefore presents the jitted engine with a handful
   of canonical shapes instead of one shape per odd batch, and every bucket
   shares one label vocabulary so the static ``n_vlabels``/``n_elabels``
   arguments match across buckets.
3. **Compile-cache bookkeeping** — the executables live in ``jax.jit``'s
   cache; :class:`CompileCache` mirrors the key set so callers can observe
   hits vs misses (``GedEngine(...).stats``) and tests can assert reuse.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine.search import EngineConfig
from repro.core.engine.tensor_graphs import (GraphPairTensors, label_vocab,
                                             pack_pairs)
from repro.core.exact.graph import Graph

MIN_SLOTS = 4

Vocab = Tuple[Tuple[int, ...], Tuple[int, ...]]


# ------------------------------------------------------------- ingestion

def as_graph(obj) -> Graph:
    """Coerce a user-facing graph description into a :class:`Graph`.

    Accepted forms:

    * ``Graph`` — returned as-is;
    * ``(vlabels, edges)`` tuple/list with ``edges`` of ``(i, j, elabel)``;
    * ``{"vlabels": [...], "edges": [...]}`` or ``{"vlabels": [...],
      "adj": matrix}`` dicts;
    * adjacency dict ``{node: (vlabel, [(neighbor, elabel), ...])}`` with
      arbitrary hashable node ids (indexed in sorted order).

    >>> g = as_graph(([0, 1, 1], [(0, 1, 1), (1, 2, 2)]))
    >>> g.n, g.m
    (3, 2)
    >>> as_graph({"a": (0, [("b", 1)]), "b": (1, [("a", 1)])}).n
    2
    """
    if isinstance(obj, Graph):
        return obj
    if isinstance(obj, dict):
        if "vlabels" in obj:
            if "adj" in obj:
                return Graph(np.asarray(obj["vlabels"]), np.asarray(obj["adj"]))
            return Graph.from_edges(list(obj["vlabels"]),
                                    list(obj.get("edges", ())))
        nodes = sorted(obj)
        index = {v: i for i, v in enumerate(nodes)}
        vlabels = [int(obj[v][0]) for v in nodes]
        edges, seen = [], set()
        for v in nodes:
            for nbr, lab in obj[v][1]:
                i, j = index[v], index[nbr]
                key = (min(i, j), max(i, j))
                if i == j or key in seen:
                    continue
                seen.add(key)
                edges.append((i, j, int(lab)))
        return Graph.from_edges(vlabels, edges)
    if isinstance(obj, (tuple, list)) and len(obj) == 2:
        vlabels, edges = obj
        return Graph.from_edges(list(vlabels), list(edges))
    raise TypeError(
        f"cannot interpret {type(obj).__name__} as a graph; expected Graph, "
        "(vlabels, edges), or an adjacency dict")


def as_pairs(pairs) -> List[Tuple[Graph, Graph]]:
    out = []
    for p in pairs:
        q, g = p
        out.append((as_graph(q), as_graph(g)))
    return out


def graphs_vocab(graphs: Sequence[Graph]) -> Vocab:
    """Shared ``(vertex_labels, edge_labels)`` vocabulary for a corpus.

    The single-graph analogue of
    :func:`repro.core.engine.tensor_graphs.label_vocab` — a
    :class:`repro.ged.GraphStore` computes it once at ingest so every
    query bucket (and the stage-0 feature histograms) share one compact
    label space.

    >>> g = as_graph(([0, 5], [(0, 1, 2)]))
    >>> graphs_vocab([g])
    ((0, 5), (2,))
    """
    return label_vocab([(g, g) for g in graphs])


def merge_vocab(vocab: Vocab, graphs: Sequence[Graph]) -> Vocab:
    """``vocab`` extended with any labels ``graphs`` introduce.

    Queries against an ingested corpus may carry labels the corpus never
    uses; packing with the merged vocabulary keeps every bucket coverage
    check satisfied while staying stable (and therefore compile-cached)
    for the common all-known-labels case.

    >>> merge_vocab(((0,), (1,)), [as_graph(([0, 7], [(0, 1, 3)]))])
    ((0, 7), (1, 3))
    """
    extra_v, extra_e = graphs_vocab(graphs)
    return (tuple(sorted(set(vocab[0]) | set(extra_v))),
            tuple(sorted(set(vocab[1]) | set(extra_e))))


# -------------------------------------------------------------- bucketing

def _pow2(n: int) -> int:
    return max(1, 1 << (int(n) - 1).bit_length())


def slot_bucket(n: int, min_slots: int = MIN_SLOTS) -> int:
    """Power-of-two slot count for a padded pair of ``n`` vertices.

    >>> [slot_bucket(n) for n in (1, 4, 5, 9)]
    [4, 4, 8, 16]
    """
    return max(min_slots, _pow2(max(n, 1)))


def pad_tail(values: np.ndarray, batch: int) -> np.ndarray:
    """Pad a per-pair value array to ``batch`` by repeating the last entry —
    the same rule :func:`pack_bucket` uses for the pairs themselves."""
    arr = np.asarray(values)
    return np.concatenate([arr, np.repeat(arr[-1:], batch - arr.shape[0],
                                          axis=0)])


def padded_batch(real: int, batch_multiple: int = 1) -> int:
    """Batch size after padding: the power of two >= ``real``, rounded up to
    a multiple of ``batch_multiple`` (the executor's shard count, so every
    device mesh shard receives an equal slice).

    >>> [padded_batch(r) for r in (1, 3, 5)]
    [1, 4, 8]
    >>> padded_batch(9, batch_multiple=8)
    16
    """
    b = _pow2(real)
    if b % batch_multiple:
        b = -(-b // batch_multiple) * batch_multiple
    return b


def pack_bucket(
    pairs: Sequence[Tuple[Graph, Graph]],
    slots: int,
    vocab: Optional[Vocab],
    batch_multiple: int = 1,
) -> Tuple[GraphPairTensors, int]:
    """Pack ``pairs`` at ``slots``, padding the batch dim to
    :func:`padded_batch` (the filler repeats the last pair).  Returns
    ``(tensors, real_count)``."""
    real = len(pairs)
    padded = list(pairs) + [pairs[-1]] * (padded_batch(real, batch_multiple)
                                          - real)
    return pack_pairs(padded, slots=slots, vocab=vocab), real


@dataclasses.dataclass
class Bucket:
    slots: int
    indices: List[int]          # positions in the plan's pair list
    packed: GraphPairTensors    # batch padded to a power of two
    real: int                   # pairs before batch padding

    def pad_values(self, values: np.ndarray) -> np.ndarray:
        """Gather per-pair values for this bucket, padded like the batch."""
        return pad_tail(np.asarray(values)[self.indices], self.packed.batch)


@dataclasses.dataclass
class Plan:
    pairs: List[Tuple[Graph, Graph]]
    buckets: List[Bucket]
    vocab: Vocab
    fixed_slots: Optional[int]  # user-pinned slot count (disables bucketing)

    @classmethod
    def lazy(cls, pairs, vocab: Optional[Vocab] = None,
             slots: Optional[int] = None) -> "Plan":
        """A plan with *no* packed buckets: pack subsets on demand.

        The staged filter-verify pipeline (:class:`repro.ged.GraphStore`)
        holds |corpus| candidate pairs per query but expects the filter
        stages to prune most of them before anything is packed; a lazy
        plan defers all packing to :meth:`subset_buckets`, so only
        survivors ever touch tensors::

            plan = Plan.lazy([(q, g) for g in survivors], vocab=vocab)
            for bucket in plan.subset_buckets(range(len(plan.pairs)),
                                              executor.pack):
                ...
        """
        pairs = as_pairs(pairs)
        if vocab is None:
            vocab = label_vocab(pairs)
        return cls(pairs, [], vocab, slots)

    def subset_buckets(self, indices: Sequence[int], packer) -> List[Bucket]:
        """Incrementally re-bucket a subset of this plan's pairs.

        The overlapped ``auto`` scheduler calls this between escalation
        rungs: survivors of rung *k* are regrouped by slot bucket
        (honouring ``fixed_slots``) and re-packed with the plan's shared
        vocab, so rung *k+1* batches keep canonical shapes — and shard
        multiples — without re-ingesting or re-planning the whole
        workload.  ``packer`` is :meth:`repro.ged.exec.Executor.pack`
        shaped: ``packer(pairs, slots, vocab) -> (tensors, real)``, which
        is how the executor's ``batch_multiple`` reaches the padding.

        Example (survivors 0 and 3 re-queued for the next rung)::

            for bucket in plan.subset_buckets([0, 3], executor.pack):
                pending = executor.run_packed_async(
                    bucket.packed, bucket.pad_values(taus), rcfg,
                    verification, real=bucket.real)
        """
        by_slots: Dict[int, List[int]] = {}
        for gi in indices:
            q, g = self.pairs[gi]
            s = self.fixed_slots or slot_bucket(max(q.n, g.n))
            by_slots.setdefault(s, []).append(gi)
        out = []
        for s in sorted(by_slots):
            idxs = by_slots[s]
            packed, real = packer([self.pairs[i] for i in idxs], s,
                                  self.vocab)
            out.append(Bucket(s, idxs, packed, real))
        return out


def build_plan(
    raw_pairs,
    slots: Optional[int] = None,
    vocab: Optional[Vocab] = None,
    batch_multiple: int = 1,
) -> Plan:
    """Ingest ``raw_pairs`` and group them into canonical-shape buckets.

    ``batch_multiple`` — pad every bucket's batch to a multiple of this
    (the executor's shard count; 1 for single-device execution).
    """
    pairs = as_pairs(raw_pairs)
    if vocab is None:
        vocab = label_vocab(pairs)
    else:
        vocab = tuple(sorted(int(a) for a in vocab[0])), \
            tuple(sorted(int(a) for a in vocab[1]))
    by_slots: Dict[int, List[int]] = {}
    for i, (q, g) in enumerate(pairs):
        s = slots if slots is not None else slot_bucket(max(q.n, g.n))
        by_slots.setdefault(s, []).append(i)
    buckets = []
    for s in sorted(by_slots):
        idxs = by_slots[s]
        packed, real = pack_bucket([pairs[i] for i in idxs], s, vocab,
                                   batch_multiple)
        buckets.append(Bucket(s, idxs, packed, real))
    return Plan(pairs, buckets, vocab, slots)


# ---------------------------------------------------------- compile cache

@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0


class CompileCache:
    """Mirror of the jit cache keys the facade has exercised.

    ``jax.jit`` owns the compiled executables; this class only tracks which
    ``(batch_shape, vocab_sizes, config, mode)`` keys have been seen, so
    engine stats can report compile reuse and tests can assert that
    same-bucket batches do not re-trace.
    """

    def __init__(self) -> None:
        self._keys: set = set()
        self.stats = CacheStats()

    @staticmethod
    def key(packed: GraphPairTensors, cfg: EngineConfig,
            verification: bool) -> tuple:
        return (packed.qv.shape, packed.n_vlabels, packed.n_elabels,
                cfg, bool(verification))

    def record(self, packed: GraphPairTensors, cfg: EngineConfig,
               verification: bool) -> bool:
        """Note one engine invocation; returns True on a cache hit."""
        k = self.key(packed, cfg, verification)
        if k in self._keys:
            self.stats.hits += 1
            return True
        self._keys.add(k)
        self.stats.misses += 1
        return False
