"""The result schemas every ``repro.ged`` entry point returns.

Whatever the backend — host solver, batched JAX engine, Pallas-kernel
engine, or the escalating ``auto`` pipeline — a query for one pair comes
back as one :class:`GedOutcome`.  Corpus-scale entry points
(:class:`repro.ged.GraphStore`) wrap it: each answered candidate is one
:class:`SearchHit` carrying the corpus id and the pipeline stage that
decided it.  Layers above (serving, benchmarks, examples) consume only
these types.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class GedOutcome:
    """Answer for one (q, g) pair.

    * Computation mode fills ``ged`` and leaves ``similar`` ``None``;
      verification mode fills ``similar`` (and ``tau``) and leaves ``ged``
      ``None`` unless the exact distance happened to be established.
    * ``certified`` — the answer carries an exactness certificate (always
      true for the ``exact`` and ``auto`` backends; for ``jax``/``pallas``
      it is the engine's pool-floor certificate).
    * ``lower_bound <= delta(q, g) <= upper_bound`` always holds; for a
      certified computation both equal ``ged``.  For a certified
      verification *rejection* the true distance exceeds ``tau`` and
      ``lower_bound`` records the engine's proven floor.
    * ``mapping`` — image of padded-q vertex ``i`` in g (``-1`` = unset);
      ``None`` when the backend produced no full mapping.
    * ``backend`` — which registry entry produced the answer (the ``auto``
      backend reports ``"auto"``, or ``"auto/exact"`` for pairs that
      escalated all the way to the host solver).
    * ``stats`` — backend-specific diagnostics (engine iterations/expanded
      states, escalation rung, ...).  Informational only.

    >>> o = GedOutcome(ged=2.0, similar=None, certified=True,
    ...                lower_bound=2.0, upper_bound=2.0, mapping=None,
    ...                backend="auto", wall_s=0.01, stats={"rung": 1})
    >>> o.certified, o.rung
    (True, 1)
    >>> o.lower_bound <= o.ged <= o.upper_bound
    True
    """

    ged: Optional[float]
    similar: Optional[bool]
    certified: bool
    lower_bound: float
    upper_bound: float
    mapping: Optional[np.ndarray]
    backend: str
    wall_s: float
    tau: Optional[float] = None
    stats: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def rung(self) -> int:
        """Escalation rung that answered (``auto`` backend; -1 = host)."""
        return int(self.stats.get("rung", 0))

    @property
    def timed_out(self) -> bool:
        """The deadline expired before this pair was certified.

        The bounds are still admissible (best-so-far anytime contract,
        see ``docs/robustness.md``); ``certified`` is always ``False``
        when this is set.
        """
        return bool(self.stats.get("timed_out", False))

    @property
    def degraded(self) -> bool:
        """A fault forced this pair down the degradation ladder.

        The answer itself is unaffected — degraded paths are
        bit-identical (kernel -> unfused) or strictly stronger
        (engine -> host solver); the flag only marks that the preferred
        execution path failed.
        """
        return bool(self.stats.get("degraded", False))


# Pipeline stages a :class:`SearchHit` / store statistic can refer to.
STAGE_INDEX = -1     # sublinear candidate index (banded WL-sketch LSH +
                     # pivot triangle bounds); like stage 0, it only rejects
STAGE_FILTER = 0     # vectorized corpus scan (label/degree/size bounds)
STAGE_BOUND = 1      # batched anchor-aware engine bounds, tiny budget
STAGE_VERIFY = 2     # full certified verification / computation


@dataclasses.dataclass
class SearchHit:
    """One corpus graph answered by a :class:`repro.ged.GraphStore` query.

    * ``graph_id`` — index into the store's ingested corpus (duplicate
      corpus entries each get their own hit, sharing one computed
      outcome).
    * ``outcome`` — the full :class:`GedOutcome` that decided this
      candidate (certified for range search and top-k).
    * ``stage`` — which pipeline stage decided it: ``STAGE_BOUND`` (1)
      when the cheap anchor-aware engine pass already certified the
      answer, ``STAGE_VERIFY`` (2) when full verification ran.  Pruned
      candidates never become hits; the stage-0 scan only rejects, so
      hits report stage 1 or 2.
    * ``query_id`` — position of the query in a ``search_batch`` call
      (``None`` for single-query entry points).

    >>> o = GedOutcome(ged=1.0, similar=None, certified=True,
    ...                lower_bound=1.0, upper_bound=1.0, mapping=None,
    ...                backend="auto", wall_s=0.0)
    >>> h = SearchHit(graph_id=7, outcome=o, stage=STAGE_VERIFY)
    >>> h.graph_id, h.ged, h.certified, h.stage
    (7, 1.0, True, 2)
    """

    graph_id: int
    outcome: GedOutcome
    stage: int
    query_id: Optional[int] = None

    @property
    def ged(self) -> Optional[float]:
        return self.outcome.ged

    @property
    def similar(self) -> Optional[bool]:
        return self.outcome.similar

    @property
    def certified(self) -> bool:
        return self.outcome.certified

    @property
    def lower_bound(self) -> float:
        return self.outcome.lower_bound

    @property
    def upper_bound(self) -> float:
        return self.outcome.upper_bound


def engine_mapping(order_row: np.ndarray, img_row: np.ndarray,
                   n: int) -> Optional[np.ndarray]:
    """Convert the engine's by-order-position image to a by-vertex mapping.

    ``img_row[pos]`` is the g-slot assigned to q vertex ``order_row[pos]``.
    Returns the first ``n`` entries (the padded pair size) or ``None`` when
    the engine produced no full mapping.

    >>> import numpy as np
    >>> engine_mapping(np.array([1, 0, 2]), np.array([2, 0, -1]), 3)
    array([ 0,  2, -1])
    >>> engine_mapping(np.array([0, 1]), np.array([-1, -1]), 2) is None
    True
    """
    if n <= 0 or np.all(img_row[:n] < 0):
        return None if n > 0 else np.zeros(0, dtype=np.int64)
    out = np.full(order_row.shape[0], -1, dtype=np.int64)
    for pos in range(n):
        if img_row[pos] >= 0:
            out[int(order_row[pos])] = int(img_row[pos])
    return out[:n]
