"""``ged.GraphStore`` — from pairs to corpora.

The paper's target workload is graph-database similarity search: a filter
phase prunes the corpus with cheap lower bounds and only survivors reach
the expensive verifier.  ``GraphStore`` is that workload's front door:
ingest a corpus once (one shared label vocabulary, per-slot-bucket
resident feature arrays, per-graph canonical digests for dedup), then ask
corpus-level questions::

    store = ged.GraphStore(db_graphs)
    hits = store.range_search(query, tau=4.0)     # all g: delta(q, g) <= tau
    near = store.top_k(query, k=10)               # 10 nearest by GED
    per_q = store.search_batch(queries, tau=4.0)  # one hit list per query

Queries run a staged filter-verify pipeline:

* **stage −1** — the sublinear candidate index
  (:class:`repro.ged.CandidateIndex`, on by default): banded WL-sketch
  LSH probes only hash-colliding bands instead of touching every corpus
  row, and pivot triangle bounds reuse DB–DB distances already in the
  engine's result cache.  Exact mode (default) is sound — band counts
  are widened from an admissible sketch bound; ``index={"recall": r}``
  is the explicit probabilistic opt-out; ``index=None`` disables stage
  −1 entirely, reproducing the previous pipeline bit-for-bit.
* **stage 0** — vectorized label-multiset / degree-sequence / size lower
  bounds over the packed corpus (:class:`repro.ged.filters.FilterIndex`;
  sharded over the mesh when the store has one) — restricted to stage
  −1's survivors when the index is on.  Sound: never prunes a true hit.
* **stage 1** — the existing anchor-aware batched engine bounds on the
  survivors, at a tiny search budget: one packed pass per slot bucket via
  :meth:`repro.ged.plan.Plan.subset_buckets` + the store's executor.
  Pairs it certifies (accept or reject) are done.
* **stage 2** — full verification of whatever remains through the store's
  :class:`~repro.ged.GedEngine` (``auto`` backend by default, so every
  answer is certified; pass ``mesh=`` to shard every stage).

Results come back as ranked :class:`~repro.ged.results.SearchHit` objects
(corpus id + outcome + the stage that decided it); ``store.stats`` is part
of the API contract — candidates per stage, filter ratio, verified count.

A store is also **durable**: :meth:`GraphStore.save` writes a compacted,
checksummed snapshot (graphs, digests, dedup groups, the packed stage-0
feature buckets and the stage −1 sketch matrix — the
:mod:`repro.store_io` layout) and :meth:`GraphStore.open` brings it back
without re-ingesting: feature arrays and the signature matrix come
straight off disk (mmap-backed), so a warm open re-packs and re-hashes
nothing yet answers queries bit-identically.  :meth:`add` /
:meth:`remove` mutate an attached store through a write-ahead journal
that is folded into a fresh snapshot by :meth:`compact` (or
automatically every ``compact_every`` entries).  See
``docs/persistence.md`` for the on-disk contract.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.exact.graph import Graph
from repro.core.exact.search import ged_verify
from repro.ged.api import GedEngine
from repro.ged.exec import (DIGESTS, Executor, ShardedExecutor, detached,
                            engine_outcome, graph_digest, wl_digest)
from repro.ged.filters import FilterIndex
from repro.ged.index import CandidateIndex
from repro.ged.plan import Plan, Vocab, as_graph, graphs_vocab, merge_vocab
from repro.ged.results import (STAGE_BOUND, STAGE_FILTER, STAGE_INDEX,
                               STAGE_VERIFY, GedOutcome, SearchHit)

_INF = float("inf")
_ZERO16 = b"\x00" * 16


class GraphStore:
    """An ingested graph corpus with staged similarity search.

    Parameters
    ----------
    graphs : corpus in any :func:`repro.ged.plan.as_graph` form.
    vocab : optional label universe; extended automatically when the
        corpus (or a query) introduces labels beyond it.
    backend / mesh / engine : verification engine for stage 2 — default a
        fresh ``GedEngine("auto", mesh=mesh)`` (certified answers).  Pass
        an existing ``engine=`` to share its executor, result cache and
        compile cache (e.g. from a serving process) — exclusive with
        ``backend``/``mesh``/engine keyword options, which would
        otherwise be silently ignored.
    digest : ``"wl"`` (default) additionally dedups *isomorphic* corpus
        entries: WL-digest collisions are candidate groups, and every
        candidate merge is confirmed by a certified zero-distance check
        with the exact host solver at ingest (WL refinement alone is an
        incomplete isomorphism test — unconfirmed collisions stay
        separate, so search answers are never aliased).  ``"exact"`` is
        the byte-identical fallback knob, skipping WL grouping entirely.
    filter_iters / filter_pool : stage-1 engine budget (``filter_iters=0``
        disables stage 1).
    index : the stage −1 candidate index (:class:`repro.ged.
        CandidateIndex`).  ``"auto"`` (default) builds one in sound exact
        mode; a dict carries its knobs (``{"recall": 0.9}`` opts into the
        probabilistic probe, ``{"pivot_seeds": 4}`` seeds distance-reuse
        pivots at ingest, ``{"wl_iters": 1}`` deepens the sketch, ...); a
        prebuilt :class:`~repro.ged.CandidateIndex` over this corpus is
        used as-is; ``None`` disables stage −1 — every query then runs
        the previous full-scan pipeline bit-for-bit.
    Remaining keyword arguments go to the :class:`GedEngine` constructor
    (``cache=``, ``pool=``, ``batch_size=`` ...).

    Corpus ids are stable handles: :meth:`add` assigns fresh ids past
    every id ever issued and :meth:`remove` tombstones (ids are never
    reused), so persisted results, journals and shared caches stay valid
    across mutations.

    Examples
    --------
    >>> from repro import ged
    >>> store = ged.GraphStore([([0, 1], [(0, 1, 1)]), ([0, 5], [])],
    ...                        backend="exact", filter_iters=0)
    >>> [h.graph_id for h in store.range_search(([0, 1], [(0, 1, 1)]), 0.5)]
    [0]
    >>> s = store.stats
    >>> s["candidates"], s["index_pruned"] + s["stage0_pruned"]
    (2, 1)
    >>> flat = ged.GraphStore([([0], [])], backend="exact", index=None)
    >>> flat.stats["candidates_stage_-1"]      # stage -1 never runs
    0
    >>> import tempfile                        # durable round trip
    >>> path = store.save(tempfile.mkdtemp())
    >>> warm = ged.GraphStore.open(path, backend="exact")
    >>> [h.graph_id for h in warm.range_search(([0, 1], [(0, 1, 1)]), 0.5)]
    [0]
    """

    def __init__(self, graphs, *, vocab: Optional[Vocab] = None,
                 backend: str = "auto", mesh=None,
                 engine: Optional[GedEngine] = None,
                 digest: str = "wl", filter_iters: int = 2,
                 filter_pool: int = 32, index="auto", **engine_options):
        if digest not in DIGESTS:
            raise ValueError(f"unknown digest {digest!r}; "
                             f"expected one of {sorted(DIGESTS)}")
        self.digest = digest
        self.filter_iters = int(filter_iters)
        self.filter_pool = int(filter_pool)
        self._index_spec = self._normalize_index(index)
        self.graphs: List[Optional[Graph]] = [as_graph(g) for g in graphs]
        self._tombstones: Set[int] = set()
        self._store_dir: Optional[str] = None
        self._journal_seq = 0
        self._journal_base = 0
        self.compact_every = 64
        self._dedup_checks = 0
        self._init_engine(backend, mesh, engine, engine_options)
        self._init_counts()
        t0 = time.perf_counter()
        self._ingest(range(len(self.graphs)), vocab)
        self._counts["ingest_wall_s"] += time.perf_counter() - t0
        self._n_live = len(self.graphs)

    # ------------------------------------------------------------- setup

    @staticmethod
    def _normalize_index(index):
        """``index=`` argument -> ``None`` | knob dict | prebuilt index."""
        if index is None or isinstance(index, CandidateIndex):
            return index
        if isinstance(index, dict):
            return dict(index)
        if index in ("auto", True):
            return {}
        raise ValueError(
            f"index= expects None, 'auto', a knob dict, or a "
            f"CandidateIndex; got {index!r}")

    def _init_engine(self, backend: str, mesh,
                     engine: Optional[GedEngine],
                     engine_options: Dict) -> None:
        if engine is not None and (backend != "auto" or mesh is not None
                                   or engine_options):
            # a supplied engine brings its own backend, placement and
            # config — accepting these too would silently ignore them
            clash = sorted(engine_options) + \
                (["mesh"] if mesh is not None else []) + \
                ([f"backend={backend!r}"] if backend != "auto" else [])
            raise TypeError(
                f"engine= is exclusive with engine construction options "
                f"(got {clash}); configure the engine you pass in")
        if engine is None:
            # The engine's result cache stays on exact digests: WL keys
            # would alias WL-equivalent non-isomorphic pairs *without*
            # the certified confirmation the store's dedup gets.
            engine = GedEngine(backend, mesh=mesh, **engine_options)
        self.engine = engine
        executor = getattr(engine._backend, "executor", None)
        if executor is None:
            executor = ShardedExecutor(mesh) if mesh is not None \
                else Executor()
        self.executor = executor
        self._filter_cfg = None
        if self.filter_iters:
            self._filter_cfg = dataclasses.replace(
                engine.config, pool=int(self.filter_pool), expand=2,
                max_iters=int(self.filter_iters))

    def _init_counts(self) -> None:
        self._counts: Dict[str, float] = {
            "queries": 0, "candidates": 0, "candidates_stage_-1": 0,
            "index_pruned": 0, "index_sketch_pruned": 0,
            "index_pivot_pruned": 0, "stage0_pruned": 0,
            "stage1_decided": 0, "stage1_accepted": 0,
            "stage2_verified": 0, "hits": 0, "topk_candidates": 0,
            "topk_verified": 0, "topk_seeded": 0, "adds": 0,
            "removals": 0, "compactions": 0, "index_wall_s": 0.0,
            "scan_wall_s": 0.0, "bound_wall_s": 0.0, "verify_wall_s": 0.0,
            "ingest_wall_s": 0.0, "vocab_wall_s": 0.0, "pack_wall_s": 0.0,
            "open_wall_s": 0.0,
        }

    def _ingest(self, present, vocab: Optional[Vocab] = None) -> None:
        """Derive everything :meth:`open` otherwise restores from disk:
        dedup groups, the shared vocabulary, the resident stage-0 feature
        buckets and the stage −1 sketch index — over ``self.graphs[i]``
        for the ids in ``present``.

        Byte-identical grouping first (always sound), then — under the
        ``"wl"`` digest — isomorphism candidates via WL collision, each
        merge *confirmed* by a certified GED == 0 check so a WL collision
        between non-isomorphic graphs can never alias answers.
        """
        present = [int(i) for i in present]
        exact_groups: Dict[bytes, List[int]] = {}
        for i in present:
            exact_groups.setdefault(graph_digest(self.graphs[i]),
                                    []).append(i)
        self._exact_of: Dict[bytes, int] = {
            d: ids[0] for d, ids in exact_groups.items()}
        groups: List[List[int]] = []
        wl_of: Dict[int, bytes] = {}
        if self.digest == "wl":
            candidates: Dict[bytes, List[List[int]]] = {}
            for ids in exact_groups.values():
                candidates.setdefault(wl_digest(self.graphs[ids[0]]),
                                      []).append(ids)
            for wd, subs in candidates.items():
                # compare against every group already formed in this WL
                # bucket (not just the first), so two isomorphic entries
                # still merge when a non-isomorphic collider sorts first
                formed: List[List[int]] = []
                for sub in subs:
                    for grp in formed:
                        self._dedup_checks += 1
                        if ged_verify(self.graphs[grp[0]],
                                      self.graphs[sub[0]], 0.0,
                                      bound="BMa").similar:
                            grp.extend(sub)
                            break
                    else:       # no confirmed match: its own group
                        formed.append(list(sub))
                for grp in formed:
                    grp = sorted(grp)
                    groups.append(grp)
                    wl_of[grp[0]] = wd
        else:
            groups.extend(exact_groups.values())
        self._members: Dict[int, List[int]] = {
            ids[0]: sorted(ids) for ids in groups}
        self._rep_of: Dict[int, int] = {
            i: rep for rep, ids in self._members.items() for i in ids}
        self._wl_of: Dict[int, bytes] = wl_of
        self._wl_reps: Dict[bytes, List[int]] = {}
        for rep, wd in wl_of.items():
            self._wl_reps.setdefault(wd, []).append(rep)
        self._rep_ids: List[int] = sorted(
            rep for rep, ids in self._members.items()
            if any(i not in self._tombstones for i in ids))

        t0 = time.perf_counter()
        live = [self.graphs[i] for i in present]
        self.vocab: Vocab = (merge_vocab(vocab, live) if vocab
                             else graphs_vocab(live))
        self._counts["vocab_wall_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        self._index = FilterIndex(self.graphs, self._rep_ids, self.vocab,
                                  self.executor)
        spec = self._index_spec
        if spec is None:
            self._cindex: Optional[CandidateIndex] = None
        elif isinstance(spec, CandidateIndex):
            self._cindex = spec
        else:
            self._cindex = CandidateIndex(
                self.graphs, self._rep_ids, executor=self.executor, **spec)
        self._counts["pack_wall_s"] += time.perf_counter() - t0
        self._bind_index()
        if self._cindex is not None:
            self._cindex.seed_pivots(vocab=self.vocab)

    def _bind_index(self, digests: Optional[Dict[int, bytes]] = None
                    ) -> None:
        if self._cindex is None:
            return
        if digests is None:
            # pivot lookups reuse the store's ingest-time exact digests
            # when the engine caches on them — no per-probe re-hashing
            digests = ({rid: d for d, rid in self._exact_of.items()
                        if rid in self._members}
                       if self.engine.digest == "exact" else None)
        self._cindex.bind_engine(self.engine, digests)

    def __len__(self) -> int:
        return self._n_live

    def member_id(self, graph) -> Optional[int]:
        """Corpus id of a *live, byte-identical* ingested graph, or
        ``None``.

        Deliberately exact (not WL): request routing must never match a
        merely WL-equivalent graph, whose true distance could differ.
        """
        return self._exact_of.get(graph_digest(as_graph(graph)))

    # ------------------------------------------------------- persistence

    def save(self, store_dir) -> str:
        """Write a durable, compacted snapshot and attach the store to
        ``store_dir`` (subsequent :meth:`add` / :meth:`remove` journal
        there).  Checksummed ``.npy`` segments plus an atomic manifest —
        a crash mid-save leaves any previous snapshot fully readable.
        Returns ``store_dir``.
        """
        from repro.store_io import graphstore_io
        store_dir = str(store_dir)
        graphstore_io.save_store(self, store_dir)
        self._store_dir = store_dir
        self._journal_base = self._journal_seq
        return store_dir

    def compact(self) -> None:
        """Fold the journal into a fresh snapshot generation (also runs
        automatically every ``compact_every`` journal entries)."""
        if self._store_dir is None:
            raise RuntimeError(
                "store is not attached to a directory; call save() first")
        from repro.store_io import graphstore_io
        graphstore_io.save_store(self, self._store_dir)
        self._journal_base = self._journal_seq
        self._counts["compactions"] += 1

    def _maybe_compact(self) -> None:
        if (self._store_dir is not None and self.compact_every
                and self._journal_seq - self._journal_base
                >= self.compact_every):
            self.compact()

    @classmethod
    def open(cls, store_dir, *, mesh=None,
             engine: Optional[GedEngine] = None, backend: str = "auto",
             graphs=None, **engine_options):
        """Reopen a persisted store without re-ingesting.

        The warm path mmaps the persisted feature buckets and sketch
        matrix straight into the resident structures — no feature
        packing, no signature builds, no dedup checks — and then replays
        any journal entries newer than the snapshot; queries against the
        result are bit-identical to the store that saved it.  Corrupt or
        truncated *derived* segments (digests, groups, features,
        sketches) are re-derived from the persisted graphs with a
        warning; corrupt *primary* segments raise — unless ``graphs=``
        supplies the original corpus, in which case the store warns,
        re-ingests it (with this call's store defaults) and re-saves.

        ``mesh`` / ``engine`` / ``backend`` and engine keyword options
        mean the same as in the constructor; store-level knobs
        (``digest``, ``filter_iters``, ``filter_pool``, index
        configuration) come from the snapshot itself.
        """
        from repro.store_io import graphstore_io
        from repro.store_io.atomic import StoreIOError
        store_dir = str(store_dir)
        t_open = time.perf_counter()
        try:
            payload = graphstore_io.read_store_manifest(store_dir)
            primary = graphstore_io.load_primary(store_dir, payload)
            base = int(payload.get("journal_base", 0))
            ops, top = graphstore_io.load_journal(store_dir, base)
        except StoreIOError as err:
            if graphs is None:
                raise
            warnings.warn(
                f"persisted store at {store_dir!r} is unreadable ({err}); "
                f"re-ingesting the supplied graphs and re-saving",
                RuntimeWarning, stacklevel=2)
            store = cls(graphs, mesh=mesh, engine=engine, backend=backend,
                        **engine_options)
            store.save(store_dir)
            store._counts["open_wall_s"] += time.perf_counter() - t_open
            return store

        self = object.__new__(cls)
        self.digest = payload["digest"]
        self.filter_iters = int(payload["filter_iters"])
        self.filter_pool = int(payload["filter_pool"])
        meta = payload.get("index")
        self._index_spec = dict(meta["knobs"]) if meta else None
        self._dedup_checks = int(payload.get("dedup_checks", 0))
        self._store_dir = None          # journal replay must not re-journal
        self._journal_seq = top
        self._journal_base = base
        self.compact_every = 64
        self.graphs = [None] * int(primary["next_id"])
        for gid, g in zip(primary["ids"], primary["graphs"]):
            self.graphs[gid] = g
        self._tombstones = {gid for gid, d
                            in zip(primary["ids"], primary["dead"]) if d}
        self._init_engine(backend, mesh, engine, engine_options)
        self._init_counts()
        vocab = (tuple(int(v) for v in payload["vocab"][0]),
                 tuple(int(v) for v in payload["vocab"][1]))
        try:
            self._restore_derived(
                graphstore_io.load_derived(store_dir, payload,
                                           primary["ids"]),
                primary["ids"], vocab)
        except StoreIOError as err:
            warnings.warn(
                f"derived segments at {store_dir!r} are corrupt ({err}); "
                f"re-deriving from the persisted graphs", RuntimeWarning,
                stacklevel=2)
            t0 = time.perf_counter()
            self._ingest(primary["ids"], vocab)
            self._counts["ingest_wall_s"] += time.perf_counter() - t0
        self._n_live = sum(1 for gid, g in enumerate(self.graphs)
                           if g is not None
                           and gid not in self._tombstones)
        for op in ops:
            self._replay(op)
        self._store_dir = store_dir
        self._counts["open_wall_s"] += time.perf_counter() - t_open
        return self

    def _restore_derived(self, derived: Dict, ids: List[int],
                         vocab: Vocab) -> None:
        """Wire mmap-backed segments straight into the resident
        structures — the warm path: no dedup checks, no feature packing,
        no signature builds (the counter contract the persistence tests
        pin).  Any inconsistency raises so :meth:`open` falls back to
        :meth:`_ingest` over the persisted graphs.
        """
        from repro.store_io.atomic import CorruptStoreError
        self.vocab = vocab
        self._exact_of = {}
        for gid, d in zip(ids, derived["exact"]):       # ids ascending:
            if gid not in self._tombstones \
                    and d not in self._exact_of:        # lowest live wins
                self._exact_of[d] = gid
        self._rep_of = dict(zip(ids, derived["rep_of"]))
        members: Dict[int, List[int]] = {}
        for gid in ids:
            members.setdefault(self._rep_of[gid], []).append(gid)
        if any(self._rep_of.get(rep) != rep for rep in members):
            raise CorruptStoreError(
                "dedup group assignment is inconsistent")
        self._members = {rep: sorted(ms)
                         for rep, ms in sorted(members.items())}
        self._wl_of = {}
        self._wl_reps = {}
        if self.digest == "wl":
            wl = dict(zip(ids, derived["wl"]))
            for rep in self._members:
                wd = wl.get(rep, _ZERO16)
                if wd != _ZERO16:
                    self._wl_of[rep] = wd
                    self._wl_reps.setdefault(wd, []).append(rep)
        self._rep_ids = sorted(
            rep for rep, ms in self._members.items()
            if any(m not in self._tombstones for m in ms))

        have = {gid for bids, _ in derived["features"].values()
                for gid in bids}
        if have != set(self._rep_ids):
            raise CorruptStoreError(
                "feature buckets do not cover the dedup representatives")
        self._index = FilterIndex(self.graphs, self._rep_ids, self.vocab,
                                  self.executor,
                                  features=derived["features"])
        idx = derived["index"]
        if self._index_spec is None or idx is None:
            self._cindex = None
        else:
            if set(idx["ids"]) != set(self._rep_ids):
                raise CorruptStoreError(
                    "index sketch rows do not cover the dedup "
                    "representatives")
            self._cindex = CandidateIndex(
                self.graphs, idx["ids"], executor=self.executor,
                sigs=idx["sigs"], max_deg=idx["max_deg"], **idx["knobs"])
            for p in idx["pivots"]:
                self._cindex.note_pivot(p)
        self._bind_index()

    def _replay(self, op: Dict) -> None:
        from repro.store_io.atomic import CorruptStoreError
        kind = op.get("op")
        if kind == "add":
            new = op.get("graphs", [])
            ids = [int(i) for i in op.get("ids", [])]
            if ids != list(range(len(self.graphs),
                                 len(self.graphs) + len(new))):
                raise CorruptStoreError(
                    "journal add entry is out of sequence")
            self.graphs.extend(new)
            self._counts["adds"] += len(new)
            self._apply_add(ids)
        elif kind == "remove":
            ids = [int(i) for i in op.get("ids", [])]
            self._counts["removals"] += len(ids)
            self._apply_remove(ids)
        else:
            raise CorruptStoreError(f"unknown journal op {kind!r}")

    # --------------------------------------------------------- mutation

    def add(self, graphs) -> List[int]:
        """Ingest additional graphs incrementally; returns their ids.

        Dedup (exact match, then certified WL merge against existing
        groups), vocabulary growth and index maintenance all match a
        fresh ingest of the combined corpus — only the new rows are
        packed and sketched, unless a new label grows the vocabulary
        (histogram widths change, forcing one stage-0 re-pack).  On an
        attached store the batch is journaled write-ahead before it is
        applied.
        """
        new = [as_graph(g) for g in graphs]
        if not new:
            return []
        ids = list(range(len(self.graphs), len(self.graphs) + len(new)))
        if self._store_dir is not None:
            from repro.store_io import graphstore_io
            self._journal_seq += 1
            graphstore_io.append_journal(
                self._store_dir, self._journal_seq,
                {"op": "add", "ids": ids}, new)
        self.graphs.extend(new)
        self._counts["adds"] += len(new)
        self._apply_add(ids)
        self._maybe_compact()
        return ids

    def remove(self, ids: Sequence[int]) -> None:
        """Tombstone corpus entries (their ids are never reused).

        Raises ``KeyError`` if any id is unknown or already removed —
        checked up front, before anything is journaled or applied.  A
        removed representative keeps serving as its group's resident
        probe object until the group's last member is gone; fully-dead
        groups leave the candidate set immediately and are dropped from
        disk at the next compaction.
        """
        ids = [int(i) for i in ids]
        seen: Set[int] = set()
        for gid in ids:
            if (gid in seen or gid not in self._rep_of
                    or gid in self._tombstones):
                raise KeyError(
                    f"graph id {gid} is not a live member of this store")
            seen.add(gid)
        if not ids:
            return
        if self._store_dir is not None:
            from repro.store_io import graphstore_io
            self._journal_seq += 1
            graphstore_io.append_journal(
                self._store_dir, self._journal_seq,
                {"op": "remove", "ids": ids})
        self._counts["removals"] += len(ids)
        self._apply_remove(ids)
        self._maybe_compact()

    def _apply_add(self, ids: List[int]) -> None:
        t0 = time.perf_counter()
        new = [self.graphs[i] for i in ids]
        merged = merge_vocab(self.vocab, new)
        self._counts["vocab_wall_s"] += time.perf_counter() - t0
        live = set(self._rep_ids)
        new_reps: List[int] = []
        new_digests: Dict[int, bytes] = {}
        for gid in ids:
            g = self.graphs[gid]
            d = graph_digest(g)
            owner = self._exact_of.get(d)
            wd = None
            rep = None
            if owner is not None:
                rep = self._rep_of[owner]
            elif self.digest == "wl":
                wd = wl_digest(g)
                for cand in self._wl_reps.get(wd, []):
                    self._dedup_checks += 1
                    if ged_verify(self.graphs[cand], g, 0.0,
                                  bound="BMa").similar:
                        rep = cand
                        break
            if rep is not None:
                self._members[rep].append(gid)
                self._members[rep].sort()
                self._rep_of[gid] = rep
                if d not in self._exact_of:
                    self._exact_of[d] = gid
                if rep not in live:
                    # a fully-dead group revived by a new member; its rep
                    # is already resident in every index structure
                    live.add(rep)
                    bisect.insort(self._rep_ids, rep)
            else:
                self._members[gid] = [gid]
                self._rep_of[gid] = gid
                self._exact_of[d] = gid
                if self.digest == "wl":
                    self._wl_of[gid] = wd
                    self._wl_reps.setdefault(wd, []).append(gid)
                live.add(gid)
                bisect.insort(self._rep_ids, gid)
                new_reps.append(gid)
                new_digests[gid] = d
        self._n_live += len(ids)
        t0 = time.perf_counter()
        if merged != self.vocab:
            # stage-0 features are vocabulary-indexed histograms: label
            # growth changes every row's width, forcing one full re-pack
            # (the sketch matrix is vocabulary-independent and keeps its
            # rows)
            self.vocab = merged
            self._index = FilterIndex(self.graphs, self._rep_ids,
                                      self.vocab, self.executor)
        elif new_reps:
            self._index.extend(self.graphs, new_reps)
        if self._cindex is not None and new_reps:
            self._cindex.extend(self.graphs, new_reps,
                                executor=self.executor)
            if self.engine.digest == "exact":
                self._cindex.bind_engine(self.engine, new_digests)
        self._counts["pack_wall_s"] += time.perf_counter() - t0

    def _apply_remove(self, ids: List[int]) -> None:
        for gid in ids:
            if gid in self._tombstones or gid not in self._rep_of:
                continue            # journal replay tolerates re-removal
            self._tombstones.add(gid)
            self._n_live -= 1
            rep = self._rep_of[gid]
            d = graph_digest(self.graphs[gid])
            if self._exact_of.get(d) == gid:
                # hand the digest to the lowest live byte-identical
                # member, so member_id routing never returns a tombstone
                repl = next(
                    (m for m in self._members[rep]
                     if m not in self._tombstones
                     and graph_digest(self.graphs[m]) == d), None)
                if repl is None:
                    del self._exact_of[d]
                else:
                    self._exact_of[d] = repl
            if all(m in self._tombstones for m in self._members[rep]):
                # group fully dead: out of the candidate set (its resident
                # rows stay; scans keyed by _rep_ids never read them)
                i = bisect.bisect_left(self._rep_ids, rep)
                if i < len(self._rep_ids) and self._rep_ids[i] == rep:
                    del self._rep_ids[i]

    # ------------------------------------------------------------ search

    def range_search(self, query, tau: float) -> List[SearchHit]:
        """Every corpus graph with ``delta(query, g) <= tau``, ranked.

        Hits are sorted by ``(upper_bound, graph_id)`` — the certified
        upper bound is exact when a stage decided the pair by computing
        the distance, and at most ``tau`` otherwise.
        """
        q = as_graph(query)
        tau = float(tau)
        self._counts["queries"] += 1
        jobs = [(rid, tau) for rid in self._rep_ids]
        decided = self._staged_verify(q, jobs)
        hits: List[SearchHit] = []
        for (rid, _), (outcome, stage) in zip(jobs, decided):
            if outcome.similar:
                hits.extend(self._group_hits(rid, outcome, stage))
        hits.sort(key=lambda h: (h.upper_bound, h.graph_id))
        self._counts["hits"] += len(hits)
        return hits

    def top_k(self, query, k: int) -> List[SearchHit]:
        """The ``k`` nearest corpus graphs by exact GED, ranked.

        Candidates are visited in increasing stage-0 lower-bound order
        and verified in chunks; the walk stops as soon as the next
        candidate's lower bound exceeds the current k-th best distance,
        so most of the corpus is never verified.  When the store has a
        candidate index, the walk is *seeded* with the index's
        sketch-nearest candidates: verifying likely-close graphs first
        tightens the k-th-best cutoff early, so the lb-ordered remainder
        exits sooner.  Seeding never changes the answer — the cutoff
        check still runs against the full lb order — it only changes how
        fast the walk converges.  Ties break by corpus id, matching a
        brute-force ``(ged, id)`` sort.
        """
        k = int(k)
        if k <= 0 or not self._rep_ids:
            return []
        q = as_graph(query)
        self._counts["queries"] += 1
        self._counts["topk_candidates"] += len(self._rep_ids)
        t0 = time.perf_counter()
        lb_of = self._index.scan_by_id(q)
        self._counts["scan_wall_s"] += time.perf_counter() - t0
        order = sorted(self._rep_ids, key=lambda rid: (lb_of[rid], rid))
        chunk = max(k, 8)
        seeds: List[int] = []
        if self._cindex is not None and len(order) > chunk:
            t0 = time.perf_counter()
            rset = set(self._rep_ids)   # nearest() may surface dead reps
            seeds = [rid for rid
                     in self._cindex.nearest(q, limit=max(2 * k, chunk))
                     if rid in rset]
            self._counts["topk_seeded"] += len(seeds)
            seedset = set(seeds)
            order = seeds + [rid for rid in order if rid not in seedset]
            qid = self._exact_of.get(graph_digest(q))
            if qid is not None:
                self._cindex.note_pivot(self._rep_of[qid])
            self._counts["index_wall_s"] += time.perf_counter() - t0
        vocab = merge_vocab(self.vocab, [q])
        collected: List[Tuple[float, int, GedOutcome]] = []
        i = 0
        while i < len(order):
            kth = collected[k - 1][0] if len(collected) >= k else _INF
            # the cutoff only applies once the walk is past the (unsorted)
            # seed prefix and into the globally lb-ordered remainder
            if i >= len(seeds) and lb_of[order[i]] > kth:
                break
            reps = order[i:i + chunk]
            t0 = time.perf_counter()
            outs = self.engine.compute(
                [(q, self.graphs[rid]) for rid in reps], vocab=vocab)
            self._counts["verify_wall_s"] += time.perf_counter() - t0
            self._counts["topk_verified"] += len(reps)
            for rid, outcome in zip(reps, outs):
                outcome.stats["stage"] = STAGE_VERIFY
                for hit in self._group_hits(rid, outcome, STAGE_VERIFY):
                    collected.append((hit.ged, hit.graph_id, hit.outcome))
            collected.sort(key=lambda t: (t[0], t[1]))
            i += len(reps)
        hits = [SearchHit(gid, outcome, STAGE_VERIFY)
                for _, gid, outcome in collected[:k]]
        self._counts["hits"] += len(hits)
        return hits

    def search_batch(self, queries, tau: float) -> List[List[SearchHit]]:
        """One ranked :meth:`range_search` hit list per query.

        Each hit's ``query_id`` is its query's position in ``queries``.
        """
        out = []
        for qi, query in enumerate(queries):
            hits = self.range_search(query, tau)
            for h in hits:
                h.query_id = qi
            out.append(hits)
        return out

    def verify_members(self, query, ids: Sequence[int],
                       taus) -> List[GedOutcome]:
        """Verify ``delta(query, graphs[id]) <= tau`` for specific members.

        The staged filter runs first (resident stage-0 features, then the
        stage-1 engine bounds), so a batch of requests against ingested
        graphs pays full verification only for undecided pairs — this is
        what :class:`repro.serving.GedVerificationService` routes batch
        traffic through once a corpus is registered.  ``taus`` is a
        scalar or one threshold per id.  Removed ids raise ``KeyError``.
        """
        q = as_graph(query)
        ids = [int(i) for i in ids]
        for gid in ids:
            if gid not in self._rep_of or gid in self._tombstones:
                raise KeyError(f"graph id {gid} is not in this store")
        taus = np.broadcast_to(
            np.asarray(taus, dtype=np.float64), (len(ids),))
        jobs: List[Tuple[int, float]] = []
        slot: Dict[Tuple[int, float], int] = {}
        for gid, tau in zip(ids, taus):
            key = (self._rep_of[gid], float(tau))
            if key not in slot:
                slot[key] = len(jobs)
                jobs.append(key)
        decided = self._staged_verify(q, jobs)
        out = []
        served: set = set()
        for gid, tau in zip(ids, taus):
            key = (self._rep_of[gid], float(tau))
            outcome, _ = decided[slot[key]]
            if gid != key[0]:
                out.append(self._dup(outcome))
            elif key in served:
                # duplicate request: its own detached copy, preserving
                # the engine path's per-position-independence invariant
                out.append(detached(outcome, dict(outcome.stats)))
            else:
                served.add(key)
                out.append(outcome)
        return out

    # ------------------------------------------------------------- stats

    @property
    def stats(self) -> Dict[str, float]:
        """Pipeline counters — the API contract for filter efficiency.

        ``candidates`` (deduped pairs entering the pipeline across all
        range/verify queries), ``candidates_stage_-1`` (pairs stage −1
        examined — equal to ``candidates`` when the index is on, 0 when
        off), ``index_pruned`` (with its ``index_sketch_pruned`` /
        ``index_pivot_pruned`` split), ``stage0_pruned``,
        ``stage1_decided`` / ``stage1_accepted``, ``stage2_verified``,
        ``filter_ratio`` (fraction of candidates decided *before* full
        verification — index-pruned candidates count as filtered, so the
        funnel ``index_pruned + stage0_pruned + stage1_decided +
        stage2_verified`` always sums to ``candidates``), ``hits``,
        per-stage wall splits (``index_wall_s`` / ``scan_wall_s`` /
        ``bound_wall_s`` / ``verify_wall_s``), top-k counters
        (``topk_seeded`` — index-suggested candidates verified first),
        dedup totals, mutation/persistence counters (``adds`` /
        ``removals`` / ``compactions`` / ``journal_pending`` and the
        ``ingest_wall_s`` = ``vocab_wall_s`` + ``pack_wall_s`` + dedup
        ingest split, ``open_wall_s`` for warm opens), the stage-0
        scan's own counters under ``filter_*`` (``filter_packed_rows``
        is 0 after a warm open — nothing was re-packed), the candidate
        index's under ``index_*`` (probes, fallbacks, tables built,
        pivot traffic, ``index_signatures_built`` — likewise 0 after a
        warm open), and the engine's under ``engine_*`` (including
        ``engine_index_pivot_hits`` / ``_misses`` — result-cache traffic
        from pivot lookups).
        """
        out = dict(self._counts)
        cand = out["candidates"]
        out["filter_ratio"] = \
            (cand - out["stage2_verified"]) / cand if cand else 0.0
        out["dedup_groups"] = len(self._rep_ids)
        out["dedup_duplicates"] = self._n_live - len(self._rep_ids)
        out["dedup_checks"] = self._dedup_checks
        out["journal_pending"] = self._journal_seq - self._journal_base
        out.update({f"filter_{k}": v
                    for k, v in self._index.stats.items()})
        if self._cindex is not None:
            out.update({f"index_{k}": v
                        for k, v in self._cindex.stats.items()})
        out.update({f"engine_{k}": v for k, v in self.engine.stats.items()})
        return out

    # --------------------------------------------------------- internal

    def _staged_verify(self, q: Graph, jobs: Sequence[Tuple[int, float]]
                       ) -> List[Tuple[GedOutcome, int]]:
        """Run the filter-verify pipeline for ``(rep_id, tau)`` jobs.

        Returns one ``(outcome, stage)`` per job, aligned.  Every stage
        only *decides* soundly: stage −1 rejects by banded-sketch and
        pivot triangle bounds (certified except for probabilistic-mode
        band misses, which are the explicit ``recall`` trade), stage 0
        rejects when its lower bound exceeds tau, stage 1 trusts the
        engine's certificate, stage 2 verifies whatever survived.
        """
        self._counts["candidates"] += len(jobs)
        results: List[Optional[Tuple[GedOutcome, int]]] = [None] * len(jobs)
        vocab = merge_vocab(self.vocab, [q])

        alive: List[int] = list(range(len(jobs)))
        if self._cindex is not None and jobs:
            t0 = time.perf_counter()
            self._counts["candidates_stage_-1"] += len(jobs)
            tau_probe = max(tau for _, tau in jobs)
            sketch = self._cindex.probe(q, tau_probe)
            want = sorted({rid for rid, _ in jobs if rid in sketch})
            piv = self._cindex.pivot_bounds(q, want, vocab=vocab) \
                if want else {}
            exact_mode = self._cindex.exact
            # a banding miss in exact mode *proves* sketch L1 > budget,
            # i.e. a distance floor strictly above the probed tau
            damage = self._cindex.damage(q, tau_probe)
            miss_lb = (np.floor(damage * tau_probe + 1e-9) + 1.0) / damage
            alive = []
            for pos, (rid, tau) in enumerate(jobs):
                slb = sketch.get(rid)
                if slb is None:
                    self._counts["index_pruned"] += 1
                    self._counts["index_sketch_pruned"] += 1
                    results[pos] = (GedOutcome(
                        ged=None, similar=False, certified=exact_mode,
                        lower_bound=float(miss_lb) if exact_mode else 0.0,
                        upper_bound=_INF, mapping=None,
                        backend="store/index", wall_s=0.0, tau=tau,
                        stats={"stage": STAGE_INDEX}), STAGE_INDEX)
                    continue
                lb = max(slb, piv.get(rid, 0.0))
                if lb > tau:
                    # admissible bound exceeded: certified in either mode
                    self._counts["index_pruned"] += 1
                    self._counts["index_sketch_pruned" if slb > tau
                                 else "index_pivot_pruned"] += 1
                    results[pos] = (GedOutcome(
                        ged=None, similar=False, certified=True,
                        lower_bound=lb, upper_bound=_INF, mapping=None,
                        backend="store/index", wall_s=0.0, tau=tau,
                        stats={"stage": STAGE_INDEX}), STAGE_INDEX)
                else:
                    alive.append(pos)
            # a query that is itself a corpus member becomes a pivot:
            # the distances this query computes are cache-resident and
            # reusable by every later query's triangle bounds
            qid = self._exact_of.get(graph_digest(q))
            if qid is not None:
                self._cindex.note_pivot(self._rep_of[qid])
            self._counts["index_wall_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        if self._cindex is None:
            lb_of = self._index.scan_by_id(q)
        else:
            # scan only stage -1 survivors; past half the corpus the
            # resident full-bucket pass is the cheaper shape
            want = sorted({jobs[pos][0] for pos in alive})
            if not want:
                lb_of = {}
            elif 2 * len(want) <= len(self._rep_ids):
                lb_of = self._index.scan_subset(q, want)
            else:
                lb_of = self._index.scan_by_id(q)
        self._counts["scan_wall_s"] += time.perf_counter() - t0
        survivors: List[int] = []
        for pos in alive:
            rid, tau = jobs[pos]
            lb = lb_of[rid]
            if lb > tau:
                self._counts["stage0_pruned"] += 1
                results[pos] = (GedOutcome(
                    ged=None, similar=False, certified=True,
                    lower_bound=lb, upper_bound=_INF, mapping=None,
                    backend="store/filter", wall_s=0.0, tau=tau,
                    stats={"stage": STAGE_FILTER}), STAGE_FILTER)
            else:
                survivors.append(pos)
        if survivors and self._filter_cfg is not None:
            plan = Plan.lazy(
                [(q, self.graphs[jobs[pos][0]]) for pos in survivors],
                vocab=vocab)
            taus_arr = np.asarray([jobs[pos][1] for pos in survivors],
                                  dtype=np.float32)
            undecided: List[int] = []
            for bucket in plan.subset_buckets(range(len(survivors)),
                                              self.executor.pack):
                t0 = time.perf_counter()
                out = self.executor.run_bucket(bucket, taus_arr,
                                               self._filter_cfg, True)
                wall = time.perf_counter() - t0
                self._counts["bound_wall_s"] += wall
                for bi, pi in enumerate(bucket.indices):
                    pos = survivors[pi]
                    if bool(out["exact"][bi]):
                        outcome = engine_outcome(
                            out, bucket.packed, bi, True,
                            float(taus_arr[pi]), "store/bound", wall,
                            rung=0)
                        outcome.stats["stage"] = STAGE_BOUND
                        self._counts["stage1_decided"] += 1
                        if outcome.similar:
                            self._counts["stage1_accepted"] += 1
                        results[pos] = (outcome, STAGE_BOUND)
                    else:
                        undecided.append(pos)
            survivors = sorted(undecided)

        if survivors:
            t0 = time.perf_counter()
            outs = self.engine.verify(
                [(q, self.graphs[jobs[pos][0]]) for pos in survivors],
                [jobs[pos][1] for pos in survivors], vocab=vocab)
            self._counts["verify_wall_s"] += time.perf_counter() - t0
            self._counts["stage2_verified"] += len(survivors)
            for pos, outcome in zip(survivors, outs):
                outcome.stats["stage"] = STAGE_VERIFY
                results[pos] = (outcome, STAGE_VERIFY)
        return results  # type: ignore[return-value]

    def _group_hits(self, rid: int, outcome: GedOutcome,
                    stage: int) -> List[SearchHit]:
        """Hits for every *live* corpus entry in ``rid``'s digest group."""
        return [SearchHit(gid, outcome if gid == rid else self._dup(outcome),
                          stage)
                for gid in self._members[rid]
                if gid not in self._tombstones]

    def _dup(self, outcome: GedOutcome) -> GedOutcome:
        """A duplicate corpus entry's copy of its representative's answer.

        Under the ``"wl"`` digest duplicates are isomorphic-but-not-
        identical, so the representative's vertex mapping does not apply
        and is dropped; exact-digest duplicates keep it.
        """
        out = detached(outcome, {**outcome.stats, "dedup": True})
        if self.digest == "wl":
            out = dataclasses.replace(out, mapping=None)
        return out
