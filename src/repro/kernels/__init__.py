"""Pallas TPU kernels for the batched GED engine (validated in interpret mode
on CPU; see ref.py for the pure-jnp oracles and tests/test_kernels.py for the
shape/dtype sweeps)."""
