"""Measured kernel autotuning and per-bucket dispatch.

PR 5's microbench rail was honest about the fused kernels: in interpret
mode on CPU the fused LSa loses below N = 128 and the fused BMa only wins
at N = 128, so a global ``use_kernel=True`` is a pessimization for the
small buckets that dominate AIDS-like workloads.  This module makes the
choice *measured* instead of global:

* ``tune_shape(kernel, n, b)`` benchmarks fused-vs-unfused (and a small
  tile-size sweep for the fused variant) at one engine-realistic shape on
  the **current** backend — compiled Mosaic on TPU, interpret otherwise —
  and records the winner in a tuning table.
* The table is keyed by ``(device_kind, kernel, N, B)`` and persisted to
  ``<dir>/tuning.json`` when a directory is configured
  (``enable_autotune(dir)`` / ``REPRO_GED_AUTOTUNE_DIR``), mirroring the
  persistent-compile-cache contract from PR 5: idempotent enable, reset
  on re-point, corrupt files recover to an empty table, and
  ``autotune_hits`` / ``autotune_misses`` / ``autotune_sweep_s`` counters
  surface in ``GedEngine.stats``.
* ``EngineConfig.use_kernel="auto"``: ``resolve_config`` runs **pre-jit**
  (in ``ged/exec.py Executor.run_packed_async``) and pins each bucket's
  ``(slots, batch)`` shape to a concrete ``KernelDispatch`` — per-family
  fused/unfused flags plus tuned tile sizes — stored on the (hashable,
  static) config, so every jit/compile cache keys on the decision and
  outcomes stay bit-identical across all dispatch paths (the kernels are
  exact vs their oracles).  Untuned shapes fall back to a conservative
  static heuristic: everything unfused under interpret-mode Pallas (the
  CPU footgun), fused only for N >= 128 on a real accelerator.

Key schema (flat strings in ``tuning.json``)::

    "<device_kind>|<kernel>|N=<n>|B=<b>"

where ``kernel`` is ``lsa`` / ``bma`` (N = bucket slots, B = state batch
through the nested vmaps = pairs x expand) or ``merge`` (N = pool size,
B = children per iteration = expand x slots).  Lookups try the exact key
first, then the nearest tuned B (log-space) at the same
``(device_kind, kernel, N)`` — kernel cost is ~linear in B, so the
winner rarely flips with B alone — and only count a miss when no
measurement for the (kernel, N) pair exists at all.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

AUTOTUNE_ENV = "REPRO_GED_AUTOTUNE_DIR"
TABLE_FILE = "tuning.json"
_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class KernelDispatch:
    """A concrete per-bucket kernel plan: static, hashable, jit-key-safe.

    ``tile_* = 0`` means the kernel's own default tiling
    (``gcd(N, 128)`` for LSa's candidate axis, ``min(N, 128)`` for BMa's
    block shape).
    """

    lsa_fused: bool = False
    lsa_tile_u: int = 0
    bma_fused: bool = False
    bma_tile_v: int = 0
    bma_tile_u: int = 0
    merge_fused: bool = False


# Module state, mirroring ``ged/exec.py``'s ``_PERSISTENT_CACHE``.
_AUTOTUNE = {
    "dir": None,        # Optional[str] — None = in-memory table only
    "table": {},        # key -> entry dict
    "hits": 0,
    "misses": 0,
    "sweep_s": 0.0,
}


# --------------------------------------------------------------------------
# table: enable / load / save / lookup
# --------------------------------------------------------------------------

def device_kind() -> str:
    """The tuning-table device key, e.g. ``"cpu"`` or ``"TPU v4"``."""
    import jax
    return jax.devices()[0].device_kind


def pallas_interpret() -> bool:
    from repro.kernels import ops as kops
    return kops.pallas_interpret()


def _table_path(path: str) -> str:
    return os.path.join(path, TABLE_FILE)


def _load(path: str) -> Dict[str, Dict]:
    """Read a tuning table; corrupt or alien files recover to empty."""
    from repro.store_io.atomic import read_json_or_none
    raw = read_json_or_none(_table_path(path))
    if not isinstance(raw, dict) or raw.get("version") != _SCHEMA_VERSION:
        return {}
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        return {}
    return {k: v for k, v in entries.items() if isinstance(v, dict)}


def _save() -> None:
    """Atomically persist the in-memory table (no-op without a dir).

    Goes through the shared atomic-IO core (:mod:`repro.store_io.atomic`)
    but keeps the raw ``{"version", "entries"}`` file format — no
    manifest envelope — so existing tables stay readable.
    """
    path = _AUTOTUNE["dir"]
    if path is None:
        return
    from repro.store_io.atomic import atomic_write_json
    payload = {"version": _SCHEMA_VERSION, "entries": _AUTOTUNE["table"]}
    atomic_write_json(_table_path(path), payload, indent=1, sort_keys=True)


def enable_autotune(path: Optional[str] = None) -> Optional[str]:
    """Point the tuning table at a directory and load any persisted rows.

    ``path=None`` falls back to ``$REPRO_GED_AUTOTUNE_DIR``; when neither
    is set the table stays purely in-memory (tuning still works, nothing
    persists).  Idempotent for a repeated path; re-pointing at a new
    directory replaces the in-memory table with that directory's rows.
    """
    path = path or os.environ.get(AUTOTUNE_ENV)
    if path is None:
        return _AUTOTUNE["dir"]
    if path == _AUTOTUNE["dir"]:
        return path
    os.makedirs(path, exist_ok=True)
    _AUTOTUNE["dir"] = path
    _AUTOTUNE["table"] = _load(path)
    return path


def reset() -> None:
    """Forget the directory, table and counters (tests / bench probes)."""
    _AUTOTUNE.update(dir=None, table={}, hits=0, misses=0, sweep_s=0.0)


def snapshot() -> Dict:
    """Copy of the module state, for save/restore around bench probes."""
    out = dict(_AUTOTUNE)
    out["table"] = dict(_AUTOTUNE["table"])
    return out


def restore(state: Dict) -> None:
    _AUTOTUNE.clear()
    _AUTOTUNE.update(state)


def autotune_stats() -> Dict[str, float]:
    """Merged into ``GedEngine.stats`` (same contract as the persistent
    compile cache counters)."""
    return {
        "autotune_hits": float(_AUTOTUNE["hits"]),
        "autotune_misses": float(_AUTOTUNE["misses"]),
        "autotune_sweep_s": float(_AUTOTUNE["sweep_s"]),
        "autotune_entries": float(len(_AUTOTUNE["table"])),
        "pallas_interpret": pallas_interpret(),
    }


def table_key(kernel: str, n: int, b: int, kind: Optional[str] = None) -> str:
    return f"{kind or device_kind()}|{kernel}|N={int(n)}|B={int(b)}"


def put(kernel: str, n: int, b: int, entry: Dict) -> Dict:
    entry = dict(entry)
    entry.update(kernel=kernel, N=int(n), B=int(b),
                 device_kind=device_kind())
    _AUTOTUNE["table"][table_key(kernel, n, b)] = entry
    _save()
    return entry


def lookup(kernel: str, n: int, b: int, count: bool = True) -> Optional[Dict]:
    """Tuned entry for ``(device_kind, kernel, n, b)``, or None.

    Falls back to the nearest tuned ``B`` (log-space) at the same
    ``(device_kind, kernel, n)`` — still a hit.  ``count=False`` probes
    without touching the hit/miss counters.
    """
    exact = _AUTOTUNE["table"].get(table_key(kernel, n, b))
    if exact is not None:
        if count:
            _AUTOTUNE["hits"] += 1
        return exact
    prefix = f"{device_kind()}|{kernel}|N={int(n)}|B="
    best, best_d = None, None
    for key, entry in _AUTOTUNE["table"].items():
        if not key.startswith(prefix):
            continue
        bb = int(key.rsplit("B=", 1)[1])
        d = abs(math.log(max(bb, 1)) - math.log(max(int(b), 1)))
        if best_d is None or d < best_d:
            best, best_d = entry, d
    if count:
        if best is not None:
            _AUTOTUNE["hits"] += 1
        else:
            _AUTOTUNE["misses"] += 1
    return best


# --------------------------------------------------------------------------
# dispatch resolution
# --------------------------------------------------------------------------

def static_heuristic(n: int) -> KernelDispatch:
    """Conservative plan for unmeasured shapes.

    Under interpret-mode Pallas (CPU) everything stays unfused — the
    measured table says fused interpret kernels lose at small N, and an
    interpret-mode "win" would be a lie about silicon anyway.  On a real
    accelerator the fused bound kernels win once tiles are full, so
    default them on from N >= 128; the merge kernel stays off until
    measured.
    """
    if pallas_interpret():
        return KernelDispatch()
    on = int(n) >= 128
    return KernelDispatch(lsa_fused=on, bma_fused=on)


def _safe_tile(tile, n: int) -> int:
    """Tile sizes from disk are untrusted: anything that doesn't divide
    the axis falls back to the kernel default (0)."""
    try:
        tile = int(tile)
    except (TypeError, ValueError):
        return 0
    if tile <= 0 or int(n) % tile != 0:
        return 0
    return tile


def resolve_config(cfg, slots: int, batch: int):
    """Pin ``use_kernel="auto"`` to a concrete ``KernelDispatch``.

    Runs once per bucket dispatch, **before** jit (``ged/exec.py``), so
    the resolved config — not the tuning table — is what every jit /
    compile cache keys on.  Non-"auto" configs pass through untouched.
    """
    if getattr(cfg, "use_kernel", None) != "auto" or cfg.dispatch is not None:
        return cfg
    n = int(slots)
    fallback = static_heuristic(n)
    b_eff = int(batch) * int(cfg.expand)

    fields = {}
    want_lsa = cfg.bound in ("lsa", "hybrid")
    want_bma = cfg.bound in ("bma", "hybrid")
    if want_lsa:
        ent = lookup("lsa", n, b_eff)
        if ent is not None:
            fields["lsa_fused"] = ent.get("impl") == "fused"
            fields["lsa_tile_u"] = _safe_tile(ent.get("tile_u"), n)
        else:
            fields["lsa_fused"] = fallback.lsa_fused
    if want_bma:
        ent = lookup("bma", n, b_eff)
        if ent is not None:
            fields["bma_fused"] = ent.get("impl") == "fused"
            fields["bma_tile_v"] = _safe_tile(ent.get("tile_v"), n)
            fields["bma_tile_u"] = _safe_tile(ent.get("tile_u"), n)
        else:
            fields["bma_fused"] = fallback.bma_fused
    ent = lookup("merge", int(cfg.pool), int(cfg.expand) * n)
    if ent is not None:
        fields["merge_fused"] = ent.get("impl") == "fused"
    else:
        fields["merge_fused"] = fallback.merge_fused
    return dataclasses.replace(cfg, dispatch=KernelDispatch(**fields))


def concrete_dispatch(cfg, n: int) -> KernelDispatch:
    """The plan the search loop follows — **pure** in ``cfg`` and ``n``.

    Called at trace time inside ``core/engine/search.py``; it must not
    consult the mutable tuning table (the jit cache keys on ``cfg``, so a
    table-dependent trace would go stale when the table changes).  An
    "auto" config that reached tracing without a resolved ``dispatch``
    (i.e. not via the executor) gets the static heuristic.
    """
    d = getattr(cfg, "dispatch", None)
    if d is not None:
        return d
    uk = cfg.use_kernel
    if uk == "auto":
        return static_heuristic(n)
    on = bool(uk)
    return KernelDispatch(lsa_fused=on, bma_fused=on)


# --------------------------------------------------------------------------
# the sweep
# --------------------------------------------------------------------------

def _timeit(fn, budget_s: float = 0.15) -> float:
    """Best-of-3 steady-state seconds per call, iteration count scaled to
    ``budget_s`` so slow interpret-mode variants don't stall the sweep."""
    import jax

    jax.block_until_ready(fn())                    # compile + warm
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    est = time.perf_counter() - t0
    iters = max(1, min(8, int(budget_s / (3.0 * max(est, 1e-7)))))
    best = math.inf
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _bound_bench(kernel: str, n: int, b: int, seed: int = 7):
    """A jitted fused/unfused bound evaluation at engine-realistic shapes:
    one dense packed pair at ``slots == n``, ``b`` random expansion states
    through the same nested-vmap structure the search loop traces.

    Returns ``bench(uk, tv, tu) -> device array``.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core.engine import bounds as eb
    from repro.core.engine.tensor_graphs import pack_pairs
    from repro.data.graphs import perturb, random_graph

    rng = np.random.default_rng(seed)
    q = random_graph(rng, n, density=0.3, n_vlabels=5, n_elabels=3)
    g = perturb(rng, q, 4, n_vlabels=5, n_elabels=3)
    t = pack_pairs([(q, g)], slots=n)
    args = tuple(jnp.asarray(x[0]) for x in
                 (t.qv, t.gv, t.qa, t.ga, t.order)) + (jnp.asarray(t.n[0]),)

    imgs = np.full((b, n), -1, np.int32)
    levels = rng.integers(1, max(2, n // 2), b).astype(np.int32)
    for i, lvl in enumerate(levels):
        imgs[i, :lvl] = rng.permutation(n)[:lvl]
    gcosts = (rng.integers(0, 8, b) * 0.5).astype(np.float32)
    states = tuple(jnp.asarray(a) for a in (imgs, levels, gcosts))

    @functools.partial(jax.jit, static_argnames=("uk", "tv", "tu"))
    def f(qv, gv, qa, ga, order, nn, im, lv, gc, uk, tv, tu):
        pc = eb.make_pair_consts(qv, gv, qa, ga, order, nn,
                                 t.n_vlabels, t.n_elabels)

        def one(img, level, gcost):
            sm = eb.state_masks(pc, img, level)
            if kernel == "lsa":
                return eb.lsa_children(pc, sm, level, gcost,
                                       use_kernel=uk, tile_u=tu)
            return eb.bma_cost_matrix(pc, sm, use_kernel=uk,
                                      tile_v=tv, tile_u=tu)

        return jax.vmap(one)(im, lv, gc)

    return lambda uk, tv, tu: f(*args, *states, uk=uk, tv=tv, tu=tu)


def _merge_bench(pool: int, children: int, seed: int = 11, pairs: int = 8):
    """A jitted sorted-pool merge step (the engine's frontier update)
    vmapped over a small pair batch.  Returns ``bench(uk) -> arrays``."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.parallel.ops import merge_sorted_topk, sort_by_key

    rng = np.random.default_rng(seed)
    na = max(int(pool) - 8, 8)                     # pool minus the pop slice
    nb = int(children)
    ka = jnp.asarray(np.sort(rng.random((pairs, na)), axis=1), jnp.float32)
    kb = jnp.asarray(rng.random((pairs, nb)), jnp.float32)
    pa = jnp.asarray(rng.integers(0, 64, (pairs, na, 16)), jnp.int32)
    pb = jnp.asarray(rng.integers(0, 64, (pairs, nb, 16)), jnp.int32)

    @functools.partial(jax.jit, static_argnames=("uk",))
    def f(ka, kb, pa, pb, uk):
        def one(ka, kb, pa, pb):
            kbs, order = sort_by_key(kb, jnp.arange(nb, dtype=jnp.int32))
            return merge_sorted_topk(ka, kbs, (pa,), (pb,), int(pool),
                                     drop_a=ka, drop_b=kbs, perm_b=order,
                                     use_kernel=uk)
        return jax.vmap(one)(ka, kb, pa, pb)

    return lambda uk: f(ka, kb, pa, pb, uk=uk)


def _tile_candidates(kernel: str, n: int) -> List[Tuple[int, int]]:
    """(tile_v, tile_u) sweep candidates; (0, 0) = the kernel default."""
    cands = [(0, 0)]
    if kernel == "lsa":
        default = math.gcd(n, 128)
        for t in (8, 32, 64):
            if n % t == 0 and t != default:
                cands.append((0, t))
    elif kernel == "bma":
        default = min(n, 128)
        for t in (8, 32):
            if n % t == 0 and t != default:
                cands.append((t, t))
    return cands


def tune_shape(kernel: str, n: int, b: int, *,
               tiles: Optional[Sequence[Tuple[int, int]]] = None,
               budget_s: float = 0.15) -> Dict:
    """Benchmark one ``(kernel, N, B)`` shape and record the winner.

    For ``lsa``/``bma``: times the unfused path and the fused kernel at
    each tile candidate; for ``merge``: times the searchsorted rank path
    vs the Pallas rank-count kernel.  The entry's ``us`` is the winner's
    own measured time (``impl`` names it), so dispatch-by-table can never
    pick a variant that measured slower.
    """
    t0 = time.perf_counter()
    if kernel in ("lsa", "bma"):
        bench = _bound_bench(kernel, int(n), int(b))
        unfused_s = _timeit(lambda: bench(False, 0, 0), budget_s)
        best_s, best_tv, best_tu = math.inf, 0, 0
        default_s = math.inf
        for tv, tu in (tiles if tiles is not None
                       else _tile_candidates(kernel, int(n))):
            s = _timeit(lambda: bench(True, tv, tu), budget_s)
            if (tv, tu) == (0, 0):
                default_s = s
            if s < best_s:
                best_s, best_tv, best_tu = s, tv, tu
        if not math.isfinite(default_s):
            default_s = best_s
        fused_wins = best_s < unfused_s
        entry = {
            "impl": "fused" if fused_wins else "unfused",
            "tile_v": best_tv if fused_wins else 0,
            "tile_u": best_tu if fused_wins else 0,
            "us": min(best_s, unfused_s) * 1e6,
            "fused_us": best_s * 1e6,
            "fused_default_us": default_s * 1e6,
            "unfused_us": unfused_s * 1e6,
        }
    elif kernel == "merge":
        bench = _merge_bench(int(n), int(b))
        unfused_s = _timeit(lambda: bench(False), budget_s)
        fused_s = _timeit(lambda: bench(True), budget_s)
        fused_wins = fused_s < unfused_s
        entry = {
            "impl": "fused" if fused_wins else "unfused",
            "tile_v": 0, "tile_u": 0,
            "us": min(fused_s, unfused_s) * 1e6,
            "fused_us": fused_s * 1e6,
            "fused_default_us": fused_s * 1e6,
            "unfused_us": unfused_s * 1e6,
        }
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    entry["pallas"] = "interpret" if pallas_interpret() else "mosaic"
    _AUTOTUNE["sweep_s"] += time.perf_counter() - t0
    return put(kernel, n, b, entry)


def tune(*, ns: Iterable[int] = (32, 64, 128),
         bs: Iterable[int] = (8, 32, 128),
         kernels: Iterable[str] = ("lsa", "bma"),
         merge_shapes: Iterable[Tuple[int, int]] = ((512, 256), (2048, 1024)),
         force: bool = False,
         tiles: Optional[Sequence[Tuple[int, int]]] = None,
         budget_s: float = 0.15) -> List[Dict]:
    """Pre-warm the table over a shape grid (skips already-tuned keys
    unless ``force``).  This is the "pre-warm a machine" entry point from
    docs/kernels.md."""
    entries = []
    for kernel in kernels:
        for n in ns:
            for b in bs:
                if not force and \
                        table_key(kernel, n, b) in _AUTOTUNE["table"]:
                    continue
                entries.append(tune_shape(kernel, n, b, tiles=tiles,
                                          budget_s=budget_s))
    for pool, children in merge_shapes:
        if not force and \
                table_key("merge", pool, children) in _AUTOTUNE["table"]:
            continue
        entries.append(tune_shape("merge", pool, children,
                                  budget_s=budget_s))
    return entries
