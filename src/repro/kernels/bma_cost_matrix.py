"""Pallas kernel: fused lambda^BMa branch-cost matrix (B, N, N).

The hottest op of the batched GED engine: for every expanded search state the
engine needs the full pairwise branch-edit cost matrix

    lam[v, u] = 1[l(v) != l(u)]
                + 1/2 * Y(inner-edge hists of v and u)
                + sum_{anchored j} 1[qa[v, order_j] != ga[u, img_j]]

Unfused, this is three (N, N)-shaped intermediates (vertex mismatch, pairwise
histogram Y, anchor mismatch counts) each round-tripping HBM.  The kernel
tiles (v, u) into VMEM blocks and accumulates the label- and anchor-
reductions with on-chip loops, writing ``lam`` once.

TPU mapping notes (DESIGN.md §2): the (TV, TU) tile is VPU-aligned (lanes =
128 on the u axis, sublanes on v); reductions over ``Le`` (edge labels) and
``N`` (anchor positions) are unrolled ``fori_loop``s over VMEM-resident
slices, so the working set is O(TV*N + TU*N) int32 + O(TV*TU) f32 per step —
about 200 KiB at N=128, comfortably inside the ~16 MiB VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(qv_ref, gv_ref, iq_ref, ig_ref, qa_ref, gc_ref, pa_ref, out_ref):
    # Tile shapes: qv (1, TV), gv (1, TU), iq (1, TV, Le), ig (1, TU, Le),
    # qa (1, TV, N), gc (1, TU, N), pa (1, N) -> out (1, TV, TU).
    qv = qv_ref[0]            # (TV,)
    gv = gv_ref[0]            # (TU,)
    iq = iq_ref[0]            # (TV, Le)
    ig = ig_ref[0]            # (TU, Le)
    qa = qa_ref[0]            # (TV, N)
    gc = gc_ref[0]            # (TU, N)
    pa = pa_ref[0]            # (N,)

    tv, le = iq.shape
    tu = ig.shape[0]
    n = qa.shape[1]

    vmis = (qv[:, None] != gv[None, :]).astype(jnp.float32)

    sq = jnp.sum(iq, axis=1)  # (TV,)
    sg = jnp.sum(ig, axis=1)  # (TU,)

    def label_body(l, acc):
        return acc + jnp.minimum(iq[:, l][:, None], ig[:, l][None, :])

    inter = jax.lax.fori_loop(0, le, label_body,
                              jnp.zeros((tv, tu), dtype=jnp.float32))
    ups = jnp.maximum(sq[:, None], sg[None, :]) - inter

    def anchor_body(j, acc):
        mism = (qa[:, j][:, None] != gc[:, j][None, :]).astype(jnp.float32)
        return acc + mism * pa[j]

    mism = jax.lax.fori_loop(0, n, anchor_body,
                             jnp.zeros((tv, tu), dtype=jnp.float32))

    out_ref[0] = vmis + 0.5 * ups + mism


@functools.partial(jax.jit, static_argnames=("tile_v", "tile_u", "interpret"))
def bma_cost_matrix_pallas(
    qv: jnp.ndarray,        # (B, N) int32
    gv: jnp.ndarray,        # (B, N) int32
    inner_q: jnp.ndarray,   # (B, N, Le) f32
    inner_g: jnp.ndarray,   # (B, N, Le) f32
    qa_ord: jnp.ndarray,    # (B, N, N) int32
    gcross: jnp.ndarray,    # (B, N, N) int32
    pos_anch: jnp.ndarray,  # (B, N) f32
    tile_v: int = 0,
    tile_u: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n = qv.shape
    le = inner_q.shape[-1]
    tv = tile_v or min(n, 128)
    tu = tile_u or min(n, 128)
    assert n % tv == 0 and n % tu == 0, (n, tv, tu)
    grid = (b, n // tv, n // tu)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tv), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, tu), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, tv, le), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tu, le), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tv, n), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tu, n), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, n), lambda b, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, tv, tu), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=interpret,
    )(qv, gv, inner_q, inner_g, qa_ord, gcross, pos_anch)
