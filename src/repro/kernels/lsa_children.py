"""Pallas kernel: fused delta^LSa child-bound vector (B, N).

The other half of the expansion hot path (the BMa half is
``bma_cost_matrix.py``): for every popped search state the engine scores
*all* children ``v_i -> u`` with the label-set anchor-aware bound — vertex
surplus, inner-edge histogram upsilons, per-anchor cross-term adjustments
and v_i's own cross component.

Unfused, the cross terms materialise a ``(pos, u, Le)`` one-hot ``aoh``
tensor plus half a dozen ``(N, N)``-shaped einsum intermediates per state,
each round-tripping HBM.  The kernel takes the *pre-reduced* histograms —
``(N, Le)``-sized contractions the engine computes with cheap matmuls —
and accumulates every per-``u`` reduction in VMEM, writing the single
``(B, N)`` bound vector once.

TPU mapping notes: the candidate axis ``u`` is tiled to the 128-lane VPU
axis; reductions over ``Le`` (edge labels) and ``N`` (anchor positions)
are ``fori_loop``s over VMEM-resident slices.  Working set per grid step:
the ``(N, TU)`` anchor-label tile (int32) plus four ``(N, TU)`` f32
accumulators and the ``(TU, Le)``/``(N, Le)`` histograms — about 380 KiB
at N = TU = 128, Le = 8, comfortably inside the ~16 MiB VMEM budget (see
docs/kernels.md for the full table).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e7


def _kernel(base_ref, free_g_ref, rowhist_g_ref, a_ju_ref, qrow_ref,
            pa_ref, cq_ref, cg_ref, base_j_ref, adjb_j_ref, hq_i_ref,
            hg_i_ref, cq_vi_ref, out_ref):
    # Tile shapes: base/free_g (1, TU), rowhist_g (1, TU, Le),
    # a_ju (1, N, TU), qrow/pa/base_j/adjb_j (1, N), cq/cg (1, N, Le),
    # hq_i/hg_i/cq_vi (1, Le) -> out (1, TU).
    base = base_ref[0]          # (TU,)
    free_g = free_g_ref[0]      # (TU,)
    rg = rowhist_g_ref[0]       # (TU, Le)
    a_ju = a_ju_ref[0]          # (N, TU)
    qrow = qrow_ref[0]          # (N,)
    pa = pa_ref[0]              # (N,)
    cq = cq_ref[0]              # (N, Le)
    cg = cg_ref[0]              # (N, Le)
    base_j = base_j_ref[0]      # (N,)
    adjb_j = adjb_j_ref[0]      # (N,)
    hq_i = hq_i_ref[0]          # (Le,)
    hg_i = hg_i_ref[0]          # (Le,)
    cq_vi = cq_vi_ref[0]        # (Le,)

    tu, le = rg.shape
    n = a_ju.shape[0]

    # ---- inner edges + v_i cross: one pass over edge labels -------------
    def label_body(l, accs):
        inter_i, inter_vi = accs
        rgl = rg[:, l]                                   # (TU,)
        inter_i = inter_i + jnp.minimum(hq_i[l], hg_i[l] - rgl)
        inter_vi = inter_vi + jnp.minimum(cq_vi[l], rgl)
        return inter_i, inter_vi

    zeros = jnp.zeros((tu,), dtype=jnp.float32)
    inter_i, inter_vi = jax.lax.fori_loop(0, le, label_body, (zeros, zeros))
    n_i1 = jnp.sum(hq_i)
    n_i2 = (jnp.sum(hg_i) - jnp.sum(rg, axis=1))         # (TU,)
    ups_i = jnp.maximum(n_i1, n_i2) - inter_i
    s1_vi = jnp.sum(cq_vi)
    s2_u = jnp.sum(rg, axis=1)
    ups_vi = jnp.maximum(s1_vi, s2_u) - inter_vi

    # ---- anchor cross terms: gather cq/cg at each (j, u)'s edge label ---
    # cg_at[j, u] = cg[j, a_ju[j, u] - 1] (0 where no edge), built as an
    # Le-step accumulation instead of the (pos, u, Le) one-hot einsum.
    def at_body(l, accs):
        cg_at, cq_at = accs
        m = (a_ju == l + 1).astype(jnp.float32)          # (N, TU)
        cg_at = cg_at + m * cg[:, l][:, None]
        cq_at = cq_at + m * cq[:, l][:, None]
        return cg_at, cq_at

    zeros_nu = jnp.zeros((n, tu), dtype=jnp.float32)
    cg_at, cq_at = jax.lax.fori_loop(0, le, at_body, (zeros_nu, zeros_nu))
    d_ju = (cg_at <= cq_at).astype(jnp.float32)
    ups_ju = jnp.where(a_ju > 0, adjb_j[:, None] + d_ju, base_j[:, None])
    cross = jnp.sum(ups_ju * pa[:, None], axis=0)        # (TU,)

    # ---- exact-delta edge mismatches of (v_i -> u) ----------------------
    mism = (qrow[:, None] != a_ju).astype(jnp.float32)
    de = jnp.sum(mism * pa[:, None], axis=0)             # (TU,)

    lb = base + de + ups_i + ups_vi + cross
    out_ref[0] = jnp.where(free_g > 0, lb, BIG)


@functools.partial(jax.jit, static_argnames=("tile_u", "interpret"))
def lsa_children_pallas(
    base: jnp.ndarray,       # (B, N) f32
    free_g: jnp.ndarray,     # (B, N) f32
    rowhist_g: jnp.ndarray,  # (B, N, Le) f32
    a_ju: jnp.ndarray,       # (B, N, N) int32
    qrow: jnp.ndarray,       # (B, N) int32
    pos_anch: jnp.ndarray,   # (B, N) f32
    cq: jnp.ndarray,         # (B, N, Le) f32
    cg: jnp.ndarray,         # (B, N, Le) f32
    base_j: jnp.ndarray,     # (B, N) f32
    adjb_j: jnp.ndarray,     # (B, N) f32
    hq_i: jnp.ndarray,       # (B, Le) f32
    hg_i: jnp.ndarray,       # (B, Le) f32
    cq_vi: jnp.ndarray,      # (B, Le) f32
    tile_u: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n = base.shape
    le = rowhist_g.shape[-1]
    # default tile: the largest power-of-two divisor of n up to the 128
    # VPU lanes — power-of-two slot buckets get 128 (or n), while pinned
    # odd slot counts still trace instead of tripping the divisibility
    # assert (an explicit tile_u must divide n)
    tu = tile_u or math.gcd(n, 128)
    assert n % tu == 0, (n, tu)
    grid = (b, n // tu)
    full_n = pl.BlockSpec((1, n), lambda b, j: (b, 0))
    full_le = pl.BlockSpec((1, le), lambda b, j: (b, 0))
    full_nle = pl.BlockSpec((1, n, le), lambda b, j: (b, 0, 0))
    tile = pl.BlockSpec((1, tu), lambda b, j: (b, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            tile,                                         # base
            tile,                                         # free_g
            pl.BlockSpec((1, tu, le), lambda b, j: (b, j, 0)),  # rowhist_g
            pl.BlockSpec((1, n, tu), lambda b, j: (b, 0, j)),   # a_ju
            full_n,                                       # qrow
            full_n,                                       # pos_anch
            full_nle,                                     # cq
            full_nle,                                     # cg
            full_n,                                       # base_j
            full_n,                                       # adjb_j
            full_le,                                      # hq_i
            full_le,                                      # hg_i
            full_le,                                      # cq_vi
        ],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(base, free_g, rowhist_g, a_ju, qrow, pos_anch, cq, cg, base_j,
      adjb_j, hq_i, hg_i, cq_vi)
