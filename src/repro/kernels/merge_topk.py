"""Pallas kernel: merge-path rank counts for the sorted-pool merge.

``parallel/ops.merge_sorted_topk`` merges two key-sorted runs by computing,
for every element, its rank in the merged order:

    rank_a[i] = i + #{j : keys_b[j] <  keys_a[i]}     (searchsorted "left")
    rank_b[j] = j + #{i : keys_a[i] <= keys_b[j]}     (searchsorted "right")

The binary searches are latency-bound on the VPU (log2(N) dependent gather
steps per element).  Because both runs are already sorted *and* small
enough to sit in VMEM whole (a (2048,) f32 run is 8 KiB against the
~16 MiB budget), the counts can instead be computed as a dense tiled
comparison-matrix reduction — pure vectorised compares + an add-reduce,
no gathers, one output write per element.  The integer counts are exactly
the searchsorted semantics, so the downstream merge (scatters, payload
gather, dropped-lb floor) is bit-identical.

One generic kernel handles both directions: ``count[x_i] = #{y_j R x_i}``
with the comparison ``R`` (strict ``<`` vs ``<=``) a static flag.  The x
run is tiled over the grid; the full y run rides along in every grid step
(revisited blocks are read-only, which Mosaic allows at any grid
position).  Working set per step: the (TX,) x tile, the (NY,) y run and
the (TX, NY) comparison tile — about 1 MiB at TX = 128, NY = 2048.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, out_ref, *, strict):
    # Tile shapes: x (1, TX), y (1, NY) -> out (1, TX).
    x = x_ref[0]                # (TX,)
    y = y_ref[0]                # (NY,)
    if strict:
        cmp = y[None, :] < x[:, None]      # (TX, NY)
    else:
        cmp = y[None, :] <= x[:, None]
    out_ref[0] = jnp.sum(cmp.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("strict", "tile_x", "interpret"))
def rank_counts_pallas(x, y, *, strict=True, tile_x=0, interpret=False):
    """count[b, i] = #{j : y[b, j] R x[b, i]}, R = ``<`` (strict) or ``<=``.

    ``x``/``y`` are key-sorted runs (B, NX)/(B, NY) f32; sortedness is not
    required for correctness here (the counts are plain comparison sums)
    but is what makes the counts equal to searchsorted ranks downstream.
    """
    b, nx = x.shape
    ny = y.shape[-1]
    tx = tile_x or math.gcd(nx, 128)
    assert nx % tx == 0, (nx, tx)
    grid = (b, nx // tx)
    kern = functools.partial(_kernel, strict=strict)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tx), lambda bb, i: (bb, i)),
            pl.BlockSpec((1, ny), lambda bb, i: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, tx), lambda bb, i: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b, nx), jnp.int32),
        interpret=interpret,
    )(x, y)


def merge_ranks_pallas(keys_a, keys_b, *, tile_x=0, interpret=False):
    """Both rank-count vectors for a two-run merge: (count_a, count_b).

    count_a[i] = #{j : keys_b[j] <  keys_a[i]}   (int32, (B, NA))
    count_b[j] = #{i : keys_a[i] <= keys_b[j]}   (int32, (B, NB))

    Two launches of the generic kernel rather than one two-output kernel:
    the two outputs tile over *different* axes, and a fused variant would
    have to revisit one of them across non-consecutive grid steps, which
    the TPU output-revisiting rule forbids.
    """
    count_a = rank_counts_pallas(keys_a, keys_b, strict=True,
                                 tile_x=tile_x, interpret=interpret)
    count_b = rank_counts_pallas(keys_b, keys_a, strict=False,
                                 tile_x=tile_x, interpret=interpret)
    return count_a, count_b
