"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode; on TPU
they compile to Mosaic.  Every wrapper accepts unbatched operands as well —
the engine calls them inside nested ``vmap``s, and ``pallas_call`` batches by
prepending grid dimensions.

Set ``REPRO_DISABLE_PALLAS=1`` to force the pure-jnp reference path
(used by the dry-run lowering, where interpret-mode pallas would obscure the
HLO cost analysis on CPU).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bma_cost_matrix import bma_cost_matrix_pallas
from repro.kernels.lsa_children import lsa_children_pallas
from repro.kernels.merge_topk import merge_ranks_pallas
from repro.kernels.reduced_top2 import reduced_top2_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _disabled() -> bool:
    return os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1"


def pallas_interpret() -> bool:
    """True when Pallas kernels would run in ``interpret=True`` mode here.

    Surfaced in ``GedEngine.stats`` (``pallas_interpret``) and consulted by
    the ``kernels/autotune.py`` static heuristic so interpret-mode timings
    can't masquerade as accelerator numbers and ``use_kernel="auto"``
    defaults to the unfused path on CPU until a shape is measured.
    """
    return _interpret()


def bma_cost_matrix(qv, gv, inner_q, inner_g, qa_ord, ga, img_cl, pos_anch,
                    tile_v=0, tile_u=0):
    """lambda^BMa free-pair cost matrix; operands may be batched or not.

    ``ga`` is gathered at ``img_cl`` here (cheap XLA gather) so the kernel
    body stays gather-free.
    """
    unbatched = qv.ndim == 1
    if unbatched:
        qv, gv, inner_q, inner_g, qa_ord, ga, img_cl, pos_anch = (
            x[None] for x in (qv, gv, inner_q, inner_g, qa_ord, ga, img_cl,
                              pos_anch))
    n = qv.shape[-1]
    # gcross[b, u, j] = ga[b, u, img_cl[b, j]]  (cheap XLA gather)
    gcross = jnp.take_along_axis(
        ga, jnp.broadcast_to(img_cl[:, None, :], ga.shape), axis=2
    )
    args = [qv, gv, inner_q, inner_g, qa_ord, gcross, pos_anch]
    if _disabled():
        out = ref.bma_cost_matrix_ref(*args)
    else:
        out = bma_cost_matrix_pallas(*args, tile_v=tile_v, tile_u=tile_u,
                                     interpret=_interpret())
    return out[0] if unbatched else out


def lsa_children(base, free_g, rowhist_g, a_ju, qrow, pos_anch, cq, cg,
                 base_j, adjb_j, hq_i, hg_i, cq_vi, tile_u=0):
    """Fused delta^LSa child-bound vector; operands may be batched or not.

    Operands are the pre-reduced histograms ``bounds.lsa_children``
    extracts with (N, Le)-sized contractions and gathers — the kernel
    body stays gather-free (see ``kernels/lsa_children.py``).
    """
    args = [base, free_g, rowhist_g, a_ju, qrow, pos_anch, cq, cg,
            base_j, adjb_j, hq_i, hg_i, cq_vi]
    unbatched = base.ndim == 1
    if unbatched:
        args = [x[None] for x in args]
    if _disabled():
        out = ref.lsa_children_ref(*args)
    else:
        out = lsa_children_pallas(*args, tile_u=tile_u,
                                  interpret=_interpret())
    return out[0] if unbatched else out


def merge_ranks(keys_a, keys_b, tile=0):
    """Rank counts for merging two key-sorted runs; batched or not.

    Returns ``(count_a, count_b)`` int32 with
    ``count_a[i] = #{j: keys_b[j] < keys_a[i]}`` and
    ``count_b[j] = #{i: keys_a[i] <= keys_b[j]}`` — exactly the
    searchsorted left/right ranks ``parallel/ops.merge_sorted_topk``
    computes, so routing through the kernel is bit-identical.
    """
    unbatched = keys_a.ndim == 1
    if unbatched:
        keys_a, keys_b = keys_a[None], keys_b[None]
    if _disabled():
        ca, cb = ref.merge_ranks_ref(keys_a, keys_b)
    else:
        ca, cb = merge_ranks_pallas(keys_a, keys_b, tile_x=tile,
                                    interpret=_interpret())
    if unbatched:
        return ca[0], cb[0]
    return ca, cb


def reduced_top2(cost, prices):
    """(min, argmin, 2nd-min) per row of ``cost + prices``."""
    unbatched = cost.ndim == 2
    if unbatched:
        cost, prices = cost[None], prices[None]
    if _disabled():
        m1, a1, m2 = ref.reduced_top2_ref(cost, prices)
    else:
        m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=_interpret())
    if unbatched:
        return m1[0], a1[0], m2[0]
    return m1, a1, m2
