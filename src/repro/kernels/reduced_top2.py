"""Pallas kernel: per-row (min, argmin, second-min) of ``cost + prices``.

The inner op of both the auction sweep (bid computation) and the forced
dual bounds (minor row minima).  Fusing the price broadcast with the double
reduction avoids materialising the reduced (B, N, N) matrix in HBM twice.

Tiling: rows (bidders) tiled to ``TR`` sublanes; the full column axis (N <=
512) stays resident in VMEM lanes, so each grid step is one VMEM-local
top-2 reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e7


def _kernel(cost_ref, prices_ref, m1_ref, a1_ref, m2_ref):
    cost = cost_ref[0]          # (TR, N)
    prices = prices_ref[0]      # (N,)
    red = cost + prices[None, :]
    m1 = jnp.min(red, axis=1)
    a1 = jnp.argmin(red, axis=1).astype(jnp.int32)
    n = red.shape[1]
    onehot = (jnp.arange(n, dtype=jnp.int32)[None, :] == a1[:, None])
    m2 = jnp.min(red + onehot.astype(red.dtype) * BIG, axis=1)
    m1_ref[0] = m1
    a1_ref[0] = a1
    m2_ref[0] = m2


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def reduced_top2_pallas(
    cost: jnp.ndarray,      # (B, N, N) f32
    prices: jnp.ndarray,    # (B, N) f32
    tile_r: int = 0,
    interpret: bool = False,
):
    b, n, _ = cost.shape
    tr = tile_r or min(n, 128)
    assert n % tr == 0
    grid = (b, n // tr)
    out_shapes = (
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.int32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tr, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, tr), lambda b, i: (b, i)),
            pl.BlockSpec((1, tr), lambda b, i: (b, i)),
            pl.BlockSpec((1, tr), lambda b, i: (b, i)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(cost, prices)
