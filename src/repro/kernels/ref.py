"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's tests sweep shapes/dtypes
and assert_allclose against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1e7


def bma_cost_matrix_ref(
    qv: jnp.ndarray,        # (B, N) int32
    gv: jnp.ndarray,        # (B, N) int32
    inner_q: jnp.ndarray,   # (B, N, Le) f32 — free-inner edge-label histograms
    inner_g: jnp.ndarray,   # (B, N, Le) f32
    qa_ord: jnp.ndarray,    # (B, N, N) int32 — q adjacency, cols by order position
    gcross: jnp.ndarray,    # (B, N, N) int32 — g adjacency gathered at images
    pos_anch: jnp.ndarray,  # (B, N) f32 — 1.0 where position j is anchored
) -> jnp.ndarray:
    """lambda^BMa(v, u) for all free-slot pairs (B, N, N).

    = 1[l(v) != l(u)]
      + 1/2 * ( max(|E_I(v)|, |E_I(u)|) - sum_l min(h_v[l], h_u[l]) )
      + sum_{anchored j} 1[ qa[v, order_j] != ga[u, img_j] ]
    """
    vmis = (qv[:, :, None] != gv[:, None, :]).astype(jnp.float32)
    sq = jnp.sum(inner_q, axis=2)
    sg = jnp.sum(inner_g, axis=2)
    inter = jnp.sum(
        jnp.minimum(inner_q[:, :, None, :], inner_g[:, None, :, :]), axis=3
    )
    ups = jnp.maximum(sq[:, :, None], sg[:, None, :]) - inter
    mism = jnp.einsum(
        "bvuj,bj->bvu",
        (qa_ord[:, :, None, :] != gcross[:, None, :, :]).astype(jnp.float32),
        pos_anch,
    )
    return vmis + 0.5 * ups + mism


def reduced_top2_ref(cost: jnp.ndarray, prices: jnp.ndarray):
    """Per-row (min, argmin, second-min) of ``cost + prices`` (B, N, N)->(B, N)x3."""
    red = cost + prices[:, None, :]
    m1 = jnp.min(red, axis=-1)
    a1 = jnp.argmin(red, axis=-1).astype(jnp.int32)
    masked = red + jax.nn.one_hot(a1, red.shape[-1], dtype=red.dtype) * BIG
    m2 = jnp.min(masked, axis=-1)
    return m1, a1, m2


def hist_intersect_ref(hq: jnp.ndarray, hg: jnp.ndarray) -> jnp.ndarray:
    """Pairwise histogram-intersection sizes: (B, Nq, L) x (B, Nu, L) -> (B, Nq, Nu)."""
    return jnp.sum(jnp.minimum(hq[:, :, None, :], hg[:, None, :, :]), axis=3)


def merge_ranks_ref(keys_a: jnp.ndarray, keys_b: jnp.ndarray):
    """Rank counts for a two-run merge: the semantics of record for
    ``kernels/merge_topk.py``.

    count_a[b, i] = #{j : keys_b[b, j] <  keys_a[b, i]}   (int32, (B, NA))
    count_b[b, j] = #{i : keys_a[b, i] <= keys_b[b, j]}   (int32, (B, NB))

    On sorted runs these equal ``searchsorted(keys_b, keys_a, "left")`` /
    ``searchsorted(keys_a, keys_b, "right")``, which is how
    ``parallel/ops.merge_sorted_topk`` consumes them.
    """
    count_a = jnp.sum(
        (keys_b[:, None, :] < keys_a[:, :, None]).astype(jnp.int32), axis=2)
    count_b = jnp.sum(
        (keys_a[:, None, :] <= keys_b[:, :, None]).astype(jnp.int32), axis=2)
    return count_a, count_b


def lsa_children_ref(
    base: jnp.ndarray,       # (B, N) f32 — g_cost + vertex-label terms per u
    free_g: jnp.ndarray,     # (B, N) f32 — 1.0 where u is a free g vertex
    rowhist_g: jnp.ndarray,  # (B, N, Le) f32 — free-neighbour edge hists of g
    a_ju: jnp.ndarray,       # (B, N, N) int32 — ga[img_j, u] (pos x u)
    qrow: jnp.ndarray,       # (B, N) int32 — qa_ord[v_i] (q edges of v_i by pos)
    pos_anch: jnp.ndarray,   # (B, N) f32 — 1.0 where position j is anchored
    cq: jnp.ndarray,         # (B, N, Le) f32 — anchored-q cross hists by pos
    cg: jnp.ndarray,         # (B, N, Le) f32 — anchored-g cross hists by pos
    base_j: jnp.ndarray,     # (B, N) f32 — max(s1, s2) - inter per pos
    adjb_j: jnp.ndarray,     # (B, N) f32 — max(s1, s2 - 1) - inter per pos
    hq_i: jnp.ndarray,       # (B, Le) f32 — free-inner edge hist of q
    hg_i: jnp.ndarray,       # (B, Le) f32 — free-inner edge hist of g
    cq_vi: jnp.ndarray,      # (B, Le) f32 — v_i's free-neighbour edge hist
) -> jnp.ndarray:
    """delta^LSa child-bound vector (B, N): +BIG where u is not free.

    The semantics of record for ``kernels/lsa_children.py``.  Operands are
    the pre-reduced histograms ``bounds.lsa_children`` extracts with cheap
    (N, Le)-sized contractions; everything (N, N)-shaped or bigger — the
    inner-edge upsilon per candidate u, the per-(anchor, u) cross-term
    adjustments (the old ``(pos, u, Le)`` one-hot ``aoh`` intermediate),
    and the exact-delta edge mismatches — happens here / in the kernel.
    """
    # ---- inner edges: remove u's incident free edges from the g side ----
    hg_i_u = hg_i[:, None, :] - rowhist_g                    # (B, N u, Le)
    n_i1 = jnp.sum(hq_i, axis=1)                             # (B,)
    n_i2 = jnp.sum(hg_i_u, axis=2)                           # (B, N)
    inter_i = jnp.sum(jnp.minimum(hq_i[:, None, :], hg_i_u), axis=2)
    ups_i = jnp.maximum(n_i1[:, None], n_i2) - inter_i

    # ---- v_i's own cross component --------------------------------------
    s1_vi = jnp.sum(cq_vi, axis=1)                           # (B,)
    s2_u = jnp.sum(rowhist_g, axis=2)                        # (B, N)
    inter_vi = jnp.sum(jnp.minimum(cq_vi[:, None, :], rowhist_g), axis=2)
    ups_vi = jnp.maximum(s1_vi[:, None], s2_u) - inter_vi

    # ---- old-anchor cross terms -----------------------------------------
    le = hq_i.shape[1]
    labels = jnp.arange(1, le + 1, dtype=jnp.int32)
    aoh = (a_ju[:, :, :, None] == labels).astype(jnp.float32)  # (B,pos,u,Le)
    cg_at = jnp.einsum("bjul,bjl->bju", aoh, cg)
    cq_at = jnp.einsum("bjul,bjl->bju", aoh, cq)
    d_ju = (cg_at <= cq_at).astype(jnp.float32)
    ups_ju = jnp.where(a_ju > 0, adjb_j[:, :, None] + d_ju,
                       base_j[:, :, None])                   # (B, pos, u)
    cross = jnp.einsum("bju,bj->bu", ups_ju, pos_anch)

    # ---- exact-delta edge mismatches of (v_i -> u) ----------------------
    de = jnp.einsum(
        "bju,bj->bu",
        (qrow[:, :, None] != a_ju).astype(jnp.float32), pos_anch)

    lb = base + de + ups_i + ups_vi + cross
    return jnp.where(free_g > 0, lb, BIG)
