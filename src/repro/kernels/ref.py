"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's tests sweep shapes/dtypes
and assert_allclose against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1e7


def bma_cost_matrix_ref(
    qv: jnp.ndarray,        # (B, N) int32
    gv: jnp.ndarray,        # (B, N) int32
    inner_q: jnp.ndarray,   # (B, N, Le) f32 — free-inner edge-label histograms
    inner_g: jnp.ndarray,   # (B, N, Le) f32
    qa_ord: jnp.ndarray,    # (B, N, N) int32 — q adjacency, cols by order position
    gcross: jnp.ndarray,    # (B, N, N) int32 — g adjacency gathered at images
    pos_anch: jnp.ndarray,  # (B, N) f32 — 1.0 where position j is anchored
) -> jnp.ndarray:
    """lambda^BMa(v, u) for all free-slot pairs (B, N, N).

    = 1[l(v) != l(u)]
      + 1/2 * ( max(|E_I(v)|, |E_I(u)|) - sum_l min(h_v[l], h_u[l]) )
      + sum_{anchored j} 1[ qa[v, order_j] != ga[u, img_j] ]
    """
    vmis = (qv[:, :, None] != gv[:, None, :]).astype(jnp.float32)
    sq = jnp.sum(inner_q, axis=2)
    sg = jnp.sum(inner_g, axis=2)
    inter = jnp.sum(
        jnp.minimum(inner_q[:, :, None, :], inner_g[:, None, :, :]), axis=3
    )
    ups = jnp.maximum(sq[:, :, None], sg[:, None, :]) - inter
    mism = jnp.einsum(
        "bvuj,bj->bvu",
        (qa_ord[:, :, None, :] != gcross[:, None, :, :]).astype(jnp.float32),
        pos_anch,
    )
    return vmis + 0.5 * ups + mism


def reduced_top2_ref(cost: jnp.ndarray, prices: jnp.ndarray):
    """Per-row (min, argmin, second-min) of ``cost + prices`` (B, N, N)->(B, N)x3."""
    red = cost + prices[:, None, :]
    m1 = jnp.min(red, axis=-1)
    a1 = jnp.argmin(red, axis=-1).astype(jnp.int32)
    masked = red + jax.nn.one_hot(a1, red.shape[-1], dtype=red.dtype) * BIG
    m2 = jnp.min(masked, axis=-1)
    return m1, a1, m2


def hist_intersect_ref(hq: jnp.ndarray, hg: jnp.ndarray) -> jnp.ndarray:
    """Pairwise histogram-intersection sizes: (B, Nq, L) x (B, Nu, L) -> (B, Nq, Nu)."""
    return jnp.sum(jnp.minimum(hq[:, :, None, :], hg[:, None, :, :]), axis=3)
