import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Pure-jnp kernel path for lowering: interpret-mode pallas_call unrolls its
# grid as a while loop of batch-dim dynamic-slices, which the SPMD
# partitioner can only handle by all-gathering the pair batch (measured:
# 494 TB/device fake traffic on ged-verify).  On TPU the Mosaic kernel is
# used; on the CPU dry-run the reference path shows XLA the real math.
os.environ["REPRO_DISABLE_PALLAS"] = "1"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init).  512 placeholder host devices back the production meshes:
(16, 16) single-pod and (2, 16, 16) multi-pod.

Per cell this launcher
  1. builds the sharded step via ``launch/steps.py`` from abstract
     ``ShapeDtypeStruct`` inputs (no allocation — a 72B tree is free),
  2. ``jax.jit(...).lower(...)`` then ``.compile()`` — success proves the
     sharding config is coherent (no mismatched collectives, no
     unpartitionable ops),
  3. records ``compiled.memory_analysis()`` (fits-in-HBM proof),
     raw ``compiled.cost_analysis()`` and the trip-count-corrected HLO
     costs (``launch/hlo_analysis.py``), analytic MODEL_FLOPS, and the
     three roofline terms, into ``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_arch
from repro.launch.flops import model_flops
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (GED_SHAPES, SHAPE_ORDER, SHAPES,
                                 cell_skip_reason)
from repro.launch.steps import build_cell, build_ged
from repro.parallel.sharding import set_rules

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # ICI, bytes/s/link

GED_CELLS = {"ged-verify": "verify_db", "ged-compute": "compute"}


def all_cells():
    cells = []
    for arch in sorted(ARCHS):
        for shape in SHAPE_ORDER:
            cells.append((arch, shape))
    for arch, shape in GED_CELLS.items():
        cells.append((arch, shape))
    return cells


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             force: bool = False) -> dict:
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        print(f"[skip-cached] {tag}: {rec.get('status')}")
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    pod_boundary = 256 if multi else 0

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": n_chips, "status": "error"}
    t0 = time.time()
    try:
        if arch in GED_CELLS:
            plan = build_ged(GED_SHAPES[shape_name], mesh)
            mf = None
        else:
            cfg = get_arch(arch)
            sh = SHAPES[shape_name]
            skip = cell_skip_reason(cfg, sh)
            if skip:
                rec["status"] = "skipped"
                rec["reason"] = skip
                out_path.write_text(json.dumps(rec, indent=1))
                print(f"[skipped ] {tag}: {skip}")
                return rec
            plan = build_cell(cfg, sh, mesh)
            mf = model_flops(cfg, sh)

        with mesh:
            jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                             out_shardings=plan.out_shardings,
                             donate_argnums=plan.donate_argnums)
            lowered = jitted.lower(*plan.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        }

        hlo = analyze_hlo(compiled.as_text(), pod_boundary=pod_boundary)
        rec["hlo"] = hlo
        # TPU-corrected peak: the CPU backend materialises f32 copies of
        # bf16 dot operands (MXU consumes bf16 natively) — subtract them.
        rec["memory"]["f32_staging_bytes"] = hlo["f32_staging_bytes"]
        # staging lives in temps; clamp so corrected >= args + out - alias
        ma_ = rec["memory"]
        rec["memory"]["peak_bytes_tpu_corrected"] = (
            ma_["argument_bytes"] + ma_["output_bytes"]
            - ma_["alias_bytes"]
            + max(ma_["temp_bytes"] - hlo["f32_staging_bytes"], 0))

        terms = {
            "compute_s": hlo["flops"] / PEAK_FLOPS,
            "memory_s": hlo["bytes_accessed"] / HBM_BW,
            "collective_s": hlo["collective_bytes"] / LINK_BW,
        }
        terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                                  if k.endswith("_s") else -1)
        rec["roofline"] = terms
        if mf is not None:
            rec["model_flops"] = mf
            per_dev_model = mf["model_flops"] / n_chips
            rec["roofline"]["model_compute_s"] = per_dev_model / PEAK_FLOPS
            rec["roofline"]["useful_flops_ratio"] = (
                per_dev_model / hlo["flops"] if hlo["flops"] else 0.0)

        step_s = max(terms["compute_s"], terms["memory_s"],
                     terms["collective_s"])
        rec["roofline"]["step_time_lower_bound_s"] = step_s
        if mf is not None and step_s > 0:
            rec["roofline"]["mfu_upper_bound"] = (
                mf["model_flops"] / n_chips / PEAK_FLOPS) / step_s

        rec["timing"] = {"lower_s": round(t_lower, 2),
                         "compile_s": round(t_compile, 2)}
        rec["meta"] = {k: v for k, v in plan.meta.items()}
        rec["status"] = "ok"
        print(f"[ok       ] {tag}: lower {t_lower:.1f}s compile "
              f"{t_compile:.1f}s bottleneck={terms['bottleneck']} "
              f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
    except Exception as e:          # record the failure — it is a bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL     ] {tag}: {rec['error']}")
    finally:
        set_rules(None)
        jax.clear_caches()

    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id | 'all' | 'ged-verify' | 'ged-compute'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = all_cells()
    if args.list:
        for a, s in cells:
            print(f"{a:24s} {s}")
        return

    if args.arch != "all":
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape != "all":
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = 0
    for mesh_kind in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mesh_kind, out_dir,
                           force=args.force)
            if rec["status"] == "error":
                n_fail += 1
            else:
                n_ok += 1
    print(f"\ndry-run complete: {n_ok} ok/skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
