"""Analytic MODEL_FLOPS per (arch x shape) — the 6·N·D yardstick.

``model_flops`` returns the *useful* flops of one step under the standard
accounting: 2·N_mm per token forward, x3 for train (fwd+bwd), where N_mm is
the matmul parameter count (embedding table lookups excluded; MoE counts
only the ``top_k`` routed experts + shared experts — 6·N_active·D), plus
attention score/value flops (4·tokens·T_avg·Hq·hd) and SSM state-update
flops, which 6·N·D alone would miss at 32k+ contexts.

The ratio MODEL_FLOPS / HLO_FLOPS (both per device) exposes remat and
dispatch waste in the compiled step.
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.models.config import ArchConfig
from repro.models.params import PSpec, param_specs
from repro.models.ssm import mamba2_dims, rwkv6_dims
from repro.launch.shapes import ShapeSpec


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _leaf_groups(cfg: ArchConfig) -> Dict[str, float]:
    """Matmul params split into {enc, dec, expert} groups."""
    import jax
    specs = param_specs(cfg)
    groups = {"enc": 0.0, "dec": 0.0, "expert": 0.0}
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=_is_pspec)[0]
    for path, spec in flat:
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        name = "/".join(str(k) for k in keys)
        if spec.shape and len(spec.shape) < 2:
            continue                       # norms, biases: negligible
        if name.startswith("embed"):
            continue                       # table lookup, not a matmul
        n = float(np.prod(spec.shape))
        if "expert" in spec.axes:
            groups["expert"] += n
        elif name.startswith("enc_layers"):
            groups["enc"] += n
        else:
            groups["dec"] += n
    return groups


def _attn_flops(cfg: ArchConfig, tokens: float, t_avg: float) -> float:
    """score + value matmuls: 2 x 2 x tokens x T x Hq x hd."""
    return 4.0 * tokens * t_avg * cfg.n_heads * cfg.hd


def _train_t_avg(cfg: ArchConfig, s: int) -> float:
    """Mean KV length per layer, respecting sliding windows."""
    windows = cfg.windows()
    total = 0.0
    for w in windows:
        total += min(w, s / 2) if w > 0 else s / 2
    return total / max(len(windows), 1)


def _ssm_state_flops(cfg: ArchConfig, tokens: float) -> float:
    if cfg.ssm is None:
        return 0.0
    if cfg.ssm.kind == "rwkv6":
        d = rwkv6_dims(cfg)
        # wkv state update + readout: ~4 ops per (head, p, p) cell per token
        return 4.0 * tokens * d["n_heads"] * d["head_dim"] ** 2 * _n_ssm(cfg)
    d = mamba2_dims(cfg)
    # SSD: state update (h,p,n) + readout per token
    return 4.0 * tokens * d["n_heads"] * d["head_dim"] * d["d_state"] \
        * _n_ssm(cfg)


def _n_ssm(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers - cfg.n_layers // cfg.hybrid_attn_every
    return 0


def _n_attn_layers(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    return cfg.n_layers


def _expert_active(cfg: ArchConfig) -> float:
    """Active routed-expert matmul params (per token) across layers."""
    if cfg.moe is None:
        return 0.0
    m = cfg.moe
    mats = 3 if True else 2               # wg, wi, wo
    return float(cfg.n_layers * mats * m.top_k * cfg.d_model * m.expert_ff)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    g = _leaf_groups(cfg)
    n_dec = g["dec"] + _expert_active(cfg)
    if cfg.tied_embeddings:
        n_dec += cfg.d_model * cfg.padded_vocab      # logits matmul

    if shape.kind == "decode":
        tokens = float(b)                            # one new token per seq
        flops = 2.0 * n_dec * tokens
        flops += _attn_flops(cfg, tokens, _decode_t_avg(cfg, s)) \
            * _n_attn_layers(cfg)
        flops += _ssm_state_flops(cfg, tokens)
        n_active = n_dec
    else:
        stream = s                                   # vlm patches included
        tokens = float(b) * stream
        mult = 3.0 if shape.kind == "train" else 1.0
        flops = mult * 2.0 * n_dec * tokens
        flops += mult * _attn_flops(cfg, tokens, _train_t_avg(cfg, stream)) \
            * _n_attn_layers(cfg)
        flops += mult * _ssm_state_flops(cfg, tokens)
        if cfg.family == "audio":
            enc_tokens = float(b) * cfg.encdec.enc_seq
            flops += mult * 2.0 * g["enc"] * enc_tokens
            flops += mult * _attn_flops(cfg, enc_tokens,
                                        cfg.encdec.enc_seq / 2) \
                * cfg.encdec.enc_layers
            # decoder cross-attention reads the encoder sequence
            flops += mult * _attn_flops(cfg, tokens, cfg.encdec.enc_seq) \
                * cfg.n_layers
        n_active = n_dec + g["enc"]

    return {"model_flops": flops, "n_matmul_params": n_dec + g["enc"],
            "n_active_matmul_params": n_active, "tokens": tokens}


def _decode_t_avg(cfg: ArchConfig, cache: int) -> float:
    windows = cfg.windows()
    att = [w for w in windows]
    if cfg.family == "hybrid":
        att = [0] * _n_attn_layers(cfg)
    if not att:
        return 0.0
    total = 0.0
    for w in att:
        total += min(w, cache) if w > 0 else cache
    return total / len(att)
