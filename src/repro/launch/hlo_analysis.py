"""Trip-count-corrected HLO cost analysis for the roofline.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (XLA's
HloCostAnalysis has no static trip counts), which under-reports a scanned
L-layer model by ~L×.  Scanned layers are exactly how every model here is
written, so we parse ``compiled.as_text()`` ourselves:

* build a per-computation symbol table (instruction -> output shape),
* extract static trip counts from each ``while`` condition
  (``compare(%iv, %constant), direction=LT`` — the lax.scan pattern),
* walk the call graph (ENTRY -> while/fusion/call/conditional) multiplying
  instruction costs by the product of enclosing trip counts,
* FLOPs: dot = 2·prod(out)·prod(contracting dims); convolution =
  2·prod(out)·prod(window)·(Cin/groups); elementwise/reduce = element count,
* bytes: operands + output at *fusion boundaries* only (a proxy for HBM
  traffic on TPU, where fusion internals live in VMEM/VREGs),
* collective bytes: Σ operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ ``-start`` variants),
  trip-count multiplied, split into ICI vs cross-pod (DCN) by inspecting
  replica groups.

All numbers are per-device (the module is post-SPMD).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OPCODE_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "floor", "ceil",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "sine", "cosine", "tan", "atan2",
    "compare", "select", "clamp", "remainder", "round-nearest-afz",
    "round-nearest-even", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "erf", "is-finite", "stochastic-convert",
}

_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "ragged-all-to-all",
}


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    table: Dict[str, Instr]


def _split_operands(text: str) -> List[str]:
    ops, depth, cur = [], 0, []
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            ops.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        tail = "".join(cur).strip()
        if tail:
            ops.append(tail)
    return ops


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    om = _OPCODE_RE.search(rest)
    if not om:
        return None
    shape = rest[: om.start(1)].strip()
    # operand list: balanced parens starting right after the opcode
    i = om.end(1)
    while i < len(rest) and rest[i] != "(":
        i += 1
    depth, j = 0, i
    while j < len(rest):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    operand_text = rest[i + 1: j]
    attrs = rest[j + 1:]
    opnames = []
    for op in _split_operands(operand_text):
        nm = re.search(r"%([\w.\-]+)", op)
        opnames.append(nm.group(1) if nm else op)
    return Instr(name, shape, om.group(1), opnames, attrs)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            cm = _COMP_RE.match(stripped)
            if cm:
                cur = Computation(cm.group(2), bool(cm.group(1)), [], {})
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
            cur.table[ins.name] = ins
    return comps


# ------------------------------------------------------------- trip counts

def _const_value(comp: Computation, name: str) -> Optional[int]:
    ins = comp.table.get(name)
    if ins is None:
        return None
    if ins.opcode == "constant":
        m = re.search(r"constant\((-?\d+)\)", ins.shape + " constant(" +
                      ",".join(ins.operands) + ")")
        # constant value is printed inside the parens we treated as operands
        if ins.operands and re.fullmatch(r"-?\d+", ins.operands[0] or ""):
            return int(ins.operands[0])
        if m:
            return int(m.group(1))
        return None
    if ins.opcode in ("broadcast", "copy", "convert") and ins.operands:
        return _const_value(comp, ins.operands[0])
    return None


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _trip_count(while_ins: Instr, cond: Optional[Computation]
                ) -> Optional[int]:
    """XLA records static trips in backend_config (lax.scan/fori loops);
    fall back to the ``compare(iv, N), direction=LT`` condition pattern."""
    m = _TRIP_RE.search(while_ins.attrs)
    if m:
        return int(m.group(1))
    if cond is None:
        return None
    for ins in cond.instrs:
        if ins.opcode != "compare" or "direction=LT" not in ins.attrs:
            continue
        for op in ins.operands:
            v = _const_value(cond, op)
            if v is not None and v > 0:
                return v
    return None


# ------------------------------------------------------------------- flops

def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.shape)
    lhs = comp.table.get(ins.operands[0]) if ins.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contracting = 1
    if lhs is not None and m and m.group(1):
        dims = _first_dims(lhs.shape)
        for di in m.group(1).split(","):
            i = int(di)
            if i < len(dims):
                contracting *= dims[i]
    return 2.0 * out_elems * contracting


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.shape)
    window = 1
    m = re.search(r"window=\{[^}]*size=([\dx]+)", ins.attrs)
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    groups = 1
    g = re.search(r"feature_group_count=(\d+)", ins.attrs)
    if g:
        groups = int(g.group(1))
    cin = 1
    dl = re.search(r"dim_labels=([\w?]+)_", ins.attrs)
    if dl and ins.operands:
        lhs = comp.table.get(ins.operands[0])
        if lhs is not None:
            f_pos = dl.group(1).find("f")
            dims = _first_dims(lhs.shape)
            if 0 <= f_pos < len(dims):
                cin = dims[f_pos]
    return 2.0 * out_elems * window * max(cin // max(groups, 1), 1)


# -------------------------------------------------------------------- walk

@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    dcn_bytes: float = 0.0
    collective_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: float = 0.0
    f32_staging_bytes: float = 0.0   # CPU-only bf16->f32 dot legalization
    warnings: List[str] = dataclasses.field(default_factory=list)


def _called(attrs: str, key: str) -> List[str]:
    m = re.search(key + r"=\{([^}]*)\}", attrs)      # brace list form
    if m:
        return [p.strip().lstrip("%") for p in m.group(1).split(",")
                if p.strip()]
    m = re.search(key + r"=%?([\w.\-]+)", attrs)     # single-name form
    return [m.group(1)] if m else []


def _root_is_dus(comp: Computation) -> bool:
    """True if the fusion computes an in-place dynamic-update-slice."""
    for ins in reversed(comp.instrs):
        if ins.opcode in ("bitcast", "tuple"):
            continue
        return ins.opcode == "dynamic-update-slice"
    return False


def _crosses_pod(attrs: str, pod_boundary: int) -> bool:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if not m and "replica_groups=[" in attrs:
        m = re.search(r"replica_groups=\[[\d,<=]*\]([\d,]+)", attrs)
    if not m:
        return False
    ids = [int(x) for x in m.group(1).split(",") if x]
    return any(i < pod_boundary for i in ids) and \
        any(i >= pod_boundary for i in ids)


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    for op in ins.operands:
        ref = comp.table.get(op)
        if ref is not None:
            total += _shape_bytes(ref.shape)
    return total


def _walk(comp: Computation, comps: Dict[str, Computation], mult: float,
          costs: Costs, in_fusion: bool, pod_boundary: int) -> None:
    for ins in comp.instrs:
        op = ins.opcode
        if op in _FREE:
            continue
        out_bytes = _shape_bytes(ins.shape)

        if op in _COLLECTIVES:
            b = _operand_bytes(ins, comp) * mult
            costs.collective_bytes += b
            costs.collective_count += mult
            costs.collective_by_op[op.replace("-start", "")] = \
                costs.collective_by_op.get(op.replace("-start", ""), 0.0) + b
            if pod_boundary and _crosses_pod(ins.attrs, pod_boundary):
                costs.dcn_bytes += b
            if not in_fusion:
                costs.bytes_accessed += (_operand_bytes(ins, comp)
                                         + out_bytes) * mult
            continue

        if op == "while":
            body, cond = _called(ins.attrs, "body"), \
                _called(ins.attrs, "condition")
            cond_comp = comps.get(cond[0]) if cond else None
            trip = _trip_count(ins, cond_comp)
            if trip is None:
                trip = 1
                costs.warnings.append(
                    f"while {ins.name}: trip count unparsed, using 1")
            if body and body[0] in comps:
                _walk(comps[body[0]], comps, mult * trip, costs, in_fusion,
                      pod_boundary)
            if cond and cond[0] in comps:
                _walk(comps[cond[0]], comps, mult * (trip + 1), costs,
                      in_fusion, pod_boundary)
            continue

        if op == "fusion":
            called = _called(ins.attrs, "calls")
            fused = comps.get(called[0]) if called else None
            if fused is not None:
                _walk(fused, comps, mult, costs, True, pod_boundary)
            if not in_fusion:
                opb = _operand_bytes(ins, comp)
                if fused is not None and _root_is_dus(fused):
                    # in-place update fusion: the big operand aliases the
                    # output; traffic ~= 2x everything except that operand
                    big = max((_shape_bytes(comp.table[o].shape)
                               for o in ins.operands if o in comp.table),
                              default=0)
                    costs.bytes_accessed += 2.0 * max(opb - big, 0) * mult
                else:
                    costs.bytes_accessed += (opb + out_bytes) * mult
            continue

        if op == "call":
            called = _called(ins.attrs, "to_apply")
            if called and called[0] in comps:
                _walk(comps[called[0]], comps, mult, costs, in_fusion,
                      pod_boundary)
            continue

        if op == "conditional":
            for br in _called(ins.attrs, "branch_computations"):
                if br in comps:
                    _walk(comps[br], comps, mult, costs, in_fusion,
                          pod_boundary)
            continue

        if op in ("custom-call",):
            if not in_fusion:
                costs.bytes_accessed += (_operand_bytes(ins, comp)
                                         + out_bytes) * mult
            continue

        # ---- plain compute op
        if op == "dot":
            costs.flops += _dot_flops(ins, comp) * mult
        elif op == "convolution":
            costs.flops += _conv_flops(ins, comp) * mult
        elif op in _ELEMENTWISE:
            costs.flops += _shape_elems(ins.shape) * mult
        elif op in ("reduce", "reduce-window", "sort", "scatter",
                    "select-and-scatter"):
            costs.flops += _operand_bytes(ins, comp) / 4.0 * mult
        # data movement ops contribute bytes only.  Sliced reads/writes
        # (dynamic-slice, gather, DUS) touch only the slice, not the full
        # operand — counting operands fully inflated a layer loop that
        # dynamic-slices from a 9 GiB stacked param tree by ~80x.
        if in_fusion:
            continue
        if op in ("dynamic-slice", "gather", "slice"):
            costs.bytes_accessed += 2.0 * out_bytes * mult
        elif op in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(ins.operands) >= 2:
                ref = comp.table.get(ins.operands[1])
                if ref is not None:
                    upd = _shape_bytes(ref.shape)
            costs.bytes_accessed += 2.0 * max(upd, 1) * mult
        else:
            costs.bytes_accessed += (_operand_bytes(ins, comp)
                                     + out_bytes) * mult


def analyze_hlo(text: str, pod_boundary: int = 0) -> Dict[str, Any]:
    """Per-device trip-count-corrected costs from post-SPMD HLO text.

    ``pod_boundary``: first device id of pod 1 (256 in the 2-pod mesh);
    0 disables DCN attribution.
    """
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    costs = Costs()
    _walk(entry, comps, 1.0, costs, False, pod_boundary)
    costs.f32_staging_bytes = _f32_staging(comps)
    return {
        "flops": costs.flops,
        "bytes_accessed": costs.bytes_accessed,
        "collective_bytes": costs.collective_bytes,
        "dcn_bytes": costs.dcn_bytes,
        "collective_by_op": costs.collective_by_op,
        "collective_count": costs.collective_count,
        "f32_staging_bytes": costs.f32_staging_bytes,
        "warnings": costs.warnings[:20],
        "n_computations": len(comps),
    }


def _f32_staging(comps: Dict[str, Computation],
                 threshold: int = 64 * 2 ** 20) -> float:
    """Bytes of large f32 buffers produced by converting bf16 tensors.

    The CPU backend legalises ``dot(bf16, bf16) -> f32`` by materialising
    f32 copies of the operands (often loop-hoisted to full stacked-layer
    size); the TPU MXU consumes bf16 natively with f32 accumulation and
    allocates none of this.  Reported so the dry-run can state a
    TPU-corrected peak alongside the raw CPU ``memory_analysis()``.
    """
    total = 0.0
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode != "convert" or not ins.shape.startswith("f32"):
                continue
            src = comp.table.get(ins.operands[0]) if ins.operands else None
            if src is None or not src.shape.startswith("bf16"):
                continue
            b = _shape_bytes(ins.shape)
            if b >= threshold:
                total += b
    return total
