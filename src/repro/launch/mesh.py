"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real launches get the same topology from the TPU runtime.

Topology (v5e target):
  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

``model`` is the ICI-contiguous axis (TP/EP/KV-shard); ``data`` carries
FSDP + batch; ``pod`` composes with ``data`` for batch and hosts the
optional 2-stage pipeline wrapper.  Gradient all-reduces are emitted
hierarchically (ICI first, DCN once) because ``pod`` is the outermost axis.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for unit tests on forced host devices."""
    return jax.make_mesh(shape, axes)


def chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
