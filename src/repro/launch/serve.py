"""Serving launcher: GED verification service or LM decode.

GED verification (the paper's workload; default):
  PYTHONPATH=src python -m repro.launch.serve --mode ged \\
      --pairs 200 --tau 9 --size 16

LM decode (reduced-scale, any assigned arch):
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch gemma3-1b \\
      --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_arch, list_archs


def serve_ged(args) -> None:
    from repro.data.graphs import perturb, random_graph
    from repro.serving import GedRequest, GedVerificationService

    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.pairs):
        q = random_graph(rng, args.size)
        g = perturb(rng, q, int(rng.integers(1, 12)))
        reqs.append(GedRequest(q, g, tau=args.tau))

    svc = GedVerificationService(batch_size=args.batch)
    t0 = time.time()
    results = svc.verify(reqs)
    dt = time.time() - t0
    n_sim = sum(1 for r in results if r.similar)
    n_cert = sum(1 for r in results if r.certified)
    print(f"verified {len(reqs)} pairs in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} pairs/s)")
    print(f"similar: {n_sim}/{len(reqs)}   certified: {n_cert}/{len(reqs)}")
    print(f"service stats: {svc.stats}")


def serve_lm(args) -> None:
    import dataclasses
    from repro.models.config import reduced
    from repro.models.params import init_params, param_count
    from repro.serving import generate

    cfg = reduced(get_arch(args.arch))
    cfg = dataclasses.replace(cfg, remat="none")
    print(f"arch={cfg.name} (reduced) params={param_count(cfg):,}")
    params = init_params(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab,
                          size=(args.batch, args.prompt_len)).astype(np.int32)
    frames = patches = None
    if cfg.family == "audio":
        frames = np.zeros((args.batch, cfg.encdec.enc_seq, cfg.d_model),
                          np.float32)
    if cfg.vlm is not None:
        patches = np.zeros((args.batch, cfg.vlm.num_patches, cfg.d_model),
                           np.float32)
    t0 = time.time()
    out = generate(params, prompt, cfg, max_new=args.max_new,
                   frames=frames, patches=patches, impl="naive")
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print("sample:", out[0][:12])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="ged", choices=("ged", "lm"))
    ap.add_argument("--seed", type=int, default=0)
    # ged
    ap.add_argument("--pairs", type=int, default=100)
    ap.add_argument("--tau", type=float, default=9.0)
    ap.add_argument("--size", type=int, default=12)
    ap.add_argument("--batch", type=int, default=64)
    # lm
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "ged":
        serve_ged(args)
    else:
        args.batch = min(args.batch, 8)
        serve_lm(args)


if __name__ == "__main__":
    main()
