"""Assigned input shapes and abstract ``input_specs()`` per (arch, shape).

Every cell of the (architecture x shape) grid is defined here.  Specs are
``jax.ShapeDtypeStruct`` stand-ins — weak-type-correct, shardable, never
allocated — consumed by ``launch/dryrun.py`` (lower + compile) and, with
concrete arrays of the same shapes, by the real train/serve launchers.

LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256   -> ``train_step``
  prefill_32k  32,768 x 32   -> ``prefill_step``
  decode_32k   32,768 x 128  -> ``serve_step`` (1 new token, 32k KV/state)
  long_500k    524,288 x 1   -> ``serve_step`` (sub-quadratic archs only)

GED engine rows (the paper's technique on the same mesh):
  ged-verify / ged-compute, pair batch scaled to 128 pairs/chip.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    subquadratic_only: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1,
                           subquadratic_only=True),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.subquadratic_only and not cfg.subquadratic:
        return "skipped (full attention; long_500k needs sub-quadratic)"
    return None


def _sds(shape: Tuple[int, ...], dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for one grid cell.

    train   -> {tokens, labels[, patches|frames][, pos]}
    prefill -> {tokens[, patches|frames][, pos]}
    decode  -> {token, cache_len}   (caches are built separately)
    """
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16

    if shape.kind == "decode":
        return {"token": _sds((b, 1), i32),
                "cache_len": _sds((), i32)}

    specs: Dict[str, Any] = {}
    if cfg.vlm is not None:
        # patches are part of the stream: text tokens fill the rest so the
        # total stream length is exactly ``seq_len``.
        p = cfg.vlm.num_patches
        text = s - p
        specs["tokens"] = _sds((b, text), i32)
        specs["patches"] = _sds((b, p, cfg.d_model), bf16)
        if shape.kind == "train":
            specs["labels"] = _sds((b, text), i32)
        return specs

    if cfg.family == "audio":
        specs["frames"] = _sds((b, cfg.encdec.enc_seq, cfg.d_model), bf16)
        specs["tokens"] = _sds((b, s), i32)
        if shape.kind == "train":
            specs["labels"] = _sds((b, s), i32)
        return specs

    specs["tokens"] = _sds((b, s), i32)
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), i32)
    return specs


# ------------------------------------------------------------- GED rows

@dataclasses.dataclass(frozen=True)
class GedShapeSpec:
    name: str
    verification: bool
    pairs_per_chip: int
    slots: int              # padded vertex capacity N
    pool: int
    expand: int
    max_iters: int
    sweeps: int


GED_SHAPES: Dict[str, GedShapeSpec] = {
    # Graph-similarity-search verification: the paper's §5.3 workload.
    "verify_db": GedShapeSpec("verify_db", True, 128, 32, 256, 4, 128, 6),
    # Exact computation (heavier per pair, fewer pairs).
    "compute": GedShapeSpec("compute", False, 32, 32, 512, 8, 256, 8),
}

GED_ARCHS = ("ged-verify", "ged-compute")


def ged_input_specs(spec: GedShapeSpec, n_chips: int) -> Dict[str, Any]:
    b = spec.pairs_per_chip * n_chips
    n = spec.slots
    f = jax.ShapeDtypeStruct
    return dict(
        qv=f((b, n), jnp.int32),
        gv=f((b, n), jnp.int32),
        qa=f((b, n, n), jnp.int32),
        ga=f((b, n, n), jnp.int32),
        order=f((b, n), jnp.int32),
        n=f((b,), jnp.int32),
        taus=f((b,), jnp.float32),
    )
