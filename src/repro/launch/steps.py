"""Cell builders: (arch x shape x mesh) -> a lowerable, sharded step.

Used by ``launch/dryrun.py`` (abstract lower+compile) and by the real
train/serve launchers (same shardings, concrete arrays).

Sharding policy
  train : FSDP over ``data`` (params' embed axis), TP over ``model``,
          batch over (``pod``, ``data``); params+opt donated.
  serve : params bf16, replicated over ``data``/``pod`` and TP over
          ``model`` (no per-layer weight gathers on the latency path);
          KV cache sequence-sharded over ``model`` (flash-decode),
          batch over (``pod``, ``data``); caches donated.
  ged   : pure DP — pair batch sharded over every mesh axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.models.params import abstract_params, param_pspecs, param_specs, PSpec
from repro.optim import AdamWConfig
from repro.parallel.sharding import (ShardingRules, default_rules,
                                     logical_spec, set_rules)
from repro.launch.shapes import (GedShapeSpec, ShapeSpec, ged_input_specs,
                                 input_specs)


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one grid cell."""
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    rules: Optional[ShardingRules]
    meta: Dict[str, Any]


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _tree_ns(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: _ns(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def abstract_opt_state(cfg: ArchConfig) -> Dict[str, Any]:
    ap = abstract_params(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {"m": jax.tree.map(f32, ap), "v": jax.tree.map(f32, ap),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_pspecs(cfg: ArchConfig, rules: ShardingRules) -> Dict[str, Any]:
    pp = param_pspecs(cfg, rules)
    return {"m": pp, "v": pp, "step": P()}


def _abstract_params_dtype(cfg: ArchConfig, dtype) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        param_specs(cfg),
                        is_leaf=lambda x: isinstance(x, PSpec))


def _input_shardings(mesh: Mesh, specs: Dict[str, Any]) -> Dict[str, Any]:
    ba = _batch_axes(mesh)
    ba_size = 1
    for a in ba:
        ba_size *= mesh.shape[a]
    out = {}
    for k, v in specs.items():
        if v.ndim == 0 or v.shape[0] % ba_size != 0:
            # degrade: replicate when the batch dim does not divide the
            # batch mesh axes (long_500k's global_batch=1)
            out[k] = _ns(mesh, P(*([None] * v.ndim)))
        else:
            out[k] = _ns(mesh, P(ba, *([None] * (v.ndim - 1))))
    return out


def _cache_pspecs(cfg: ArchConfig, batch: int, cache_len: int,
                  rules: ShardingRules) -> Dict[str, P]:
    shapes = T.cache_shapes(cfg, batch, cache_len)
    axes = T.cache_axes(cfg)
    return {k: logical_spec(shape, axes[k], rules)
            for k, (shape, _) in shapes.items()}


# ------------------------------------------------------------------- train

def build_train(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                impl: str = "auto", schedule: str = "dense",
                accum: Optional[int] = None, fsdp: bool = True) -> CellPlan:
    rules = default_rules(mesh, fsdp=fsdp)
    set_rules(rules)
    acc = cfg.train_accum if accum is None else accum
    step = T.make_train_step(cfg, AdamWConfig(), accum=acc, impl=impl,
                             schedule=schedule)

    params_a = abstract_params(cfg)
    opt_a = abstract_opt_state(cfg)
    batch_a = input_specs(cfg, shape)

    pshard = _tree_ns(mesh, param_pspecs(cfg, rules))
    oshard = _tree_ns(mesh, opt_pspecs(cfg, rules))
    bshard = _input_shardings(mesh, batch_a)
    metrics_shard = {k: _ns(mesh, P()) for k in ("grad_norm", "lr", "loss")}

    return CellPlan(
        fn=step,
        args=(params_a, opt_a, batch_a),
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, metrics_shard),
        donate_argnums=(0, 1),
        rules=rules,
        meta={"kind": "train", "accum": acc},
    )


# ----------------------------------------------------------------- prefill

def build_prefill(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                  impl: str = "auto", schedule: str = "dense") -> CellPlan:
    rules = default_rules(mesh, fsdp=False)   # serve: weights TP, no FSDP
    set_rules(rules)
    ins = input_specs(cfg, shape)
    b = shape.global_batch

    params_a = _abstract_params_dtype(cfg, jnp.bfloat16)
    pshard = _tree_ns(mesh, param_pspecs(cfg, rules))
    inshard = _input_shardings(mesh, ins)

    fn = functools.partial(_prefill_fn, cfg=cfg, impl=impl, schedule=schedule)

    ba = _batch_axes(mesh)
    logits_shard = _ns(mesh, logical_spec((b, cfg.padded_vocab),
                                          ("batch", "vocab"), rules))
    cache_shard = _tree_ns(
        mesh, _cache_pspecs(cfg, b, _stream_len(cfg, shape), rules))

    return CellPlan(
        fn=fn,
        args=(params_a, ins),
        in_shardings=(pshard, inshard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(),
        rules=rules,
        meta={"kind": "prefill", "batch_axes": ba},
    )


def _stream_len(cfg: ArchConfig, shape: ShapeSpec) -> int:
    # cache length produced by a prefill of this shape (vlm: patches + text)
    return shape.seq_len


def _prefill_fn(params, ins, *, cfg: ArchConfig, impl, schedule):
    return T.prefill_step(params, ins["tokens"], cfg,
                          frames=ins.get("frames"),
                          patches=ins.get("patches"),
                          impl=impl, schedule=schedule)


# ------------------------------------------------------------------ decode

def build_decode(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> CellPlan:
    rules = default_rules(mesh, fsdp=False)
    set_rules(rules)
    b, s = shape.global_batch, shape.seq_len
    ins = input_specs(cfg, shape)

    params_a = _abstract_params_dtype(cfg, jnp.bfloat16)
    caches_a = T.init_caches(cfg, b, s, abstract=True)

    pshard = _tree_ns(mesh, param_pspecs(cfg, rules))
    cshard = _tree_ns(mesh, _cache_pspecs(cfg, b, s, rules))
    inshard = _input_shardings(mesh, ins)

    fn = functools.partial(_decode_fn, cfg=cfg)

    logits_shard = _ns(mesh, logical_spec((b, cfg.padded_vocab),
                                          ("batch", "vocab"), rules))

    return CellPlan(
        fn=fn,
        args=(params_a, caches_a, ins["token"], ins["cache_len"]),
        in_shardings=(pshard, cshard, inshard["token"], inshard["cache_len"]),
        out_shardings=(logits_shard, cshard),
        donate_argnums=(1,),
        rules=rules,
        meta={"kind": "decode"},
    )


def _decode_fn(params, caches, token, cache_len, *, cfg: ArchConfig):
    return T.decode_step(params, caches, token, cache_len, cfg)


# --------------------------------------------------------------------- ged

def build_ged(spec: GedShapeSpec, mesh: Mesh, *, n_vlabels: int = 64,
              n_elabels: int = 8, use_kernel: bool = False) -> CellPlan:
    """The paper's engine as a mesh workload: pure DP over pairs.

    ``use_kernel=False`` in dry-runs so XLA sees the engine math for
    cost analysis (the Pallas path is validated in tests/benchmarks).
    """
    from repro.core.engine.search import EngineConfig, run_pair

    set_rules(None)
    ec = EngineConfig(pool=spec.pool, expand=spec.expand,
                      max_iters=spec.max_iters, sweeps=spec.sweeps,
                      bound="hybrid", strategy="astar",
                      use_kernel=use_kernel)
    n_chips = mesh.devices.size
    ins = ged_input_specs(spec, n_chips)

    all_axes = P(tuple(mesh.axis_names))
    inshard = {k: _ns(mesh, all_axes if v.ndim == 1
                      else P(tuple(mesh.axis_names),
                             *([None] * (v.ndim - 1))))
               for k, v in ins.items()}

    def fn(qv, gv, qa, ga, order, n, taus):
        def one(qv1, gv1, qa1, ga1, o1, n1, t1):
            return run_pair((qv1, gv1, qa1, ga1, o1, n1,
                             n_vlabels, n_elabels), ec, t1,
                            spec.verification)
        return jax.vmap(one)(qv, gv, qa, ga, order, n, taus)

    args = tuple(ins[k] for k in ("qv", "gv", "qa", "ga", "order", "n",
                                  "taus"))
    in_sh = tuple(inshard[k] for k in ("qv", "gv", "qa", "ga", "order", "n",
                                       "taus"))
    return CellPlan(
        fn=fn, args=args, in_shardings=in_sh, out_shardings=None,
        donate_argnums=(), rules=None,
        meta={"kind": "ged-verify" if spec.verification else "ged-compute",
              "pairs": ins["qv"].shape[0], "slots": spec.slots,
              "pool": spec.pool},
    )


# ------------------------------------------------------------------ entry

def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               **overrides) -> CellPlan:
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, **overrides)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, **overrides)
    if shape.kind == "decode":
        return build_decode(cfg, shape, mesh)
    raise ValueError(shape.kind)
