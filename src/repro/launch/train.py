"""End-to-end fault-tolerant trainer.

Examples (CPU, reduced scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --scale reduced \\
      --steps 60 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --scale reduced \\
      --steps 60 --fault-steps 25,45        # injected failures + recovery

At full scale the same script runs under the production mesh: params/opt
are sharded by ``launch.steps.build_train`` (FSDP + TP), the data pipeline
is deterministic-by-step, and checkpoints are written async + atomically.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.checkpoint import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.params import init_params, param_count
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultInjector, train_loop


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--scale", default="reduced",
                    choices=("reduced", "full"))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fault-steps", default="",
                    help="comma-separated steps at which to inject failures")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=256,
                    help="reduced-scale width (256 -> ~15-100M params)")
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.scale == "reduced":
        cfg = reduced(cfg, layers=args.layers, d_model=args.d_model,
                      vocab=2048, d_ff=args.d_model * 4, heads=4)
        cfg = dataclasses.replace(cfg, remat="none")

    print(f"arch={cfg.name} family={cfg.family} params={param_count(cfg):,}")

    params = init_params(cfg, seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, warmup=10, total_steps=args.steps)
    opt = adamw_init(params)
    step_fn_raw = T.make_train_step(cfg, opt_cfg, accum=args.accum,
                                    impl="naive")
    step_jit = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt = state
        tokens, labels = batch
        b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.vlm is not None:
            b["patches"] = jnp.zeros(
                (tokens.shape[0], cfg.vlm.num_patches, cfg.d_model),
                jnp.bfloat16)
        if cfg.family == "audio":
            b["frames"] = jnp.zeros(
                (tokens.shape[0], cfg.encdec.enc_seq, cfg.d_model),
                jnp.bfloat16)
        params, opt, metrics = step_jit(params, opt, b)
        return (params, opt), metrics

    def make_pipeline(start_step: int):
        return TokenPipeline(args.seed, args.batch, args.seq, cfg.vocab,
                             start_step=start_step)

    ckpt = CheckpointManager(args.ckpt_dir, keep_last_k=2)
    injector = FaultInjector(
        [int(x) for x in args.fault_steps.split(",") if x.strip()])

    t0 = time.time()
    (params, opt), history = train_loop(
        step_fn, (params, opt), make_pipeline, ckpt,
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        injector=injector, log_every=10,
        on_metrics=lambda s, m: print(
            f"step {s:5d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f}"))
    dt = time.time() - t0
    if history:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"\ndone: {args.steps} steps in {dt:.1f}s — "
              f"loss {first:.4f} -> {last:.4f}")
        if last >= first:
            print("WARNING: loss did not decrease")


if __name__ == "__main__":
    main()
