"""Model substrate: configs, layers, and per-family step functions."""

from repro.models.config import ArchConfig, reduced

__all__ = ["ArchConfig", "reduced"]
