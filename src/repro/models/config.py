"""Architecture configuration dataclasses (one instance per assigned arch)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    expert_ff: int
    shared_experts: int = 0
    shared_ff: int = 0
    capacity_factor: float = 1.25
    padded_experts: int = 0  # experts padded for even EP sharding (0 = none)

    @property
    def total_experts(self) -> int:
        return self.padded_experts or self.num_experts


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    kind: str            # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int
    enc_seq: int          # fixed encoder length (whisper: 1500)


@dataclasses.dataclass(frozen=True)
class VLMCfg:
    num_patches: int      # patch embeddings prepended to the text stream
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 1e4
    rope_pct: float = 1.0
    window_pattern: Tuple[int, ...] = ()   # per-layer windows, 0 = global; cycled
    global_rope_theta: float = 0.0         # gemma3: different theta on globals
    # body details
    mlp: str = "swiglu"             # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm | rmsnorm1p
    sandwich_norm: bool = False
    tied_embeddings: bool = False
    embed_scale: bool = False       # gemma: x *= sqrt(d)
    mlp_bias: bool = False
    # submodules
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    encdec: Optional[EncDecCfg] = None
    vlm: Optional[VLMCfg] = None
    hybrid_attn_every: int = 0      # zamba2: shared attn block every k slots
    # training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"             # none | full
    train_accum: int = 8            # gradient-accumulation microbatches
    vocab_pad_to: int = 128
    # serving
    subquadratic: bool = False      # eligible for long_500k
    kv_quant: bool = False          # int8 KV cache (dense-family decode)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    def windows(self) -> Tuple[int, ...]:
        """Per-layer attention windows (0 = full/global)."""
        if not self.window_pattern:
            return (0,) * self.n_layers
        pat = self.window_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))


def reduced(cfg: ArchConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 512, d_ff: int = 128, heads: int = 4,
            kv_heads: Optional[int] = None) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    kv = kv_heads if kv_heads is not None else min(cfg.n_kv_heads, heads)
    kwargs = dict(
        n_layers=layers, d_model=d_model, n_heads=heads, n_kv_heads=max(kv, 1),
        d_ff=d_ff, vocab=vocab, head_dim=d_model // heads,
    )
    if cfg.moe is not None:
        kwargs["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2), expert_ff=32,
            shared_ff=32 if cfg.moe.shared_experts else 0, padded_experts=0,
        )
    if cfg.ssm is not None:
        kwargs["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16,
        )
    if cfg.encdec is not None:
        kwargs["encdec"] = EncDecCfg(enc_layers=2, enc_seq=16)
    if cfg.vlm is not None:
        kwargs["vlm"] = VLMCfg(num_patches=8, mrope_sections=(4, 6, 6))
    if cfg.hybrid_attn_every:
        kwargs["hybrid_attn_every"] = 3
    if cfg.window_pattern:
        kwargs["window_pattern"] = (8, 8, 0)
    return dataclasses.replace(cfg, **kwargs)
