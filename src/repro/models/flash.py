"""Blocked online-softmax attention (pure-JAX "flash") with custom VJP.

Naive attention materialises (B, H, S, T) scores — 34 GB/device at
train_4k and 4 TB at prefill_32k.  This module computes attention in
(block_q x block_k) tiles with running (max, sum, acc) statistics, and a
``custom_vjp`` whose backward *recomputes* per-tile scores instead of saving
them — O(S * block) live memory in both directions.  It is the pure-JAX
reference (and the ``ref.py`` oracle for the Pallas port in
``repro/kernels/flash_attention.py``); the tiling mirrors what the TPU
kernel does in VMEM.

Two schedules:

* ``schedule="dense"`` — one scan over KV tiles, full rectangle computed,
  causality by masking.  2x FLOP waste for causal attention (visible in the
  dry-run HLO; the §Perf log removes it).
* ``schedule="tri"`` — one scan over the *static pair list*
  ``[(qi, ki) for qi in range(nq) for ki in range(qi+1)]``: only the lower
  triangle of tiles is ever computed.  Same static shapes, half the FLOPs.
  (Perf iteration 1; exact same numerics as dense.)

GQA is handled natively: q (B, S, Hq, hd), k/v (B, T, Hk, hd) with
Hq = G * Hk; tiles contract in grouped form so k/v are never repeated.

``window`` (sliding-window attention) and ``kv_valid`` (cross-attention
padding) are traced operands so one compiled body serves gemma3's mixed
local/global layer stack under ``lax.scan``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _tile_mask(qi, ki, bq, bk, causal, window, kv_valid, q_offset):
    """(bq, bk) bool mask for tile (qi, ki). window/kv_valid are traced."""
    qpos = q_offset + qi * bq + jnp.arange(bq)[:, None]
    kpos = ki * bk + jnp.arange(bk)[None, :]
    m = kpos < kv_valid
    if causal:
        m &= kpos <= qpos
        m &= (window <= 0) | (kpos > qpos - window)
    return m


def _scores(qt, kt, scale):
    # qt: (B,Hk,G,bq,hd)  kt: (B,Hk,bk,hd) -> (B,Hk,G,bq,bk) f32
    return jax.lax.dot_general(
        qt, kt, (((4,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32) * scale


def _pairs(nq: int, nk: int, causal: bool, bq: int, bk: int
           ) -> Tuple[np.ndarray, np.ndarray]:
    if not causal:
        qi, ki = np.meshgrid(np.arange(nq), np.arange(nk), indexing="ij")
        return qi.reshape(-1), ki.reshape(-1)
    out = [(q, k) for q in range(nq) for k in range(nk)
           if k * bk <= q * bq + bq - 1]  # tile intersects causal region
    arr = np.asarray(out, dtype=np.int32)
    return arr[:, 0], arr[:, 1]


def _flash_fwd(q, k, v, causal: bool, schedule: str, block_q: int,
               block_k: int, window, kv_valid, q_offset):
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    scale = 1.0 / math.sqrt(hd)
    nq, nk = s // block_q, t // block_k

    qf = jnp.moveaxis(q.reshape(b, s, hk, g, hd), 1, 3)     # (B,Hk,G,S,hd)
    kf = jnp.moveaxis(k, 1, 2)                              # (B,Hk,T,hd)
    vf = jnp.moveaxis(v, 1, 2)

    acc0 = jnp.zeros((b, hk, g, s, hd), jnp.float32)
    m0 = jnp.full((b, hk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)

    if schedule == "tri" and causal:
        qis, kis = _pairs(nq, nk, True, block_q, block_k)
    else:
        qis, kis = _pairs(nq, nk, False, block_q, block_k)

    def body(carry, idx):
        acc, m, l = carry
        qi, ki = idx
        qt = jax.lax.dynamic_slice_in_dim(qf, qi * block_q, block_q, axis=3)
        kt = jax.lax.dynamic_slice_in_dim(kf, ki * block_k, block_k, axis=2)
        vt = jax.lax.dynamic_slice_in_dim(vf, ki * block_k, block_k, axis=2)
        sc = _scores(qt, kt, scale)
        mask = _tile_mask(qi, ki, block_q, block_k, causal, window,
                          kv_valid, q_offset)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        mt = jax.lax.dynamic_slice_in_dim(m, qi * block_q, block_q, axis=3)
        lt = jax.lax.dynamic_slice_in_dim(l, qi * block_q, block_q, axis=3)
        at = jax.lax.dynamic_slice_in_dim(acc, qi * block_q, block_q, axis=3)
        m_new = jnp.maximum(mt, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(mt - m_new)
        l_new = lt * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vt.astype(jnp.float32), (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)             # (B,Hk,G,bq,hd)
        a_new = at * corr[..., None] + pv
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, qi * block_q, 3)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qi * block_q, 3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qi * block_q, 3)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.asarray(qis, jnp.int32), jnp.asarray(kis, jnp.int32)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None])
    lse = m + jnp.log(l_safe)
    out_std = jnp.moveaxis(out, 3, 1).reshape(b, s, hq, hd)
    return out_std.astype(q.dtype), (out, lse)


def _flash_bwd_impl(q, k, v, out, lse, do, causal, schedule, block_q,
                    block_k, window, kv_valid, q_offset):
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    scale = 1.0 / math.sqrt(hd)
    nq, nk = s // block_q, t // block_k

    qf = jnp.moveaxis(q.reshape(b, s, hk, g, hd), 1, 3).astype(jnp.float32)
    kf = jnp.moveaxis(k, 1, 2).astype(jnp.float32)
    vf = jnp.moveaxis(v, 1, 2).astype(jnp.float32)
    dof = jnp.moveaxis(do.reshape(b, s, hk, g, hd), 1, 3).astype(jnp.float32)
    delta = jnp.sum(out * dof, axis=-1)                     # (B,Hk,G,S)

    if schedule == "tri" and causal:
        qis, kis = _pairs(nq, nk, True, block_q, block_k)
    else:
        qis, kis = _pairs(nq, nk, False, block_q, block_k)

    def body(carry, idx):
        dq, dk, dv = carry
        qi, ki = idx
        qt = jax.lax.dynamic_slice_in_dim(qf, qi * block_q, block_q, axis=3)
        kt = jax.lax.dynamic_slice_in_dim(kf, ki * block_k, block_k, axis=2)
        vt = jax.lax.dynamic_slice_in_dim(vf, ki * block_k, block_k, axis=2)
        dot = jax.lax.dynamic_slice_in_dim(dof, qi * block_q, block_q, axis=3)
        lt = jax.lax.dynamic_slice_in_dim(lse, qi * block_q, block_q, axis=3)
        dt = jax.lax.dynamic_slice_in_dim(delta, qi * block_q, block_q, axis=3)
        sc = _scores(qt, kt, scale)
        mask = _tile_mask(qi, ki, block_q, block_k, causal, window,
                          kv_valid, q_offset)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jnp.exp(sc - lt[..., None])                     # (B,Hk,G,bq,bk)
        # dv_tile = p^T @ do
        dv_t = jax.lax.dot_general(
            p, dot, (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
            preferred_element_type=jnp.float32)             # (B,Hk,G,bk,hd)
        dp = jax.lax.dot_general(
            dot, vt, (((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)             # (B,Hk,G,bq,bk)
        ds = p * (dp - dt[..., None]) * scale
        dq_t = jax.lax.dot_general(
            ds, kt, (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)             # (B,Hk,G,bq,hd)
        dk_t = jax.lax.dot_general(
            ds, qt, (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
            preferred_element_type=jnp.float32)             # (B,Hk,G,bk,hd)
        dq_old = jax.lax.dynamic_slice_in_dim(dq, qi * block_q, block_q, 3)
        dq = jax.lax.dynamic_update_slice_in_dim(dq, dq_old + dq_t,
                                                 qi * block_q, 3)
        dk_old = jax.lax.dynamic_slice_in_dim(dk, ki * block_k, block_k, 2)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, dk_old + jnp.sum(dk_t, axis=2), ki * block_k, 2)
        dv_old = jax.lax.dynamic_slice_in_dim(dv, ki * block_k, block_k, 2)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, dv_old + jnp.sum(dv_t, axis=2), ki * block_k, 2)
        return (dq, dk, dv), None

    dq0 = jnp.zeros_like(qf)
    dk0 = jnp.zeros_like(kf)
    dv0 = jnp.zeros_like(vf)
    (dq, dk, dv), _ = jax.lax.scan(
        body, (dq0, dk0, dv0),
        (jnp.asarray(qis, jnp.int32), jnp.asarray(kis, jnp.int32)))
    dq_std = jnp.moveaxis(dq, 3, 1).reshape(b, s, hq, hd).astype(q.dtype)
    dk_std = jnp.moveaxis(dk, 2, 1).astype(k.dtype)
    dv_std = jnp.moveaxis(dv, 2, 1).astype(v.dtype)
    return dq_std, dk_std, dv_std


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, schedule: str = "dense",
                    block_q: int = 512, block_k: int = 512,
                    window: jnp.ndarray | int = 0,
                    kv_valid: jnp.ndarray | int = 10 ** 9,
                    q_offset: jnp.ndarray | int = 0):
    """q: (B,S,Hq,hd), k/v: (B,T,Hk,hd) -> (B,S,Hq,hd)."""
    out, _ = _flash_fwd(q, k, v, causal, schedule, block_q, block_k,
                        jnp.asarray(window), jnp.asarray(kv_valid),
                        jnp.asarray(q_offset))
    return out


def _fwd_rule(q, k, v, causal, schedule, block_q, block_k, window=0,
              kv_valid=10 ** 9, q_offset=0):
    window = jnp.asarray(window)
    kv_valid = jnp.asarray(kv_valid)
    q_offset = jnp.asarray(q_offset)
    out, (out_f32, lse) = _flash_fwd(q, k, v, causal, schedule, block_q,
                                     block_k, window, kv_valid, q_offset)
    return out, (q, k, v, out_f32, lse, window, kv_valid, q_offset)


def _bwd_rule(causal, schedule, block_q, block_k, res, do):
    q, k, v, out_f32, lse, window, kv_valid, q_offset = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, out_f32, lse, do, causal, schedule,
                                 block_q, block_k, window, kv_valid, q_offset)
    return (dq, dk, dv, jnp.zeros_like(window), jnp.zeros_like(kv_valid),
            jnp.zeros_like(q_offset))


flash_attention.defvjp(_fwd_rule, _bwd_rule)


def reference_attention(q, k, v, causal=True, window=0, kv_valid=10 ** 9,
                        q_offset=0):
    """Naive O(S*T) oracle for tests (f32)."""
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qf = q.reshape(b, s, hk, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = kpos < kv_valid
    if causal:
        m &= kpos <= qpos
        m &= (jnp.asarray(window) <= 0) | (kpos > qpos - window)
    sc = jnp.where(m[None, None, None], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return o.reshape(b, s, hq, hd).astype(q.dtype)
