"""Shared layer library for the assigned architectures.

Pure functions over parameter pytrees.  All matmuls run through ``dot`` which
casts to the compute dtype (bf16 by default) and accumulates in f32.
Sharding is annotated with logical axis names via ``repro.parallel.constrain``
(no-ops without installed rules, so CPU smoke tests see plain code).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.parallel.sharding import constrain

# --------------------------------------------------------------------- util

def cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def dot(x: jnp.ndarray, w: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    return jax.lax.dot_general(
        x.astype(cdt(cfg)), w.astype(cdt(cfg)),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(cdt(cfg))


def einsum(expr: str, *args, cfg: ArchConfig) -> jnp.ndarray:
    cast = [a.astype(cdt(cfg)) for a in args]
    return jnp.einsum(expr, *cast, preferred_element_type=jnp.float32
                      ).astype(cdt(cfg))


# -------------------------------------------------------------------- norms

def norm(x: jnp.ndarray, p: Dict, cfg: ArchConfig, eps: float = 1e-6
         ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm in ("layernorm", "layernorm1p"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        scale = p["scale"] + 1.0 if cfg.norm == "layernorm1p" else p["scale"]
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        xn = xf * jax.lax.rsqrt(ms + eps)
        scale = p["scale"] + 1.0 if cfg.norm == "rmsnorm1p" else p["scale"]
        out = xn * scale
    return out.astype(x.dtype)


def head_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
                 ) -> jnp.ndarray:
    """qk-norm: RMS over the head dim. x: (..., hd), scale: (hd,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------- rope

def _rope_angles(pos: jnp.ndarray, dims: int, theta: float) -> jnp.ndarray:
    """pos: (...,) -> (..., dims/2) angles."""
    freq = theta ** (-jnp.arange(0, dims, 2, dtype=jnp.float32) / dims)
    return pos[..., None].astype(jnp.float32) * freq


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig,
               theta: Optional[float] = None) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, hd).

    * pos (B, S): standard RoPE over the first ``rope_pct * hd`` dims.
    * pos (3, B, S): M-RoPE — the rotary half-dims are split into
      ``cfg.vlm.mrope_sections`` groups driven by (t, h, w) position streams.
    """
    hd = x.shape[-1]
    rot = int(hd * cfg.rope_pct)
    rot -= rot % 2
    th = cfg.rope_theta if theta is None else theta
    if pos.ndim == 3 and cfg.vlm is not None:
        secs = cfg.vlm.mrope_sections
        assert sum(secs) == rot // 2, (secs, rot)
        ang_parts = []
        full = _rope_angles(pos, rot, th)          # (3, B, S, rot/2)
        start = 0
        for i, s in enumerate(secs):
            ang_parts.append(full[i, ..., start:start + s])
            start += s
        ang = jnp.concatenate(ang_parts, axis=-1)  # (B, S, rot/2)
    else:
        ang = _rope_angles(pos, rot, th)           # (B, S, rot/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------- attention

def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, f = x.shape
    return x.reshape(b, s, n_heads, f // n_heads)


def qkv_project(x: jnp.ndarray, p: Dict, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = dot(x, p["wq"], cfg)
    k = dot(x, p["wk"], cfg)
    v = dot(x, p["wv"], cfg)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"])
        k = head_rmsnorm(k, p["k_norm"])
    return q, k, v


def _gqa_scores(q, k, cfg: ArchConfig):
    """(B,S,Hq,hd) x (B,T,Hk,hd) -> (B,Hq,S,T) with GQA grouping."""
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, hd)
    out = einsum("bskgd,btkd->bkgst", qg, k, cfg=cfg)
    return out.reshape(b, hk * g, s, t)


def _gqa_out(w, v, cfg: ArchConfig):
    """(B,Hq,S,T) x (B,T,Hk,hd) -> (B,S,Hq,hd)."""
    b, hq, s, t = w.shape
    hk = v.shape[2]
    g = hq // hk
    wg = w.reshape(b, hk, g, s, t)
    out = einsum("bkgst,btkd->bskgd", wg, v, cfg=cfg)
    return out.reshape(b, s, hq, v.shape[-1])


def attention_train(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                    pos: jnp.ndarray, window: int = 0,
                    theta: Optional[float] = None,
                    kv_x: Optional[jnp.ndarray] = None,
                    causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention (train / prefill).  window>0 = sliding window.

    ``kv_x`` switches to cross-attention (no rope on k, no causal mask).
    """
    b, s, d = x.shape
    if kv_x is None:
        q, k, v = qkv_project(x, p, cfg)
        rp = pos if pos.ndim == 3 else pos
        q = apply_rope(q, rp, cfg, theta)
        k = apply_rope(k, rp, cfg, theta)
        t = s
    else:
        q = _split_heads(dot(x, p["wq"], cfg), cfg.n_heads)
        k = _split_heads(dot(kv_x, p["wk"], cfg), cfg.n_kv_heads)
        v = _split_heads(dot(kv_x, p["wv"], cfg), cfg.n_kv_heads)
        t = kv_x.shape[1]
        causal = False
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32) / math.sqrt(cfg.hd)
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(t)[None, :]
        mask = ki <= qi
        if window > 0:
            mask &= ki > qi - window
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w.astype(cdt(cfg)), v, cfg)
    o = o.reshape(b, s, -1)
    o = dot(o, p["wo"], cfg)
    if cfg.attn_out_bias:
        o = o + p["bo"].astype(o.dtype)
    return o


def attention_decode(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, cache_len: jnp.ndarray,
                     window: int = 0, theta: Optional[float] = None,
                     rolling: bool = False,
                     k_scale: Optional[jnp.ndarray] = None,
                     v_scale: Optional[jnp.ndarray] = None,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a (B, T, Hk, hd) cache.

    The cache sequence axis is annotated ``kv_seq`` (sequence-sharded over the
    ``model`` axis at scale); softmax statistics over the sharded axis lower
    to partial reductions + small all-reduces (flash-decode pattern).

    ``rolling=True`` treats the cache as a ring buffer of size ``window``
    (gemma3 local layers at 500k context): slot = pos % window.

    ``k_scale``/``v_scale`` (B, Hk) switch to an int8-quantised cache:
    reads dequantise against the per-(batch, head) prefill scale, the new
    token's row is quantised (clipped) into the same scale — halves cache
    bytes at rest AND per-step read traffic vs bf16.
    """
    b = x.shape[0]
    q, k, v = qkv_project(x, p, cfg)           # (B, 1, H*, hd)
    # decode positions: (B,) scalar-per-row; for M-RoPE archs the three
    # position streams coincide during text decoding, so standard RoPE on the
    # shared stream is exact.
    posb = jnp.broadcast_to(pos.reshape(-1, 1)[:b], (b, 1))
    q = apply_rope(q, posb, cfg, theta)
    k = apply_rope(k, posb, cfg, theta)

    t = k_cache.shape[1]
    if rolling:  # ring buffer of size `window`
        slot = cache_len % jnp.maximum(t, 1)
    else:
        slot = jnp.minimum(cache_len, t - 1)
    # NOTE(perf, measured): the DUS form aliases the carried cache inside
    # the layer loop; a one-hot jnp.where variant was tried and REFUTED —
    # it materialises a fresh cache per layer (+5 GiB temps on
    # qwen2-72b decode_32k).  See EXPERIMENTS.md §Perf iteration D2.
    if k_scale is not None:                    # int8-quantised cache
        k_row = _quant_row(k[:, 0], k_scale)
        v_row = _quant_row(v[:, 0], v_scale)
        k_cache = k_cache.at[:, slot].set(k_row)
        v_cache = v_cache.at[:, slot].set(v_row)
        k_eff = k_cache.astype(cdt(cfg)) \
            * k_scale[:, None, :, None].astype(cdt(cfg))
        v_eff = v_cache.astype(cdt(cfg)) \
            * v_scale[:, None, :, None].astype(cdt(cfg))
    else:
        k_cache = k_cache.at[:, slot].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[:, slot].set(v[:, 0].astype(v_cache.dtype))
        k_eff = k_cache.astype(cdt(cfg))
        v_eff = v_cache.astype(cdt(cfg))
    k_cache = constrain(k_cache, "batch", "kv_seq", None, None)
    v_cache = constrain(v_cache, "batch", "kv_seq", None, None)

    scores = _gqa_scores(q, k_eff, cfg).astype(jnp.float32)
    scores = scores / math.sqrt(cfg.hd)        # (B, Hq, 1, T)
    ti = jnp.arange(t)
    if rolling:
        valid = (ti <= slot) | (cache_len >= t)
    else:
        valid = ti <= slot
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w.astype(cdt(cfg)), v_eff, cfg)
    o = o.reshape(b, 1, -1)
    o = dot(o, p["wo"], cfg)
    if cfg.attn_out_bias:
        o = o + p["bo"].astype(o.dtype)
    return o, k_cache, v_cache


def _quant_row(row: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """(B, H, hd) bf16 -> int8 against per-(B, H) scale (clipped)."""
    q = jnp.round(row.astype(jnp.float32)
                  / jnp.maximum(scale[:, :, None], 1e-8))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def quantize_kv(kc: jnp.ndarray, vc: jnp.ndarray):
    """(L, B, S, H, hd) bf16 caches -> (int8 caches, (L, B, H) scales)."""
    def one(c):
        amax = jnp.max(jnp.abs(c.astype(jnp.float32)), axis=(2, 4))
        scale = jnp.maximum(amax, 1e-8) / 127.0          # (L, B, H)
        q = jnp.round(c.astype(jnp.float32)
                      / scale[:, :, None, :, None])
        return jnp.clip(q, -127, 127).astype(jnp.int8), scale
    kq, ks = one(kc)
    vq, vs = one(vc)
    return kq, vq, ks, vs


def cross_attention_decode(x, p, cfg: ArchConfig, k_cache, v_cache):
    """Decoder cross-attention against precomputed encoder KV (no mask)."""
    b = x.shape[0]
    q = _split_heads(dot(x, p["wq"], cfg), cfg.n_heads)
    scores = _gqa_scores(q, k_cache.astype(cdt(cfg)), cfg).astype(jnp.float32)
    scores = scores / math.sqrt(cfg.hd)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w.astype(cdt(cfg)), v_cache.astype(cdt(cfg)), cfg)
    o = dot(o.reshape(b, 1, -1), p["wo"], cfg)
    if cfg.attn_out_bias:
        o = o + p["bo"].astype(o.dtype)
    return o


# ----------------------------------------------------------------------- mlp

def mlp(x: jnp.ndarray, p: Dict, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(dot(x, p["wg"], cfg)) * dot(x, p["wi"], cfg)
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(dot(x, p["wi"], cfg)))
    else:  # gelu
        h = dot(x, p["wi"], cfg)
        if cfg.mlp_bias:
            h = h + p["bi"].astype(h.dtype)
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "ff")
    o = dot(h, p["wo"], cfg)
    if cfg.mlp_bias:
        o = o + p["bo"].astype(o.dtype)
    return o


# ------------------------------------------------------------------- embeds

def embed_tokens(tokens: jnp.ndarray, embed: jnp.ndarray, cfg: ArchConfig
                 ) -> jnp.ndarray:
    x = jnp.take(embed, tokens, axis=0).astype(cdt(cfg))
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return constrain(x, "batch", None, None)


def lm_logits(x: jnp.ndarray, params: Dict, cfg: ArchConfig) -> jnp.ndarray:
    w = params["embed"] if cfg.tied_embeddings else params["lm_head"]
    if cfg.tied_embeddings:
        logits = einsum("bsd,vd->bsv", x, w, cfg=cfg)
    else:
        logits = dot(x, w, cfg)
    return constrain(logits.astype(jnp.float32), "batch", None, "vocab")


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, vocab: int
                  ) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
