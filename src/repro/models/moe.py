"""Token-choice top-k MoE with *grouped* sort-based capacity dispatch.

Two formulations were measured in the dry-run (EXPERIMENTS.md §Perf):

* **global sort dispatch** (v1): argsort over all T*k assignments + a
  data-dependent scatter.  Under SPMD with tokens sharded over
  (``pod``, ``data``) and experts over ``model``, XLA cannot partition a
  data-dependent scatter whose indices span shards — it *replicates* the
  token activations per layer (memory 191 s / collective 353 s roofline
  terms for moonshot train_4k: 100x above compute).
* **grouped dispatch** (v2, this file): tokens are split into G groups
  aligned with their (``pod``, ``data``) shard; the sort/scatter runs
  *within* each group (vmapped, batch dim sharded, zero cross-shard data
  dependence), producing an (G, E, C_g, d) buffer that is G-sharded and
  model-replicated.  Expert matmuls contract with E-sharded weights (free
  local slicing), and the single structured collective is the all-gather
  of expert outputs over ``model`` before the local combine gather —
  E*C_g*d*2B per device per layer ~= k*cf*tokens_per_shard*d*2B, the
  information-theoretic EP volume.

Memory is O(T_g*k*d + E*C_g*d) per device: linear in local tokens.

Shared experts (qwen2-moe) are plain always-on MLPs added to the output.
Padded experts (60 -> 64 for even EP-16) are real rows in the weight
tensors whose router logits are masked to -inf, so they never win top-k;
FLOP accounting uses the unpadded count.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.parallel.ops import top_k_sorted
from repro.parallel.sharding import constrain, get_rules


def router_topk(x: jnp.ndarray, wr: jnp.ndarray, cfg: ArchConfig):
    """x: (T, d) -> (weights (T,k), ids (T,k)) with padded experts masked."""
    moe = cfg.moe
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        wr.astype(jnp.float32))
    if moe.total_experts != moe.num_experts:
        pad_mask = jnp.arange(moe.total_experts) >= moe.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    # sort-based top-k: lax.top_k is an SPMD-opaque custom-call that
    # all-gathers the token batch (see parallel/ops.py).  ids carry no
    # gradient; weights are re-read from probs through a one-hot einsum so
    # the router gradient flows with no gather anywhere (this jaxlib's
    # batched-gather transpose is broken, and one-hot x probs partitions
    # cleanly besides).
    _, ids = top_k_sorted(jax.lax.stop_gradient(probs), moe.top_k)
    onehot = jax.nn.one_hot(ids, moe.total_experts, dtype=probs.dtype)
    weights = jnp.einsum("tke,te->tk", onehot, probs)
    weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
    return weights, ids, probs


def capacity(tokens: int, cfg: ArchConfig) -> int:
    moe = cfg.moe
    c = int(math.ceil(tokens * moe.top_k / moe.total_experts
                      * moe.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def _num_groups(b: int, s: int) -> int:
    """Groups = batch-shard count, so per-group dispatch is shard-local."""
    rules = get_rules()
    if rules is None:
        return 1
    g = rules.mesh_size(rules.table.get("batch"))
    if g <= 1 or b % g != 0:
        return 1
    return g


def _dispatch_group(xg: jnp.ndarray, idg: jnp.ndarray, e: int, cap: int,
                    cdt) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray]:
    """One group's sort-based dispatch.  xg: (Tg, d), idg: (Tg, k).

    Returns (ex_in (E, C, d), slot (Tg*k,), keep (Tg*k,), inv (Tg*k,)).
    """
    tg, k = idg.shape
    flat_ids = idg.reshape(tg * k)
    token_idx = jnp.repeat(jnp.arange(tg), k)
    order = jnp.argsort(flat_ids)                       # stable
    sorted_ids = flat_ids[order]
    sorted_tok = token_idx[order]
    pos = jnp.arange(tg * k)
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e))
    rank = pos - starts[sorted_ids]
    keep = rank < cap
    slot = jnp.where(keep, sorted_ids * cap + rank, tg * k)  # OOB -> dropped

    buf = jnp.zeros((e * cap + 1, xg.shape[-1]), cdt)
    buf = buf.at[slot].set(xg[sorted_tok].astype(cdt), mode="drop")
    ex_in = buf[:-1].reshape(e, cap, xg.shape[-1])
    inv = jnp.argsort(order)
    return ex_in, slot, keep, inv


def _combine_group(ex_out_flat: jnp.ndarray, slot: jnp.ndarray,
                   keep: jnp.ndarray, inv: jnp.ndarray, tg: int, k: int
                   ) -> jnp.ndarray:
    """Undo one group's dispatch: (E*C, d) -> (Tg, k, d)."""
    picked = jnp.where(
        keep[:, None],
        ex_out_flat[jnp.clip(slot, 0, ex_out_flat.shape[0] - 1)], 0.0)
    return picked[inv].reshape(tg, k, -1)


def moe_mlp(x: jnp.ndarray, p: Dict, cfg: ArchConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). p holds router + expert + shared weights."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = moe.top_k
    e = moe.total_experts
    cdt = jnp.dtype(cfg.compute_dtype)

    xt = x.reshape(t, d)
    weights, ids, probs = router_topk(xt, p["router"], cfg)

    # ---- grouped dispatch (shard-local sort; G = batch-shard count) -------
    g = _num_groups(b, s)
    tg = t // g
    cap = capacity(tg, cfg)
    xg = xt.reshape(g, tg, d)
    xg = constrain(xg, "batch", None, None)
    idg = ids.reshape(g, tg, k)
    ex_in, slot, keep, inv = jax.vmap(
        lambda xx, ii: _dispatch_group(xx, ii, e, cap, cdt))(xg, idg)
    # (G, E, C, d): G over (pod, data); E replicated here — each model-axis
    # device holds every group's dispatch (dispatch is cheap; compute isn't)
    ex_in = constrain(ex_in, "batch", None, None, None)

    # ---- expert MLPs (swiglu), E contracted against model-sharded weights --
    def edot(a, w):
        # (G, E, C, x) @ (E, x, y) -> (G, E, C, y), batched over E
        return jax.lax.dot_general(
            a, w.astype(cdt), (((3,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32).astype(cdt).transpose(
                1, 0, 2, 3)

    ex_in_e = constrain(ex_in, "batch", "expert", None, None)
    h = jax.nn.silu(edot(ex_in_e, p["wg"])) * edot(ex_in_e, p["wi"])
    ex_out = edot(h, p["wo"])                           # (G, E, C, d)
    # combine gathers across experts -> requires full E per device: the ONE
    # structured collective (all-gather of E*C*d over ``model``)
    ex_out = constrain(ex_out, "batch", None, None, None)

    # ---- gather back + combine ---------------------------------------------
    flat_out = ex_out.reshape(g, e * cap, d)
    per_assign = jax.vmap(
        lambda fo, sl, kp, iv: _combine_group(fo, sl, kp, iv, tg, k)
    )(flat_out, slot, keep, inv)                        # (G, Tg, k, d)
    wgt = weights.reshape(g, tg, k)
    # bf16 operands + f32 accumulation: upcasting per_assign (T*k, d) to
    # f32 doubled the largest combine-side HBM flow (measured -1.8 TB/dev
    # on moonshot train_4k)
    out = jnp.einsum("gtk,gtkd->gtd", wgt.astype(cdt), per_assign,
                     preferred_element_type=jnp.float32).astype(cdt)
    out = out.reshape(t, d)

    # ---- shared experts (always-on) ----------------------------------------
    if moe.shared_experts:
        sh = jax.nn.silu(xt.astype(cdt) @ p["shared_wg"].astype(cdt)) \
            * (xt.astype(cdt) @ p["shared_wi"].astype(cdt))
        out = out + (sh @ p["shared_wo"].astype(cdt))

    return out.reshape(b, s, d)


def aux_loss(probs: jnp.ndarray, ids: jnp.ndarray, cfg: ArchConfig
             ) -> jnp.ndarray:
    """Switch-style load-balancing loss (mean prob * mean assignment rate)."""
    moe = cfg.moe
    e = moe.total_experts
    assign = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    assign = assign / jnp.maximum(jnp.sum(assign), 1.0)
    imp = jnp.mean(probs, axis=0)
    return e * jnp.sum(assign * imp)
