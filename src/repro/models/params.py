"""Parameter specs: one declarative tree per architecture.

Each leaf is a ``PSpec(shape, axes, init)``.  From the same tree we derive:

* ``abstract_params`` — ShapeDtypeStruct tree for dry-runs (no allocation;
  a 72B tree is built in microseconds),
* ``init_params`` — concrete initialisation (only ever called for reduced /
  example-scale configs),
* ``param_pspecs`` — logical axes -> PartitionSpec tree for pjit
  in_shardings (FSDP over ``data`` via the "embed" axis, TP over ``model``
  via "qkv_flat"/"ff"/"vocab"/"expert"; per-tensor degradation handled by
  ``repro.parallel.sharding.logical_spec``).

Layer stacks are stored with a leading L axis and consumed by ``lax.scan``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.ssm import mamba2_dims, rwkv6_dims
from repro.parallel.sharding import ShardingRules, logical_spec

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"        # normal|zeros|ones|small|alog|dtbias|mix|wbase
    scale: float = 0.02


def _attn_specs(cfg: ArchConfig, d: int, causal_self: bool = True
                ) -> Dict[str, PSpec]:
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out: Dict[str, PSpec] = {
        "wq": PSpec((d, hq * hd), ("embed", "qkv_flat")),
        "wk": PSpec((d, hk * hd), ("embed", "qkv_flat")),
        "wv": PSpec((d, hk * hd), ("embed", "qkv_flat")),
        "wo": PSpec((hq * hd, d), ("qkv_flat", "embed"), "small"),
    }
    if cfg.qkv_bias:
        out["bq"] = PSpec((hq * hd,), ("qkv_flat",), "zeros")
        out["bk"] = PSpec((hk * hd,), ("qkv_flat",), "zeros")
        out["bv"] = PSpec((hk * hd,), ("qkv_flat",), "zeros")
    if cfg.attn_out_bias:
        out["bo"] = PSpec((d,), (None,), "zeros")
    if cfg.qk_norm:
        out["q_norm"] = PSpec((hd,), (None,), "ones")
        out["k_norm"] = PSpec((hd,), (None,), "ones")
    return out


def _norm_specs(cfg: ArchConfig, d: int) -> Dict[str, PSpec]:
    plus_one = cfg.norm in ("rmsnorm1p", "layernorm1p")
    out = {"scale": PSpec((d,), (None,), "zeros" if plus_one else "ones")}
    if cfg.norm.startswith("layernorm"):
        out["bias"] = PSpec((d,), (None,), "zeros")
    return out


def _mlp_specs(cfg: ArchConfig, d: int, ff: int) -> Dict[str, PSpec]:
    out: Dict[str, PSpec] = {
        "wi": PSpec((d, ff), ("embed", "ff")),
        "wo": PSpec((ff, d), ("ff", "embed"), "small"),
    }
    if cfg.mlp == "swiglu":
        out["wg"] = PSpec((d, ff), ("embed", "ff"))
    if cfg.mlp_bias:
        out["bi"] = PSpec((ff,), ("ff",), "zeros")
        out["bo"] = PSpec((d,), (None,), "zeros")
    return out


def _moe_specs(cfg: ArchConfig) -> Dict[str, PSpec]:
    moe, d = cfg.moe, cfg.d_model
    e, ff = moe.total_experts, moe.expert_ff
    out: Dict[str, PSpec] = {
        "router": PSpec((d, e), ("embed", None)),
        "wg": PSpec((e, d, ff), ("expert", "embed", None)),
        "wi": PSpec((e, d, ff), ("expert", "embed", None)),
        "wo": PSpec((e, ff, d), ("expert", None, "embed"), "small"),
    }
    if moe.shared_experts:
        sf = moe.shared_ff or moe.shared_experts * ff
        out["shared_wg"] = PSpec((d, sf), ("embed", "ff"))
        out["shared_wi"] = PSpec((d, sf), ("embed", "ff"))
        out["shared_wo"] = PSpec((sf, d), ("ff", "embed"), "small")
    return out


def _mamba_specs(cfg: ArchConfig) -> Dict[str, PSpec]:
    dims = mamba2_dims(cfg)
    d, di, h = cfg.d_model, dims["d_inner"], dims["n_heads"]
    gn = dims["n_groups"] * dims["d_state"]
    return {
        "in_z": PSpec((d, di), ("embed", "ff")),
        "in_x": PSpec((d, di), ("embed", "ff")),
        "in_bc": PSpec((d, 2 * gn), ("embed", None)),
        "in_dt": PSpec((d, h), ("embed", None)),
        "conv_w": PSpec((cfg.ssm.d_conv, di), (None, "ff")),
        "conv_b": PSpec((di,), ("ff",), "zeros"),
        "dt_bias": PSpec((h,), (None,), "dtbias"),
        "a_log": PSpec((h,), (None,), "alog"),
        "d_skip": PSpec((h,), (None,), "ones"),
        "norm_scale": PSpec((di,), ("ff",), "ones"),
        "out_proj": PSpec((di, d), ("ff", "embed"), "small"),
    }


def _rwkv_specs(cfg: ArchConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    lora = 64
    out: Dict[str, PSpec] = {
        "wr": PSpec((d, d), ("embed", "qkv_flat")),
        "wk": PSpec((d, d), ("embed", "qkv_flat")),
        "wv": PSpec((d, d), ("embed", "qkv_flat")),
        "wg": PSpec((d, d), ("embed", "qkv_flat")),
        "wo": PSpec((d, d), ("qkv_flat", "embed"), "small"),
        "w_lora_a": PSpec((d, lora), ("embed", None)),
        "w_lora_b": PSpec((lora, d), (None, "embed")),
        "w_base": PSpec((d,), (None,), "wbase"),
        "u": PSpec((d,), (None,), "mix"),
        "ln_x_scale": PSpec((d,), (None,), "ones"),
        "ln_x_bias": PSpec((d,), (None,), "zeros"),
        "fk": PSpec((d, cfg.d_ff), ("embed", "ff")),
        "fv": PSpec((cfg.d_ff, d), ("ff", "embed"), "small"),
        "fr": PSpec((d, d), ("embed", "qkv_flat")),
    }
    for name in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w",
                 "mix_fk", "mix_fr"):
        out[name] = PSpec((d,), (None,), "mix")
    return out


def _layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """Specs for ONE layer of the main (scanned) stack."""
    d = cfg.d_model
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return {"ln1": _norm_specs(cfg, d), "ln2": _norm_specs(cfg, d),
                "rwkv": _rwkv_specs(cfg)}
    if cfg.family in ("ssm", "hybrid") and cfg.ssm is not None \
            and cfg.ssm.kind == "mamba2":
        return {"ln1": _norm_specs(cfg, d), "mamba": _mamba_specs(cfg)}
    body: Dict[str, Any] = {
        "ln1": _norm_specs(cfg, d), "ln2": _norm_specs(cfg, d),
        "attn": _attn_specs(cfg, d),
    }
    if cfg.moe is not None:
        body["moe"] = _moe_specs(cfg)
    else:
        body["mlp"] = _mlp_specs(cfg, d, cfg.d_ff)
    if cfg.sandwich_norm:
        body["ln1b"] = _norm_specs(cfg, d)
        body["ln2b"] = _norm_specs(cfg, d)
    return body


def _stack(tree: Any, n: int) -> Any:
    def f(s: PSpec) -> PSpec:
        return PSpec((n,) + s.shape, (None,) + s.axes, s.init, s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, PSpec))


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    specs: Dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "embed")),
        "final_norm": _norm_specs(cfg, d),
    }
    if not cfg.tied_embeddings:
        specs["lm_head"] = PSpec((d, v), ("embed", "vocab"))

    if cfg.family == "audio":
        # encoder stack (non-causal, layernorm) + decoder stack w/ cross-attn
        enc_layer = {
            "ln1": _norm_specs(cfg, d), "ln2": _norm_specs(cfg, d),
            "attn": _attn_specs(cfg, d),
            "mlp": _mlp_specs(cfg, d, cfg.d_ff),
        }
        specs["enc_layers"] = _stack(enc_layer, cfg.encdec.enc_layers)
        specs["enc_final_norm"] = _norm_specs(cfg, d)
        dec_layer = {
            "ln1": _norm_specs(cfg, d), "ln2": _norm_specs(cfg, d),
            "ln3": _norm_specs(cfg, d),
            "attn": _attn_specs(cfg, d),
            "cross": _attn_specs(cfg, d),
            "mlp": _mlp_specs(cfg, d, cfg.d_ff),
        }
        specs["layers"] = _stack(dec_layer, cfg.n_layers)
        return specs

    if cfg.family == "hybrid":
        # zamba2: n_mamba scanned mamba layers + ONE shared attn+mlp block
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        n_mamba = cfg.n_layers - n_attn
        specs["layers"] = _stack(_layer_specs(cfg), n_mamba)
        specs["shared_attn"] = {
            "ln1": _norm_specs(cfg, d), "ln2": _norm_specs(cfg, d),
            "attn": _attn_specs(cfg, d),
            "mlp": _mlp_specs(cfg, d, cfg.d_ff),
        }
        return specs

    specs["layers"] = _stack(_layer_specs(cfg), cfg.n_layers)
    return specs


# ------------------------------------------------------------------ derive

def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def abstract_params(cfg: ArchConfig) -> Any:
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt),
                        param_specs(cfg), is_leaf=_is_pspec)


def param_pspecs(cfg: ArchConfig, rules: ShardingRules) -> Any:
    return jax.tree.map(lambda s: logical_spec(s.shape, s.axes, rules),
                        param_specs(cfg), is_leaf=_is_pspec)


def param_count(cfg: ArchConfig) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(param_specs(cfg), is_leaf=_is_pspec))


def _init_leaf(s: PSpec, key, cfg: ArchConfig) -> jnp.ndarray:
    dt = jnp.dtype(cfg.param_dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "alog":       # mamba A in [1, 16]
        u = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if s.init == "dtbias":     # inverse softplus of dt in [1e-3, 0.1]
        u = jax.random.uniform(key, s.shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(dt)
    if s.init == "mix":
        return jax.random.uniform(key, s.shape, jnp.float32, 0.0, 1.0
                                  ).astype(dt)
    if s.init == "wbase":
        return jnp.full(s.shape, -4.0, dt)
    scale = s.scale
    if s.init == "small":      # residual-out projections: 0.02/sqrt(2L)
        scale = s.scale / math.sqrt(max(2 * cfg.n_layers, 1))
    fan_in_dims = s.shape[:-1] if len(s.shape) > 1 else s.shape
    del fan_in_dims
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(dt)


def init_params(cfg: ArchConfig, seed: int = 0) -> Any:
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_pspec)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    vals = [_init_leaf(s, k, cfg) for s, k in zip(leaves, keys)]
    return treedef.unflatten(vals)
