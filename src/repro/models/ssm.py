"""Attention-free sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both are implemented twice:

* **chunked parallel form** for train/prefill — sequence split into chunks of
  ``cfg.ssm.chunk``; within-chunk interactions are dense (MXU-friendly
  (c x c) / (hd x state) matmuls), across-chunk state is carried by one
  ``lax.scan`` over chunks.  O(S * c) work, O(S/c) scan steps.
* **recurrent form** for decode — O(1) state per layer, independent of
  context length.  This is what makes ``long_500k`` a constant-memory cell
  for rwkv6-3b / zamba2-7b.

Conventions: inputs are (B, S, d); params are per-layer dicts (stacked along
a leading L axis by the caller and scanned).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.parallel.sharding import constrain


def _cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


# ============================================================= Mamba2 (SSD)

def mamba2_dims(cfg: ArchConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return dict(d_inner=d_inner, n_heads=n_heads, d_state=s.d_state,
                head_dim=s.head_dim, n_groups=s.n_groups, d_conv=s.d_conv)


def _ssd_chunk_scan(xh, dt, a_log, b, c, d_skip, chunk: int):
    """Chunked SSD.  xh: (B,S,H,P), dt: (B,S,H), a_log: (H,) <=0 decay,
    b,c: (B,S,G,N) with G groups broadcast over heads.  Returns (B,S,H,P).

    Scalar-per-head decay: within a chunk, y = (C B^T ∘ L) x (causal, decay
    weighted) + decay^t * C state_in;  state_out = decay^c * state_in +
    sum_t decay^(c-t) dt_t B_t x_t.
    """
    bsz, s, h, p = xh.shape
    g, n = b.shape[2], b.shape[3]
    s_orig = s
    pad = (-s) % chunk                      # zero-pad: dt=0 => no state change
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, b, c = zp(xh), zp(dt), zp(b), zp(c)
        s += pad
    nc = s // chunk
    rep = h // g

    xc = xh.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)

    # per-step log decay: dA = dt * a_log  (a_log < 0)
    da = dtc * a_log[None, None, None, :]            # (B,nc,c,H)
    da_cum = jnp.cumsum(da, axis=2)                  # inclusive cumsum

    def body(state, inp):
        xk, dtk, bk, ck, dak, dacum = inp            # leading axis B
        # intra-chunk: L[t,u] = exp(dacum_t - dacum_u) for u <= t
        rel = dacum[:, :, None, :] - dacum[:, None, :, :]   # (B,c,c,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        l_mat = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        # scores: C_t . B_u  (group-broadcast over heads)
        bk_h = jnp.repeat(bk, rep, axis=2)           # (B,c,H,N)
        ck_h = jnp.repeat(ck, rep, axis=2)
        scores = jnp.einsum("bthn,buhn->btuh", ck_h, bk_h) * l_mat
        y_intra = jnp.einsum("btuh,buh,buhp->bthp", scores, dtk, xk)
        # contribution of carried state: y += exp(dacum_t) * C_t . state
        y_state = jnp.einsum("bthn,bhpn->bthp", ck_h, state) \
            * jnp.exp(dacum)[..., None]
        # state update: state' = exp(da_total) state + sum_u exp(dacum_c - dacum_u) dt_u B_u x_u
        da_tot = dacum[:, -1]                        # (B,H)
        w = jnp.exp(da_tot[:, None, :] - dacum)      # (B,c,H)
        upd = jnp.einsum("buh,buh,buhn,buhp->bhpn", w, dtk, bk_h, xk)
        state = jnp.exp(da_tot)[:, :, None, None] * state + upd
        return state, (y_intra + y_state)

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dtc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(da, 1, 0).astype(jnp.float32),
          jnp.moveaxis(da_cum, 1, 0).astype(jnp.float32))
    final, ys = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    y = y + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    return y[:, :s_orig], final


def mamba2_train(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                 return_state: bool = False):
    """Full-sequence Mamba2 block (train / prefill). x: (B, S, d)."""
    dims = mamba2_dims(cfg)
    bsz, s, d = x.shape
    di, h, n, hp = (dims["d_inner"], dims["n_heads"], dims["d_state"],
                    dims["head_dim"])
    g = dims["n_groups"]
    cdt = _cdt(cfg)

    xc_ = x.astype(cdt)
    z = (xc_ @ p["in_z"].astype(cdt)).astype(jnp.float32)
    xin = (xc_ @ p["in_x"].astype(cdt)).astype(jnp.float32)
    bc = (xc_ @ p["in_bc"].astype(cdt)).astype(jnp.float32)
    dt = (xc_ @ p["in_dt"].astype(cdt)).astype(jnp.float32)
    b, c = jnp.split(bc, 2, axis=-1)
    # causal depthwise conv over (xin) — kernel (K, di)
    k = cfg.ssm.d_conv
    xpad = jnp.pad(xin, ((0, 0), (k - 1, 0), (0, 0)))
    xconv = sum(xpad[:, i:i + s] * p["conv_w"][i][None, None, :]
                for i in range(k)) + p["conv_b"][None, None, :]
    xconv = jax.nn.silu(xconv)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])     # (B,S,H)
    a_log = -jnp.exp(p["a_log"])                                # (H,) < 0

    xh = xconv.reshape(bsz, s, h, hp)
    bg = b.reshape(bsz, s, g, n)
    cg = c.reshape(bsz, s, g, n)
    y, final = _ssd_chunk_scan(xh, dt, a_log, bg, cg, p["d_skip"],
                               cfg.ssm.chunk)
    y = y.reshape(bsz, s, di)
    # gated rmsnorm (mamba2 norm-before-out)
    yn = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    y = yn * p["norm_scale"][None, None, :] * jax.nn.silu(z)
    out = (y.astype(cdt) @ p["out_proj"].astype(cdt)).astype(x.dtype)
    if return_state:
        return out, {"ssd": final, "conv": xin[:, s - (k - 1):]}
    return out


def mamba2_init_state(cfg: ArchConfig, batch: int) -> Dict[str, jnp.ndarray]:
    dims = mamba2_dims(cfg)
    return {
        "ssd": jnp.zeros((batch, dims["n_heads"], dims["head_dim"],
                          dims["d_state"]), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, dims["d_inner"]),
                          jnp.float32),
    }


def mamba2_decode(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                  state: Dict[str, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrent step. x: (B, 1, d)."""
    dims = mamba2_dims(cfg)
    bsz = x.shape[0]
    di, h, n, hp = (dims["d_inner"], dims["n_heads"], dims["d_state"],
                    dims["head_dim"])
    g = dims["n_groups"]
    cdt = _cdt(cfg)

    xc_ = x[:, 0].astype(cdt)
    z = (xc_ @ p["in_z"].astype(cdt)).astype(jnp.float32)
    xin = (xc_ @ p["in_x"].astype(cdt)).astype(jnp.float32)
    bc = (xc_ @ p["in_bc"].astype(cdt)).astype(jnp.float32)
    dt = (xc_ @ p["in_dt"].astype(cdt)).astype(jnp.float32)
    b, c = jnp.split(bc, 2, axis=-1)
    conv_hist = jnp.concatenate([state["conv"], xin[:, None, :]], axis=1)
    k = cfg.ssm.d_conv
    xconv = sum(conv_hist[:, i] * p["conv_w"][i][None, :] for i in range(k)) \
        + p["conv_b"][None, :]
    xconv = jax.nn.silu(xconv)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, :])            # (B,H)
    a_log = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a_log[None, :])                            # (B,H)

    xh = xconv.reshape(bsz, h, hp)
    bh = jnp.repeat(b.reshape(bsz, g, n), h // g, axis=1)
    ch = jnp.repeat(c.reshape(bsz, g, n), h // g, axis=1)
    new_ssd = da[:, :, None, None] * state["ssd"] \
        + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, bh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_ssd) \
        + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, di)
    yn = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    y = yn * p["norm_scale"][None, :] * jax.nn.silu(z)
    out = (y.astype(cdt) @ p["out_proj"].astype(cdt)).astype(x.dtype)
    return out[:, None, :], {"ssd": new_ssd, "conv": conv_hist[:, 1:]}


# ============================================================ RWKV6 (Finch)

def rwkv6_dims(cfg: ArchConfig) -> Dict[str, int]:
    hd = cfg.ssm.head_dim
    return dict(n_heads=cfg.d_model // hd, head_dim=hd)


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """x_{t-1} stream; ``prev`` (B, d) seeds position -1 (decode carries it)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _rwkv_proj(x, xprev, mix, w, lora_a=None, lora_b=None):
    """RWKV6 data-dependent interpolation + projection."""
    xm = x + (xprev - x) * mix[None, None, :]
    out = xm @ w
    if lora_a is not None:
        out = out + jnp.tanh(xm @ lora_a) @ lora_b
    return out


def _wkv6_chunk_scan(r, k, v, w_log, u, chunk: int):
    """Chunked WKV6.  r,k,v: (B,S,H,hd); w_log: (B,S,H,hd) <= 0 log-decay
    (data-dependent, per-channel); u: (H, hd) bonus.  Returns (B,S,H,hd).

    State S_h ∈ R^{hd x hd}: S_t = diag(exp(w_log_t)) S_{t-1} + k_t v_t^T,
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T).
    """
    bsz, s, h, hd = r.shape
    s_orig = s
    pad = (-s) % chunk          # zero-pad: w_log=0, k=0 => state preserved
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w_log = zp(r), zp(k), zp(v), zp(w_log)
        s += pad
    nc = s // chunk

    def resh(x):
        return jnp.moveaxis(x.reshape(bsz, nc, chunk, h, hd), 1, 0)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w_log)

    def body(state, inp):
        rk, kk, vk, wk = inp                       # (B,c,H,hd)
        wcum = jnp.cumsum(wk, axis=1)              # inclusive
        # o_t = r_t diag(exp(wcum_{t-1})) state  (decay BEFORE t's update)
        wcum_excl = wcum - wk
        y_state = jnp.einsum("bthd,bhde->bthe", rk * jnp.exp(wcum_excl), state)
        # intra-chunk: u<t term with decay prod_{j=u+1..t-1} -> exp(wcum_excl_t - wcum_u)
        rel = wcum_excl[:, :, None] - wcum[:, None, :]      # (B,t,u,H,hd)
        tri_lt = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        decay = jnp.where(tri_lt[None, :, :, None, None], jnp.exp(rel), 0.0)
        att = jnp.einsum("bthd,btuhd,buhd->btuh", rk, decay, kk)
        # diagonal (current token) bonus term
        diag = jnp.einsum("bthd,hd,bthd->bth", rk, u, kk)
        y_intra = jnp.einsum("btuh,buhe->bthe", att, vk) \
            + diag[..., None] * vk
        # state update
        w_tot = wcum[:, -1]                        # (B,H,hd)
        wrem = w_tot[:, None] - wcum               # decay from u+1..c
        kw = kk * jnp.exp(wrem)
        state = jnp.exp(w_tot)[..., None] * state \
            + jnp.einsum("buhd,buhe->bhde", kw, vk)
        return state, y_state + y_intra

    state0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)
    final, ys = jax.lax.scan(body, state0,
                             (rc.astype(jnp.float32), kc.astype(jnp.float32),
                              vc.astype(jnp.float32), wc.astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, hd)[:, :s_orig], final


def rwkv6_time_mix(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                   prev_x: jnp.ndarray | None = None,
                   state: jnp.ndarray | None = None):
    """RWKV6 attention (time-mix).  Train mode when state is None."""
    dims = rwkv6_dims(cfg)
    h, hd = dims["n_heads"], dims["head_dim"]
    bsz, s, d = x.shape
    xf = x.astype(jnp.float32)
    xprev = _token_shift(xf, prev_x)

    r = _rwkv_proj(xf, xprev, p["mix_r"], p["wr"])
    k = _rwkv_proj(xf, xprev, p["mix_k"], p["wk"])
    v = _rwkv_proj(xf, xprev, p["mix_v"], p["wv"])
    g = _rwkv_proj(xf, xprev, p["mix_g"], p["wg"])
    # data-dependent decay (low-rank): w = exp(-exp(base + lora))
    wl = _rwkv_proj(xf, xprev, p["mix_w"], jnp.zeros((d, d), jnp.float32),
                    p["w_lora_a"], p["w_lora_b"]) + p["w_base"][None, None, :]
    w_log = -jnp.exp(wl)                                # (B,S,d) <= 0

    def heads(t):
        return t.reshape(bsz, s, h, hd)

    if state is None:
        y, new_state = _wkv6_chunk_scan(heads(r), heads(k), heads(v),
                                        heads(w_log), p["u"].reshape(h, hd),
                                        cfg.ssm.chunk)
    else:
        rh, kh, vh = heads(r)[:, 0], heads(k)[:, 0], heads(v)[:, 0]
        wh = jnp.exp(heads(w_log)[:, 0])                 # (B,H,hd)
        u = p["u"].reshape(h, hd)
        kv = jnp.einsum("bhd,bhe->bhde", kh, vh)
        y = jnp.einsum("bhd,bhde->bhe", rh, state + u[None, :, :, None] * kv)
        new_state = wh[..., None] * state + kv
        y = y[:, None]                                   # (B,1,H,hd)

    # group-norm over heads + output gate
    yf = y.reshape(bsz, -1, h, hd)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn * p["ln_x_scale"].reshape(1, 1, h, hd) \
        + p["ln_x_bias"].reshape(1, 1, h, hd)
    out = (yn.reshape(bsz, -1, d) * jax.nn.silu(g)) @ p["wo"]
    return out.astype(x.dtype), new_state, xf[:, -1]


def rwkv6_channel_mix(x: jnp.ndarray, p: Dict, cfg: ArchConfig,
                      prev_x: jnp.ndarray | None = None):
    """RWKV6 FFN (channel-mix) with token shift + squared relu."""
    xf = x.astype(jnp.float32)
    xprev = _token_shift(xf, prev_x)
    xk = xf + (xprev - xf) * p["mix_fk"][None, None, :]
    xr = xf + (xprev - xf) * p["mix_fr"][None, None, :]
    kk = jnp.square(jax.nn.relu(xk @ p["fk"]))
    out = jax.nn.sigmoid(xr @ p["fr"]) * (kk @ p["fv"])
    return out.astype(x.dtype), xf[:, -1]
