"""Forward passes and step functions for all ten assigned architectures.

One scanned-block formulation per family:

* dense / moe / vlm — pre-norm attention + (MLP | MoE), ``lax.scan`` over a
  stacked (L, ...) parameter tree; per-layer window/theta are *scanned
  arrays* so gemma3's 5:1 local:global pattern shares one compiled body.
* ssm (rwkv6 / mamba2) — token-shift / SSD blocks, chunked for train,
  O(1)-state recurrence for decode.
* hybrid (zamba2) — grouped scan: (k-1) scanned mamba layers then the ONE
  weight-shared attention block per group (weight reuse = zamba signature).
* audio (whisper) — encoder stack (non-causal) + decoder stack with
  cross-attention; conv frontend stubbed (frames arrive pre-embedded).

Memory discipline: attention goes through ``flash_attention`` (blocked
online softmax, recompute-backward) whenever S*T is large; layer scan bodies
are ``jax.checkpoint``-ed when ``cfg.remat == "full"``; ``train_step``
accumulates gradients over ``accum`` microbatches with a ``lax.scan`` so
activation peak is one microbatch.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig
from repro.models.flash import flash_attention, reference_attention
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.parallel.sharding import constrain

FLASH_MIN = 2048 * 2048   # S*T above which the blocked path is used
BLOCK = 512


def _use_flash(s: int, t: int, impl: str) -> bool:
    if impl == "flash":
        return True
    if impl == "naive":
        return False
    return (s * t >= FLASH_MIN) and s % BLOCK == 0 and t % BLOCK == 0


def _cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def sinusoid_pos(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    tab = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(tab, jnp.float32)


def sinusoid_row(pos, d: int) -> jnp.ndarray:
    """Single sinusoid row at (traced) scalar position ``pos``."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


# ------------------------------------------------------------ attention wrap

def attention_full(x, p, cfg: ArchConfig, pos, window, theta, *,
                   impl: str = "auto", schedule: str = "dense",
                   causal: bool = True, kv_x=None, kv_valid: int = 10 ** 9):
    """Self- or cross-attention over a full sequence."""
    b, s, _ = x.shape
    if kv_x is None:
        q, k, v = L.qkv_project(x, p, cfg)
        if cfg.rope_pct > 0:
            q = L.apply_rope(q, pos, cfg, theta)
            k = L.apply_rope(k, pos, cfg, theta)
        t = s
    else:
        q = L._split_heads(L.dot(x, p["wq"], cfg), cfg.n_heads)
        k = L._split_heads(L.dot(kv_x, p["wk"], cfg), cfg.n_kv_heads)
        v = L._split_heads(L.dot(kv_x, p["wv"], cfg), cfg.n_kv_heads)
        t = kv_x.shape[1]
        causal = False
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    if _use_flash(s, t, impl):
        o = flash_attention(q, k, v, causal, schedule, BLOCK, BLOCK,
                            window, kv_valid, 0)
    else:
        o = reference_attention(q, k, v, causal, window, kv_valid, 0)
    o = o.reshape(b, s, -1).astype(_cdt(cfg))
    o = L.dot(o, p["wo"], cfg)
    if cfg.attn_out_bias:
        o = o + p["bo"].astype(o.dtype)
    return o


# -------------------------------------------------------------- block bodies

def dense_block(x, lp, cfg: ArchConfig, pos, window, theta, impl, schedule):
    h = L.norm(x, lp["ln1"], cfg)
    a = attention_full(h, lp["attn"], cfg, pos, window, theta,
                       impl=impl, schedule=schedule)
    if cfg.sandwich_norm:
        a = L.norm(a, lp["ln1b"], cfg)
    x = x + a
    h = L.norm(x, lp["ln2"], cfg)
    if cfg.moe is not None:
        m = moe_lib.moe_mlp(h, lp["moe"], cfg)
    else:
        m = L.mlp(h, lp["mlp"], cfg)
    if cfg.sandwich_norm:
        m = L.norm(m, lp["ln2b"], cfg)
    return x + m


def rwkv_block(x, lp, cfg: ArchConfig):
    h = L.norm(x, lp["ln1"], cfg)
    a, _, _ = ssm_lib.rwkv6_time_mix(h, lp["rwkv"], cfg)
    x = x + a
    h = L.norm(x, lp["ln2"], cfg)
    m, _ = ssm_lib.rwkv6_channel_mix(h, lp["rwkv"], cfg)
    return x + m


def mamba_block(x, lp, cfg: ArchConfig):
    h = L.norm(x, lp["ln1"], cfg)
    return x + ssm_lib.mamba2_train(h, lp["mamba"], cfg)


def shared_attn_block(x, sp, cfg: ArchConfig, pos, impl, schedule):
    h = L.norm(x, sp["ln1"], cfg)
    x = x + attention_full(h, sp["attn"], cfg, pos, 0, cfg.rope_theta,
                           impl=impl, schedule=schedule)
    h = L.norm(x, sp["ln2"], cfg)
    return x + L.mlp(h, sp["mlp"], cfg)


# --------------------------------------------------------------- layer scans

def _maybe_ckpt(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _layer_meta(cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-layer (window, rope_theta) arrays for the scanned stack."""
    windows = np.asarray(cfg.windows(), np.int32)
    thetas = np.full(cfg.n_layers, cfg.rope_theta, np.float32)
    if cfg.global_rope_theta:
        thetas = np.where(windows == 0, cfg.global_rope_theta, thetas)
    return jnp.asarray(windows), jnp.asarray(thetas)


def forward_hidden(params, tokens, cfg: ArchConfig, *, pos=None,
                   patches=None, frames=None, impl="auto",
                   schedule="dense") -> jnp.ndarray:
    """Token stream -> final hidden states (pre final-norm)."""
    if cfg.family == "audio":
        enc = whisper_encode(params, frames, cfg, impl, schedule)
        return whisper_decoder_hidden(params, tokens, enc, cfg, impl,
                                      schedule)

    x = L.embed_tokens(tokens, params["embed"], cfg)
    if cfg.vlm is not None and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        body = _maybe_ckpt(lambda c, lp: (rwkv_block(c, lp, cfg), None), cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    if cfg.family == "ssm" and cfg.ssm.kind == "mamba2":
        body = _maybe_ckpt(lambda c, lp: (mamba_block(c, lp, cfg), None), cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    if cfg.family == "hybrid":
        return zamba_hidden(params, x, cfg, pos, impl, schedule)

    windows, thetas = _layer_meta(cfg)

    def body(c, inp):
        lp, w, th = inp
        return dense_block(c, lp, cfg, pos, w, th, impl, schedule), None

    body = _maybe_ckpt(body, cfg)
    x, _ = jax.lax.scan(body, x, (params["layers"], windows, thetas))
    return x


def zamba_hidden(params, x, cfg: ArchConfig, pos, impl, schedule):
    k = cfg.hybrid_attn_every
    n_attn = cfg.n_layers // k
    per_group = k - 1
    grouped = n_attn * per_group
    mam = params["layers"]
    head = jax.tree.map(
        lambda a: a[:grouped].reshape((n_attn, per_group) + a.shape[1:]), mam)
    tail = jax.tree.map(lambda a: a[grouped:], mam)
    shared = params["shared_attn"]

    inner = lambda c, lp: (mamba_block(c, lp, cfg), None)

    def group_body(c, glp):
        c, _ = jax.lax.scan(inner, c, glp)
        c = shared_attn_block(c, shared, cfg, pos, impl, schedule)
        return c, None

    x, _ = jax.lax.scan(_maybe_ckpt(group_body, cfg), x, head)
    x, _ = jax.lax.scan(_maybe_ckpt(inner, cfg), x, tail)
    return x


# ------------------------------------------------------------------ whisper

def _enc_pad(cfg: ArchConfig) -> int:
    es = cfg.encdec.enc_seq
    return -(-es // BLOCK) * BLOCK if es >= BLOCK else es


def whisper_encode(params, frames, cfg: ArchConfig, impl="auto",
                   schedule="dense") -> jnp.ndarray:
    """frames: (B, enc_seq, d) pre-embedded (conv frontend stub)."""
    b, es, d = frames.shape
    pad = _enc_pad(cfg) - es
    x = frames.astype(_cdt(cfg)) + sinusoid_pos(es, d)[None].astype(_cdt(cfg))
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (b, x.shape[1]))

    def body(c, lp):
        h = L.norm(c, lp["ln1"], cfg)
        a = attention_full(h, lp["attn"], cfg, pos, 0, cfg.rope_theta,
                           impl=impl, schedule=schedule, causal=False,
                           kv_valid=es)
        c = c + a
        h = L.norm(c, lp["ln2"], cfg)
        return c + L.mlp(h, lp["mlp"], cfg), None

    x, _ = jax.lax.scan(_maybe_ckpt(body, cfg), x, params["enc_layers"])
    x = L.norm(x, params["enc_final_norm"], cfg)
    return x[:, :es]


def whisper_decoder_hidden(params, tokens, enc, cfg: ArchConfig,
                           impl="auto", schedule="dense") -> jnp.ndarray:
    b, s = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg)
    x = x + sinusoid_pos(s, cfg.d_model)[None].astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    es = enc.shape[1]
    pad = _enc_pad(cfg) - es
    enc_p = jnp.pad(enc, ((0, 0), (0, pad), (0, 0))) if pad else enc

    def body(c, lp):
        h = L.norm(c, lp["ln1"], cfg)
        c = c + attention_full(h, lp["attn"], cfg, pos, 0, cfg.rope_theta,
                               impl=impl, schedule=schedule)
        h = L.norm(c, lp["ln2"], cfg)
        c = c + attention_full(h, lp["cross"], cfg, pos, 0, cfg.rope_theta,
                               impl=impl, schedule=schedule, kv_x=enc_p,
                               kv_valid=es)
        h = L.norm(c, lp["ln3"], cfg)
        return c + L.mlp(h, lp["mlp"], cfg), None

    x, _ = jax.lax.scan(_maybe_ckpt(body, cfg), x, params["layers"])
    return x


# --------------------------------------------------------------------- loss

def masked_cross_entropy(logits, labels, vocab: int,
                         mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab:
        pad = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(pad[None, None], -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            impl="auto", schedule="dense") -> jnp.ndarray:
    h = forward_hidden(params, batch["tokens"], cfg,
                       pos=batch.get("pos"), patches=batch.get("patches"),
                       frames=batch.get("frames"), impl=impl,
                       schedule=schedule)
    if cfg.vlm is not None and "patches" in batch:
        h = h[:, batch["patches"].shape[1]:]       # loss on text positions
    h = L.norm(h, params["final_norm"], cfg)
    logits = L.lm_logits(h, params, cfg)
    return masked_cross_entropy(logits, batch["labels"], cfg.vocab,
                                batch.get("loss_mask"))


# --------------------------------------------------------------- train step

def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, accum: int = 1,
                    impl="auto", schedule="dense"):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    ``accum`` splits the (already data-sharded) global batch into sequential
    microbatches via ``lax.scan`` — activation memory peaks at 1/accum of
    the naive step; gradients accumulate in f32 shards (same sharding as
    params, i.e. reduce-scattered under FSDP).
    """
    sched = cosine_schedule(opt_cfg.warmup, opt_cfg.total_steps,
                            opt_cfg.min_lr_frac)

    def lfn(params, mb):
        return loss_fn(params, mb, cfg, impl, schedule)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(lfn)(params, batch)
        else:
            def re(x):
                mb = x.shape[0] // accum
                y = x.reshape((accum, mb) + x.shape[1:])
                return y

            mbs = jax.tree.map(re, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(lfn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg, sched)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ----------------------------------------------------------------- caches

def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int
                 ) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """name -> (shape, dtype) for the decode state of one model."""
    hk, hd, d = cfg.n_kv_heads, cfg.hd, cfg.d_model
    bf, f32 = jnp.bfloat16, jnp.float32
    out: Dict[str, Tuple[Tuple[int, ...], Any]] = {}
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        dims = ssm_lib.rwkv6_dims(cfg)
        h, p = dims["n_heads"], dims["head_dim"]
        out["wkv"] = ((cfg.n_layers, batch, h, p, p), f32)
        out["att_x"] = ((cfg.n_layers, batch, d), f32)
        out["ffn_x"] = ((cfg.n_layers, batch, d), f32)
        return out
    if cfg.family == "ssm" and cfg.ssm.kind == "mamba2":
        dims = ssm_lib.mamba2_dims(cfg)
        out["ssd"] = ((cfg.n_layers, batch, dims["n_heads"],
                       dims["head_dim"], dims["d_state"]), f32)
        out["conv"] = ((cfg.n_layers, batch, cfg.ssm.d_conv - 1,
                        dims["d_inner"]), f32)
        return out
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        n_attn = cfg.n_layers // k
        n_mamba = cfg.n_layers - n_attn
        dims = ssm_lib.mamba2_dims(cfg)
        out["ssd"] = ((n_mamba, batch, dims["n_heads"], dims["head_dim"],
                       dims["d_state"]), f32)
        out["conv"] = ((n_mamba, batch, cfg.ssm.d_conv - 1,
                        dims["d_inner"]), f32)
        out["attn_k"] = ((n_attn, batch, cache_len, hk, hd), bf)
        out["attn_v"] = ((n_attn, batch, cache_len, hk, hd), bf)
        return out
    if cfg.family == "audio":
        es = cfg.encdec.enc_seq
        out["self_k"] = ((cfg.n_layers, batch, cache_len, hk, hd), bf)
        out["self_v"] = ((cfg.n_layers, batch, cache_len, hk, hd), bf)
        out["cross_k"] = ((cfg.n_layers, batch, es, hk, hd), bf)
        out["cross_v"] = ((cfg.n_layers, batch, es, hk, hd), bf)
        return out
    windows = cfg.windows()
    if any(w > 0 for w in windows):      # gemma3: ring-buffer local layers
        n_local = sum(1 for w in windows if w > 0)
        n_global = cfg.n_layers - n_local
        w = max(w for w in windows if w > 0)
        out["local_k"] = ((n_local, batch, min(w, cache_len), hk, hd), bf)
        out["local_v"] = ((n_local, batch, min(w, cache_len), hk, hd), bf)
        out["global_k"] = ((n_global, batch, cache_len, hk, hd), bf)
        out["global_v"] = ((n_global, batch, cache_len, hk, hd), bf)
        return out
    if cfg.kv_quant:
        out["k"] = ((cfg.n_layers, batch, cache_len, hk, hd), jnp.int8)
        out["v"] = ((cfg.n_layers, batch, cache_len, hk, hd), jnp.int8)
        out["k_scale"] = ((cfg.n_layers, batch, hk), f32)
        out["v_scale"] = ((cfg.n_layers, batch, hk), f32)
        return out
    out["k"] = ((cfg.n_layers, batch, cache_len, hk, hd), bf)
    out["v"] = ((cfg.n_layers, batch, cache_len, hk, hd), bf)
    return out


def init_caches(cfg: ArchConfig, batch: int, cache_len: int,
                abstract: bool = False) -> Dict[str, Any]:
    shapes = cache_shapes(cfg, batch, cache_len)
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}
    return {k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()}


def cache_axes(cfg: ArchConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    """Logical axes for each cache entry (KV seq sharded over ``model``)."""
    shapes = cache_shapes(cfg, 2, 4)
    out: Dict[str, Tuple[Optional[str], ...]] = {}
    for k, (shape, _) in shapes.items():
        if k in ("ssd", "conv", "wkv", "att_x", "ffn_x"):
            out[k] = (None, "batch") + (None,) * (len(shape) - 2)
        elif k.endswith("_scale"):
            out[k] = (None, "batch", None)
        else:
            out[k] = (None, "batch", "kv_seq", None, None)
    return out


# -------------------------------------------------------------- decode step

def decode_step(params, caches, token, cache_len, cfg: ArchConfig,
                enc: Optional[jnp.ndarray] = None):
    """One-token decode. token: (B, 1) int32; cache_len: scalar int32.

    Returns (logits (B, V) f32, new_caches).
    """
    b = token.shape[0]
    x = L.embed_tokens(token, params["embed"], cfg)
    posb = jnp.full((b,), cache_len, jnp.int32)
    new_caches = dict(caches)

    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        def body(c, inp):
            lp, wkv, ax, fx = inp
            h = L.norm(c, lp["ln1"], cfg)
            a, new_wkv, new_ax = ssm_lib.rwkv6_time_mix(
                h, lp["rwkv"], cfg, prev_x=ax, state=wkv)
            c = c + a
            h = L.norm(c, lp["ln2"], cfg)
            m, new_fx = ssm_lib.rwkv6_channel_mix(h, lp["rwkv"], cfg,
                                                  prev_x=fx)
            return c + m, (new_wkv, new_ax, new_fx)

        x, (wkv, ax, fx) = jax.lax.scan(
            body, x, (params["layers"], caches["wkv"], caches["att_x"],
                      caches["ffn_x"]))
        new_caches = {"wkv": wkv, "att_x": ax, "ffn_x": fx}

    elif cfg.family == "ssm" and cfg.ssm.kind == "mamba2":
        def body(c, inp):
            lp, ssd, conv = inp
            h = L.norm(c, lp["ln1"], cfg)
            a, st = ssm_lib.mamba2_decode(h, lp["mamba"], cfg,
                                          {"ssd": ssd, "conv": conv})
            return c + a, (st["ssd"], st["conv"])

        x, (ssd, conv) = jax.lax.scan(
            body, x, (params["layers"], caches["ssd"], caches["conv"]))
        new_caches = {"ssd": ssd, "conv": conv}

    elif cfg.family == "hybrid":
        x, new_caches = _zamba_decode(params, caches, x, posb, cache_len, cfg)

    elif cfg.family == "audio":
        # absolute (sinusoidal) positions: add the row at position cache_len
        x = x + sinusoid_row(cache_len, cfg.d_model)[None, None].astype(x.dtype)

        def body(c, inp):
            lp, sk, sv, ck, cv = inp
            h = L.norm(c, lp["ln1"], cfg)
            a, sk, sv = L.attention_decode(h, lp["attn"], cfg, sk, sv,
                                           posb, cache_len)
            c = c + a
            h = L.norm(c, lp["ln2"], cfg)
            c = c + L.cross_attention_decode(h, lp["cross"], cfg, ck, cv)
            h = L.norm(c, lp["ln3"], cfg)
            return c + L.mlp(h, lp["mlp"], cfg), (sk, sv)

        x, (sk, sv) = jax.lax.scan(
            body, x, (params["layers"], caches["self_k"], caches["self_v"],
                      caches["cross_k"], caches["cross_v"]))
        new_caches = dict(caches, self_k=sk, self_v=sv)

    elif any(w > 0 for w in cfg.windows()):    # gemma3, unrolled mixed stack
        windows = np.asarray(cfg.windows(), np.int64)
        thetas = np.full(cfg.n_layers, cfg.rope_theta, np.float64)
        if cfg.global_rope_theta:
            thetas = np.where(windows == 0, cfg.global_rope_theta, thetas)
        li = gi = 0
        lk, lv = list(caches["local_k"]), list(caches["local_v"])
        gk, gv = list(caches["global_k"]), list(caches["global_v"])
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            w = int(windows[i])
            h = L.norm(x, lp["ln1"], cfg)
            if w > 0:
                a, lk[li], lv[li] = L.attention_decode(
                    h, lp["attn"], cfg, lk[li], lv[li], posb, cache_len,
                    window=w, theta=float(thetas[i]), rolling=True)
                li += 1
            else:
                a, gk[gi], gv[gi] = L.attention_decode(
                    h, lp["attn"], cfg, gk[gi], gv[gi], posb, cache_len,
                    theta=float(thetas[i]))
                gi += 1
            if cfg.sandwich_norm:
                a = L.norm(a, lp["ln1b"], cfg)
            x = x + a
            h = L.norm(x, lp["ln2"], cfg)
            m = L.mlp(h, lp["mlp"], cfg)
            if cfg.sandwich_norm:
                m = L.norm(m, lp["ln2b"], cfg)
            x = x + m
        new_caches = {
            "local_k": jnp.stack(lk) if lk else caches["local_k"],
            "local_v": jnp.stack(lv) if lv else caches["local_v"],
            "global_k": jnp.stack(gk) if gk else caches["global_k"],
            "global_v": jnp.stack(gv) if gv else caches["global_v"],
        }

    else:                                      # dense / moe / vlm
        windows, thetas = _layer_meta(cfg)

        # fori_loop with the caches as CARRY + per-layer dynamic-update:
        # a scan would stream the (L,B,T,H,hd) caches through xs/ys,
        # multi-buffering ~5x the cache in temps (measured: 41.4 GiB/dev
        # for qwen2-72b decode_32k); the carried DUS aliases in place.
        def body(i, carry):
            c, kc_all, vc_all = carry
            lp = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                params["layers"])
            th = thetas[i]
            h = L.norm(c, lp["ln1"], cfg)
            kc = jax.lax.dynamic_index_in_dim(kc_all, i, 0, False)
            vc = jax.lax.dynamic_index_in_dim(vc_all, i, 0, False)
            scales = {}
            if cfg.kv_quant:
                scales = dict(
                    k_scale=jax.lax.dynamic_index_in_dim(
                        caches["k_scale"], i, 0, False),
                    v_scale=jax.lax.dynamic_index_in_dim(
                        caches["v_scale"], i, 0, False))
            a, kc, vc = L.attention_decode(h, lp["attn"], cfg, kc, vc,
                                           posb, cache_len, window=0,
                                           theta=th, **scales)
            kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, i, 0)
            vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, i, 0)
            c = c + a
            h = L.norm(c, lp["ln2"], cfg)
            if cfg.moe is not None:
                m = moe_lib.moe_mlp(h, lp["moe"], cfg)
            else:
                m = L.mlp(h, lp["mlp"], cfg)
            return (c + m, kc_all, vc_all)

        x, kc, vc = jax.lax.fori_loop(
            0, cfg.n_layers, body, (x, caches["k"], caches["v"]))
        new_caches = {"k": kc, "v": vc}
        if cfg.kv_quant:
            new_caches["k_scale"] = caches["k_scale"]
            new_caches["v_scale"] = caches["v_scale"]

    x = L.norm(x, params["final_norm"], cfg)
    logits = L.lm_logits(x, params, cfg)[:, 0]
    return logits, new_caches


def _zamba_decode(params, caches, x, posb, cache_len, cfg: ArchConfig):
    k = cfg.hybrid_attn_every
    n_attn = cfg.n_layers // k
    per_group = k - 1
    grouped = n_attn * per_group
    mam = params["layers"]
    regroup = lambda a: a[:grouped].reshape((n_attn, per_group) + a.shape[1:])
    head = jax.tree.map(regroup, mam)
    tail = jax.tree.map(lambda a: a[grouped:], mam)
    shared = params["shared_attn"]
    ssd_h, conv_h = (jax.tree.map(regroup, caches["ssd"]),
                     jax.tree.map(regroup, caches["conv"]))
    ssd_t = caches["ssd"][grouped:]
    conv_t = caches["conv"][grouped:]

    def mamba_step(c, inp):
        lp, ssd, conv = inp
        h = L.norm(c, lp["ln1"], cfg)
        a, st = ssm_lib.mamba2_decode(h, lp["mamba"], cfg,
                                      {"ssd": ssd, "conv": conv})
        return c + a, (st["ssd"], st["conv"])

    def group_body(c, inp):
        glp, ssd, conv, ak, av = inp
        c, (ssd, conv) = jax.lax.scan(mamba_step, c, (glp, ssd, conv))
        h = L.norm(c, shared["ln1"], cfg)
        a, ak, av = L.attention_decode(h, shared["attn"], cfg, ak, av,
                                       posb, cache_len)
        c = c + a
        h = L.norm(c, shared["ln2"], cfg)
        c = c + L.mlp(h, shared["mlp"], cfg)
        return c, (ssd, conv, ak, av)

    x, (ssd_h2, conv_h2, ak, av) = jax.lax.scan(
        group_body, x, (head, ssd_h, conv_h, caches["attn_k"],
                        caches["attn_v"]))
    x, (ssd_t2, conv_t2) = jax.lax.scan(mamba_step, x, (tail, ssd_t, conv_t))
    new = {
        "ssd": jnp.concatenate([ssd_h2.reshape((grouped,) + ssd_h2.shape[2:]),
                                ssd_t2]),
        "conv": jnp.concatenate(
            [conv_h2.reshape((grouped,) + conv_h2.shape[2:]), conv_t2]),
        "attn_k": ak, "attn_v": av,
    }
    return x, new


# ------------------------------------------------------------- prefill step

def prefill_step(params, tokens, cfg: ArchConfig, *, frames=None,
                 patches=None, pos=None, impl="auto", schedule="dense"):
    """Full-sequence forward that also builds the decode state.

    Returns (last-position logits (B, V), caches at len S).
    """
    b, s = tokens.shape
    if pos is None:
        pos_arr = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    else:
        pos_arr = pos

    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        x = L.embed_tokens(tokens, params["embed"], cfg)

        def body(c, lp):
            h = L.norm(c, lp["ln1"], cfg)
            a, st, ax = ssm_lib.rwkv6_time_mix(h, lp["rwkv"], cfg)
            c = c + a
            h = L.norm(c, lp["ln2"], cfg)
            m, fx = ssm_lib.rwkv6_channel_mix(h, lp["rwkv"], cfg)
            return c + m, (st, ax, fx)

        x, (wkv, ax, fx) = jax.lax.scan(body, x, params["layers"])
        caches = {"wkv": wkv, "att_x": ax, "ffn_x": fx}

    elif cfg.family == "ssm" and cfg.ssm.kind == "mamba2":
        x = L.embed_tokens(tokens, params["embed"], cfg)

        def body(c, lp):
            h = L.norm(c, lp["ln1"], cfg)
            a, st = ssm_lib.mamba2_train(h, lp["mamba"], cfg,
                                         return_state=True)
            return c + a, st

        x, sts = jax.lax.scan(body, x, params["layers"])
        caches = {"ssd": sts["ssd"], "conv": sts["conv"]}

    elif cfg.family == "audio":
        enc = whisper_encode(params, frames, cfg, impl, schedule)
        x, caches = _whisper_prefill_dec(params, tokens, enc, cfg, impl,
                                         schedule)

    elif cfg.family == "hybrid":
        x, caches = _zamba_prefill(params, tokens, cfg, pos_arr, impl,
                                   schedule)

    else:
        x, caches = _dense_prefill(params, tokens, cfg, pos_arr, patches,
                                   impl, schedule)

    x = L.norm(x, params["final_norm"], cfg)
    logits = L.lm_logits(x[:, -1:], params, cfg)[:, 0]
    return logits, caches


def _attn_with_cache(h, lp_attn, cfg, pos_arr, w, th, impl, schedule):
    """Full-seq self attention returning (out, roped k, v) for the cache."""
    q, kk, vv = L.qkv_project(h, lp_attn, cfg)
    if cfg.rope_pct > 0:
        q = L.apply_rope(q, pos_arr, cfg, th)
        kk = L.apply_rope(kk, pos_arr, cfg, th)
    s = h.shape[1]
    if _use_flash(s, s, impl):
        o = flash_attention(q, kk, vv, True, schedule, BLOCK, BLOCK, w, 10**9, 0)
    else:
        o = reference_attention(q, kk, vv, True, w, 10**9, 0)
    o = L.dot(o.reshape(h.shape[0], s, -1).astype(_cdt(cfg)), lp_attn["wo"], cfg)
    if cfg.attn_out_bias:
        o = o + lp_attn["bo"].astype(o.dtype)
    return o, kk.astype(jnp.bfloat16), vv.astype(jnp.bfloat16)


def _dense_prefill(params, tokens, cfg, pos_arr, patches, impl, schedule):
    x = L.embed_tokens(tokens, params["embed"], cfg)
    if cfg.vlm is not None and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        b = x.shape[0]
        pos_arr = pos_arr if pos_arr.shape[-1] == x.shape[1] else \
            jnp.broadcast_to(jnp.arange(x.shape[1])[None], (b, x.shape[1]))
    windows, thetas = _layer_meta(cfg)
    mixed = any(w > 0 for w in cfg.windows())

    def body(c, inp):
        lp, w, th = inp
        h = L.norm(c, lp["ln1"], cfg)
        a, kk, vv = _attn_with_cache(h, lp["attn"], cfg, pos_arr, w, th,
                                     impl, schedule)
        if cfg.sandwich_norm:
            a = L.norm(a, lp["ln1b"], cfg)
        c = c + a
        h = L.norm(c, lp["ln2"], cfg)
        if cfg.moe is not None:
            m = moe_lib.moe_mlp(h, lp["moe"], cfg)
        else:
            m = L.mlp(h, lp["mlp"], cfg)
        if cfg.sandwich_norm:
            m = L.norm(m, lp["ln2b"], cfg)
        return c + m, (kk, vv)

    x, (kc, vc) = jax.lax.scan(body, x, (params["layers"], windows, thetas))

    if not mixed:
        if cfg.kv_quant:
            kq, vq, ks, vs = L.quantize_kv(kc, vc)
            return x, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return x, {"k": kc, "v": vc}
    # gemma3: split stacked caches into ring-buffer local + full global
    wlist = cfg.windows()
    w = max(ww for ww in wlist if ww > 0)
    s = kc.shape[2]
    local_idx = jnp.asarray(
        [i for i, ww in enumerate(wlist) if ww > 0], jnp.int32)
    global_idx = jnp.asarray(
        [i for i, ww in enumerate(wlist) if ww == 0], jnp.int32)
    keep = min(w, s)
    # ring-buffer layout: position p lives in slot p % keep (decode uses
    # modular indexing), so scatter the last ``keep`` positions accordingly
    pos_tail = jnp.arange(s - keep, s)
    ring_slots = pos_tail % keep
    lk = kc[local_idx]
    lv = vc[local_idx]
    ring_k = jnp.zeros_like(lk[:, :, :keep]).at[:, :, ring_slots].set(
        lk[:, :, pos_tail])
    ring_v = jnp.zeros_like(lv[:, :, :keep]).at[:, :, ring_slots].set(
        lv[:, :, pos_tail])
    caches = {
        "local_k": ring_k,
        "local_v": ring_v,
        "global_k": kc[global_idx],
        "global_v": vc[global_idx],
    }
    return x, caches


def _zamba_prefill(params, tokens, cfg, pos_arr, impl, schedule):
    k = cfg.hybrid_attn_every
    n_attn = cfg.n_layers // k
    per_group = k - 1
    grouped = n_attn * per_group
    x = L.embed_tokens(tokens, params["embed"], cfg)
    mam = params["layers"]
    regroup = lambda a: a[:grouped].reshape((n_attn, per_group) + a.shape[1:])
    head = jax.tree.map(regroup, mam)
    tail = jax.tree.map(lambda a: a[grouped:], mam)
    shared = params["shared_attn"]

    def mamba_step(c, lp):
        h = L.norm(c, lp["ln1"], cfg)
        a, st = ssm_lib.mamba2_train(h, lp["mamba"], cfg, return_state=True)
        return c + a, st

    def group_body(c, glp):
        c, sts = jax.lax.scan(mamba_step, c, glp)
        h = L.norm(c, shared["ln1"], cfg)
        a, kk, vv = _attn_with_cache(h, shared["attn"], cfg, pos_arr, 0,
                                     cfg.rope_theta, impl, schedule)
        c = c + a
        h = L.norm(c, shared["ln2"], cfg)
        c = c + L.mlp(h, shared["mlp"], cfg)
        return c, (sts, kk, vv)

    x, (sts_h, ak, av) = jax.lax.scan(group_body, x, head)
    x, sts_t = jax.lax.scan(mamba_step, x, tail)
    flat = lambda a: a.reshape((grouped,) + a.shape[2:])
    caches = {
        "ssd": jnp.concatenate([flat(sts_h["ssd"]), sts_t["ssd"]]),
        "conv": jnp.concatenate([flat(sts_h["conv"]), sts_t["conv"]]),
        "attn_k": ak, "attn_v": av,
    }
    return x, caches


def _whisper_prefill_dec(params, tokens, enc, cfg, impl, schedule):
    b, s = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg)
    x = x + sinusoid_pos(s, cfg.d_model)[None].astype(x.dtype)
    pos_arr = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    es = enc.shape[1]
    pad = _enc_pad(cfg) - es
    enc_p = jnp.pad(enc, ((0, 0), (0, pad), (0, 0))) if pad else enc

    def body(c, lp):
        h = L.norm(c, lp["ln1"], cfg)
        a, sk, sv = _attn_with_cache(h, lp["attn"], cfg, pos_arr, 0,
                                     cfg.rope_theta, impl, schedule)
        c = c + a
        h = L.norm(c, lp["ln2"], cfg)
        c = c + attention_full(h, lp["cross"], cfg, pos_arr, 0,
                               cfg.rope_theta, impl=impl, schedule=schedule,
                               kv_x=enc_p, kv_valid=es)
        ck = L._split_heads(L.dot(enc, lp["cross"]["wk"], cfg),
                            cfg.n_kv_heads).astype(jnp.bfloat16)
        cv = L._split_heads(L.dot(enc, lp["cross"]["wv"], cfg),
                            cfg.n_kv_heads).astype(jnp.bfloat16)
        h = L.norm(c, lp["ln3"], cfg)
        return c + L.mlp(h, lp["mlp"], cfg), (sk, sv, ck, cv)

    x, (sk, sv, ck, cv) = jax.lax.scan(body, x, params["layers"])
    return x, {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
