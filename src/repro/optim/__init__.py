from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.optim.compress import (
    compress_int8, decompress_int8, error_feedback_update,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm",
    "cosine_schedule",
    "compress_int8", "decompress_int8", "error_feedback_update",
]
