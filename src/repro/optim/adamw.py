"""AdamW with decoupled weight decay, global-norm clipping, f32 state.

Pure-pytree implementation (no optax dependency in this image).  The
optimizer state inherits each parameter's sharding (same logical axes), so
FSDP-sharded params get FSDP-sharded moments for free under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # schedule hook: step -> multiplier (see schedule.cosine_schedule)
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig,
                 lr_schedule: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (params', state', metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = cfg.lr * (lr_schedule(step) if lr_schedule is not None else 1.0)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
