"""Int8 error-feedback gradient compression for DCN-crossing reductions.

At 1000+ node scale the cross-pod (DCN) all-reduce of bf16/f32 gradients is
the bottleneck collective.  We quantise each gradient leaf to int8 with a
per-leaf scale before the pod-axis reduction and keep the quantisation error
as residual state added back next step (error feedback => unbiased in the
long run, standard 1-bit/8-bit Adam trick).

Used by ``runtime/loop.py`` when ``compress_dcn=True``: the grad tree is
quantised, ``jax.lax.psum`` over the ``pod`` axis runs on int32 accumulators
(exact), and the result is rescaled.  4x fewer bytes over DCN than f32.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(grad: jnp.ndarray, residual: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantise ``grad + residual``; return (q, scale, new_residual)."""
    target = grad.astype(jnp.float32) + residual
    q, scale = compress_int8(target)
    new_residual = target - decompress_int8(q, scale)
    return q, scale, new_residual


def psum_compressed(grads: Any, residuals: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    int8 payload is summed in int32 (exact for <=2^23 shards), then rescaled
    by the max scale across the axis so every shard decodes identically.
    """
    def one(g, r):
        q, scale, new_r = error_feedback_update(g, r)
        # All shards must agree on a scale: use the axis max, re-quantise.
        gscale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round((g.astype(jnp.float32) + r) / gscale),
                     -127, 127).astype(jnp.int8)
        new_r = g.astype(jnp.float32) + r - q.astype(jnp.float32) * gscale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * gscale / n).astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
