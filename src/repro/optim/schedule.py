"""Learning-rate schedules (step -> multiplier in [0, 1])."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(warmup: int, total_steps: int, min_frac: float = 0.1):
    """Linear warmup then cosine decay to ``min_frac``."""
    def fn(step: jnp.ndarray) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = (step - warmup) / jnp.maximum(total_steps - warmup, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn
