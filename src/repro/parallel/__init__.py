from repro.parallel.sharding import (
    ShardingRules,
    default_rules,
    logical_spec,
    constrain,
    set_rules,
    get_rules,
)

__all__ = [
    "ShardingRules",
    "default_rules",
    "logical_spec",
    "constrain",
    "set_rules",
    "get_rules",
]
