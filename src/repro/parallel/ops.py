"""SPMD-friendly op variants.

``jax.lax.top_k`` lowers to a TopK custom-call that the SPMD partitioner
treats as opaque: every operand is ALL-GATHERED to full global shape first.
Measured on the ged-verify dry-run cell (32768 pairs, top_k inside the
search loop): 494 TB of all-gather traffic per device — 98% of the cell's
collective bytes — for an op that is mathematically per-row.

``top_k_sorted`` uses argsort + take_along_axis instead: ``sort`` HLO is
batch-partitionable, and the gather carries explicit batch dims, so the
batch dimension stays sharded.  For the small k (<=8) and rows (<=4096)
used here the sort costs the same MXU-free VPU pass the custom-call would.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax.numpy as jnp


def shard_map(f: Optional[Callable] = None, *, mesh, in_specs, out_specs,
              check: bool = False):
    """Version-portable ``shard_map`` (usable bare or as a decorator factory).

    Newer jax exposes ``jax.shard_map`` (replication check flag spelled
    ``check_vma``); this jaxlib only has ``jax.experimental.shard_map``
    (spelled ``check_rep``).  Resolve whichever exists and translate the
    ``check`` flag, so callers never touch the moving API surface.
    """
    import inspect

    import jax
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    flag = "check_vma" if "check_vma" in params else "check_rep"

    def wrap(fn: Callable) -> Callable:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **{flag: check})

    return wrap if f is None else wrap(f)


def top_k_sorted(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Largest-k along the last axis. Drop-in for ``jax.lax.top_k``.

    One variadic ``lax.sort`` carrying (keys, iota) — no gather in the
    forward, vmap/SPMD transparent.  NOTE: this jaxlib's sort *transpose*
    (like its batched-gather transpose) is broken, so don't differentiate
    through the returned values; the MoE router instead takes
    ``stop_gradient`` ids and re-reads weights via a one-hot einsum
    (``models/moe.py``) — gradient-correct and gather-free.
    """
    import jax
    n = x.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), x.shape)
    neg_sorted, order = jax.lax.sort((-x, idx), num_keys=1, dimension=-1)
    return -neg_sorted[..., :k], order[..., :k]
