"""SPMD-friendly op variants.

``jax.lax.top_k`` lowers to a TopK custom-call that the SPMD partitioner
treats as opaque: every operand is ALL-GATHERED to full global shape first.
Measured on the ged-verify dry-run cell (32768 pairs, when top_k still ran
inside the search loop): 494 TB of all-gather traffic per device — 98% of
the cell's collective bytes — for an op that is mathematically per-row.

``top_k_sorted`` uses a variadic sort + gather instead: ``sort`` HLO is
batch-partitionable, and the gather carries explicit batch dims, so the
batch dimension stays sharded.  The MoE router still pops through it; the
GED search loop no longer needs *any* per-iteration pool-sized sort — its
pool is kept key-sorted, pop is a slice, and :func:`merge_sorted_topk`
(below) folds freshly sorted children in with two binary-search rank
passes (see ``core/engine/search.py`` and ``docs/kernels.md``).
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp


def shard_map(f: Optional[Callable] = None, *, mesh, in_specs, out_specs,
              check: bool = False):
    """Version-portable ``shard_map`` (usable bare or as a decorator factory).

    Newer jax exposes ``jax.shard_map`` (replication check flag spelled
    ``check_vma``); this jaxlib only has ``jax.experimental.shard_map``
    (spelled ``check_rep``).  Resolve whichever exists and translate the
    ``check`` flag, so callers never touch the moving API surface.
    """
    import inspect

    import jax
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    flag = "check_vma" if "check_vma" in params else "check_rep"

    def wrap(fn: Callable) -> Callable:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **{flag: check})

    return wrap if f is None else wrap(f)


def top_k_sorted(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Largest-k along the last axis. Drop-in for ``jax.lax.top_k``.

    One variadic ``lax.sort`` carrying (keys, iota) — no gather in the
    forward, vmap/SPMD transparent.  NOTE: this jaxlib's sort *transpose*
    (like its batched-gather transpose) is broken, so don't differentiate
    through the returned values; the MoE router instead takes
    ``stop_gradient`` ids and re-reads weights via a one-hot einsum
    (``models/moe.py``) — gradient-correct and gather-free.
    """
    import jax
    n = x.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), x.shape)
    neg_sorted, order = jax.lax.sort((-x, idx), num_keys=1, dimension=-1)
    return -neg_sorted[..., :k], order[..., :k]


def sort_by_key(keys: jnp.ndarray, payload: Any
                ) -> Tuple[jnp.ndarray, Any]:
    """Stable ascending sort of ``keys`` (1-D) carrying a payload pytree.

    The permutation comes from one variadic ``lax.sort`` over
    ``(keys, iota)`` — stable (equal keys keep their input order),
    batch-partitionable, and gather-free in the key pass; payload leaves
    (any trailing shape, leading axis = ``len(keys)``) are gathered once.
    """
    import jax
    n = keys.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    keys_sorted, order = jax.lax.sort((keys, iota), num_keys=1, dimension=-1)
    return keys_sorted, jax.tree.map(lambda x: x[order], payload)


def merge_sorted_topk(
    keys_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    payload_a: Any,
    payload_b: Any,
    keep: int,
    drop_a: Optional[jnp.ndarray] = None,
    drop_b: Optional[jnp.ndarray] = None,
    perm_b: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Merge two key-sorted runs, keep the smallest ``keep``, no argsort.

    The search loop's frontier-maintenance primitive (the sorted-pool
    invariant): run A is the surviving pool — already sorted from the
    previous merge — and run B is the freshly sorted child batch.  Rather
    than re-sorting all ``len(A) + len(B)`` keys every iteration, each
    element's merged rank is its own index plus its binary-search position
    in the *other* run (the merge-path rank trick):

        rank_a[i] = i + |{j : keys_b[j] <  keys_a[i]}|   (ties: A first)
        rank_b[j] = j + |{i : keys_a[i] <= keys_b[j]}|

    which is a stable merge — identical ordering to a stable sort of
    ``concat(A, B)`` — at ``O((|A|+|B|) log)`` binary-search cost instead
    of a full ``O((|A|+|B|) log(|A|+|B|))`` sort network.  Elements with
    rank >= ``keep`` are dropped; the returned scalar is the minimum of
    their ``drop_*`` values (``+inf`` when nothing was dropped), which is
    how the engine tracks the dropped-lower-bound floor its exactness
    certificate depends on.

    ``payload_*`` are pytrees of arrays with leading axis matching their
    run's keys.  Payload rows move through one *gather* from the
    concatenated runs via a scalar source-index map — XLA lowers row
    gathers far better than row scatters (2x on the CPU backend at pool
    shapes, see the ``kernel_hotpath`` bench) and the scalar scatters
    building the map are cheap.  ``perm_b`` composes a preceding key sort
    into that map: pass ``payload_b`` (and ``drop_b``) in *pre-sort* row
    order together with the sort permutation (sorted position ``j`` came
    from row ``perm_b[j]``), and the payload skips its own sort-time
    gather entirely — the engine sorts only child *keys*.

    1-D keys only — the engine ``vmap``s this over pairs.  ``keep`` must
    not exceed ``len(A) + len(B)`` (short runs would leave zero-filled
    output rows).

    ``use_kernel=True`` computes the two rank-count passes with the
    Pallas comparison-matrix kernel (``kernels/merge_topk.py``) instead
    of binary searches — same integer ranks (the kernel counts exactly
    the searchsorted left/right semantics), so the output is
    bit-identical; everything downstream (scatters, payload gather,
    floor) is shared.
    """
    import jax
    na, nb = keys_a.shape[0], keys_b.shape[0]

    if use_kernel:
        from repro.kernels import ops as kops
        count_a, count_b = kops.merge_ranks(keys_a, keys_b)
        rank_a = jnp.arange(na, dtype=jnp.int32) + count_a
        rank_b = jnp.arange(nb, dtype=jnp.int32) + count_b
    else:
        def rank_in(run, values, side):
            # unrolled binary search for short runs: log2(n) fused gather
            # steps beat the rolled scan's loop-carry overhead inside the
            # engine's while_loop; the rolled form wins on big runs
            method = "scan_unrolled" if run.shape[0] <= 256 else "scan"
            return jnp.searchsorted(run, values, side=side,
                                    method=method).astype(jnp.int32)

        rank_a = jnp.arange(na, dtype=jnp.int32) + rank_in(keys_b, keys_a,
                                                           "left")
        rank_b = jnp.arange(nb, dtype=jnp.int32) + rank_in(keys_a, keys_b,
                                                           "right")

    # keys land via (cheap) scalar scatters; payload rows via one gather
    keys_out = jnp.zeros((keep,), keys_a.dtype)
    keys_out = keys_out.at[rank_a].set(keys_a, mode="drop")
    keys_out = keys_out.at[rank_b].set(keys_b, mode="drop")

    row_b = jnp.arange(nb, dtype=jnp.int32) if perm_b is None \
        else perm_b.astype(jnp.int32)
    src = jnp.zeros((keep,), jnp.int32)
    src = src.at[rank_a].set(jnp.arange(na, dtype=jnp.int32), mode="drop")
    src = src.at[rank_b].set(na + row_b, mode="drop")
    payload_out = jax.tree.map(
        lambda xa, xb: jnp.concatenate([xa, xb], axis=0)[src],
        payload_a, payload_b)

    if drop_a is None:
        drop_a = keys_a
    if drop_b is None:
        drop_b = keys_b
    elif perm_b is not None:
        drop_b = drop_b[row_b]              # re-align with the sorted keys
    inf = jnp.asarray(jnp.inf, drop_a.dtype)
    dropped_min = jnp.minimum(
        jnp.min(jnp.where(rank_a >= keep, drop_a, inf), initial=jnp.inf),
        jnp.min(jnp.where(rank_b >= keep, drop_b, inf), initial=jnp.inf),
    ).astype(drop_a.dtype)
    return keys_out, payload_out, dropped_min
