"""GPipe-style pipeline parallelism over a mesh axis (the ``pod`` axis).

``pipeline_apply`` runs a stage function over ``S`` pipeline stages with
``M`` microbatches in the classic (M + S - 1)-tick schedule:

  tick t: every stage applies its layer chunk to the activation it holds,
  then ``ppermute``s the result one stage forward; stage 0 feeds
  microbatch t while t < M; the last stage emits microbatch t-(S-1).

Implemented with ``shard_map`` over the stage axis so each device holds
only its stage's parameters (leading stage dim sharded), and the boundary
transfer is a single ``collective_permute`` per tick — on a 2-pod mesh
that is exactly one DCN hop per microbatch, overlapping with the next
microbatch's compute under XLA's latency-hiding scheduler.

Bubble fraction = (S-1)/(M+S-1); callers pick M >= 4*S in practice.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.ops import shard_map


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jnp.ndarray,
                   mesh: Mesh, axis: str = "pod",
                   microbatches: int | None = None) -> jnp.ndarray:
    """Run ``y = stages(x)`` pipelined over ``mesh.shape[axis]`` stages.

    stage_fn(params_slice, act) -> act : one stage's computation.
    stage_params: pytree with leading dim = n_stages (sharded over axis).
    x: (B, ...) global batch; B % microbatches == 0.
    """
    s = mesh.shape[axis]
    m = microbatches or (4 * s)
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m

    xs = x.reshape((m, mb) + x.shape[1:])

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    other = tuple(a for a in mesh.axis_names if a != axis)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspec_params, P(None)),
        out_specs=P(None),
        check=False)
    def run(params_s, xs_rep):
        # params_s has leading dim 1 on each device (its stage's slice)
        params_local = jax.tree.map(lambda a: a[0], params_s)
        idx = jax.lax.axis_index(axis)
        n_ticks = m + s - 1
        state = jnp.zeros_like(xs_rep[0])            # activation held here
        outs = jnp.zeros_like(xs_rep)

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (while available)
            feed = xs_rep[jnp.minimum(t, m - 1)]
            state = jnp.where((idx == 0) & (t < m), feed, state)
            out = stage_fn(params_local, state)
            # emit from the last stage: tick t produces microbatch t-(s-1)
            emit = t - (s - 1)
            do_emit = (idx == s - 1) & (emit >= 0)
            outs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(emit, 0), 0),
                lambda o: o, outs)
            # shift activations one stage forward (ring; stage 0's incoming
            # value is ignored — it re-feeds from xs next tick)
            state = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % s) for i in range(s)])
            return state, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (state, outs))
        # every stage computed an ``outs``; only the last stage's is real.
        # psum after masking so the replicated output is consistent.
        outs = jnp.where(idx == s - 1, outs, 0)
        outs = jax.lax.psum(outs, axis)
        if other:
            pass  # other axes untouched: fn runs identically per shard
        return outs

    ys = run(stage_params, xs)
    return ys.reshape((b,) + x.shape[1:])


def stack_stages(layer_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer tree -> (S, L/S, ...) stage-major tree."""
    def f(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(f, layer_params)
