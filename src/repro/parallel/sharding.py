"""Logical-axis sharding rules -> PartitionSpecs.

Model code annotates tensors with *logical* axis names ("batch", "seq",
"embed", "heads", "ff", "vocab", "expert", "kv_seq", ...).  A ``ShardingRules``
table maps logical names to mesh axes; rules degrade per-tensor: a logical
axis whose size does not divide the mapped mesh axes is silently replicated
(e.g. gemma3's 4 attention heads on a 16-way model axis), so a single rule
set serves every architecture.

Rules are installed per-launch (a plain module global — launches are single
threaded) and read at trace time by ``constrain``/``logical_spec``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    table: Dict[str, AxisVal]

    def mesh_size(self, axis: AxisVal) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            axis = (axis,)
        size = 1
        for a in axis:
            size *= self.mesh.shape[a]
        return size


def default_rules(mesh: Mesh, fsdp: bool = True) -> ShardingRules:
    names = mesh.axis_names
    batch_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in names)
    table: Dict[str, AxisVal] = {
        "batch": batch_axes or None,
        "pairs": batch_axes or None,      # GED verification pairs
        "seq": None,
        "act_seq": "model",               # sequence-parallel activations
        "kv_seq": "model",                # decode KV cache sequence sharding
        "embed": ("data" if (fsdp and "data" in names) else None),
        "heads": "model",
        "qkv_flat": "model",              # flattened (H*hd) projections
        "ff": "model",
        "vocab": "model",
        "expert": "model",
        "conv": None,
        "state": None,
        "stage": ("pod" if "pod" in names else None),
    }
    return ShardingRules(mesh, table)


_RULES: Optional[ShardingRules] = None


def set_rules(rules: Optional[ShardingRules]) -> None:
    global _RULES
    _RULES = rules


def get_rules() -> Optional[ShardingRules]:
    return _RULES


def logical_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for ``shape`` with logical ``axes`` (None = replicated).

    Degrades to replication per-dimension when the dim does not divide the
    mapped mesh axes.
    """
    rules = rules or _RULES
    if rules is None:
        return P()
    spec = []
    for dim, name in zip(shape, axes):
        if name is None:
            spec.append(None)
            continue
        mapped = rules.table.get(name)
        if mapped is None:
            spec.append(None)
            continue
        if dim % rules.mesh_size(mapped) != 0:
            spec.append(None)  # degrade: replicate this dim
        else:
            spec.append(mapped)
    return P(*spec)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names (no-op w/o rules)."""
    rules = _RULES
    if rules is None:
        return x
    spec = logical_spec(x.shape, axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def named_sharding(rules: ShardingRules, shape: Sequence[int],
                   axes: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(rules.mesh, logical_spec(shape, axes, rules))


def pairs_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry GED verification pairs (the ``"pairs"`` logical
    axis of :func:`default_rules`): ``pod`` x ``data`` on production meshes,
    the first axis of an unnamed-convention mesh.  The sharded GED executor
    (``repro.ged.exec.ShardedExecutor``) shards pair batches over exactly
    these axes."""
    mapped = default_rules(mesh).table.get("pairs")
    if isinstance(mapped, str):
        return (mapped,)
    return tuple(mapped) if mapped else (mesh.axis_names[0],)
