from repro.runtime.loop import FaultInjector, SimulatedFault, train_loop
from repro.runtime.scheduler import GedScheduler, difficulty

__all__ = ["FaultInjector", "SimulatedFault", "train_loop",
           "GedScheduler", "difficulty"]
