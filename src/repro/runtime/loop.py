"""Fault-tolerant step loop: checkpoint/restart with exact replay.

The data pipeline is deterministic-by-step (``repro.data.tokens``), so a
restart from step k replays the exact same batches — loss curves across a
failure are bit-identical to an uninterrupted run (asserted in
``tests/test_runtime.py``).

``FaultInjector`` simulates node failures: raise ``SimulatedFault`` at
configured steps (or via ``REPRO_FAULT_STEPS=7,13``), as a stand-in for a
real preemption/ICI-failure signal.  On any fault the loop restores the
last committed checkpoint, rewinds the pipeline, and continues; repeated
faults at the same step are bounded by ``max_restarts``.
"""

from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class SimulatedFault(RuntimeError):
    pass


class FaultInjector:
    def __init__(self, fail_at: Optional[Iterable[int]] = None,
                 env: str = "REPRO_FAULT_STEPS"):
        if fail_at is None:
            raw = os.environ.get(env, "")
            fail_at = [int(x) for x in raw.split(",") if x.strip()]
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


def train_loop(
    step_fn: Callable,                  # (state, batch) -> (state, metrics)
    state: Any,
    make_pipeline: Callable[[int], Any],  # start_step -> iterator of batches
    ckpt: CheckpointManager,
    total_steps: int,
    ckpt_every: int = 50,
    injector: Optional[FaultInjector] = None,
    state_shardings: Optional[Any] = None,
    max_restarts: int = 8,
    log_every: int = 10,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Tuple[Any, List[Dict]]:
    """Run ``total_steps`` with checkpoint/restart. Returns (state, history)."""
    injector = injector or FaultInjector([])
    history: List[Dict] = []
    restarts = 0

    start = ckpt.latest_step() or 0
    if start:
        _, state, _ = ckpt.restore(state, step=start,
                                   shardings=state_shardings)
    step = start
    pipeline = make_pipeline(step)

    while step < total_steps:
        try:
            batch = next(pipeline)
            injector.maybe_fail(step)
            state, metrics = step_fn(state, batch)
            step += 1
            if step % log_every == 0 or step == total_steps:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                history.append(m)
                if on_metrics:
                    on_metrics(step, m)
            if step % ckpt_every == 0 or step == total_steps:
                ckpt.save(step, state)
        except SimulatedFault as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts") from e
            ckpt.wait()
            restore_to = ckpt.latest_step() or 0
            if restore_to:
                _, state, _ = ckpt.restore(state, step=restore_to,
                                           shardings=state_shardings)
            else:
                raise RuntimeError(
                    "fault before first checkpoint; cannot recover") from e
            if hasattr(pipeline, "close"):
                pipeline.close()
            step = restore_to
            # drop metrics from the rolled-back region: replay re-logs them
            history = [h for h in history if h["step"] <= restore_to]
            pipeline = make_pipeline(step)        # exact replay
    ckpt.wait()
    if hasattr(pipeline, "close"):
        pipeline.close()
    return state, history
