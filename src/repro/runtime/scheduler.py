"""Straggler-aware scheduling for GED workloads.

GED pairs have wildly variable difficulty (the paper's own TLE phenomenon:
one pair can take 10^4x another at the same |V|).  In a lockstep batched
engine the slowest pair in a batch sets the batch's wall time, so naive
batching wastes the whole mesh on a handful of stragglers.

Mitigation, in order:
  1. **cost model** — ``difficulty()`` predicts search effort from |V|,
     edge density, label diversity and the threshold margin;
  2. **LPT packing** — pairs are sorted by predicted difficulty and packed
     longest-processing-time-first into batches with equalised predicted
     work, so batch wall-times are balanced and easy batches use small
     ``max_iters`` budgets;
  3. **escalation** — pairs whose result is not certified exact
     (pool overflow / iteration cap) are re-queued with a bigger pool;
     the final rung is the exact host solver (``repro.core.exact``),
     mirroring the paper's guidance that AStar+-BMa handles the heavy
     tail while trivial pairs should never pay for it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


def _entropy(labels: Sequence[int]) -> float:
    vals, counts = np.unique(np.asarray(labels), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log(p + 1e-12)).sum())


def difficulty(n_q: int, n_g: int, m_q: int, m_g: int,
               vlabels_q: Sequence[int], vlabels_g: Sequence[int],
               tau: Optional[float] = None) -> float:
    """Predicted search effort for one pair (arbitrary units).

    * branching grows with |V(g)|; depth with |V(q)| -> n_g ** ~sqrt scaling
      captured as n_q * n_g;
    * dense graphs make bounds looser (more edge interactions): x (1 + density);
    * low label diversity makes bounds looser: / (1 + H_v);
    * verification with small tau prunes hard: x sigmoid(tau - |size diff|).
    """
    n_q, n_g = min(n_q, n_g), max(n_q, n_g)
    density = (m_q + m_g) / max(n_q + n_g, 1)
    h = _entropy(list(vlabels_q) + list(vlabels_g))
    base = n_q * n_g * (1.0 + density) / (1.0 + h)
    if tau is not None:
        size_gap = abs(n_g - n_q) + abs(m_g - m_q)
        margin = tau - size_gap          # >0: can't reject cheaply
        base *= 1.0 / (1.0 + math.exp(-0.8 * margin))
    return base


@dataclasses.dataclass
class Batch:
    indices: List[int]
    predicted: float
    rung: int                      # escalation rung (0 = first attempt)


ESCALATION_RUNGS = (
    # (pool, expand, max_iters) per rung; final rung handled by host solver
    (256, 4, 128),
    (1024, 8, 512),
    (4096, 8, 2048),
)


class GedScheduler:
    """Difficulty-sorted LPT packer with escalation re-queue."""

    def __init__(self, batch_size: int, rungs=ESCALATION_RUNGS):
        self.batch_size = batch_size
        self.rungs = rungs

    def pack(self, difficulties: Sequence[float], rung: int = 0
             ) -> List[Batch]:
        """LPT: sort desc, fill the currently-lightest open batch."""
        n = len(difficulties)
        if n == 0:
            return []
        n_batches = max(1, math.ceil(n / self.batch_size))
        order = np.argsort(-np.asarray(difficulties, dtype=np.float64))
        batches = [Batch([], 0.0, rung) for _ in range(n_batches)]
        loads = np.zeros(n_batches)
        sizes = np.zeros(n_batches, dtype=int)
        for idx in order:
            open_mask = sizes < self.batch_size
            cand = np.where(open_mask)[0]
            tgt = cand[np.argmin(loads[cand])]
            batches[tgt].indices.append(int(idx))
            loads[tgt] += difficulties[idx]
            sizes[tgt] += 1
            batches[tgt].predicted = float(loads[tgt])
        return batches

    def engine_params(self, rung: int) -> Optional[Tuple[int, int, int]]:
        """(pool, expand, max_iters) for this rung; None -> host solver."""
        if rung < len(self.rungs):
            return self.rungs[rung]
        return None

    def escalate(self, batch: Batch, uncertified: Sequence[int]) -> Optional[Batch]:
        """Re-queue the pairs (by index into the batch) that failed
        certification; None when the next rung is the host solver."""
        if not uncertified:
            return None
        nxt = batch.rung + 1
        idxs = [batch.indices[i] for i in uncertified]
        if nxt >= len(self.rungs):
            return Batch(idxs, 0.0, nxt)      # caller routes to host solver
        return Batch(idxs, 0.0, nxt)
