from repro.serving.ged_service import GedVerificationService, GedRequest
from repro.serving.lm_decode import generate

__all__ = ["GedVerificationService", "GedRequest", "generate"]
