from repro.serving.ged_service import (GedRequest, GedSimilarityService,
                                       GedVerificationService, SearchRequest)
from repro.serving.lm_decode import generate

__all__ = ["GedVerificationService", "GedSimilarityService", "GedRequest",
           "SearchRequest", "generate"]
