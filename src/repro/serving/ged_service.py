"""Batched GED verification service — the paper's §5.3 workload as a
production server.

Request: (q, g, tau) -> "is delta(q, g) <= tau?", certified.

Pipeline per flush:
  1. predict per-pair difficulty (``runtime.scheduler.difficulty``),
  2. LPT-pack into equalised batches (straggler mitigation),
  3. run the batched AStar+-hybrid engine (``core.engine.api.verify_batch``)
     — data-parallel over every mesh axis at scale,
  4. escalate pairs whose answer is not *certified* (pool overflow /
     iteration cap) through bigger-pool rungs,
  5. final rung: the exact host solver (``core.exact``) — the paper-faithful
     AStar+-BMa — so every answer the service returns is exact.

The same object serves GED *computation* via ``compute()`` (incumbent
initialised to +inf instead of tau — identical engine, per the unified
framework).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine.api import ged_batch, verify_batch
from repro.core.engine.search import EngineConfig
from repro.core.engine.tensor_graphs import pack_pairs
from repro.core.exact.graph import Graph
from repro.core.exact.search import ged as exact_ged, ged_verify
from repro.runtime.scheduler import GedScheduler, difficulty


@dataclasses.dataclass
class GedRequest:
    q: Graph
    g: Graph
    tau: float = 0.0


@dataclasses.dataclass
class GedResult:
    similar: Optional[bool]      # verification answer (None for compute)
    ged: Optional[float]         # exact GED when computed
    certified: bool
    rung: int                    # 0.. engine rungs, -1 = host solver
    wall_s: float


class GedVerificationService:
    def __init__(self, batch_size: int = 256, slots: int = 32,
                 strategy: str = "astar", bound: str = "hybrid",
                 use_kernel: bool = False):
        self.scheduler = GedScheduler(batch_size)
        self.slots = slots
        self.strategy = strategy
        self.bound = bound
        self.use_kernel = use_kernel
        self.stats: Dict[str, float] = {"pairs": 0, "escalated": 0,
                                        "host_solved": 0, "batches": 0}

    # ------------------------------------------------------------ public

    def verify(self, requests: Sequence[GedRequest]) -> List[GedResult]:
        return self._run(requests, verification=True)

    def compute(self, pairs: Sequence[Tuple[Graph, Graph]]
                ) -> List[GedResult]:
        reqs = [GedRequest(q, g, 0.0) for q, g in pairs]
        return self._run(reqs, verification=False)

    # ---------------------------------------------------------- internal

    def _difficulties(self, reqs: Sequence[GedRequest], verification: bool
                      ) -> List[float]:
        out = []
        for r in reqs:
            out.append(difficulty(
                r.q.n, r.g.n, r.q.m, r.g.m, r.q.vlabels, r.g.vlabels,
                tau=r.tau if verification else None))
        return out

    def _engine_cfg(self, rung: int) -> Optional[EngineConfig]:
        params = self.scheduler.engine_params(rung)
        if params is None:
            return None
        pool, expand, max_iters = params
        return EngineConfig(pool=pool, expand=expand, max_iters=max_iters,
                            bound=self.bound, strategy=self.strategy,
                            use_kernel=self.use_kernel)

    def _run(self, reqs: Sequence[GedRequest], verification: bool
             ) -> List[GedResult]:
        t0 = time.time()
        results: List[Optional[GedResult]] = [None] * len(reqs)
        diffs = self._difficulties(reqs, verification)
        queue = self.scheduler.pack(diffs, rung=0)
        self.stats["pairs"] += len(reqs)

        while queue:
            batch = queue.pop(0)
            self.stats["batches"] += 1
            cfg = self._engine_cfg(batch.rung)
            if cfg is None:
                # final rung: exact host solver (paper-faithful AStar+-BMa)
                for gi in batch.indices:
                    r = reqs[gi]
                    self.stats["host_solved"] += 1
                    if verification:
                        res = ged_verify(r.q, r.g, r.tau, bound="BMa",
                                         strategy=self.strategy)
                        results[gi] = GedResult(
                            similar=bool(res.similar), ged=None,
                            certified=True, rung=-1,
                            wall_s=time.time() - t0)
                    else:
                        res = exact_ged(r.q, r.g, bound="BMa",
                                        strategy=self.strategy)
                        results[gi] = GedResult(
                            similar=None, ged=float(res.ged),
                            certified=True, rung=-1,
                            wall_s=time.time() - t0)
                continue

            pairs = [(reqs[gi].q, reqs[gi].g) for gi in batch.indices]
            packed = pack_pairs(pairs, slots=self.slots)
            if verification:
                taus = [reqs[gi].tau for gi in batch.indices]
                out = verify_batch(packed, taus, cfg)
                certified = out["exact"]
                answer = out["similar"]
            else:
                out = ged_batch(packed, cfg)
                certified = out["exact"]
                answer = out["ged"]

            uncertified = []
            for bi, gi in enumerate(batch.indices):
                if bool(certified[bi]):
                    results[gi] = GedResult(
                        similar=bool(answer[bi]) if verification else None,
                        ged=None if verification else float(answer[bi]),
                        certified=True, rung=batch.rung,
                        wall_s=time.time() - t0)
                else:
                    uncertified.append(bi)
            if uncertified:
                self.stats["escalated"] += len(uncertified)
                nxt = self.scheduler.escalate(batch, uncertified)
                if nxt is not None:
                    queue.append(nxt)
        return results  # type: ignore[return-value]
