"""GED serving: pairwise verification and corpus similarity search.

Two services over the ``repro.ged`` facade:

* :class:`GedVerificationService` — request/response wrapper for
  (q, g, tau) -> "is delta(q, g) <= tau?", certified, over
  ``GedEngine(backend="auto")`` (difficulty prediction, LPT straggler
  packing, batched AStar+-hybrid engine, escalation rungs, exact host
  solver as the final rung).  It rides the overlapped (async-dispatch)
  rung path — pass ``mesh=`` to shard every rung over a device mesh,
  ``overlap=False`` for the sequential loop.  Once a corpus is
  registered (:meth:`~GedVerificationService.register_corpus`), batch
  verification requests whose target graph lives in the corpus route
  through the :class:`~repro.ged.GraphStore` filter pipeline — resident
  stage-0 bounds plus the stage-1 engine-bound pass decide most pairs
  before full verification runs.
* :class:`GedSimilarityService` — the corpus-search route: ingest a
  database once, then serve ``range_search`` / ``top_k`` /
  ``search_batch`` requests returning ranked
  :class:`~repro.ged.SearchHit` lists (see ``docs/search.md``).

Duplicate requests — the common case for similarity-search traffic —
are deduplicated by the engine's result cache (tau-aware), so repeats
cost a hash lookup, not a search.
``GedResult`` aliases ``GedOutcome`` for *readers* of the old result
type (the ``similar``/``ged``/``certified``/``rung``/``wall_s`` fields
survive); code that *constructed* ``GedResult`` must switch to
``GedOutcome``'s richer signature.

Both services sit behind an :class:`AdmissionController`: a bounded
pending-work budget that sheds excess load with
:class:`repro.ged.Overloaded` (carrying a ``retry_after_s`` hint)
*before* any engine work runs, and a :meth:`~GedVerificationService.
health` surface reporting queue depth, shed count and p50/p99 request
wall time — see ``docs/robustness.md``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.exact.graph import Graph
from repro.ged import GedEngine, GedOutcome, GraphStore, SearchHit, as_graph
from repro.ged.exec import graph_digest
from repro.ged.faults import Overloaded

GedResult = GedOutcome  # read-compatible alias (see module docstring)


@dataclasses.dataclass
class GedRequest:
    """One verification/compute request.  ``deadline_s`` caps this
    request's share of engine wall time (anytime contract: on expiry the
    outcome still carries admissible bounds, ``certified=False``)."""

    q: Graph
    g: Graph
    tau: float = 0.0
    deadline_s: Optional[float] = None


class AdmissionController:
    """Bounded admission for a serving endpoint.

    Tracks pairs currently being answered; a batch that would push the
    pending count past ``capacity`` is shed with :class:`Overloaded`
    *before* any engine work starts — except when the service is idle,
    where an oversized batch is admitted whole rather than being
    undeliverable at any load (capacity bounds *queueing*, not request
    size).  Completed requests feed a bounded window of wall times for
    the p50/p99 health quantiles; ``retry_after_s`` is estimated from
    the recent p50 per-pair service time.

    >>> ac = AdmissionController(capacity=4)
    >>> with ac.admit(3): pass                    # 3 pairs, fits
    >>> with ac.admit(100): pass                  # oversized but idle: ok
    >>> ac.shed
    0
    """

    def __init__(self, capacity: int = 1024, window: int = 256):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self.pending = 0
        self.shed = 0
        self.admitted = 0
        self._walls: Deque[float] = collections.deque(maxlen=int(window))
        self._pair_s = 0.0          # EWMA seconds per pair, for retry hint

    def admit(self, n_pairs: int):
        """Context manager guarding ``n_pairs`` of engine work; raises
        :class:`Overloaded` when the budget is exhausted."""
        return _Admission(self, max(int(n_pairs), 1))

    def _try_enter(self, n: int) -> None:
        with self._lock:
            if self.pending > 0 and self.pending + n > self.capacity:
                self.shed += 1
                retry = max(self._pair_s, 1e-3) * max(self.pending, 1)
                raise Overloaded(min(retry, 30.0), self.pending,
                                 self.capacity)
            self.pending += n
            self.admitted += 1

    def _leave(self, n: int, wall_s: float) -> None:
        with self._lock:
            self.pending = max(self.pending - n, 0)
            self._walls.append(wall_s)
            per_pair = wall_s / n
            self._pair_s = (per_pair if self._pair_s == 0.0
                            else 0.8 * self._pair_s + 0.2 * per_pair)

    def _quantile(self, q: float) -> float:
        walls = sorted(self._walls)
        if not walls:
            return 0.0
        return walls[min(int(q * len(walls)), len(walls) - 1)]

    @property
    def health(self) -> Dict[str, float]:
        with self._lock:
            return {
                "queue_depth": float(self.pending),
                "capacity": float(self.capacity),
                "shed": float(self.shed),
                "admitted": float(self.admitted),
                "p50_wall_s": self._quantile(0.50),
                "p99_wall_s": self._quantile(0.99),
            }


class _Admission:
    def __init__(self, controller: AdmissionController, n: int):
        self._c, self._n = controller, n

    def __enter__(self):
        self._c._try_enter(self._n)
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self._c._leave(self._n, time.monotonic() - self._t0)
        return False


@dataclasses.dataclass
class SearchRequest:
    """One corpus-similarity query: range search (``tau``) or ``k``-NN."""

    query: object                # anything ``repro.ged.as_graph`` accepts
    tau: Optional[float] = None  # range search threshold
    k: Optional[int] = None      # top-k (exclusive with tau)


class GedVerificationService:
    """Request/response wrapper over the escalating ``auto`` engine.

    Rides the overlapped (async-dispatch) rung path by default; pass
    ``mesh=`` to run every rung's batches sharded over a device mesh, or
    ``overlap=False`` to force the sequential rung loop.  Example::

        svc = GedVerificationService(batch_size=128,
                                     mesh=jax.make_mesh((8,), ("data",)))
        outs = svc.verify([GedRequest(q, g, tau=4.0), ...])

    With a registered corpus, batch verification against known graphs
    goes through the store's staged filter first::

        svc.register_corpus(db_graphs)
        outs = svc.verify(reqs)     # in-corpus targets: filter-then-verify
    """

    def __init__(self, batch_size: int = 256, slots: int = 32,
                 strategy: str = "astar", bound: str = "hybrid",
                 use_kernel: bool = False, cache_size: int = 4096,
                 mesh=None, overlap: bool = True, capacity: int = 1024,
                 deadline_s: Optional[float] = None):
        self.engine = GedEngine(
            backend="auto", slots=slots, batch_size=batch_size,
            strategy=strategy, bound=bound, use_kernel=use_kernel,
            cache_size=cache_size, mesh=mesh, overlap=overlap,
            deadline_s=deadline_s)
        # exposed for tests/tuning: mutating ``scheduler.rungs`` reshapes
        # the escalation ladder of the underlying auto backend.
        self.scheduler = self.engine._backend.scheduler
        self.store: Optional[GraphStore] = None
        self.admission = AdmissionController(capacity=capacity)

    @property
    def stats(self) -> Dict[str, float]:
        """Pipeline counters plus executor / cache hit totals (and the
        registered store's ``store_*`` counters, once a corpus exists)."""
        out = dict(self.engine.stats)
        if self.store is not None:
            out.update({f"store_{k}": v for k, v in self.store.stats.items()
                        if not k.startswith("engine_")})
        return out

    def health(self) -> Dict[str, float]:
        """Liveness snapshot: admission queue depth / shed count, p50/p99
        request wall time, and the engine's robustness counters
        (``timed_out_pairs``, ``degraded_*``, retries)."""
        out = self.admission.health
        for k in ("timed_out_pairs", "degraded_host", "degraded_kernel",
                  "retries", "shared_cache_lock_timeouts"):
            out[k] = float(self.engine.stats.get(k, 0.0))
        return out

    # ------------------------------------------------------------ public

    def register_corpus(self, graphs=None, *, store_dir: Optional[str]
                        = None, **store_options) -> GraphStore:
        """Ingest a corpus; later batch verification against its members
        routes through the store's filter-verify pipeline.

        ``store_dir=`` warm-starts instead of ingesting: the persisted
        store (:meth:`repro.ged.GraphStore.save`) is reopened with its
        own snapshot-recorded knobs — so ``store_options`` must stay
        empty — and ``graphs`` becomes the optional rebuild fallback for
        a corrupted snapshot.

        Either way the store shares this service's engine — and
        therefore its result cache, compile cache and executor (mesh
        placement included; the candidate index's pivot distances live
        in that shared result cache) — so ``store_options`` may only
        carry store-level knobs (``digest``, ``filter_iters``,
        ``filter_pool``, ``vocab``, ``index``); engine-level options
        raise.  Returns the store for direct ``range_search`` /
        ``top_k`` use.
        """
        if store_dir is not None:
            if store_options:
                raise TypeError(
                    f"store_dir= restores store options from the "
                    f"snapshot; got {sorted(store_options)}")
            self.store = GraphStore.open(store_dir, engine=self.engine,
                                         graphs=graphs)
            return self.store
        if graphs is None:
            raise TypeError("register_corpus needs graphs or store_dir=")
        # GedEngine slots are pinned for the serving batch shape; the
        # store's stage-1 buckets pack through the same engine config.
        self.store = GraphStore(graphs, engine=self.engine,
                                **store_options)
        return self.store

    def verify(self, requests: Sequence[GedRequest]) -> List[GedOutcome]:
        """Answer a batch of verification requests.

        Sheds the whole batch with :class:`repro.ged.Overloaded` when the
        admission budget is exhausted (see :attr:`admission`).  Requests
        carrying ``deadline_s`` take the direct engine path with the
        deadline propagated — the store's filter-verify route has no
        deadline support, so a deadline-carrying request trades the
        corpus filter's pruning for a hard latency cap.
        """
        with self.admission.admit(len(requests)):
            return self._verify_admitted(requests)

    def _verify_admitted(self, requests: Sequence[GedRequest]
                         ) -> List[GedOutcome]:
        results: List[Optional[GedOutcome]] = [None] * len(requests)
        # Deadline-carrying requests bypass store routing (see verify);
        # group them by budget so one engine call shares one Deadline.
        deadlines: Dict[float, List[int]] = {}
        rest: List[int] = []
        for i, r in enumerate(requests):
            if r.deadline_s is not None:
                deadlines.setdefault(float(r.deadline_s), []).append(i)
            else:
                rest.append(i)
        for budget, idxs in deadlines.items():
            outs = self.engine.verify(
                [(requests[i].q, requests[i].g) for i in idxs],
                [requests[i].tau for i in idxs], deadline_s=budget)
            for i, o in zip(idxs, outs):
                results[i] = o
        if rest and self.store is None:
            outs = self.engine.verify(
                [(requests[i].q, requests[i].g) for i in rest],
                [requests[i].tau for i in rest])
            for i, o in zip(rest, outs):
                results[i] = o
            return results  # type: ignore[return-value]
        # Route in-corpus targets through the staged filter; everything
        # else takes the plain engine path.  Matching and query grouping
        # are byte-exact (graph_digest): a merely-isomorphic rewrite must
        # not be answered with another graph's outcome or mapping.
        in_store: Dict[bytes, List[int]] = {}
        direct: List[int] = []
        member: Dict[int, int] = {}
        for i in rest:
            r = requests[i]
            gid = self.store.member_id(r.g)
            if gid is None:
                direct.append(i)
            else:
                member[i] = gid
                in_store.setdefault(graph_digest(as_graph(r.q)),
                                    []).append(i)
        for idxs in in_store.values():
            outs = self.store.verify_members(
                requests[idxs[0]].q, [member[i] for i in idxs],
                [requests[i].tau for i in idxs])
            for i, o in zip(idxs, outs):
                results[i] = o
        if direct:
            outs = self.engine.verify(
                [(requests[i].q, requests[i].g) for i in direct],
                [requests[i].tau for i in direct])
            for i, o in zip(direct, outs):
                results[i] = o
        return results  # type: ignore[return-value]

    def compute(self, pairs: Sequence[Tuple[Graph, Graph]],
                deadline_s: Optional[float] = None) -> List[GedOutcome]:
        with self.admission.admit(len(pairs)):
            return self.engine.compute(pairs, deadline_s=deadline_s)


class GedSimilarityService:
    """Corpus similarity search as a request/response service.

    A thin route over :class:`repro.ged.GraphStore`: ingest the database
    at construction, then serve ranged and k-NN queries.  ``index=``
    configures the store's sublinear stage −1 candidate index
    (:class:`repro.ged.CandidateIndex`): the default ``"auto"`` builds a
    sound exact-mode index, a knob dict tunes it — ``index={"recall":
    0.95}`` trades exactness for selectivity explicitly, ``index=
    {"pivot_seeds": 4}`` pre-computes DB–DB pivot distances into the
    engine's result cache at ingest — and ``index=None`` serves with the
    plain full-scan pipeline.  Example::

        svc = GedSimilarityService(db_graphs, mesh=mesh,
                                   index={"recall": 0.95})
        hits = svc.range_search(query, tau=4.0)
        answers = svc.search([SearchRequest(q1, tau=3.0),
                              SearchRequest(q2, k=10)])

    ``store_dir=`` warm-starts serving from a persisted store
    (:meth:`repro.ged.GraphStore.save`) instead of re-ingesting —
    store-level knobs (``digest``, ``filter_iters``, ``index`` config)
    come from the snapshot, remaining keyword options configure the
    fresh engine, and ``graphs`` becomes the optional rebuild fallback
    for a corrupted snapshot::

        svc = GedSimilarityService(store_dir="/var/ged/corpus")
    """

    def __init__(self, graphs=None, *, store_dir: Optional[str] = None,
                 mesh=None, batch_size: int = 256, index="auto",
                 capacity: int = 256, **store_options):
        if store_dir is not None:
            self.store = GraphStore.open(
                store_dir, mesh=mesh, batch_size=batch_size,
                graphs=graphs, **store_options)
        elif graphs is not None:
            self.store = GraphStore(graphs, mesh=mesh,
                                    batch_size=batch_size, index=index,
                                    **store_options)
        else:
            raise TypeError(
                "GedSimilarityService needs graphs or store_dir=")
        # one admission unit per *query* (a query fans out to a corpus
        # scan, so pair-level accounting would always look oversized).
        self.admission = AdmissionController(capacity=capacity)

    @property
    def stats(self) -> Dict[str, float]:
        """The store's filter/verify counters (``docs/search.md``)."""
        return self.store.stats

    def health(self) -> Dict[str, float]:
        """Admission/latency snapshot (queue depth, shed, p50/p99 wall)
        plus the store's timed-out/degraded engine counters."""
        out = self.admission.health
        stats = self.store.stats
        for k in ("engine_timed_out_pairs", "engine_degraded_host",
                  "engine_degraded_kernel", "engine_retries"):
            out[k] = float(stats.get(k, 0.0))
        return out

    def range_search(self, query, tau: float) -> List[SearchHit]:
        with self.admission.admit(1):
            return self.store.range_search(query, tau)

    def top_k(self, query, k: int) -> List[SearchHit]:
        with self.admission.admit(1):
            return self.store.top_k(query, k)

    def search(self, requests: Sequence[SearchRequest]
               ) -> List[List[SearchHit]]:
        """Answer a mixed batch of range / top-k requests, in order.

        The whole batch is admitted (or shed with
        :class:`repro.ged.Overloaded`) as one unit of ``len(requests)``
        queries."""
        for r in requests:          # validate before any work runs
            if (r.tau is None) == (r.k is None):
                raise ValueError(
                    "SearchRequest needs exactly one of tau= or k=")
        with self.admission.admit(len(requests)):
            out: List[List[SearchHit]] = []
            for qi, r in enumerate(requests):
                hits = (self.store.range_search(r.query, r.tau)
                        if r.tau is not None else
                        self.store.top_k(r.query, r.k))
                for h in hits:
                    h.query_id = qi
                out.append(hits)
            return out
