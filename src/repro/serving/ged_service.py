"""Batched GED verification service — the paper's §5.3 workload as a
production server.

Request: (q, g, tau) -> "is delta(q, g) <= tau?", certified.

The pipeline (difficulty prediction, LPT straggler packing, batched
AStar+-hybrid engine, escalation through bigger-pool rungs, exact host
solver as the final rung) lives in ``repro.ged.backends.AutoBackend``;
this service is a thin request/response wrapper over
``repro.ged.GedEngine(backend="auto")`` and therefore rides the
overlapped (async-dispatch) rung path — pass ``mesh=`` to shard every
rung over a device mesh, ``overlap=False`` for the sequential loop.  Every answer it returns is
certified exact, and every answer is a ``repro.ged.GedOutcome``.
Duplicate requests — the common case for similarity-search traffic —
are deduplicated by the engine's result cache (tau-aware), so repeats
cost a hash lookup, not a search.
``GedResult`` aliases it for *readers* of the old result type (the
``similar``/``ged``/``certified``/``rung``/``wall_s`` fields survive);
code that *constructed* ``GedResult`` must switch to ``GedOutcome``'s
richer signature.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.exact.graph import Graph
from repro.ged import GedEngine, GedOutcome

GedResult = GedOutcome  # read-compatible alias (see module docstring)


@dataclasses.dataclass
class GedRequest:
    q: Graph
    g: Graph
    tau: float = 0.0


class GedVerificationService:
    """Request/response wrapper over the escalating ``auto`` engine.

    Rides the overlapped (async-dispatch) rung path by default; pass
    ``mesh=`` to run every rung's batches sharded over a device mesh, or
    ``overlap=False`` to force the sequential rung loop.  Example::

        svc = GedVerificationService(batch_size=128,
                                     mesh=jax.make_mesh((8,), ("data",)))
        outs = svc.verify([GedRequest(q, g, tau=4.0), ...])
    """

    def __init__(self, batch_size: int = 256, slots: int = 32,
                 strategy: str = "astar", bound: str = "hybrid",
                 use_kernel: bool = False, cache_size: int = 4096,
                 mesh=None, overlap: bool = True):
        self.engine = GedEngine(
            backend="auto", slots=slots, batch_size=batch_size,
            strategy=strategy, bound=bound, use_kernel=use_kernel,
            cache_size=cache_size, mesh=mesh, overlap=overlap)
        # exposed for tests/tuning: mutating ``scheduler.rungs`` reshapes
        # the escalation ladder of the underlying auto backend.
        self.scheduler = self.engine._backend.scheduler

    @property
    def stats(self) -> Dict[str, float]:
        """Pipeline counters plus executor / cache hit totals."""
        return self.engine.stats

    # ------------------------------------------------------------ public

    def verify(self, requests: Sequence[GedRequest]) -> List[GedOutcome]:
        return self.engine.verify([(r.q, r.g) for r in requests],
                                  [r.tau for r in requests])

    def compute(self, pairs: Sequence[Tuple[Graph, Graph]]
                ) -> List[GedOutcome]:
        return self.engine.compute(pairs)
