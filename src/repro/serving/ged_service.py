"""GED serving: pairwise verification and corpus similarity search.

Two services over the ``repro.ged`` facade:

* :class:`GedVerificationService` — request/response wrapper for
  (q, g, tau) -> "is delta(q, g) <= tau?", certified, over
  ``GedEngine(backend="auto")`` (difficulty prediction, LPT straggler
  packing, batched AStar+-hybrid engine, escalation rungs, exact host
  solver as the final rung).  It rides the overlapped (async-dispatch)
  rung path — pass ``mesh=`` to shard every rung over a device mesh,
  ``overlap=False`` for the sequential loop.  Once a corpus is
  registered (:meth:`~GedVerificationService.register_corpus`), batch
  verification requests whose target graph lives in the corpus route
  through the :class:`~repro.ged.GraphStore` filter pipeline — resident
  stage-0 bounds plus the stage-1 engine-bound pass decide most pairs
  before full verification runs.
* :class:`GedSimilarityService` — the corpus-search route: ingest a
  database once, then serve ``range_search`` / ``top_k`` /
  ``search_batch`` requests returning ranked
  :class:`~repro.ged.SearchHit` lists (see ``docs/search.md``).

Duplicate requests — the common case for similarity-search traffic —
are deduplicated by the engine's result cache (tau-aware), so repeats
cost a hash lookup, not a search.
``GedResult`` aliases ``GedOutcome`` for *readers* of the old result
type (the ``similar``/``ged``/``certified``/``rung``/``wall_s`` fields
survive); code that *constructed* ``GedResult`` must switch to
``GedOutcome``'s richer signature.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.exact.graph import Graph
from repro.ged import GedEngine, GedOutcome, GraphStore, SearchHit, as_graph
from repro.ged.exec import graph_digest

GedResult = GedOutcome  # read-compatible alias (see module docstring)


@dataclasses.dataclass
class GedRequest:
    q: Graph
    g: Graph
    tau: float = 0.0


@dataclasses.dataclass
class SearchRequest:
    """One corpus-similarity query: range search (``tau``) or ``k``-NN."""

    query: object                # anything ``repro.ged.as_graph`` accepts
    tau: Optional[float] = None  # range search threshold
    k: Optional[int] = None      # top-k (exclusive with tau)


class GedVerificationService:
    """Request/response wrapper over the escalating ``auto`` engine.

    Rides the overlapped (async-dispatch) rung path by default; pass
    ``mesh=`` to run every rung's batches sharded over a device mesh, or
    ``overlap=False`` to force the sequential rung loop.  Example::

        svc = GedVerificationService(batch_size=128,
                                     mesh=jax.make_mesh((8,), ("data",)))
        outs = svc.verify([GedRequest(q, g, tau=4.0), ...])

    With a registered corpus, batch verification against known graphs
    goes through the store's staged filter first::

        svc.register_corpus(db_graphs)
        outs = svc.verify(reqs)     # in-corpus targets: filter-then-verify
    """

    def __init__(self, batch_size: int = 256, slots: int = 32,
                 strategy: str = "astar", bound: str = "hybrid",
                 use_kernel: bool = False, cache_size: int = 4096,
                 mesh=None, overlap: bool = True):
        self.engine = GedEngine(
            backend="auto", slots=slots, batch_size=batch_size,
            strategy=strategy, bound=bound, use_kernel=use_kernel,
            cache_size=cache_size, mesh=mesh, overlap=overlap)
        # exposed for tests/tuning: mutating ``scheduler.rungs`` reshapes
        # the escalation ladder of the underlying auto backend.
        self.scheduler = self.engine._backend.scheduler
        self.store: Optional[GraphStore] = None

    @property
    def stats(self) -> Dict[str, float]:
        """Pipeline counters plus executor / cache hit totals (and the
        registered store's ``store_*`` counters, once a corpus exists)."""
        out = dict(self.engine.stats)
        if self.store is not None:
            out.update({f"store_{k}": v for k, v in self.store.stats.items()
                        if not k.startswith("engine_")})
        return out

    # ------------------------------------------------------------ public

    def register_corpus(self, graphs=None, *, store_dir: Optional[str]
                        = None, **store_options) -> GraphStore:
        """Ingest a corpus; later batch verification against its members
        routes through the store's filter-verify pipeline.

        ``store_dir=`` warm-starts instead of ingesting: the persisted
        store (:meth:`repro.ged.GraphStore.save`) is reopened with its
        own snapshot-recorded knobs — so ``store_options`` must stay
        empty — and ``graphs`` becomes the optional rebuild fallback for
        a corrupted snapshot.

        Either way the store shares this service's engine — and
        therefore its result cache, compile cache and executor (mesh
        placement included; the candidate index's pivot distances live
        in that shared result cache) — so ``store_options`` may only
        carry store-level knobs (``digest``, ``filter_iters``,
        ``filter_pool``, ``vocab``, ``index``); engine-level options
        raise.  Returns the store for direct ``range_search`` /
        ``top_k`` use.
        """
        if store_dir is not None:
            if store_options:
                raise TypeError(
                    f"store_dir= restores store options from the "
                    f"snapshot; got {sorted(store_options)}")
            self.store = GraphStore.open(store_dir, engine=self.engine,
                                         graphs=graphs)
            return self.store
        if graphs is None:
            raise TypeError("register_corpus needs graphs or store_dir=")
        # GedEngine slots are pinned for the serving batch shape; the
        # store's stage-1 buckets pack through the same engine config.
        self.store = GraphStore(graphs, engine=self.engine,
                                **store_options)
        return self.store

    def verify(self, requests: Sequence[GedRequest]) -> List[GedOutcome]:
        if self.store is None:
            return self.engine.verify([(r.q, r.g) for r in requests],
                                      [r.tau for r in requests])
        # Route in-corpus targets through the staged filter; everything
        # else takes the plain engine path.  Matching and query grouping
        # are byte-exact (graph_digest): a merely-isomorphic rewrite must
        # not be answered with another graph's outcome or mapping.
        results: List[Optional[GedOutcome]] = [None] * len(requests)
        in_store: Dict[bytes, List[int]] = {}
        direct: List[int] = []
        member: Dict[int, int] = {}
        for i, r in enumerate(requests):
            gid = self.store.member_id(r.g)
            if gid is None:
                direct.append(i)
            else:
                member[i] = gid
                in_store.setdefault(graph_digest(as_graph(r.q)),
                                    []).append(i)
        for idxs in in_store.values():
            outs = self.store.verify_members(
                requests[idxs[0]].q, [member[i] for i in idxs],
                [requests[i].tau for i in idxs])
            for i, o in zip(idxs, outs):
                results[i] = o
        if direct:
            outs = self.engine.verify(
                [(requests[i].q, requests[i].g) for i in direct],
                [requests[i].tau for i in direct])
            for i, o in zip(direct, outs):
                results[i] = o
        return results  # type: ignore[return-value]

    def compute(self, pairs: Sequence[Tuple[Graph, Graph]]
                ) -> List[GedOutcome]:
        return self.engine.compute(pairs)


class GedSimilarityService:
    """Corpus similarity search as a request/response service.

    A thin route over :class:`repro.ged.GraphStore`: ingest the database
    at construction, then serve ranged and k-NN queries.  ``index=``
    configures the store's sublinear stage −1 candidate index
    (:class:`repro.ged.CandidateIndex`): the default ``"auto"`` builds a
    sound exact-mode index, a knob dict tunes it — ``index={"recall":
    0.95}`` trades exactness for selectivity explicitly, ``index=
    {"pivot_seeds": 4}`` pre-computes DB–DB pivot distances into the
    engine's result cache at ingest — and ``index=None`` serves with the
    plain full-scan pipeline.  Example::

        svc = GedSimilarityService(db_graphs, mesh=mesh,
                                   index={"recall": 0.95})
        hits = svc.range_search(query, tau=4.0)
        answers = svc.search([SearchRequest(q1, tau=3.0),
                              SearchRequest(q2, k=10)])

    ``store_dir=`` warm-starts serving from a persisted store
    (:meth:`repro.ged.GraphStore.save`) instead of re-ingesting —
    store-level knobs (``digest``, ``filter_iters``, ``index`` config)
    come from the snapshot, remaining keyword options configure the
    fresh engine, and ``graphs`` becomes the optional rebuild fallback
    for a corrupted snapshot::

        svc = GedSimilarityService(store_dir="/var/ged/corpus")
    """

    def __init__(self, graphs=None, *, store_dir: Optional[str] = None,
                 mesh=None, batch_size: int = 256, index="auto",
                 **store_options):
        if store_dir is not None:
            self.store = GraphStore.open(
                store_dir, mesh=mesh, batch_size=batch_size,
                graphs=graphs, **store_options)
        elif graphs is not None:
            self.store = GraphStore(graphs, mesh=mesh,
                                    batch_size=batch_size, index=index,
                                    **store_options)
        else:
            raise TypeError(
                "GedSimilarityService needs graphs or store_dir=")

    @property
    def stats(self) -> Dict[str, float]:
        """The store's filter/verify counters (``docs/search.md``)."""
        return self.store.stats

    def range_search(self, query, tau: float) -> List[SearchHit]:
        return self.store.range_search(query, tau)

    def top_k(self, query, k: int) -> List[SearchHit]:
        return self.store.top_k(query, k)

    def search(self, requests: Sequence[SearchRequest]
               ) -> List[List[SearchHit]]:
        """Answer a mixed batch of range / top-k requests, in order."""
        for r in requests:          # validate before any work runs
            if (r.tau is None) == (r.k is None):
                raise ValueError(
                    "SearchRequest needs exactly one of tau= or k=")
        out: List[List[SearchHit]] = []
        for qi, r in enumerate(requests):
            hits = (self.store.range_search(r.query, r.tau)
                    if r.tau is not None else
                    self.store.top_k(r.query, r.k))
            for h in hits:
                h.query_id = qi
            out.append(hits)
        return out
