"""LM serving: prefill + token-by-token decode with a sharded KV cache.

``generate`` drives the real model step functions (the same ones the
dry-run lowers for the production mesh) at example scale: prefill builds
the cache, then ``decode_step`` is jitted once and re-invoked per token
with donated caches — steady-state decode allocates nothing.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ArchConfig


def greedy_sample(logits: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """(B, V_padded) f32 -> (B, 1) int32, masking vocab padding."""
    if logits.shape[-1] > vocab:
        pad = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(pad[None], -jnp.inf, logits)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def generate(params, prompt: np.ndarray, cfg: ArchConfig, max_new: int = 16,
             cache_len: Optional[int] = None,
             frames: Optional[np.ndarray] = None,
             patches: Optional[np.ndarray] = None,
             impl: str = "auto") -> np.ndarray:
    """Greedy generation. prompt: (B, S) int32. Returns (B, max_new)."""
    b, s = prompt.shape
    total = cache_len or (s + max_new)

    logits, caches = jax.jit(
        functools.partial(T.prefill_step, cfg=cfg, impl=impl)
    )(params, jnp.asarray(prompt), frames=frames, patches=patches)

    # right-size the decode cache: prefill caches cover [0, s); decode wants
    # capacity ``total`` (rwkv6/mamba carry O(1) state - nothing to grow).
    caches = _grow_caches(caches, cfg, b, s, total)

    step = jax.jit(functools.partial(T.decode_step, cfg=cfg),
                   donate_argnums=(1,))

    token = greedy_sample(logits, cfg.vocab)
    out = [token]
    pos = s
    for _ in range(max_new - 1):
        logits, caches = step(params, caches, token, jnp.int32(pos))
        token = greedy_sample(logits, cfg.vocab)
        out.append(token)
        pos += 1
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def _grow_caches(caches: Dict, cfg: ArchConfig, b: int, s: int, total: int
                 ) -> Dict:
    want = T.cache_shapes(cfg, b, total)
    out = {}
    for k, v in caches.items():
        shape, dt = want[k]
        if v.shape == shape:
            out[k] = v.astype(dt)
            continue
        buf = jnp.zeros(shape, dt)
        # KV entries: (L, B, T, H, hd) — copy the prefilled [0, s) slice.
        sl = tuple(slice(0, min(a, b_)) for a, b_ in zip(v.shape, shape))
        out[k] = buf.at[sl].set(v[sl].astype(dt))
    return out
