"""``repro.store_io`` — the durable substrate under the serving tier.

Three layers (bottom up):

* :mod:`repro.store_io.atomic` — the shared atomic-IO core every
  persistence path in the tree goes through: atomic-rename JSON,
  schema-versioned checksummed manifests, checksummed mmap-loadable
  ``.npy`` segments, and advisory file locks.  The autotune table
  (``tuning.json``) writes through it too.
* :mod:`repro.store_io.graphstore_io` — the on-disk layout and
  (de)serialization behind :meth:`repro.ged.GraphStore.save` /
  :meth:`~repro.ged.GraphStore.open`: generation directories, the
  append/delete journal, and compaction.
* :mod:`repro.store_io.shared_cache` — :class:`SharedResultCache`, the
  file-locked cross-process LRU of certified GED scalars layered behind
  the engine's in-memory result cache
  (``GedEngine(shared_cache_dir=...)``).

See ``docs/persistence.md`` for the full on-disk contract.
"""

from repro.store_io.atomic import (CorruptStoreError, SchemaVersionError,
                                   StoreIOError)
from repro.store_io.shared_cache import SHARED_CACHE_ENV, SharedResultCache

__all__ = [
    "StoreIOError",
    "CorruptStoreError",
    "SchemaVersionError",
    "SharedResultCache",
    "SHARED_CACHE_ENV",
]
