"""The shared atomic-IO core under every ``repro`` persistence path.

Three idioms already lived in the tree — the ``tuning.json``
tmp-then-``os.replace`` write in :mod:`repro.kernels.autotune`, the
two-phase tmp-dir-then-rename commit in :mod:`repro.checkpoint.manager`,
and the manifest-plus-arrays split both share.  This module is those
idioms generalized once, so every durable artifact (the autotune table,
:class:`repro.ged.GraphStore` segments, the cross-process shared result
cache) goes through one write path:

* **Atomic JSON** (:func:`atomic_write_json` / :func:`read_json_or_none`)
  — write to a same-directory temp file, ``os.replace`` into place.
  Readers either see the old bytes or the new bytes, never a torn write.
* **Checksummed, schema-versioned manifests** (:func:`write_manifest` /
  :func:`read_manifest`) — the JSON layer plus an envelope
  ``{kind, version, checksum, payload}``.  A reader states the ``kind``
  and ``version`` it understands; alien kinds and version bumps raise
  :class:`SchemaVersionError`, bit rot raises :class:`CorruptStoreError`
  — callers decide whether that means "rebuild" or "refuse", but never
  silently serve wrong data.
* **Checksummed ``.npy`` segments** (:func:`write_array` /
  :func:`read_array`) — one array per file in the plain ``.npy`` format
  so readers can ``mmap`` them (``np.load(mmap_mode="r")``); the write
  returns a manifest entry (size + BLAKE2b digest) the reader verifies
  *before* mapping, so a truncated or flipped segment is caught at open,
  not at query time.
* **Advisory file locks** (:func:`file_lock`) — ``fcntl``-based mutual
  exclusion for multi-process writers (the shared result cache's
  eviction sweeps).  Readers never need the lock: every write above is
  atomic-rename, so a reader sees complete files by construction.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import time
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = [
    "StoreIOError", "CorruptStoreError", "SchemaVersionError",
    "LockTimeout",
    "atomic_write_bytes", "atomic_write_json", "read_json_or_none",
    "write_manifest", "read_manifest", "write_array", "read_array",
    "file_lock", "checksum_file",
]


class StoreIOError(RuntimeError):
    """Base class for persistence failures callers may recover from."""


class LockTimeout(StoreIOError):
    """:func:`file_lock` could not acquire the lock within ``timeout``.

    A peer process died (or stalled) holding the advisory lock.  Callers
    decide the policy — the shared result cache fails *open* (skips the
    eviction sweep, still writes atomically) so one dead peer cannot
    wedge every engine process on the machine.
    """


class CorruptStoreError(StoreIOError):
    """A segment or manifest failed its checksum / structure check."""


class SchemaVersionError(StoreIOError):
    """On-disk schema is a kind/version this code does not understand."""


# ------------------------------------------------------------- primitives

def checksum_bytes(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


def checksum_file(path: str, chunk: int = 1 << 20) -> str:
    """Streaming BLAKE2b of a file (segments may be large; never slurp)."""
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Write-all-or-nothing: temp file in the target directory, fsync,
    ``os.replace``.  Readers of ``path`` never observe a partial write."""
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory,
                               prefix=os.path.basename(path) + ".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def atomic_write_json(path: str, payload, *, indent: int = 1,
                      sort_keys: bool = True) -> None:
    """Atomically persist ``payload`` as JSON, exactly as given (no
    envelope) — the ``tuning.json`` write path.  Callers owning a legacy
    on-disk format keep it byte-compatible through this."""
    text = json.dumps(payload, indent=indent, sort_keys=sort_keys)
    atomic_write_bytes(path, text.encode("utf-8"))


def read_json_or_none(path: str):
    """Parse a JSON file; *any* problem (missing, unreadable, torn by a
    non-atomic writer, not JSON) comes back as ``None`` — the
    "corrupt files recover to empty" contract of the autotune table."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


# ----------------------------------------------- schema'd manifest layer

def write_manifest(path: str, payload, *, kind: str, version: int) -> None:
    """Atomic JSON with a ``{kind, version, checksum, payload}`` envelope.

    The checksum covers the canonical serialization of ``payload`` so a
    partially-flipped manifest cannot masquerade as valid.

    >>> import tempfile, os
    >>> d = tempfile.mkdtemp()
    >>> p = os.path.join(d, "m.json")
    >>> write_manifest(p, {"a": 1}, kind="demo", version=1)
    >>> read_manifest(p, kind="demo", version=1)
    {'a': 1}
    """
    body = json.dumps(payload, sort_keys=True)
    atomic_write_json(path, {
        "kind": kind,
        "version": int(version),
        "checksum": checksum_bytes(body.encode("utf-8")),
        "payload": payload,
    })


def read_manifest(path: str, *, kind: str, version: int):
    """Validated manifest payload.

    Raises :class:`CorruptStoreError` when the file is missing, not
    JSON, structurally alien, or fails its checksum;
    :class:`SchemaVersionError` when kind/version say "written by other
    code" — distinct, because a version bump is *not* bit rot and
    callers may message it differently.
    """
    raw = read_json_or_none(path)
    if raw is None:
        raise CorruptStoreError(f"manifest {path!r} is missing or unreadable")
    if not isinstance(raw, dict) or "payload" not in raw:
        raise CorruptStoreError(f"manifest {path!r} has no payload envelope")
    if raw.get("kind") != kind or raw.get("version") != version:
        raise SchemaVersionError(
            f"manifest {path!r} is kind={raw.get('kind')!r} "
            f"version={raw.get('version')!r}; this code reads "
            f"kind={kind!r} version={version}")
    body = json.dumps(raw["payload"], sort_keys=True)
    if raw.get("checksum") != checksum_bytes(body.encode("utf-8")):
        raise CorruptStoreError(f"manifest {path!r} failed its checksum")
    return raw["payload"]


# -------------------------------------------------------- array segments

def write_array(directory: str, name: str, arr: np.ndarray) -> Dict:
    """Persist one array as an atomic ``.npy`` segment; returns its
    manifest entry (``{"file", "bytes", "checksum"}``).

    Plain ``.npy`` (not ``.npz``) so :func:`read_array` can hand back an
    ``mmap``-backed view — warm opens touch pages on demand instead of
    copying the corpus through RAM.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, name)
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=name + ".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            np.save(f, np.ascontiguousarray(arr), allow_pickle=False)
            f.flush()
            os.fsync(f.fileno())
        entry = {"file": name, "bytes": os.path.getsize(tmp),
                 "checksum": checksum_file(tmp)}
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    return entry


def read_array(directory: str, entry: Dict, *,
               mmap: bool = True) -> np.ndarray:
    """Load a segment written by :func:`write_array`, verifying size and
    checksum *first* (one streaming pass; the subsequent ``mmap`` load
    still reads pages lazily).  A truncated or bit-flipped segment
    raises :class:`CorruptStoreError` — never a silently-wrong array."""
    try:
        name = entry["file"]
    except (TypeError, KeyError):
        raise CorruptStoreError(f"malformed segment entry {entry!r}")
    path = os.path.join(directory, name)
    try:
        size = os.path.getsize(path)
    except OSError:
        raise CorruptStoreError(f"segment {path!r} is missing")
    if size != entry.get("bytes"):
        raise CorruptStoreError(
            f"segment {path!r} is {size} bytes; manifest says "
            f"{entry.get('bytes')} (truncated write?)")
    if checksum_file(path) != entry.get("checksum"):
        raise CorruptStoreError(f"segment {path!r} failed its checksum")
    try:
        return np.load(path, mmap_mode="r" if mmap else None,
                       allow_pickle=False)
    except ValueError as e:
        raise CorruptStoreError(f"segment {path!r} is not a .npy: {e}")


# ---------------------------------------------------------------- locking

@contextlib.contextmanager
def file_lock(path: str, timeout: Optional[float] = None,
              poll_s: float = 0.02) -> Iterator[None]:
    """Advisory exclusive lock on ``path`` (created if absent).

    POSIX ``fcntl.flock``; on platforms without ``fcntl`` the lock
    degrades to a no-op — single-process use stays correct either way,
    because every write under the lock is itself atomic-rename.

    ``timeout=None`` blocks indefinitely (the historical behavior);
    a finite ``timeout`` polls non-blocking acquisitions every
    ``poll_s`` seconds and raises :class:`LockTimeout` when the budget
    runs out — so a peer process that died holding the lock costs
    callers a bounded wait, not a hang.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    try:
        import fcntl
    except ImportError:                                 # pragma: no cover
        yield
        return
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    try:
        if timeout is None:
            fcntl.flock(fd, fcntl.LOCK_EX)
        else:
            t_end = time.monotonic() + float(timeout)
            while True:
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                    break
                except OSError:
                    if time.monotonic() >= t_end:
                        raise LockTimeout(
                            f"could not acquire {path!r} within "
                            f"{timeout:g}s (peer died holding it?)")
                    time.sleep(min(poll_s, max(0.0,
                                               t_end - time.monotonic())))
        yield
    finally:
        with contextlib.suppress(OSError):
            fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)
