"""On-disk layout + (de)serialization for :class:`repro.ged.GraphStore`.

The store's durable form is a *generation directory* of checksummed
``.npy`` segments plus one atomic manifest, with an append/delete journal
on the side (``docs/persistence.md`` has the full contract)::

    <store_dir>/
      graphstore.json         # manifest: the atomic commit point
      seg-00000003/           # current generation (immutable once named)
        graphs.ids.npy  graphs.n.npy  graphs.vlabels.npy  graphs.adj.npy
        dead.npy  rep_of.npy  digests.exact.npy  [digests.wl.npy]
        feat8.ids.npy  feat8.vhist.npy ...      # per-slot-bucket stage-0
        index.ids.npy  index.sigs.npy           # stage −1 sketch matrix
      journal/
        j-00000004.seg/ ...   # arrays of an appended batch
        j-00000004.json       # entry (written last = commit point)

Writes follow the two-phase idiom of :mod:`repro.checkpoint.manager`:
segments land in a temp directory, the directory is renamed into place,
and only then does the manifest atomically switch generations — a crash
at any point leaves the previous generation fully readable.  Segment
data splits into **primary** state (the graphs themselves, tombstone
flags, the journal) and **derived** state (digests, dedup groups,
feature buckets, sketch matrix): derived corruption is recoverable by
re-deriving from primary, so callers get to warn-and-rebuild instead of
failing (:meth:`repro.ged.GraphStore.open` does exactly that).

``GraphStore.save`` always writes a *compacted* snapshot — live graphs
plus the (possibly tombstoned) representatives live groups still probe
through — and folds the journal into it; ``journal_base`` in the
manifest is the watermark below which journal entries are already
folded, which keeps replay correct even if a crash interrupts journal
cleanup.
"""

from __future__ import annotations

import contextlib
import os
import re
import shutil
import tempfile
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine.corpus import CorpusFeatures
from repro.core.exact.graph import Graph
from repro.store_io.atomic import (CorruptStoreError, read_manifest,
                                   read_array, write_array, write_manifest)

__all__ = ["save_store", "read_store_manifest", "load_primary",
           "load_derived", "load_journal", "append_journal",
           "clear_journal", "MANIFEST_NAME"]

STORE_KIND = "graphstore"
STORE_VERSION = 1
JOURNAL_KIND = "graphstore-journal"
MANIFEST_NAME = "graphstore.json"
JOURNAL_DIR = "journal"

_GEN_RE = re.compile(r"^seg-(\d{8})$")
_JOURNAL_RE = re.compile(r"^j-(\d{8})\.json$")


def manifest_path(store_dir: str) -> str:
    return os.path.join(store_dir, MANIFEST_NAME)


# ------------------------------------------------------ graph array codec

def pack_graph_arrays(graphs: Sequence[Graph]) -> Dict[str, np.ndarray]:
    """Ragged corpus -> three flat arrays (``n`` + concatenated vertex
    labels + concatenated row-major adjacency blocks)."""
    n = np.asarray([g.n for g in graphs], dtype=np.int64)
    vlabels = (np.concatenate([np.asarray(g.vlabels, dtype=np.int64)
                               for g in graphs])
               if graphs else np.zeros(0, dtype=np.int64))
    adj = (np.concatenate([np.asarray(g.adj, dtype=np.int64).reshape(-1)
                           for g in graphs])
           if graphs else np.zeros(0, dtype=np.int64))
    return {"n": n, "vlabels": vlabels, "adj": adj}


def unpack_graph_arrays(n: np.ndarray, vlabels: np.ndarray,
                        adj: np.ndarray) -> List[Graph]:
    vptr = np.concatenate([[0], np.cumsum(n)])
    aptr = np.concatenate([[0], np.cumsum(n * n)])
    if vptr[-1] != len(vlabels) or aptr[-1] != len(adj):
        raise CorruptStoreError(
            "graph arrays are inconsistent: label/adjacency lengths do "
            "not match the per-graph sizes")
    out = []
    for i, ni in enumerate(n):
        ni = int(ni)
        out.append(Graph(
            vlabels=np.ascontiguousarray(vlabels[vptr[i]:vptr[i + 1]]),
            adj=np.ascontiguousarray(
                adj[aptr[i]:aptr[i + 1]]).reshape(ni, ni)))
    return out


def _pack_digests(digests: Sequence[bytes]) -> np.ndarray:
    if not digests:
        return np.zeros((0, 16), dtype=np.uint8)
    return np.stack([np.frombuffer(d, dtype=np.uint8) for d in digests])


def _unpack_digests(arr: np.ndarray) -> List[bytes]:
    return [bytes(row.tobytes()) for row in np.asarray(arr, dtype=np.uint8)]


# ----------------------------------------------------------------- saving

def save_store(store, store_dir: str) -> None:
    """Write a full (compacted) snapshot of ``store`` and commit it.

    Keeps every live graph plus tombstoned representatives whose groups
    still have live members (they remain the group's probe object);
    fully-dead groups and dead non-representative members are dropped —
    their ids are never reused (``next_id`` is persisted)."""
    store_dir = str(store_dir)
    os.makedirs(store_dir, exist_ok=True)
    live = {i for i in range(len(store.graphs))
            if store.graphs[i] is not None and i not in store._tombstones}
    keep = sorted(live | set(store._rep_ids))
    gen_num = _next_generation(store_dir)
    gen_name = f"seg-{gen_num:08d}"
    tmp = tempfile.mkdtemp(dir=store_dir, prefix=gen_name + ".tmp-")
    try:
        segments: Dict[str, Dict] = {}

        def put(name: str, arr: np.ndarray) -> None:
            segments[name] = write_array(tmp, name + ".npy", arr)

        graphs = [store.graphs[i] for i in keep]
        packed = pack_graph_arrays(graphs)
        put("graphs.ids", np.asarray(keep, dtype=np.int64))
        put("graphs.n", packed["n"])
        put("graphs.vlabels", packed["vlabels"])
        put("graphs.adj", packed["adj"])
        put("dead", np.asarray([i in store._tombstones for i in keep],
                               dtype=np.uint8))
        put("rep_of", np.asarray([store._rep_of[i] for i in keep],
                                 dtype=np.int64))
        digest_of = {gid: d for d, gid in store._exact_of.items()}
        from repro.ged.exec import graph_digest
        put("digests.exact", _pack_digests(
            [digest_of.get(i) or graph_digest(store.graphs[i])
             for i in keep]))
        if store.digest == "wl":
            put("digests.wl", _pack_digests(
                [store._wl_of.get(i, b"\x00" * 16) for i in keep]))

        keep_set = set(keep)
        feature_slots: List[int] = []
        for b in store._index.buckets:
            # resident buckets never shrink, so they may still carry rows
            # for representatives of fully-dead groups — dropped here,
            # like their graphs
            rows = np.asarray([ri for ri, gid in enumerate(b.ids[:b.real])
                               if gid in keep_set], dtype=np.int64)
            if not len(rows):
                continue
            feature_slots.append(int(b.slots))
            put(f"feat{b.slots}.ids",
                np.asarray([b.ids[ri] for ri in rows], dtype=np.int64))
            f = b.features
            for part, arr in (("vhist", f.vhist), ("ehist", f.ehist),
                              ("degs", f.degs), ("n", f.n), ("m", f.m)):
                put(f"feat{b.slots}.{part}",
                    np.ascontiguousarray(np.asarray(arr)[:b.real][rows]))

        index_meta = None
        cindex = store._cindex
        if cindex is not None:
            rows = [pos for pos, gid in enumerate(cindex.ids)
                    if gid in keep_set]
            put("index.ids", np.asarray([cindex.ids[pos] for pos in rows],
                                        dtype=np.int64))
            put("index.sigs",
                np.ascontiguousarray(np.asarray(cindex.sigs)[rows]))
            index_meta = {
                "knobs": {
                    "dims_v": cindex.spec.dims_v,
                    "dims_e": cindex.spec.dims_e,
                    "wl_iters": cindex.spec.wl_iters,
                    "reps": cindex.reps,
                    "recall": cindex.recall,
                    "max_pivots": cindex.max_pivots,
                    "pivot_seeds": cindex.pivot_seeds,
                    "pivot_coverage": cindex.pivot_coverage,
                    "pivot_min_candidates": cindex.pivot_min_candidates,
                    "seed": cindex.seed,
                },
                "max_deg": int(cindex._max_deg),
                "pivots": [int(p) for p in cindex._pivots
                           if p in keep_set],
            }

        payload = {
            "generation": gen_name,
            "segments": segments,
            "digest": store.digest,
            "filter_iters": int(store.filter_iters),
            "filter_pool": int(store.filter_pool),
            "vocab": [[int(v) for v in store.vocab[0]],
                      [int(v) for v in store.vocab[1]]],
            "index": index_meta,
            "feature_slots": feature_slots,
            "next_id": len(store.graphs),
            "dedup_checks": int(store._dedup_checks),
            "journal_base": int(store._journal_seq),
        }
        os.rename(tmp, os.path.join(store_dir, gen_name))
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # the manifest swap is the commit point: a crash before this line
    # leaves the previous generation (and manifest) fully intact
    write_manifest(manifest_path(store_dir), payload,
                   kind=STORE_KIND, version=STORE_VERSION)
    _cleanup(store_dir, keep_gen=gen_name,
             journal_base=int(store._journal_seq))


def _next_generation(store_dir: str) -> int:
    newest = -1
    with contextlib.suppress(OSError):
        for name in os.listdir(store_dir):
            m = _GEN_RE.match(name.split(".tmp-")[0])
            if m:
                newest = max(newest, int(m.group(1)))
    return newest + 1


def _cleanup(store_dir: str, keep_gen: str, journal_base: int) -> None:
    """Best-effort removal of superseded generations, stale temp dirs and
    folded journal entries.  Failure here is harmless: the manifest's
    generation pointer and ``journal_base`` watermark already make stale
    files unreachable."""
    with contextlib.suppress(OSError):
        for name in os.listdir(store_dir):
            full = os.path.join(store_dir, name)
            if _GEN_RE.match(name) and name != keep_gen:
                shutil.rmtree(full, ignore_errors=True)
            elif ".tmp-" in name:
                shutil.rmtree(full, ignore_errors=True)
    _cleanup_journal(store_dir, journal_base)


def _cleanup_journal(store_dir: str, journal_base: int) -> None:
    jdir = os.path.join(store_dir, JOURNAL_DIR)
    with contextlib.suppress(OSError):
        for name in os.listdir(jdir):
            m = _JOURNAL_RE.match(name)
            seq = int(m.group(1)) if m else None
            if seq is None and name.endswith(".seg"):
                stem = name[:-len(".seg")]
                if stem.startswith("j-"):
                    with contextlib.suppress(ValueError):
                        seq = int(stem[2:].split(".tmp-")[0])
            if seq is not None and seq <= journal_base:
                full = os.path.join(jdir, name)
                if os.path.isdir(full):
                    shutil.rmtree(full, ignore_errors=True)
                else:
                    with contextlib.suppress(OSError):
                        os.unlink(full)


# ---------------------------------------------------------------- loading

def read_store_manifest(store_dir: str) -> Dict:
    return read_manifest(manifest_path(store_dir),
                         kind=STORE_KIND, version=STORE_VERSION)


def load_primary(store_dir: str, payload: Dict) -> Dict:
    """The non-derivable half of a snapshot: graphs by id + tombstones."""
    gen = os.path.join(store_dir, payload["generation"])
    segs = payload["segments"]

    def arr(name: str, mmap: bool = False) -> np.ndarray:
        if name not in segs:
            raise CorruptStoreError(
                f"manifest lists no {name!r} segment")
        return read_array(gen, segs[name], mmap=mmap)

    ids = np.asarray(arr("graphs.ids"), dtype=np.int64)
    graphs = unpack_graph_arrays(
        np.asarray(arr("graphs.n"), dtype=np.int64),
        arr("graphs.vlabels", mmap=True), arr("graphs.adj", mmap=True))
    dead = np.asarray(arr("dead"), dtype=bool)
    if not (len(ids) == len(graphs) == len(dead)):
        raise CorruptStoreError("graph/id/tombstone segment lengths differ")
    next_id = int(payload.get("next_id", 0))
    if len(ids) and (next_id <= int(ids.max()) or len(set(ids.tolist()))
                     != len(ids)):
        raise CorruptStoreError("graph id segment is inconsistent")
    return {
        "ids": [int(i) for i in ids],
        "graphs": graphs,
        "dead": [bool(d) for d in dead],
        "next_id": next_id,
    }


def load_derived(store_dir: str, payload: Dict, ids: List[int]) -> Dict:
    """Everything re-derivable from the primary state: digests, dedup
    group assignment, per-bucket stage-0 features (mmap-backed), and the
    stage −1 sketch state.  Raises :class:`CorruptStoreError` on any
    inconsistency — the caller falls back to re-deriving."""
    gen = os.path.join(store_dir, payload["generation"])
    segs = payload["segments"]

    def arr(name: str, mmap: bool = False) -> np.ndarray:
        if name not in segs:
            raise CorruptStoreError(f"manifest lists no {name!r} segment")
        return read_array(gen, segs[name], mmap=mmap)

    k = len(ids)
    exact = _unpack_digests(arr("digests.exact"))
    wl = (_unpack_digests(arr("digests.wl"))
          if payload["digest"] == "wl" else None)
    rep_of = np.asarray(arr("rep_of"), dtype=np.int64)
    if len(exact) != k or len(rep_of) != k or (wl is not None
                                               and len(wl) != k):
        raise CorruptStoreError("derived segment lengths differ from ids")
    id_set = set(ids)
    if any(int(r) not in id_set for r in rep_of):
        raise CorruptStoreError("rep_of references an absent graph id")

    features: Dict[int, Tuple[List[int], CorpusFeatures]] = {}
    for slots in payload.get("feature_slots", []):
        slots = int(slots)
        bids = [int(i) for i in
                np.asarray(arr(f"feat{slots}.ids"), dtype=np.int64)]
        cf = CorpusFeatures(
            vhist=arr(f"feat{slots}.vhist", mmap=True),
            ehist=arr(f"feat{slots}.ehist", mmap=True),
            degs=arr(f"feat{slots}.degs", mmap=True),
            n=arr(f"feat{slots}.n", mmap=True),
            m=arr(f"feat{slots}.m", mmap=True))
        if not (cf.vhist.shape[0] == cf.ehist.shape[0] == cf.degs.shape[0]
                == cf.n.shape[0] == cf.m.shape[0] == len(bids)):
            raise CorruptStoreError(
                f"feature bucket {slots} segment lengths differ")
        if any(b not in id_set for b in bids):
            raise CorruptStoreError(
                f"feature bucket {slots} references an absent graph id")
        features[slots] = (bids, cf)

    index_state = None
    meta = payload.get("index")
    if meta is not None:
        sig_ids = [int(i) for i in
                   np.asarray(arr("index.ids"), dtype=np.int64)]
        sigs = arr("index.sigs", mmap=True)
        if sigs.shape[0] != len(sig_ids) \
                or any(i not in id_set for i in sig_ids):
            raise CorruptStoreError("index sketch segments are inconsistent")
        index_state = {
            "knobs": dict(meta.get("knobs", {})),
            "max_deg": int(meta.get("max_deg", 0)),
            "pivots": [int(p) for p in meta.get("pivots", [])],
            "ids": sig_ids,
            "sigs": sigs,
        }
    return {"exact": exact, "wl": wl,
            "rep_of": [int(r) for r in rep_of],
            "features": features, "index": index_state}


# ---------------------------------------------------------------- journal

def append_journal(store_dir: str, seq: int, op: Dict,
                   graphs: Optional[Sequence[Graph]] = None) -> None:
    """Durably append one mutation.  Array segments (for adds) are
    written first; the entry JSON — written atomically, last — is the
    commit point, so a crash mid-append leaves an ignorable orphan
    segment directory, never a half-applied entry."""
    jdir = os.path.join(store_dir, JOURNAL_DIR)
    os.makedirs(jdir, exist_ok=True)
    stem = f"j-{int(seq):08d}"
    entry = dict(op)
    if graphs is not None:
        segdir = os.path.join(jdir, stem + ".seg")
        packed = pack_graph_arrays(list(graphs))
        entry["segments"] = {
            name: write_array(segdir, f"{stem}.{name}.npy", arr)
            for name, arr in packed.items()}
        entry["segdir"] = stem + ".seg"
    write_manifest(os.path.join(jdir, stem + ".json"), entry,
                   kind=JOURNAL_KIND, version=STORE_VERSION)


def load_journal(store_dir: str, base: int) -> Tuple[List[Dict], int]:
    """Committed journal entries with seq > ``base``, in order, with add
    segments decoded back into graphs.  A broken *final* entry is an
    interrupted append — dropped with a warning; a broken earlier entry
    would leave later entries unreplayable, so it raises."""
    jdir = os.path.join(store_dir, JOURNAL_DIR)
    seqs = []
    with contextlib.suppress(OSError):
        for name in os.listdir(jdir):
            m = _JOURNAL_RE.match(name)
            if m and int(m.group(1)) > base:
                seqs.append(int(m.group(1)))
    seqs.sort()
    ops: List[Dict] = []
    top = base
    for pos, seq in enumerate(seqs):
        stem = f"j-{seq:08d}"
        try:
            entry = read_manifest(os.path.join(jdir, stem + ".json"),
                                  kind=JOURNAL_KIND, version=STORE_VERSION)
            op = dict(entry)
            if "segments" in entry:
                segdir = os.path.join(jdir, entry["segdir"])
                op["graphs"] = unpack_graph_arrays(
                    np.asarray(read_array(segdir, entry["segments"]["n"]),
                               dtype=np.int64),
                    read_array(segdir, entry["segments"]["vlabels"]),
                    read_array(segdir, entry["segments"]["adj"]))
        except (CorruptStoreError, KeyError, OSError) as e:
            if pos == len(seqs) - 1:
                warnings.warn(
                    f"dropping interrupted journal entry {stem}: {e}",
                    RuntimeWarning)
                break
            raise CorruptStoreError(
                f"journal entry {stem} is corrupt with later entries "
                f"present: {e}")
        ops.append(op)
        top = seq
    return ops, top


def clear_journal(store_dir: str, base: int) -> None:
    """Remove folded journal entries (seq <= ``base``)."""
    _cleanup_journal(store_dir, int(base))
