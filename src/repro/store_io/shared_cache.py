"""Cross-process result-cache tier behind the engine's ``ResultCache``.

The in-memory :class:`repro.ged.exec.ResultCache` dies with its process;
this tier is the durable layer *behind* it: an on-disk LRU of **certified
scalars only**, keyed on the same canonical pair digests (tau-aware), so
a warm serving process answers pairs an earlier process already proved.

Design constraints, in order:

* **Never a wrong answer.**  Only certified outcomes are admitted, and
  only their scalars (``ged`` / ``similar`` / bounds / ``tau``) are
  stored — a certificate makes the scalar exact independent of which
  engine config or backend produced it, which is also why the on-disk
  key deliberately drops the in-memory key's config/backend components.
  Mappings are never stored (they are only index-valid for the exact
  byte-level graphs that produced them, and entries may be read by a
  process holding different objects).
* **Multi-process safe.**  One entry per file, written atomically
  (:func:`repro.store_io.atomic.atomic_write_bytes` idiom), so readers
  need no lock — they see a complete entry or none.  Writers serialize
  mutation + eviction sweeps through one advisory
  :func:`~repro.store_io.atomic.file_lock`; a corrupt or torn entry
  (only possible if something non-atomic touched the directory) reads
  as a miss, never as data.
* **LRU by access time.**  Reads touch the entry's mtime; the eviction
  sweep (amortized, under the lock) drops the oldest entries beyond
  ``max_entries``.  Counters (``hits`` / ``misses`` / ``evictions``)
  are per-process and surface in ``engine.stats`` as
  ``shared_cache_*`` — the same contract the persistent compile cache
  and autotune table follow.

Wired by ``GedEngine(shared_cache_dir=...)`` or the
``REPRO_GED_SHARED_CACHE_DIR`` environment variable (see
``docs/persistence.md``).
"""

from __future__ import annotations

import contextlib
import hashlib
import os
import struct
from typing import TYPE_CHECKING, Dict, Optional

from repro.store_io.atomic import (LockTimeout, atomic_write_json,
                                   file_lock, read_json_or_none)

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.ged.results import GedOutcome

__all__ = ["SharedResultCache", "SHARED_CACHE_ENV"]

SHARED_CACHE_ENV = "REPRO_GED_SHARED_CACHE_DIR"
_SCHEMA_VERSION = 1
_INF = float("inf")


def _encode(value: Optional[float]) -> Optional[float]:
    if value is None:
        return None
    value = float(value)
    if value == _INF:
        return "inf"                # JSON has no Infinity literal
    return value


def _decode(value) -> Optional[float]:
    if value is None:
        return None
    if value == "inf":
        return _INF
    return float(value)


class SharedResultCache:
    """On-disk LRU of certified GED scalars, shared across processes.

    ``key`` everywhere below is the engine's in-memory pair key
    (:func:`repro.ged.exec.pair_key`); only its digest/mode/tau prefix
    reaches the disk key — see the module docstring for why.

    >>> import tempfile
    >>> from repro.ged.results import GedOutcome
    >>> cache = SharedResultCache(tempfile.mkdtemp())
    >>> key = ("exact", b"q-digest", b"g-digest", False, None, None, "jax")
    >>> cache.get(key) is None, cache.misses
    (True, 1)
    >>> out = GedOutcome(ged=2.0, similar=None, certified=True,
    ...                  lower_bound=2.0, upper_bound=2.0, mapping=None,
    ...                  backend="jax", wall_s=0.01)
    >>> cache.put(key, out)
    True
    >>> hit = cache.get(key)
    >>> hit.ged, hit.certified, hit.backend, cache.hits
    (2.0, True, 'shared-cache', 1)
    """

    def __init__(self, directory: str, max_entries: int = 4096,
                 sweep_every: int = 32, lock_timeout_s: float = 10.0):
        self.directory = str(directory)
        self.max_entries = int(max_entries)
        self.sweep_every = max(int(sweep_every), 1)
        self.lock_timeout_s = (None if lock_timeout_s is None
                               else float(lock_timeout_s))
        os.makedirs(self.directory, exist_ok=True)
        self._lock_path = os.path.join(self.directory, "lock")
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.lock_timeouts = 0
        self._puts = 0

    # ---------------------------------------------------------- keying

    def _path(self, key: tuple) -> str:
        # (digest_kind, dq, dg, verification, tau) — the canonical,
        # config-independent prefix of the in-memory pair key.  Both pair
        # orientations map to one entry: GED is symmetric and only
        # scalars are stored, so orientation cannot matter.
        digest_kind, dq, dg, verification, tau = key[:5]
        h = hashlib.blake2b(digest_size=16)
        h.update(str(digest_kind).encode("utf-8"))
        for d in sorted((bytes(dq), bytes(dg))):
            h.update(b"\x00")
            h.update(d)
        h.update(b"\x01" if verification else b"\x02")
        h.update(b"none" if tau is None else struct.pack("<d", float(tau)))
        return os.path.join(self.directory, h.hexdigest() + ".json")

    # ----------------------------------------------------------- lookup

    def get(self, key: tuple) -> Optional[GedOutcome]:
        """Certified outcome for ``key``, rebuilt from stored scalars, or
        ``None``.  Reads are lock-free (atomic writes guarantee complete
        files); a hit touches the entry's mtime to mark recency."""
        # imported here, not at module top: repro.ged imports this module
        # (via GedEngine), so the leaf-module import must stay lazy
        from repro.ged.results import GedOutcome
        path = self._path(key)
        raw = read_json_or_none(path)
        if (not isinstance(raw, dict)
                or raw.get("v") != _SCHEMA_VERSION
                or "lb" not in raw or "ub" not in raw):
            self.misses += 1
            return None
        with contextlib.suppress(OSError):
            os.utime(path)
        self.hits += 1
        return GedOutcome(
            ged=_decode(raw.get("ged")),
            similar=(None if raw.get("similar") is None
                     else bool(raw["similar"])),
            certified=True,
            lower_bound=_decode(raw["lb"]),
            upper_bound=_decode(raw["ub"]),
            mapping=None,
            backend="shared-cache",
            wall_s=0.0,
            tau=_decode(raw.get("tau")),
            stats={"cached": "shared"},
        )

    def put(self, key: tuple, outcome: GedOutcome) -> bool:
        """Admit a *certified* outcome's scalars; returns whether it was
        stored.  Serialized with other writers through the directory
        lock; an amortized LRU sweep keeps the entry count bounded."""
        if not outcome.certified:
            return False
        payload = {
            "v": _SCHEMA_VERSION,
            "ged": _encode(outcome.ged),
            "similar": (None if outcome.similar is None
                        else bool(outcome.similar)),
            "lb": _encode(outcome.lower_bound),
            "ub": _encode(outcome.upper_bound),
            "tau": _encode(outcome.tau),
        }
        try:
            self._check_lock_fault()
            with file_lock(self._lock_path, timeout=self.lock_timeout_s):
                atomic_write_json(self._path(key), payload, indent=0)
                self._puts += 1
                if (self._puts % self.sweep_every == 1
                        or self.sweep_every == 1):
                    self._evict_locked()
        except LockTimeout:
            # Fail open: a peer died holding the lock.  The entry write
            # itself is atomic-rename (safe without the lock); only the
            # eviction sweep needs mutual exclusion, so we skip it and
            # count the event (surfaces as shared_cache_lock_timeouts).
            self.lock_timeouts += 1
            from repro.ged.faults import warn_once  # leaf module, lazy
            warn_once("shared-cache-lock",
                      f"shared result cache lock {self._lock_path!r} "
                      f"timed out after {self.lock_timeout_s:g}s; "
                      "writing without eviction sweep (fail-open)")
            atomic_write_json(self._path(key), payload, indent=0)
        return True

    def _check_lock_fault(self) -> None:
        """Deterministic chaos hook: the ``lock`` fault site simulates a
        dead peer by raising the timeout path directly (lazy import —
        this module must stay importable without repro.ged)."""
        from repro.ged.faults import get_injector
        inj = get_injector()
        if inj is not None:
            try:
                inj.check("lock")
            except Exception as exc:
                raise LockTimeout(
                    f"injected lock timeout on {self._lock_path!r}"
                ) from exc

    def entries(self) -> int:
        """Current on-disk entry count (directory scan; stats-path only)."""
        try:
            with os.scandir(self.directory) as it:
                return sum(1 for e in it if e.name.endswith(".json"))
        except OSError:
            return 0

    @property
    def stats(self) -> Dict[str, float]:
        return {"hits": float(self.hits), "misses": float(self.misses),
                "evictions": float(self.evictions),
                "lock_timeouts": float(self.lock_timeouts)}

    # --------------------------------------------------------- internal

    def _evict_locked(self) -> None:
        """Drop oldest-accessed entries beyond ``max_entries`` (caller
        holds the lock).  Concurrent deletions are benign — a vanished
        file is skipped, a re-read after eviction is just a miss."""
        try:
            with os.scandir(self.directory) as it:
                rows = [(e.stat().st_mtime, e.path) for e in it
                        if e.name.endswith(".json")]
        except OSError:
            return
        excess = len(rows) - self.max_entries
        if excess <= 0:
            return
        rows.sort()
        for _, path in rows[:excess]:
            with contextlib.suppress(OSError):
                os.unlink(path)
                self.evictions += 1
