"""Shared fixtures.  NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here — smoke tests and benches must see the real single CPU device.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import numpy as np
import pytest

from repro.core.exact.graph import Graph


@pytest.fixture
def paper_fig1_pair():
    """A reconstruction of the paper's Figure 1 pair (figure not in text).

    Satisfies every property the text states: structure q = {(v1,v2),(v1,v3),
    (v3,v4)}, g = {(u1,u2),(u2,u4),(u3,u4)}; identity mapping editorial cost
    3; delta(q, g) = 3; delta^LS(f1) = 0 and delta^LSa(f1) = 2 for
    f1 = {v1 -> u1} (verified by exhaustive search over label placements).
    """
    A, B = 0, 1
    a, b = 1, 2
    q = Graph.from_edges([A, B, A, A], [(0, 1, a), (0, 2, b), (2, 3, a)])
    g = Graph.from_edges([A, B, A, A], [(0, 1, b), (1, 3, a), (2, 3, a)])
    return q, g


@pytest.fixture
def paper_fig3_pair():
    """Paper Figure 3: delta(q, g) <= 5 (4 vertices vs 5 vertices)."""
    A, B, C = 0, 1, 2
    a, b = 1, 2
    q = Graph.from_edges([A, B, B, B], [(0, 1, a), (1, 2, 1), (2, 3, b), (1, 3, b)])
    g = Graph.from_edges(
        [B, B, B, B, C],
        [(0, 1, a), (1, 2, b), (2, 3, b), (1, 3, b), (0, 4, b), (3, 4, 1)],
    )
    return q, g


@pytest.fixture
def rng():
    return np.random.default_rng(0)
