"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch:
  * one train step — finite loss, params update, no NaNs;
  * prefill + decode — decode logits at position s must match the
    full-sequence forward logits at position s (validates KV caches, ring
    buffers, SSM/RWKV recurrences and the hybrid shared-attn cache against
    the parallel formulation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.params import init_params, param_count
from repro.optim import AdamWConfig, adamw_init

ARCH_IDS = sorted(ARCHS)


def _smoke_cfg(name, lossless_moe=False):
    base = get_arch(name)
    # windowed archs: 3 layers so both local and global caches exist
    cfg = reduced(base, layers=3 if base.window_pattern else 2)
    # f32 compute so prefill/decode consistency is tight on CPU
    cfg = dataclasses.replace(cfg, remat="none", compute_dtype="float32")
    if lossless_moe and cfg.moe is not None:
        # capacity high enough that no token is dropped — routing-drop
        # policy differs between full-forward and single-token decode, so
        # the consistency oracle needs drop-free dispatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


def _batch_for(cfg, b, s, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.vlm is not None:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm.num_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encdec.enc_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = _smoke_cfg(arch)
    assert param_count(cfg) > 0
    params = init_params(cfg, seed=0)
    opt = adamw_init(params)
    step = T.make_train_step(cfg, AdamWConfig(lr=1e-3, warmup=1,
                                              total_steps=10),
                             accum=1, impl="naive")
    rng = np.random.default_rng(0)
    batch = _batch_for(cfg, b=2, s=16, rng=rng)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), params, params2), 0.0)
    assert delta > 0
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(params2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_accum_matches(arch):
    """Gradient accumulation (scan over microbatches) == single big batch."""
    cfg = _smoke_cfg(arch)
    params = init_params(cfg, seed=0)
    opt = adamw_init(params)
    rng = np.random.default_rng(1)
    batch = _batch_for(cfg, b=4, s=8, rng=rng)
    s1 = T.make_train_step(cfg, AdamWConfig(lr=1e-3), accum=1, impl="naive")
    s2 = T.make_train_step(cfg, AdamWConfig(lr=1e-3), accum=2, impl="naive")
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-4)
    l1 = jax.tree.leaves(p1)[0]
    l2 = jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = _smoke_cfg(arch, lossless_moe=True)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(2)
    b, s = 2, 12
    batch = _batch_for(cfg, b, s + 1, rng)
    tokens = batch["tokens"]
    frames = batch.get("frames")
    patches = batch.get("patches")

    # prefill on the first s tokens
    logits_p, caches = T.prefill_step(params, tokens[:, :s], cfg,
                                      frames=frames, patches=patches,
                                      impl="naive")
    # decode token s against the cache
    stream = s + (cfg.vlm.num_patches if cfg.vlm is not None else 0)
    caches = _grow(caches, cfg, b, stream + 4)
    logits_d, _ = T.decode_step(params, caches, tokens[:, s:s + 1],
                                jnp.int32(stream), cfg)

    # oracle: full forward over s+1 tokens
    h = T.forward_hidden(params, tokens[:, :s + 1], cfg, patches=patches,
                         frames=frames, impl="naive")
    from repro.models import layers as L
    h = L.norm(h, params["final_norm"], cfg)
    logits_full = L.lm_logits(h, params, cfg)

    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_full[:, stream - 1]),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_full[:, stream]),
                               atol=2e-3, rtol=2e-2)


def _grow(caches, cfg, b, total):
    want = T.cache_shapes(cfg, b, total)
    out = {}
    for k, v in caches.items():
        shape, dt = want[k]
        if v.shape == shape:
            out[k] = v.astype(dt)
            continue
        buf = jnp.zeros(shape, dt)
        sl = tuple(slice(0, min(a, bb)) for a, bb in zip(v.shape, shape))
        out[k] = buf.at[sl].set(v[sl].astype(dt))
    return out


def test_gemma3_window_pattern():
    cfg = get_arch("gemma3-1b")
    w = cfg.windows()
    assert len(w) == 26
    assert sum(1 for x in w if x == 0) == 4          # globals (every 6th)
    assert all(x in (0, 512) for x in w)


def test_moe_configs_pad_evenly():
    for name in ("qwen2-moe-a2.7b", "moonshot-v1-16b-a3b"):
        cfg = get_arch(name)
        assert cfg.moe.total_experts % 16 == 0       # EP-16 divisible


def test_param_counts_in_range():
    """Sanity: full-scale param counts within 25% of the nominal sizes."""
    nominal = {
        "qwen3-8b": 8.2e9, "qwen2-72b": 72.7e9, "gemma3-1b": 1.0e9,
        "nemotron-4-15b": 15e9, "rwkv6-3b": 3.1e9, "zamba2-7b": 7.4e9,
        "whisper-large-v3": 1.5e9, "qwen2-vl-2b": 1.5e9,
        "qwen2-moe-a2.7b": 14.3e9,
        # the ASSIGNED spec (48L x 64e x d_ff 1408) gives 28B total; the
        # name's nominal 16B corresponds to the 27L original — we follow
        # the assignment (DESIGN.md §4).
        "moonshot-v1-16b-a3b": 28e9,
    }
    for name, want in nominal.items():
        got = param_count(get_arch(name))
        assert 0.7 * want < got < 1.35 * want, (name, got, want)
