"""Overlapped ``auto`` escalation on the executor mesh: mesh parity,
sequential-vs-overlapped parity, the always-certified regression guard,
survivor re-bucketing, and the async stats knobs."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ged
from repro.core.exact.brute import brute_force_ged
from repro.data.graphs import perturb, random_graph
from repro.ged.exec import Executor, ShardedExecutor


def _pairs(seed, count, nmin=4, nmax=8, ops=(1, 5)):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(count):
        q = random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                         density=0.4, n_vlabels=3, n_elabels=2)
        out.append((q, perturb(rng, q, int(rng.integers(*ops)),
                               n_vlabels=3, n_elabels=2)))
    return out


OPTS = dict(batch_size=4, pool=256, expand=4, max_iters=256)


def _tiny_rungs(eng, rungs=((8, 2, 4), (256, 4, 128))):
    """Shrink the escalation ladder so rung 0 leaves real survivors."""
    eng._backend.scheduler.rungs = rungs
    return eng


# ----------------------------------------------------------- mesh parity

def test_auto_on_mesh_matches_plain_auto():
    """``GedEngine(backend="auto", mesh=...)`` must return outcomes
    identical (ged / similar / certified) to plain ``auto`` on the same
    pairs — only the placement differs."""
    import jax
    pairs = _pairs(0, 10)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    plain = ged.GedEngine("auto", **OPTS)
    sharded = ged.GedEngine("auto", mesh=mesh, **OPTS)
    assert isinstance(plain._backend.executor, Executor)
    assert isinstance(sharded._backend.executor, ShardedExecutor)
    assert sharded.batch_multiple == jax.device_count()

    a = plain.compute(pairs)
    b = sharded.compute(pairs)
    for oa, ob in zip(a, b):
        assert (oa.ged, oa.certified) == (ob.ged, ob.certified)

    for tau in (2.0, 4.0):
        va = ged.GedEngine("auto", **OPTS).verify(pairs, tau)
        vb = ged.GedEngine("auto", mesh=mesh, **OPTS).verify(pairs, tau)
        for oa, ob in zip(va, vb):
            assert (oa.similar, oa.certified) == (ob.similar, ob.certified)


AUTO_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from repro import ged
    from repro.ged.exec import ShardedExecutor
    from repro.data.graphs import perturb, random_graph

    assert jax.device_count() == 8
    rng = np.random.default_rng(6)
    pairs = []
    for _ in range(11):     # odd count: rung batches pad to multiples of 8
        q = random_graph(rng, int(rng.integers(4, 9)), density=0.4,
                         n_vlabels=3, n_elabels=2)
        pairs.append((q, perturb(rng, q, 3, n_vlabels=3, n_elabels=2)))
    opts = dict(batch_size=4, pool=256, expand=4, max_iters=256)

    ref = ged.GedEngine("auto", **opts).compute(pairs)
    mesh = jax.make_mesh((8,), ("data",))
    eng = ged.GedEngine("auto", mesh=mesh, **opts)
    assert isinstance(eng._backend.executor, ShardedExecutor)
    assert eng.batch_multiple == 8
    got = eng.compute(pairs)
    assert [(o.ged, o.certified) for o in got] == \\
        [(o.ged, o.certified) for o in ref]

    vref = ged.GedEngine("auto", **opts).verify(pairs, 4.0)
    vgot = ged.GedEngine("auto", mesh=mesh, **opts).verify(pairs, 4.0)
    assert [(o.similar, o.certified) for o in vgot] == \\
        [(o.similar, o.certified) for o in vref]
    print("OK")
""")


@pytest.mark.slow
def test_auto_on_mesh_parity_on_8_devices():
    """The PR-2 subprocess harness, pointed at auto-on-sharded: overlapped
    escalation over a real 8-shard mesh answers exactly like plain auto."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", AUTO_MESH_SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ------------------------------------------- overlapped-vs-sequential

def test_sequential_and_overlapped_agree_under_escalation():
    pairs = _pairs(1, 12)
    seq = _tiny_rungs(ged.GedEngine("auto", overlap=False, **OPTS))
    ovl = _tiny_rungs(ged.GedEngine("auto", overlap=True, max_in_flight=3,
                                    **OPTS))
    a = seq.compute(pairs)
    b = ovl.compute(pairs)
    assert [(o.ged, o.certified) for o in a] == \
        [(o.ged, o.certified) for o in b]

    vseq = _tiny_rungs(ged.GedEngine("auto", overlap=False, **OPTS))
    vovl = _tiny_rungs(ged.GedEngine("auto", overlap=True, **OPTS))
    va = vseq.verify(pairs, 3.0)
    vb = vovl.verify(pairs, 3.0)
    assert [(o.similar, o.certified) for o in va] == \
        [(o.similar, o.certified) for o in vb]


def test_overlapped_escalation_never_uncertified():
    """Regression guard for the async scheduler: whatever rung answered a
    pair — engine rung in flight, re-bucketed survivor, or host-solver
    tail overlapped with device work — the outcome carries a certificate
    and matches the brute-force oracle."""
    pairs = _pairs(2, 10, nmin=3, nmax=6, ops=(2, 6))
    truth = [brute_force_ged(q, g) for q, g in pairs]
    eng = _tiny_rungs(ged.GedEngine("auto", overlap=True, max_in_flight=3,
                                    **OPTS), rungs=((4, 1, 2),))
    outs = eng.compute(pairs)
    assert all(o.certified for o in outs)
    assert [o.ged for o in outs] == truth
    # the tiny ladder must have really exercised escalation + host tail
    assert eng.stats["escalated"] > 0
    assert eng.stats["host_solved"] > 0
    assert any(o.rung == -1 for o in outs)


def test_overlap_stats_knobs():
    pairs = _pairs(3, 8)
    eng = _tiny_rungs(ged.GedEngine("auto", overlap=True, **OPTS))
    eng.compute(pairs)
    s = eng.stats
    assert s["overlap_saved_s"] >= 0.0
    assert s["dispatches"] > 0 and s["batches"] > 0
    assert "survivors_rung_0" in s
    survivors = sum(v for k, v in s.items()
                    if k.startswith("survivors_rung_"))
    assert survivors == s["escalated"]


# ------------------------------------------------ survivor re-bucketing

def test_subset_buckets_rebuckets_survivors():
    from repro.ged.plan import build_plan

    sizes = [3, 5, 8, 4, 6]
    rng = np.random.default_rng(4)
    pairs = []
    for n in sizes:
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        pairs.append((q, perturb(rng, q, 2, n_vlabels=3, n_elabels=2)))

    plan = build_plan(pairs)
    ex = Executor()
    survivors = [0, 2, 3]
    buckets = plan.subset_buckets(survivors, ex.pack)
    assert sorted(i for b in buckets for i in b.indices) == survivors
    # sizes 3 and 4 share the 4-slot bucket; size 8 gets its own
    assert [b.slots for b in buckets] == [4, 8]
    for b in buckets:
        assert b.packed.batch % ex.batch_multiple == 0
        assert b.real == len(b.indices)

    # pinned slots disable re-bucketing: one bucket at the fixed shape
    pinned = build_plan(pairs, slots=16)
    (bucket,) = pinned.subset_buckets(survivors, ex.pack)
    assert bucket.slots == 16 and bucket.indices == survivors


def test_shard_padded_subset_buckets():
    """Re-bucketed survivor batches honour the executor's shard multiple
    (what a mesh executor needs between rungs)."""
    from repro.ged.plan import build_plan

    class Wide(Executor):
        batch_multiple = 8

    pairs = _pairs(5, 5, nmin=3, nmax=6)
    plan = build_plan(pairs)
    buckets = plan.subset_buckets([0, 1, 4], Wide().pack)
    assert all(b.packed.batch % 8 == 0 for b in buckets)
    assert sorted(i for b in buckets for i in b.indices) == [0, 1, 4]
