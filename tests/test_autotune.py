"""Measured kernel autotuning + per-bucket dispatch (kernels/autotune.py).

Three contracts under test:

* the tuning table: round-trip through disk, corrupt-file recovery,
  nearest-B fallback, hit/miss counters;
* dispatch resolution: ``use_kernel="auto"`` pins a concrete
  ``KernelDispatch`` pre-jit (table winners when tuned, the conservative
  static heuristic when not, hostile tile sizes neutralised);
* the invariant everything rests on: engine outcomes are bit-identical
  across every dispatch decision — fused/unfused x tuned/untuned tiles x
  astar/dfs x compute/verify — so dispatch can only ever change speed.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.engine import EngineConfig, ged_batch, pack_pairs, \
    verify_batch
from repro.data.graphs import perturb, random_graph
from repro.kernels import autotune
from repro.kernels.autotune import KernelDispatch


@pytest.fixture(autouse=True)
def _isolated_table():
    """Every test runs on a private in-memory table and restores the
    process-global state afterwards (the table is process-global by
    design, like the persistent compile cache)."""
    saved = autotune.snapshot()
    autotune.reset()
    yield
    autotune.restore(saved)


def _make_pairs(seed, count, nmin=4, nmax=9, ops=5):
    rng = np.random.default_rng(seed)
    pairs = []
    for _ in range(count):
        n = int(rng.integers(nmin, nmax))
        q = random_graph(rng, n, density=0.35, n_vlabels=3, n_elabels=2)
        if rng.random() < 0.5:
            g = perturb(rng, q, int(rng.integers(0, ops)),
                        n_vlabels=3, n_elabels=2)
        else:
            g = random_graph(rng, int(rng.integers(nmin, nmax)),
                             density=0.35, n_vlabels=3, n_elabels=2)
        pairs.append((q, g))
    return pairs


# ------------------------------------------------------------------ table

def test_table_round_trip(tmp_path):
    autotune.enable_autotune(str(tmp_path))
    autotune.put("lsa", 32, 8, {"impl": "fused", "tile_u": 8, "us": 1.0})
    autotune.put("merge", 512, 256, {"impl": "unfused", "us": 2.0})
    # a fresh process-equivalent: reset then re-enable the same dir
    autotune.reset()
    autotune.enable_autotune(str(tmp_path))
    ent = autotune.lookup("lsa", 32, 8, count=False)
    assert ent is not None and ent["impl"] == "fused" \
        and ent["tile_u"] == 8
    assert autotune.lookup("merge", 512, 256, count=False)["us"] == 2.0
    # entries carry their identity + device key
    assert ent["kernel"] == "lsa" and ent["N"] == 32 and ent["B"] == 8
    assert ent["device_kind"] == autotune.device_kind()


def test_table_corrupt_file_recovers_empty(tmp_path):
    path = tmp_path / autotune.TABLE_FILE
    path.write_text("{this is not json")
    autotune.enable_autotune(str(tmp_path))
    assert autotune.lookup("lsa", 32, 8, count=False) is None
    # and the table is usable again: writes land and persist
    autotune.put("lsa", 32, 8, {"impl": "unfused"})
    data = json.loads(path.read_text())
    assert data["version"] == autotune._SCHEMA_VERSION
    assert len(data["entries"]) == 1


@pytest.mark.parametrize("payload", [
    "[]",                                   # wrong top-level type
    '{"version": 999, "entries": {}}',      # alien schema version
    '{"version": 1, "entries": [1, 2]}',    # entries not a map
])
def test_table_alien_schema_recovers_empty(tmp_path, payload):
    (tmp_path / autotune.TABLE_FILE).write_text(payload)
    autotune.enable_autotune(str(tmp_path))
    assert autotune._AUTOTUNE["table"] == {}


def test_lookup_nearest_b_and_counters():
    autotune.put("lsa", 32, 8, {"impl": "unfused"})
    autotune.put("lsa", 32, 128, {"impl": "fused", "tile_u": 0})
    # exact hit
    assert autotune.lookup("lsa", 32, 8)["impl"] == "unfused"
    # nearest-B in log space: B=64 is closer to 128 than to 8
    assert autotune.lookup("lsa", 32, 64)["impl"] == "fused"
    assert autotune.lookup("lsa", 32, 2)["impl"] == "unfused"
    # other N -> miss
    assert autotune.lookup("lsa", 64, 8) is None
    s = autotune.autotune_stats()
    assert s["autotune_hits"] == 3 and s["autotune_misses"] == 1
    assert s["autotune_entries"] == 2
    assert "pallas_interpret" in s


def test_enable_is_idempotent_and_repoint_reloads(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    autotune.enable_autotune(str(a))
    autotune.put("lsa", 16, 8, {"impl": "fused"})
    assert autotune.enable_autotune(str(a)) == str(a)   # no-op
    assert autotune.lookup("lsa", 16, 8, count=False) is not None
    autotune.enable_autotune(str(b))                    # re-point: empty
    assert autotune.lookup("lsa", 16, 8, count=False) is None
    autotune.enable_autotune(str(a))                    # back: reloaded
    assert autotune.lookup("lsa", 16, 8, count=False) is not None


# --------------------------------------------------------------- dispatch

def test_resolve_config_uses_table_winners():
    autotune.put("lsa", 16, 64, {"impl": "fused", "tile_u": 8})
    autotune.put("bma", 16, 64, {"impl": "unfused"})
    autotune.put("merge", 1024, 128, {"impl": "fused"})
    cfg = EngineConfig(use_kernel="auto")
    r = autotune.resolve_config(cfg, slots=16, batch=8)   # b_eff = 64
    assert r.use_kernel == "auto"
    assert r.dispatch == KernelDispatch(
        lsa_fused=True, lsa_tile_u=8, bma_fused=False, merge_fused=True)
    # non-auto configs pass through untouched
    cfg2 = EngineConfig(use_kernel=True)
    assert autotune.resolve_config(cfg2, 16, 8) is cfg2


def test_resolve_config_untuned_falls_back_to_heuristic():
    cfg = EngineConfig(use_kernel="auto")
    r = autotune.resolve_config(cfg, slots=16, batch=8)
    assert r.dispatch == autotune.static_heuristic(16)
    if autotune.pallas_interpret():
        # the CPU footgun fix: interpret-mode pallas never wins by default
        assert r.dispatch == KernelDispatch()


def test_resolve_config_neutralises_hostile_tiles():
    # a hand-edited table entry whose tile doesn't divide the bucket
    autotune.put("lsa", 16, 64, {"impl": "fused", "tile_u": 7})
    autotune.put("bma", 16, 64, {"impl": "fused", "tile_v": "x",
                                 "tile_u": -8})
    r = autotune.resolve_config(EngineConfig(use_kernel="auto"), 16, 8)
    assert r.dispatch.lsa_fused and r.dispatch.lsa_tile_u == 0
    assert r.dispatch.bma_fused and r.dispatch.bma_tile_v == 0 \
        and r.dispatch.bma_tile_u == 0


def test_concrete_dispatch_is_pure_in_cfg():
    # booleans map to global on/off regardless of the table
    autotune.put("lsa", 16, 8, {"impl": "unfused"})
    on = autotune.concrete_dispatch(EngineConfig(use_kernel=True), 16)
    assert on.lsa_fused and on.bma_fused and not on.merge_fused
    off = autotune.concrete_dispatch(EngineConfig(use_kernel=False), 16)
    assert off == KernelDispatch()
    # a resolved dispatch wins over everything
    d = KernelDispatch(merge_fused=True)
    cfg = EngineConfig(use_kernel="auto", dispatch=d)
    assert autotune.concrete_dispatch(cfg, 16) is d
    # unresolved "auto" at trace time -> the static heuristic, never the
    # table (the jit cache keys on cfg, not on mutable table state)
    cfg2 = EngineConfig(use_kernel="auto")
    assert autotune.concrete_dispatch(cfg2, 16) == \
        autotune.static_heuristic(16)


def test_engine_config_validates_use_kernel():
    with pytest.raises(ValueError):
        EngineConfig(use_kernel="fast")
    # the three legal values construct fine
    for v in (True, False, "auto"):
        assert EngineConfig(use_kernel=v).use_kernel == v


def test_tune_shape_records_measured_winner():
    ent = autotune.tune_shape("lsa", 8, 4, tiles=((0, 0),), budget_s=0.01)
    assert ent["impl"] in ("fused", "unfused")
    assert ent["us"] == min(ent["fused_us"], ent["unfused_us"])
    assert autotune.lookup("lsa", 8, 4, count=False) is ent or \
        autotune.lookup("lsa", 8, 4, count=False) == ent
    assert autotune.autotune_stats()["autotune_sweep_s"] > 0


# ------------------------------------------------- engine parity (the gate)

_DISPATCHES = [
    KernelDispatch(),                                        # all unfused
    KernelDispatch(lsa_fused=True, bma_fused=True),          # default tiles
    KernelDispatch(lsa_fused=True, lsa_tile_u=8,
                   bma_fused=True, bma_tile_v=8, bma_tile_u=8),  # tuned
    KernelDispatch(merge_fused=True),                        # fused merge
    KernelDispatch(lsa_fused=True, bma_fused=True,
                   merge_fused=True),                        # everything
]


@pytest.mark.parametrize("strategy", ["astar", "dfs"])
def test_engine_bit_identical_across_dispatch_compute(strategy):
    """Every dispatch decision must yield byte-identical engine output —
    the whole dict, not just the distance (the kernels are exact vs their
    oracles and the merge kernel computes identical integer ranks)."""
    pairs = _make_pairs(23, 6)
    t = pack_pairs(pairs, slots=16)
    base = dict(pool=128, expand=4, max_iters=128, strategy=strategy)
    ref = ged_batch(t, EngineConfig(use_kernel=False, **base))
    for d in _DISPATCHES:
        cfg = EngineConfig(use_kernel="auto", dispatch=d, **base)
        out = ged_batch(t, cfg)
        assert set(out) == set(ref)
        for key in out:
            assert np.array_equal(out[key], ref[key]), (strategy, d, key)


@pytest.mark.parametrize("strategy", ["astar", "dfs"])
def test_engine_bit_identical_across_dispatch_verify(strategy):
    pairs = _make_pairs(27, 6)
    t = pack_pairs(pairs, slots=16)
    taus = np.asarray([2.0, 3.0, 2.0, 4.0, 1.0, 3.0], np.float32)
    base = dict(pool=128, expand=4, max_iters=128, strategy=strategy)
    ref = verify_batch(t, taus, EngineConfig(use_kernel=False, **base))
    for d in (_DISPATCHES[2], _DISPATCHES[4]):
        cfg = EngineConfig(use_kernel="auto", dispatch=d, **base)
        out = verify_batch(t, taus, cfg)
        for key in out:
            assert np.array_equal(out[key], ref[key]), (strategy, d, key)


def test_dispatch_never_changes_outcome_property():
    """Hypothesis: for random pairs and random dispatch plans, every
    ``GedOutcome`` field through the public facade is invariant."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro import ged

    # draw from a fixed palette so jit compilations are shared across
    # examples (each distinct cfg is its own trace)
    palette = st.sampled_from(_DISPATCHES)

    base = ged.GedEngine("jax", cache=False, pool=128, max_iters=128)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 10), d=palette)
    def check(seed, d):
        pairs = _make_pairs(seed, 3, nmin=4, nmax=8)
        eng = ged.GedEngine("jax", use_kernel="auto", cache=False,
                            pool=128, max_iters=128, dispatch=d)
        oa = eng.compute(pairs)
        ob = base.compute(pairs)
        for a, b in zip(oa, ob):
            assert (a.ged, a.similar, a.certified, a.lower_bound,
                    a.upper_bound) == (b.ged, b.similar, b.certified,
                                       b.lower_bound, b.upper_bound)
            assert np.array_equal(a.mapping, b.mapping)

    check()


# ----------------------------------------------------------------- facade

def test_facade_accepts_auto_on_every_backend():
    from repro import ged
    pairs = _make_pairs(3, 3, nmin=4, nmax=7)
    outs = {}
    for backend in ("jax", "pallas", "exact"):
        eng = ged.GedEngine(backend, use_kernel="auto", cache=False,
                            pool=128, max_iters=128)
        outs[backend] = [(o.ged, o.certified) for o in eng.compute(pairs)]
    assert outs["jax"] == outs["pallas"] == outs["exact"]
    # contradicting booleans still raise
    with pytest.raises(ValueError):
        ged.GedEngine("jax", use_kernel=True)
    with pytest.raises(ValueError):
        ged.GedEngine("pallas", use_kernel=False)


def test_facade_stats_surface_autotune_and_interpret(tmp_path):
    from repro import ged
    eng = ged.GedEngine("jax", use_kernel="auto", cache=False,
                        autotune_dir=str(tmp_path), pool=128,
                        max_iters=128)
    assert eng.autotune_dir == str(tmp_path)
    eng.compute(_make_pairs(5, 2, nmin=4, nmax=7))
    s = eng.stats
    for key in ("autotune_hits", "autotune_misses", "autotune_sweep_s",
                "autotune_entries", "pallas_interpret"):
        assert key in s, key
    # untuned shapes miss into the heuristic and are counted
    assert s["autotune_misses"] >= 1
    import jax
    assert s["pallas_interpret"] == (jax.default_backend() != "tpu")


def test_facade_auto_resolution_keys_compile_cache(tmp_path):
    """Two buckets, one engine: each resolves its own dispatch, and the
    executor's compile ledger sees the resolved configs."""
    from repro import ged
    autotune.enable_autotune(str(tmp_path))
    # make slots-8 buckets prefer a fused merge, leave slots-16 untuned
    autotune.put("merge", 1024, 64, {"impl": "fused"})
    eng = ged.GedEngine("jax", use_kernel="auto", cache=False,
                        pool=128, max_iters=128)
    small = _make_pairs(7, 2, nmin=4, nmax=7)
    big = _make_pairs(9, 2, nmin=10, nmax=13)
    outs = eng.compute(small + big)
    assert len(outs) == 4
    ref = ged.GedEngine("jax", cache=False, pool=128, max_iters=128)
    want = ref.compute(small + big)
    for a, b in zip(outs, want):
        assert (a.ged, a.certified) == (b.ged, b.certified)
