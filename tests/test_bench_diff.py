"""The benchmark-trajectory diff tool: section/row/metric alignment,
regression detection, and baseline fallbacks."""

import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

from bench_diff import (diff_sections, label_rows, regressions,  # noqa: E402
                        row_label)

OLD = {
    "backend_throughput": [
        {"backend": "jax", "pairs_per_s": 100.0, "compile_s": 5.0},
        {"backend": "sharded", "pairs_per_s": 80.0},
    ],
    "escalation_overlap": [{"mode": "sequential", "pairs_per_s": 50.0}],
}
NEW = {
    "backend_throughput": [
        {"backend": "jax", "pairs_per_s": 70.0, "compile_s": 4.0},
        {"backend": "sharded", "pairs_per_s": 85.0},
    ],
    "similarity_search": [{"corpus": 132, "queries_per_s": 9.0}],
}


def test_rows_align_by_identity_not_position():
    rows = diff_sections(OLD, NEW)
    jax_tp = next(r for r in rows if r["row"] == "backend=jax"
                  and r["metric"] == "pairs_per_s")
    assert jax_tp["old"] == 100.0 and jax_tp["new"] == 70.0
    assert jax_tp["delta_pct"] == -30.0
    shard = next(r for r in rows if r["row"] == "backend=sharded"
                 and r["metric"] == "pairs_per_s")
    assert shard["delta_pct"] == 6.25


def test_added_and_removed_sections_survive():
    rows = diff_sections(OLD, NEW)
    added = [r for r in rows if r["section"] == "similarity_search"]
    assert added and all(r["old"] is None and r["delta_pct"] is None
                         for r in added)
    gone = [r for r in rows if r["section"] == "escalation_overlap"]
    assert gone and all(r["new"] is None for r in gone)


def test_regressions_flag_only_big_throughput_drops():
    rows = diff_sections(OLD, NEW)
    regs = regressions(rows, threshold_pct=20.0)
    assert [(r["row"], r["metric"]) for r in regs] == \
        [("backend=jax", "pairs_per_s")]
    assert regressions(rows, threshold_pct=50.0) == []
    # non-throughput metrics (compile_s shrank 20%) never count
    assert all(r["metric"].endswith("_per_s") for r in regs)


def test_examined_frac_regresses_when_it_rises():
    """Stage −1 selectivity is smaller-is-better: a rising examined_frac
    is a regression, a falling one is an improvement."""
    old = {"candidate_index": [
        {"case": "exact/100000", "examined_frac": 0.01,
         "queries_per_s": 5.0}]}
    worse = {"candidate_index": [
        {"case": "exact/100000", "examined_frac": 0.05,
         "queries_per_s": 5.0}]}
    better = {"candidate_index": [
        {"case": "exact/100000", "examined_frac": 0.002,
         "queries_per_s": 5.0}]}
    regs = regressions(diff_sections(old, worse), threshold_pct=20.0)
    assert [(r["row"], r["metric"]) for r in regs] == \
        [("case=exact/100000", "examined_frac")]
    assert regressions(diff_sections(old, better), threshold_pct=20.0) == []


def test_row_label_falls_back_to_position():
    assert row_label({"backend": "jax"}, 0) == "backend=jax"
    assert row_label({"tau": 3.0}, 1) == "tau=3.0"
    assert row_label({"x": 1}, 2) == "row2"


def test_duplicate_row_labels_do_not_collide():
    """Two rows with the same identifying field must both be diffed."""
    rows = [{"backend": "jax", "pairs_per_s": 10.0},
            {"backend": "jax", "pairs_per_s": 20.0}]
    assert set(label_rows(rows)) == {"backend=jax", "backend=jax#1"}
    diff = diff_sections({"s": rows},
                         {"s": [{"backend": "jax", "pairs_per_s": 10.0},
                                {"backend": "jax", "pairs_per_s": 5.0}]})
    tp = {r["row"]: r for r in diff if r["metric"] == "pairs_per_s"}
    assert tp["backend=jax"]["delta_pct"] == 0.0
    assert tp["backend=jax#1"]["delta_pct"] == -75.0
    assert len(regressions(diff, 20.0)) == 1


def test_cli_handles_missing_file_and_is_non_blocking(tmp_path):
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "bench_diff.py"),
         str(tmp_path / "nope.json")],
        capture_output=True, text=True, cwd=ROOT)
    assert out.returncode == 0                   # warn, never gate
    # a file with no committed baseline also exits 0
    scratch = tmp_path / "BENCH.json"
    scratch.write_text(json.dumps(NEW))
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "bench_diff.py"),
         str(scratch)],
        capture_output=True, text=True, cwd=ROOT)
    assert out.returncode == 0
    assert "no baseline" in out.stdout
