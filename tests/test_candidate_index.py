"""``ged.CandidateIndex`` — the stage −1 candidate generator: signature
host/device parity (including the 8-device sharded build), empirical
admissibility of the sketch-damage constant, exact-mode probe soundness
against the brute-force oracle (seeded sweeps plus a hypothesis
property), probabilistic-mode measured recall, band-table reuse, pivot
triangle bounds through the engine's shared result cache, the restricted
stage-0 subset scan, and ``GraphStore(index=None)`` parity."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ged
from repro.core.exact.brute import brute_force_ged
from repro.data.graphs import perturb, random_graph
from repro.ged.exec import (Executor, SketchSpec, batch_signatures,
                            graph_digest, wl_signature)
from repro.ged.index import CandidateIndex, sketch_damage

STORE_OPTS = dict(pool=256, expand=4, max_iters=256, batch_size=8)


def _corpus(seed, count, nmin=3, nmax=7, planted=2):
    rng = np.random.default_rng(seed)
    graphs = [random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                           density=0.4, n_vlabels=3, n_elabels=2)
              for _ in range(count)]
    for _ in range(planted):
        graphs.append(perturb(rng, graphs[0], int(rng.integers(1, 3)),
                              n_vlabels=3, n_elabels=2))
    return graphs


# --------------------------------------------------- signature parity

@pytest.mark.parametrize("spec", [
    SketchSpec(),
    SketchSpec(wl_iters=1),
    SketchSpec(dims_v=32, dims_e=8, wl_iters=2),
])
def test_batch_signatures_match_host_signatures(spec):
    """The JAX-batched corpus signature build is bit-identical to the
    host path — exact-mode soundness leans on the two never diverging."""
    rng = np.random.default_rng(11)
    graphs = [random_graph(rng, int(rng.integers(2, 11)), density=0.5,
                           n_vlabels=5, n_elabels=3) for _ in range(40)]
    sigs = batch_signatures(graphs, spec, Executor())
    assert sigs.shape == (40, spec.dims)
    host = np.stack([wl_signature(g, spec) for g in graphs])
    assert np.array_equal(sigs, host)
    for g, s in zip(graphs, host):
        assert s[-2] == g.n and s[-1] == np.count_nonzero(g.adj) // 2


def test_sketch_damage_bounds_sketch_movement():
    """Empirical admissibility: k unit edits never move the sketch by
    more than k * damage in L1, at depth 0 and depth 1."""
    rng = np.random.default_rng(12)
    for spec in (SketchSpec(), SketchSpec(wl_iters=1)):
        for _ in range(40):
            g = random_graph(rng, int(rng.integers(3, 9)), density=0.5,
                             n_vlabels=3, n_elabels=2)
            k = int(rng.integers(1, 4))
            h = perturb(rng, g, k, n_vlabels=3, n_elabels=2)
            deg = max(int(g.degrees().max()), int(h.degrees().max()))
            damage = sketch_damage(spec, deg)
            l1 = int(np.abs(wl_signature(g, spec).astype(np.int64)
                            - wl_signature(h, spec).astype(np.int64)).sum())
            assert l1 <= damage * k, (spec, k, l1, damage)


# ----------------------------------------------------- probe soundness

def test_exact_probe_is_sound_against_bruteforce():
    """exact=True stage −1 never drops a graph within tau, and the lower
    bounds it reports never exceed the true GED."""
    corpus = _corpus(13, 20, planted=4)
    idx = CandidateIndex(corpus, list(range(len(corpus))))
    assert idx.exact
    rng = np.random.default_rng(14)
    queries = [corpus[0], corpus[-1],
               random_graph(rng, 5, density=0.5, n_vlabels=3, n_elabels=2)]
    for q in queries:
        truth = [brute_force_ged(q, g) for g in corpus]
        for tau in (0.0, 1.0, 2.0, 3.0):
            got = idx.probe(q, tau)
            for i, t in enumerate(truth):
                if t <= tau:
                    assert i in got, (tau, i, t, sorted(got))
            for i, lb in got.items():
                assert lb <= truth[i] + 1e-6, (tau, i, lb, truth[i])


def test_exact_probe_soundness_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), tau=st.integers(0, 4))
    def run(seed, tau):
        rng = np.random.default_rng(seed)
        corpus = [random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                               n_vlabels=2, n_elabels=2) for _ in range(8)]
        query = random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                             n_vlabels=2, n_elabels=2)
        idx = CandidateIndex(corpus, list(range(len(corpus))),
                             reps=1 + seed % 3)
        got = idx.probe(query, float(tau))
        for i, g in enumerate(corpus):
            if brute_force_ged(query, g) <= tau:
                assert i in got, (seed, tau, i)

    run()


def test_probabilistic_probe_meets_recall_target():
    """recall=r keeps ceil(r * (budget+1)) pigeonhole bands, so measured
    recall over a seeded workload must come out >= the configured r."""
    corpus = _corpus(15, 24, planted=6)
    idx = CandidateIndex(corpus, list(range(len(corpus))), recall=0.7)
    assert not idx.exact
    tau, hits, found = 2.0, 0, 0
    for qi in (0, 1, len(corpus) - 1, len(corpus) - 2):
        q = corpus[qi]
        got = idx.probe(q, tau)
        for i, g in enumerate(corpus):
            if brute_force_ged(q, g) <= tau:
                hits += 1
                found += int(i in got)
    assert hits > 0
    assert found / hits >= 0.7, (found, hits)
    with pytest.raises(ValueError):
        CandidateIndex(corpus, [0], recall=0.0)
    with pytest.raises(ValueError):
        CandidateIndex(corpus, [0], recall=1.5)


def test_band_tables_built_lazily_and_reused():
    corpus = _corpus(16, 16)
    idx = CandidateIndex(corpus, list(range(len(corpus))), reps=2)
    assert idx.stats["tables_built"] == 0        # ingest builds nothing
    q = corpus[0]
    idx.probe(q, 1.0)
    built = idx.stats["tables_built"]
    assert built == 2                            # one table per rep
    idx.probe(corpus[1], 1.0)
    idx.probe(q, 1.0)
    assert idx.stats["tables_built"] == built    # same band count: reused
    idx.probe(q, 2.0)                            # wider budget: new tables
    assert idx.stats["tables_built"] == built + 2


def test_probe_falls_back_to_linear_scan_when_bands_exceed_dims():
    """When budget+1 > sketch dims banding cannot certify anything — the
    probe must degrade to the (sound) full-sketch scan, not mis-prune."""
    corpus = _corpus(17, 10)
    idx = CandidateIndex(corpus, list(range(len(corpus))),
                         dims_v=4, dims_e=2)
    q = corpus[0]
    tau = float(idx.spec.dims)                   # budget = 2*tau >> dims
    got = idx.probe(q, tau)
    assert idx.stats["probe_fallbacks"] == 1
    truth = [brute_force_ged(q, g) for g in corpus]
    for i, t in enumerate(truth):
        if t <= tau:
            assert i in got


# ----------------------------------------- pivots + shared result cache

def test_pivot_bounds_are_admissible_and_use_shared_cache():
    corpus = _corpus(18, 12, planted=3)
    eng = ged.GedEngine("jax", **{k: v for k, v in STORE_OPTS.items()
                                  if k != "batch_size"})
    idx = CandidateIndex(corpus, list(range(len(corpus))),
                         pivot_seeds=2, pivot_coverage=6,
                         pivot_min_candidates=1)
    idx.bind_engine(eng)
    assert idx.seed_pivots() > 0                 # DB–DB pairs -> eng cache
    assert idx.use_pivots
    rng = np.random.default_rng(19)
    q = random_graph(rng, 5, density=0.5, n_vlabels=3, n_elabels=2)
    ids = list(range(len(corpus)))
    bounds = idx.pivot_bounds(q, ids)
    assert eng.stats["index_pivot_hits"] >= 1    # cached d(p, y) reads
    for y, lb in bounds.items():
        assert lb > 0.0
        assert lb <= brute_force_ged(q, corpus[y]) + 1e-6, (y, lb)


def test_cached_distance_probes_both_orientations_and_counts():
    rng = np.random.default_rng(20)
    a = random_graph(rng, 5, density=0.5, n_vlabels=3, n_elabels=2)
    b = perturb(rng, a, 1, n_vlabels=3, n_elabels=2)
    c = random_graph(rng, 4, density=0.5, n_vlabels=3, n_elabels=2)
    eng = ged.GedEngine("exact")
    assert eng.cached_distance(a, b) is None     # cold cache
    assert eng.stats["index_pivot_misses"] == 1
    d = eng.compute([(a, b)])[0].ged
    hits0 = eng.stats["result_cache_hits"]
    assert eng.cached_distance(b, a) == d        # reversed orientation
    assert eng.stats["index_pivot_hits"] == 1
    assert eng.stats["result_cache_hits"] == hits0   # peek: no LRU churn
    # a verification-only entry (tau-keyed) must never answer a
    # distance probe — its ged field may be a bound, not the distance
    eng.verify([(a, c)], [0.0])
    assert eng.cached_distance(a, c) is None
    # digests= path reads the same entries without re-hashing
    assert eng.cached_distance(
        digests=(graph_digest(ged.as_graph(a)),
                 graph_digest(ged.as_graph(b)))) == d


def test_store_index_none_reproduces_indexed_answers_bit_for_bit():
    corpus = _corpus(21, 14, planted=3)
    indexed = ged.GraphStore(corpus, **STORE_OPTS)
    flat = ged.GraphStore(corpus, index=None, **STORE_OPTS)
    assert flat._cindex is None and indexed._cindex is not None
    rng = np.random.default_rng(22)
    queries = [corpus[0],
               random_graph(rng, 5, density=0.5, n_vlabels=3, n_elabels=2)]
    for q in queries:
        for tau in (0.0, 2.0, 4.0):
            assert [(h.graph_id, h.ged, h.similar, h.certified)
                    for h in indexed.range_search(q, tau)] == \
                   [(h.graph_id, h.ged, h.similar, h.certified)
                    for h in flat.range_search(q, tau)], tau
        for k in (1, 3, 7):
            assert [(h.graph_id, h.ged) for h in indexed.top_k(q, k)] == \
                   [(h.graph_id, h.ged) for h in flat.top_k(q, k)], k
    s = indexed.stats
    assert s["index_pruned"] > 0                 # the index did real work
    assert s["index_sketch_pruned"] + s["index_pivot_pruned"] == \
        s["index_pruned"]


def test_store_accepts_index_knobs_and_instance():
    corpus = _corpus(23, 8)
    knobbed = ged.GraphStore(corpus, index={"recall": 0.9, "reps": 1},
                             **STORE_OPTS)
    assert knobbed._cindex is not None and not knobbed._cindex.exact
    hits = knobbed.range_search(corpus[0], 0.0)
    assert any(h.graph_id == 0 for h in hits)
    with pytest.raises(ValueError):
        ged.GraphStore(corpus, index="bogus", **STORE_OPTS)


def test_scan_subset_matches_full_scan():
    from repro.ged.filters import FilterIndex
    from repro.ged.plan import graphs_vocab
    rng = np.random.default_rng(24)
    graphs = [random_graph(rng, int(rng.integers(2, 9)), density=0.4,
                           n_vlabels=3, n_elabels=2) for _ in range(17)]
    idx = FilterIndex(graphs, list(range(len(graphs))),
                      graphs_vocab(graphs), Executor())
    q = random_graph(rng, 5, density=0.4, n_vlabels=3, n_elabels=2)
    full = idx.scan_by_id(q)
    for subset in ([0], [3, 11, 16], list(range(0, 17, 2))):
        scanned0 = idx.stats["scanned"]
        got = idx.scan_subset(q, subset)
        assert idx.stats["scanned"] - scanned0 == len(subset)
        assert set(got) == set(subset)
        for gid in subset:
            assert got[gid] == pytest.approx(full[gid]), gid
    assert idx.stats["subset_scans"] == 3


# ------------------------------------------- sharded signature build

SHARDED_SIGS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from repro.data.graphs import random_graph
    from repro.ged.exec import (ShardedExecutor, SketchSpec,
                                batch_signatures, wl_signature)

    assert jax.device_count() == 8
    rng = np.random.default_rng(25)
    graphs = [random_graph(rng, int(rng.integers(2, 11)), density=0.5,
                           n_vlabels=5, n_elabels=3) for _ in range(37)]
    ex = ShardedExecutor(jax.make_mesh((8,), ("data",)))
    for spec in (SketchSpec(), SketchSpec(wl_iters=1)):
        sigs = batch_signatures(graphs, spec, ex, chunk=16)
        host = np.stack([wl_signature(g, spec) for g in graphs])
        assert np.array_equal(sigs, host), spec
    print("OK")
""")


@pytest.mark.slow
def test_sharded_signature_build_parity_on_8_devices():
    """batch_signatures under a real 8-device ShardedExecutor stays
    bit-identical to the host signature path (exact-mode soundness)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_SIGS_SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
