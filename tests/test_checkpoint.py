"""Checkpoint manager: atomic commit, GC, async writes, elastic restore."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    t = _tree()
    m.save(10, t, extra={"data_step": 10})
    step, t2, extra = m.restore(t)
    assert step == 10 and extra == {"data_step": 10}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path):
    m = CheckpointManager(tmp_path, keep_last_k=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, t)
    m.wait()
    assert m.all_steps() == [3, 4]
    # no tmp dirs left behind
    assert not [p for p in os.listdir(tmp_path) if ".tmp-" in p]


def test_atomic_no_partial_state_visible(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    t = _tree()
    m.save(5, t)
    # simulate a crashed write: stray tmp dir must be ignored
    crash = tmp_path / "step_00000009.tmp-deadbeef"
    crash.mkdir()
    (crash / "manifest.json").write_text("{}")
    assert m.latest_step() == 5


def test_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, _tree())
    bad = {"layers": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4, 8))},
           "step": jnp.asarray(0)}
    with pytest.raises((ValueError, KeyError)):
        m.restore(bad)


ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager

    mesh = jax.make_mesh((%d,), ("data",))
    sh = NamedSharding(mesh, P("data"))
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    tree = {"w": jax.device_put(tree["w"], sh)}
    m = CheckpointManager(%r, async_save=False)
    if %r == "save":
        m.save(3, tree)
    else:
        step, t2, _ = m.restore(tree, shardings={"w": sh})
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(t2["w"]),
            np.arange(32, dtype=np.float32).reshape(8, 4))
        assert t2["w"].sharding.is_equivalent_to(sh, 2)
    print("OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Save on 8 devices, restore on 4 — global arrays re-shard host-side."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for devs, mode in ((8, "save"), (4, "restore")):
        script = ELASTIC % (devs, os.path.abspath(src), devs,
                            str(tmp_path), mode)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
