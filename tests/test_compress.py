"""Int8 error-feedback gradient compression (optim/compress.py)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.optim.compress import (compress_int8, decompress_int8,
                                  error_feedback_update)


def test_roundtrip_bounded_error(rng):
    x = np.asarray(rng.normal(size=(64, 64)) * 3.0, np.float32)
    q, scale = compress_int8(x)
    err = np.abs(decompress_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges(rng):
    """Residual carry: the long-run mean of decompressed grads equals the
    true gradient (unbiasedness of error feedback)."""
    import jax.numpy as jnp
    g = jnp.asarray(rng.normal(size=(32,)) * 1e-3, jnp.float32)
    r = jnp.zeros_like(g)
    acc = np.zeros((32,), np.float64)
    n = 50
    for _ in range(n):
        q, s, r = error_feedback_update(g, r)
        acc += np.asarray(decompress_int8(q, s), np.float64)
    np.testing.assert_allclose(acc / n, np.asarray(g), atol=float(s) / n + 1e-7)


PSUM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.optim.compress import psum_compressed
    from repro.parallel.ops import shard_map

    mesh = jax.make_mesh((4,), ("pod",))
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    res = jnp.zeros((4, 16), jnp.float32)

    @jax.jit
    def run(g, r):
        def f(g_s, r_s):
            out, new_r = psum_compressed({"g": g_s[0]}, {"g": r_s[0]}, "pod")
            return out["g"][None], new_r["g"][None]
        from jax.sharding import PartitionSpec as P
        return shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                         out_specs=(P("pod"), P("pod")),
                         check=False)(g, r)

    out, new_r = run(grads, res)
    want = np.mean(np.asarray(grads), axis=0)
    got = np.asarray(out)[0]
    # int8 mean across 4 shards: tolerance ~ max|g| / 127
    tol = float(np.abs(np.asarray(grads)).max()) / 127 + 1e-6
    np.testing.assert_allclose(got, want, atol=tol)
    # every shard decodes the identical reduced gradient
    for i in range(1, 4):
        np.testing.assert_array_equal(np.asarray(out)[i], got)
    print("OK")
""")


@pytest.mark.slow
def test_psum_compressed_multidevice():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", PSUM % src],
                         capture_output=True, text=True, timeout=180)
    assert out.returncode == 0, out.stderr[-2500:]
    assert "OK" in out.stdout
