"""The docs suite stays honest: README + docs/ links resolve, the pages
the README promises exist, and the link checker itself works."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

from check_links import check_file, heading_slugs, markdown_files, slugify  # noqa: E402


def _doc_files():
    return markdown_files([str(ROOT / "README.md"), str(ROOT / "docs")])


def test_docs_suite_exists():
    names = {p.name for p in _doc_files()}
    assert {"README.md", "architecture.md", "backends.md",
            "benchmarks.md", "search.md"} <= names


def test_no_broken_links_or_anchors():
    errors = []
    for f in _doc_files():
        errors.extend(check_file(f))
    assert not errors, "\n".join(errors)


def test_slugify_matches_github_rules():
    assert slugify("Reading `BENCH_engine.json`") == \
        "reading-bench_enginejson"
    assert slugify("Escalation: the `auto` pipeline") == \
        "escalation-the-auto-pipeline"


def test_checker_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](nope.md) and [anchor](#nowhere)\n"
                   "# Real Heading\n")
    errors = check_file(bad)
    assert len(errors) == 2
    ok = tmp_path / "ok.md"
    ok.write_text("[self](#real-heading)\n# Real Heading\n")
    assert check_file(ok) == []
    assert "real-heading" in heading_slugs(ok)


def test_checker_ignores_code_and_handles_duplicate_headings(tmp_path):
    doc = tmp_path / "doc.md"
    doc.write_text("use `[text](not/a/link.md)` syntax\n"
                   "see [second](#example-1)\n"
                   "## Example\n## Example\n")
    assert check_file(doc) == []
    assert heading_slugs(doc) == {"example", "example-1"}
