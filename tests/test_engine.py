"""Batched JAX engine vs the exact paper-faithful solver."""

import numpy as np
import pytest

from repro.core.engine import EngineConfig, ged_batch, pack_pairs, verify_batch
from repro.core.engine import auction as auc
from repro.core.exact.assignment import hungarian
from repro.core.exact.search import ged as exact_ged

import jax.numpy as jnp

from repro.data.graphs import perturb, random_graph


def _make_pairs(seed, count, nmin=4, nmax=9, ops=5):
    rng = np.random.default_rng(seed)
    pairs = []
    for _ in range(count):
        n = int(rng.integers(nmin, nmax))
        q = random_graph(rng, n, density=0.35, n_vlabels=3, n_elabels=2)
        if rng.random() < 0.5:
            g = perturb(rng, q, int(rng.integers(0, ops)), n_vlabels=3, n_elabels=2)
        else:
            g = random_graph(rng, int(rng.integers(nmin, nmax)),
                             density=0.35, n_vlabels=3, n_elabels=2)
        pairs.append((q, g))
    return pairs


# ----------------------------------------------------------------- auction
def test_auction_dual_bound_is_admissible():
    rng = np.random.default_rng(3)
    for _ in range(20):
        n = int(rng.integers(2, 10))
        cost = (rng.integers(0, 12, size=(n, n)) * 0.5).astype(np.float32)
        _, opt = hungarian(cost)
        c = jnp.asarray(cost)[None]
        for sweeps in (0, 1, 4, 16, 64):
            st = auc.run_auction(c, sweeps)
            lb = float(auc.dual_bound(c, st.prices)[0])
            assert lb <= opt + 1e-4, f"sweeps={sweeps}: {lb} > {opt}"
        # enough sweeps should reach (near-)optimality via the dual
        st = auc.run_auction(c, 4 * n + 16)
        lb = float(auc.dual_bound(c, st.prices)[0])
        assert lb >= opt - n * 0.25 - 1e-3


def test_auction_forced_dual_bounds_admissible():
    rng = np.random.default_rng(5)
    for _ in range(15):
        n = int(rng.integers(2, 8))
        cost = (rng.integers(0, 12, size=(n, n)) * 0.5).astype(np.float32)
        row = int(rng.integers(0, n))
        c = jnp.asarray(cost)[None]
        st = auc.run_auction(c, 24)
        forced = np.asarray(
            auc.forced_dual_bounds(c, st.prices, jnp.asarray([row]))
        )[0]
        # oracle: exact forced optimum per column
        from repro.core.exact.assignment import solve_forced_all
        want, _, _ = solve_forced_all(cost.astype(float), row)
        assert np.all(forced <= want + 1e-3), (forced, want)


def test_greedy_primal_is_permutation():
    rng = np.random.default_rng(7)
    for _ in range(10):
        n = int(rng.integers(2, 12))
        cost = jnp.asarray(rng.random((1, n, n)), jnp.float32)
        st = auc.run_auction(cost, 8)
        perm = np.asarray(auc.greedy_primal(cost, st.prices))[0]
        assert sorted(perm.tolist()) == list(range(n))


# ------------------------------------------------------------------ engine
@pytest.mark.parametrize("bound,min_exact", [("lsa", 0.9), ("bma", 0.75),
                                             ("hybrid", 0.9)])
def test_engine_matches_exact_ged(bound, min_exact):
    pairs = _make_pairs(11, 12)
    t = pack_pairs(pairs, slots=16)
    cfg = EngineConfig(pool=1024, expand=4, max_iters=1024, sweeps=12,
                       bound=bound)
    out = ged_batch(t, cfg)
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    ok = out["exact"]
    # certified results must be right; the certificate must usually fire
    # (pure-bma dual bounds are looser -> more conservative certificates)
    assert np.array_equal(out["ged"][ok].astype(int), want[ok]), (out, want)
    assert ok.mean() >= min_exact, out


def test_engine_dfs_strategy_matches():
    pairs = _make_pairs(13, 8)
    t = pack_pairs(pairs, slots=16)
    cfg = EngineConfig(pool=1024, expand=4, max_iters=2048, sweeps=8,
                       bound="hybrid", strategy="dfs")
    out = ged_batch(t, cfg)
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    ok = out["exact"]
    assert np.all(out["ged"][ok].astype(int) == want[ok])
    assert ok.mean() >= 0.9


def test_engine_verification_matches_exact():
    pairs = _make_pairs(17, 10)
    t = pack_pairs(pairs, slots=16)
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    for delta in (-1, 0, 1):
        taus = np.maximum(want + delta, 0).astype(np.float32)
        out = verify_batch(t, taus, EngineConfig(pool=512, expand=4,
                                                 max_iters=512, sweeps=8))
        expect = want <= taus
        assert np.all(out["exact"])
        assert np.array_equal(out["similar"], expect), (delta, out, want)


def test_engine_certificate_detects_truncation():
    """With a pathologically small budget, inexact results must be flagged."""
    pairs = _make_pairs(19, 6, nmin=8, nmax=10, ops=8)
    t = pack_pairs(pairs, slots=16)
    cfg = EngineConfig(pool=16, expand=2, max_iters=3, sweeps=2, bound="lsa")
    out = ged_batch(t, cfg)
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    wrong = out["ged"].astype(int) != want
    # every wrong answer must carry exact=False
    assert not np.any(wrong & out["exact"]), (out["ged"], want, out["exact"])


@pytest.mark.parametrize("strategy", ["astar", "dfs"])
@pytest.mark.parametrize("bound", ["lsa", "hybrid"])
def test_engine_kernel_and_reference_paths_bit_identical(strategy, bound):
    """use_kernel=True/False must produce bit-identical engine outputs —
    every field, not just the distance: the fused kernels compute the very
    same bound values (small-half float arithmetic is exact), so the whole
    search trajectory must match."""
    pairs = _make_pairs(23, 6)
    t = pack_pairs(pairs, slots=16)
    base = dict(pool=256, expand=4, bound=bound, strategy=strategy)
    out_k = ged_batch(t, EngineConfig(use_kernel=True, **base))
    out_r = ged_batch(t, EngineConfig(use_kernel=False, **base))
    assert set(out_k) == set(out_r)
    for key in out_k:
        assert np.array_equal(out_k[key], out_r[key]), (strategy, bound, key)


@pytest.mark.parametrize("strategy", ["astar", "dfs"])
def test_engine_kernel_paths_bit_identical_verification(strategy):
    pairs = _make_pairs(27, 6)
    t = pack_pairs(pairs, slots=16)
    taus = np.asarray([2.0, 3.0, 2.0, 4.0, 1.0, 3.0], np.float32)
    base = dict(pool=256, expand=4, strategy=strategy)
    out_k = verify_batch(t, taus, EngineConfig(use_kernel=True, **base))
    out_r = verify_batch(t, taus, EngineConfig(use_kernel=False, **base))
    assert set(out_k) == set(out_r)
    for key in out_k:
        assert np.array_equal(out_k[key], out_r[key]), (strategy, key)


def test_engine_kernel_paths_bit_identical_pad_heavy():
    """Small graphs rattling around big slot buckets: PAD slots dominate
    and the kernels must mask them exactly like the reference path."""
    pairs = _make_pairs(31, 5, nmin=3, nmax=6)
    t = pack_pairs(pairs, slots=32)
    out_k = ged_batch(t, EngineConfig(pool=128, expand=4, use_kernel=True))
    out_r = ged_batch(t, EngineConfig(pool=128, expand=4, use_kernel=False))
    for key in out_k:
        assert np.array_equal(out_k[key], out_r[key]), key
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    ok = out_k["exact"]
    assert ok.mean() >= 0.8
    assert np.array_equal(out_k["ged"][ok].astype(int), want[ok])


def test_engine_identical_graphs_zero():
    rng = np.random.default_rng(29)
    pairs = [(g, g.copy()) for g in
             (random_graph(rng, n, 0.3) for n in (4, 6, 9, 12))]
    t = pack_pairs(pairs, slots=16)
    out = ged_batch(t, EngineConfig(pool=128, expand=2))
    assert np.all(out["ged"] == 0)
    assert np.all(out["exact"])


def test_engine_unequal_sizes():
    rng = np.random.default_rng(31)
    pairs = []
    for _ in range(6):
        q = random_graph(rng, int(rng.integers(3, 6)), 0.4, 3, 2)
        g = random_graph(rng, int(rng.integers(6, 10)), 0.3, 3, 2)
        pairs.append((q, g))
    t = pack_pairs(pairs, slots=16)
    out = ged_batch(t, EngineConfig(pool=1024, expand=8, max_iters=1024))
    want = np.array([exact_ged(q, g, bound="BMa").ged for q, g in pairs])
    ok = out["exact"]
    assert ok.mean() >= 0.8
    assert np.array_equal(out["ged"][ok].astype(int), want[ok])
