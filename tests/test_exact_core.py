"""Exact-core correctness: editorial cost, assignment, bounds, search."""

import itertools

import numpy as np
import pytest

from repro.core.exact.assignment import (
    brute_force_assignment,
    hungarian,
    solve_forced_all,
)
from repro.core.exact.bounds import BoundEvaluator, PairContext, remaining_lower_bound
from repro.core.exact.brute import brute_force_extension_cost, brute_force_ged
from repro.core.exact.graph import Graph, editorial_cost, pad_pair
from repro.core.exact.multiset import multiset_edit_distance
from repro.core.exact.order import matching_order
from repro.core.exact.search import BOUNDS, ged, ged_verify
from repro.data.graphs import perturb, random_graph


# ---------------------------------------------------------------- multiset
def test_multiset_edit_distance_paper_example():
    assert multiset_edit_distance(["a", "a", "b"], ["a", "a", "a"]) == 1
    assert multiset_edit_distance([], []) == 0
    assert multiset_edit_distance([], [1, 2, 3]) == 3


# -------------------------------------------------------------- editorial
def test_editorial_cost_identity():
    rng = np.random.default_rng(1)
    for _ in range(10):
        g = random_graph(rng, 8)
        assert editorial_cost(g, g, np.arange(8)) == 0


def test_editorial_cost_paper_fig1(paper_fig1_pair):
    q, g = paper_fig1_pair
    # identity mapping v_i -> u_i has editorial cost 3 (paper intro)
    assert editorial_cost(q, g, np.arange(4)) == 3


def test_editorial_cost_symmetric_under_inverse():
    rng = np.random.default_rng(2)
    for _ in range(20):
        q = random_graph(rng, 6)
        g = random_graph(rng, 6)
        f = rng.permutation(6)
        finv = np.argsort(f)
        assert editorial_cost(q, g, f) == editorial_cost(g, q, finv)


# -------------------------------------------------------------- assignment
@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 6])
def test_hungarian_matches_brute_force(n):
    rng = np.random.default_rng(n)
    for _ in range(25):
        cost = rng.integers(0, 20, size=(n, n)).astype(float) * 0.5
        col, total = hungarian(cost)
        _, bf = brute_force_assignment(cost)
        assert sorted(col.tolist()) == list(range(n))
        assert total == pytest.approx(bf)
        assert sum(cost[i, col[i]] for i in range(n)) == pytest.approx(total)


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6])
def test_solve_forced_all_matches_per_column_solves(n):
    rng = np.random.default_rng(100 + n)
    for trial in range(15):
        cost = rng.integers(0, 15, size=(n, n)).astype(float) * 0.5
        row = int(rng.integers(0, n))
        forced, col, total = solve_forced_all(cost, row)
        _, bf_total = brute_force_assignment(cost)
        assert total == pytest.approx(bf_total)
        for c in range(n):
            # oracle: brute force over permutations with row -> c fixed
            best = np.inf
            others = [r for r in range(n) if r != row]
            cols = [cc for cc in range(n) if cc != c]
            for perm in itertools.permutations(cols):
                s = cost[row, c] + sum(cost[r, p] for r, p in zip(others, perm))
                best = min(best, s)
            assert forced[c] == pytest.approx(best), f"col {c}"


# ------------------------------------------------------------------ bounds
def _random_state(rng, q, g, order, level):
    img = tuple(int(u) for u in rng.choice(g.n, size=level, replace=False))
    return img


def _state_g_cost(ctx, order, img):
    """delta_f(q[f], g[f]) computed from scratch."""
    q, g = ctx.q, ctx.g
    i = len(img)
    anchors_q = order[:i]
    cost = 0
    for j in range(i):
        if q.vlabels[anchors_q[j]] != g.vlabels[img[j]]:
            cost += 1
    for j in range(i):
        for k in range(j + 1, i):
            if q.adj[anchors_q[j], anchors_q[k]] != g.adj[img[j], img[k]]:
                cost += 1
    return float(cost)


@pytest.mark.parametrize("kind", list(BOUNDS))
def test_bounds_admissible_against_brute_force(kind):
    """lb(f) <= min editorial cost over all extensions of f (Def. 3.1)."""
    rng = np.random.default_rng(7)
    for trial in range(12):
        n = int(rng.integers(4, 7))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = perturb(rng, q, int(rng.integers(0, 4)), n_vlabels=3, n_elabels=2)
        q, g, _ = pad_pair(q, g)
        order = matching_order(q, g)
        ctx = PairContext(q, g, order)
        ev = BoundEvaluator(ctx)
        level = int(rng.integers(0, n - 1))
        img = _random_state(rng, q, g, order, level)
        g_cost = _state_g_cost(ctx, order, img)
        from repro.core.exact.bounds import SCORERS
        scores = SCORERS[kind].__get__(ev)(img, g_cost, None)
        for u in range(n):
            if not np.isfinite(scores.lb[u]):
                continue
            oracle = brute_force_extension_cost(q, g, order, img + (u,))
            assert scores.lb[u] <= oracle + 1e-9, (
                f"{kind} inadmissible: lb={scores.lb[u]} > opt={oracle} "
                f"(n={n}, level={level}, img={img}, u={u})"
            )
            # child g_cost must be the exact partial editorial cost
            assert scores.g_cost[u] == pytest.approx(
                _state_g_cost(ctx, order, img + (u,))
            )


def test_bound_dominance_chain():
    """BMa >= LSa >= LS and BMa >= BM on whole states (Lemma 4.1 et al.)."""
    rng = np.random.default_rng(11)
    for trial in range(30):
        n = int(rng.integers(4, 8))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = perturb(rng, q, int(rng.integers(0, 5)), n_vlabels=3, n_elabels=2)
        q, g, _ = pad_pair(q, g)
        order = matching_order(q, g)
        ctx = PairContext(q, g, order)
        level = int(rng.integers(0, n))
        img = _random_state(rng, q, g, order, level)
        ls = remaining_lower_bound(ctx, img, "LS")
        lsa = remaining_lower_bound(ctx, img, "LSa")
        bm = remaining_lower_bound(ctx, img, "BM")
        bma = remaining_lower_bound(ctx, img, "BMa")
        assert lsa >= ls - 1e-9
        assert bma >= lsa - 1e-9, f"BMa {bma} < LSa {lsa} (img={img})"
        assert bma >= bm - 1e-9


def test_ls_fast_children_match_naive_state_bound():
    """Alg. 4 surplus-counter scoring == naive recomputation per child."""
    rng = np.random.default_rng(13)
    for trial in range(15):
        n = int(rng.integers(4, 8))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = perturb(rng, q, 2, n_vlabels=3, n_elabels=2)
        q, g, _ = pad_pair(q, g)
        order = matching_order(q, g)
        ctx = PairContext(q, g, order)
        ev = BoundEvaluator(ctx)
        level = int(rng.integers(0, n - 1))
        img = _random_state(rng, q, g, order, level)
        g_cost = _state_g_cost(ctx, order, img)
        for kind in ("LS", "LSa"):
            from repro.core.exact.bounds import SCORERS
            scores = SCORERS[kind].__get__(ev)(img, g_cost, None)
            for u in range(n):
                if not np.isfinite(scores.lb[u]):
                    continue
                naive = (
                    _state_g_cost(ctx, order, img + (u,))
                    + remaining_lower_bound(ctx, img + (u,), kind)
                )
                assert scores.lb[u] == pytest.approx(naive), (
                    f"{kind} fast != naive at u={u}: "
                    f"{scores.lb[u]} vs {naive} (img={img})"
                )


# ------------------------------------------------------------------ search
@pytest.mark.parametrize("bound", ["LS", "LSa", "BM", "BMa"])
@pytest.mark.parametrize("strategy", ["astar", "dfs"])
def test_search_matches_brute_force(bound, strategy):
    rng = np.random.default_rng(17)
    for trial in range(10):
        n = int(rng.integers(3, 6))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        m = int(rng.integers(3, 6))
        g = random_graph(rng, m, density=0.4, n_vlabels=3, n_elabels=2)
        expected = brute_force_ged(q, g)
        res = ged(q, g, bound=bound, strategy=strategy)
        assert res.ged == expected, (
            f"{strategy}-{bound}: got {res.ged}, want {expected} (trial {trial})"
        )


@pytest.mark.parametrize("bound", ["BMaN", "SMa", "SM"])
def test_search_matches_brute_force_slow_bounds(bound):
    rng = np.random.default_rng(19)
    for trial in range(5):
        n = int(rng.integers(3, 6))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        expected = brute_force_ged(q, g)
        res = ged(q, g, bound=bound)
        assert res.ged == expected


def test_search_no_expand_all_matches():
    rng = np.random.default_rng(23)
    for trial in range(8):
        n = int(rng.integers(3, 6))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        expected = brute_force_ged(q, g)
        for bound in ("LSa", "BMa"):
            res = ged(q, g, bound=bound, expand_all=False)
            assert res.ged == expected


def test_search_paper_fig1(paper_fig1_pair):
    q, g = paper_fig1_pair
    for bound in BOUNDS:
        res = ged(q, g, bound=bound)
        assert res.ged == 3, bound


def test_search_paper_fig3(paper_fig3_pair):
    q, g = paper_fig3_pair
    res = ged(q, g, bound="BMa")
    assert res.ged == brute_force_ged(q, g)
    assert res.ged <= 5  # paper: one 5-op script exists


def test_best_mapping_cost_equals_ged():
    rng = np.random.default_rng(29)
    for trial in range(10):
        q = random_graph(rng, 6, density=0.4)
        g = perturb(rng, q, 3)
        res = ged(q, g, bound="BMa")
        qp, gp, _ = pad_pair(q, g)
        assert editorial_cost(qp, gp, res.best_mapping) == res.ged


def test_verification_agrees_with_computation():
    rng = np.random.default_rng(31)
    for trial in range(15):
        n = int(rng.integers(3, 7))
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        g = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        d = ged(q, g, bound="BMa").ged
        for tau in (d - 1, d, d + 1):
            if tau < 0:
                continue
            for strategy in ("astar", "dfs"):
                res = ged_verify(q, g, tau=tau, bound="BMa", strategy=strategy)
                assert res.similar == (d <= tau), (
                    f"tau={tau}, d={d}, strategy={strategy}"
                )


def test_astar_search_space_not_larger_than_dfs():
    """Paper §5.3: T_{<=delta} subset of T_DFS (expanded-state counts)."""
    rng = np.random.default_rng(37)
    wins = 0
    total = 0
    for trial in range(10):
        q = random_graph(rng, 7, density=0.35, n_vlabels=3, n_elabels=2)
        g = perturb(rng, q, 4)
        ra = ged(q, g, bound="LSa", strategy="astar")
        rd = ged(q, g, bound="LSa", strategy="dfs")
        assert ra.ged == rd.ged
        total += 1
        if ra.stats.best_extension_calls <= rd.stats.best_extension_calls:
            wins += 1
    assert wins >= total * 0.8  # overwhelmingly smaller or equal


def test_tighter_bound_smaller_search_space():
    rng = np.random.default_rng(41)
    agg = {"LS": 0, "LSa": 0, "BMa": 0}
    for trial in range(8):
        q = random_graph(rng, 7, density=0.35, n_vlabels=3, n_elabels=2)
        g = perturb(rng, q, 4)
        res = {b: ged(q, g, bound=b) for b in ("LS", "LSa", "BMa")}
        geds = {r.ged for r in res.values()}
        assert len(geds) == 1
        for b in agg:
            agg[b] += res[b].stats.best_extension_calls
    assert agg["BMa"] <= agg["LSa"] <= agg["LS"]


def test_unequal_sizes_and_swap():
    rng = np.random.default_rng(43)
    for trial in range(8):
        q = random_graph(rng, int(rng.integers(3, 5)), density=0.4)
        g = random_graph(rng, int(rng.integers(5, 8)), density=0.3)
        expected = brute_force_ged(q, g)
        assert ged(q, g, bound="BMa").ged == expected
        assert ged(g, q, bound="BMa").ged == expected  # symmetry


def test_matching_order_is_permutation():
    rng = np.random.default_rng(47)
    for _ in range(10):
        q = random_graph(rng, 9, density=0.3)
        g = random_graph(rng, 9, density=0.3)
        order = matching_order(q, g)
        assert sorted(order.tolist()) == list(range(9))
