"""Chaos matrix for the robustness layer (``docs/robustness.md``).

The contract under test, in order of importance:

1. **Never a wrong answer.**  No injected fault or deadline may flip an
   answer — a degraded or timed-out outcome is uncertified or carries
   admissible bounds that bracket the true GED (checked against the
   brute-force oracle), and every *certified* outcome matches the
   fault-free run exactly.
2. **Always an answer.**  Faults and expired deadlines produce valid
   :class:`~repro.ged.GedOutcome` rows for every pair — never an
   exception out of ``compute``/``verify``.
3. **No poisoned caches.**  Timed-out or uncertified-degraded outcomes
   must not enter the result caches (in-memory or shared).
4. **Bit-identity without faults.**  The robustness plumbing is inert
   when no deadline is set and no fault fires.
"""

import os

import numpy as np
import pytest

from repro import ged
from repro.core.exact.brute import brute_force_ged
from repro.data.graphs import random_graph
from repro.ged.faults import (Deadline, FaultInjector, Overloaded,
                              RetryPolicy, cheap_lower_bound,
                              install_injector)
from repro.store_io.atomic import LockTimeout, file_lock

ENGINE_OPTS = dict(slots=16, batch_size=8, pool=64, expand=4,
                   max_iters=256, cache=False)


def _pairs(n=6, seed=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        q = random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                         n_vlabels=2, n_elabels=2)
        g = random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                         n_vlabels=2, n_elabels=2)
        out.append((q, g))
    return out


@pytest.fixture(autouse=True)
def _no_global_injector():
    install_injector(None)
    yield
    install_injector(None)


def _truths(pairs):
    return [float(brute_force_ged(q, g)) for q, g in pairs]


def _assert_sound(outs, truths, taus=None):
    for i, (o, t) in enumerate(zip(outs, truths)):
        if not (o.certified and taus is not None):
            # Certified verification verdicts may carry the engine's
            # tau-prune sentinel as lower_bound (evidence that lb > tau,
            # not a global bound); everything else — compute outcomes,
            # timed-out and degraded fallbacks — must bracket the truth.
            assert o.lower_bound <= t + 1e-9, (i, o.lower_bound, t)
            assert o.upper_bound >= t - 1e-9, (i, o.upper_bound, t)
        if o.certified and o.ged is not None:
            assert o.ged == pytest.approx(t), (i, o.ged, t)
        if taus is not None and o.similar is not None:
            assert o.similar == (t <= taus[i] + 1e-9), (i, o.similar, t)


# ----------------------------------------------------------- deadlines


def test_expired_deadline_exact_backend_answers_soundly():
    pairs = _pairs()
    truths = _truths(pairs)
    eng = ged.GedEngine("exact", deadline_s=0.0, cache=False)
    outs = eng.compute(pairs)
    assert len(outs) == len(pairs)
    for o in outs:
        assert o.timed_out and not o.certified
    _assert_sound(outs, truths)
    assert eng.stats["timed_out_pairs"] == len(pairs)


def test_expired_deadline_auto_backend_answers_soundly():
    pairs = _pairs()
    truths = _truths(pairs)
    taus = [1.0] * len(pairs)
    eng = ged.GedEngine("auto", deadline_s=0.0, **ENGINE_OPTS)
    outs = eng.verify(pairs, taus)
    assert len(outs) == len(pairs)
    assert all(o.timed_out and not o.certified for o in outs)
    _assert_sound(outs, truths, taus)


def test_mid_run_deadline_auto_keeps_rung_bounds():
    # A short-but-nonzero budget with a forced multi-rung ladder: some
    # pairs certify in time, the rest must carry admissible best-so-far
    # bounds from the rungs that did run.
    pairs = _pairs(10, seed=11)
    truths = _truths(pairs)
    taus = [2.0] * len(pairs)
    eng = ged.GedEngine("auto", **ENGINE_OPTS)
    eng._backend.scheduler.rungs = ((8, 1, 4), (64, 4, 64))
    outs = eng.verify(pairs, taus, deadline_s=0.05)
    assert len(outs) == len(pairs)
    _assert_sound(outs, truths, taus)
    for o in outs:
        assert o.certified or o.timed_out


def test_per_pair_deadline_on_host_solver():
    pairs = _pairs(4, seed=5)
    truths = _truths(pairs)
    eng = ged.GedEngine("exact", per_pair_deadline_s=0.0, cache=False)
    outs = eng.compute(pairs)
    for o in outs:
        assert o.timed_out and not o.certified
    _assert_sound(outs, truths)


def test_no_deadline_bit_identity():
    pairs = _pairs()
    taus = [1.0] * len(pairs)
    plain = ged.GedEngine("auto", **ENGINE_OPTS).verify(pairs, taus)
    roomy = ged.GedEngine("auto", deadline_s=3600.0,
                          **ENGINE_OPTS).verify(pairs, taus)
    for a, b in zip(plain, roomy):
        assert (a.similar, a.certified, a.ged, a.lower_bound,
                a.upper_bound) == (b.similar, b.certified, b.ged,
                                   b.lower_bound, b.upper_bound)
        assert not a.timed_out and not b.timed_out


def test_deadline_object_is_shared_across_flush():
    d = Deadline(3600.0)
    assert not d.expired() and d.remaining() > 3599.0
    child = d.sub(1.0)
    assert child.remaining() <= 1.0
    inherit = d.sub(None)
    assert inherit.t_end == d.t_end
    assert Deadline(0.0).expired()
    assert not Deadline(None).expired()


# -------------------------------------------------------------- faults


def test_transient_dispatch_fault_retries_to_identical_answers():
    pairs = _pairs()
    taus = [1.0] * len(pairs)
    clean = ged.GedEngine("jax", **ENGINE_OPTS).verify(pairs, taus)
    eng = ged.GedEngine("jax", fault_inject="dispatch@times=1,kind=transient",
                        retry=RetryPolicy(max_retries=2, base_s=0.0),
                        **ENGINE_OPTS)
    outs = eng.verify(pairs, taus)
    assert eng.stats["retries"] == 1
    for a, b in zip(clean, outs):
        assert (a.similar, a.certified, a.lower_bound, a.upper_bound) == \
            (b.similar, b.certified, b.lower_bound, b.upper_bound)


def test_permanent_dispatch_fault_degrades_to_host():
    pairs = _pairs()
    taus = [1.0] * len(pairs)
    truths = _truths(pairs)
    clean = ged.GedEngine("jax", **ENGINE_OPTS).verify(pairs, taus)
    eng = ged.GedEngine("jax", fault_inject="dispatch@times=inf",
                        retry=RetryPolicy(max_retries=1, base_s=0.0),
                        **ENGINE_OPTS)
    outs = eng.verify(pairs, taus)
    assert eng.stats["degraded_host"] == len(pairs)
    _assert_sound(outs, truths, taus)
    for a, b in zip(clean, outs):
        # host fallback is exact: verdicts agree, flagged degraded.
        assert a.similar == b.similar and b.certified and b.degraded


def test_kernel_fault_degrades_to_unfused_bit_identical():
    pairs = _pairs()
    taus = [1.0] * len(pairs)
    clean = ged.GedEngine("pallas", **ENGINE_OPTS).verify(pairs, taus)
    eng = ged.GedEngine("pallas", fault_inject="kernel@times=inf",
                        retry=RetryPolicy(max_retries=0, base_s=0.0),
                        **ENGINE_OPTS)
    outs = eng.verify(pairs, taus)
    assert eng.stats.get("degraded_kernel", 0) >= 1
    for a, b in zip(clean, outs):
        # unfused path is bit-identical, so certification survives.
        assert (a.similar, a.certified, a.lower_bound, a.upper_bound) == \
            (b.similar, b.certified, b.lower_bound, b.upper_bound)


def test_result_site_fault_recovers_via_refused_redispatch():
    pairs = _pairs()
    taus = [1.0] * len(pairs)
    clean = ged.GedEngine("pallas", **ENGINE_OPTS).verify(pairs, taus)
    eng = ged.GedEngine("pallas", fault_inject="result@times=1",
                        **ENGINE_OPTS)
    outs = eng.verify(pairs, taus)
    for a, b in zip(clean, outs):
        assert a.similar == b.similar and b.certified


def test_host_fault_yields_uncertified_sound_floor():
    pairs = _pairs(3, seed=9)
    truths = _truths(pairs)
    eng = ged.GedEngine("exact", fault_inject="host@times=inf",
                        cache=False)
    outs = eng.compute(pairs)
    for o in outs:
        assert not o.certified and o.degraded
    _assert_sound(outs, truths)
    assert eng.stats["fault_host"] == len(pairs)


def test_rung_scoped_fault_leaves_other_rungs_alone():
    pairs = _pairs(8, seed=21)
    taus = [2.0] * len(pairs)
    truths = _truths(pairs)
    clean_eng = ged.GedEngine("auto", **ENGINE_OPTS)
    clean_eng._backend.scheduler.rungs = ((8, 1, 4), (64, 4, 64))
    clean = clean_eng.verify(pairs, taus)
    eng = ged.GedEngine("auto", fault_inject="dispatch@rung=1,times=inf",
                        retry=RetryPolicy(max_retries=0, base_s=0.0),
                        **ENGINE_OPTS)
    eng._backend.scheduler.rungs = ((8, 1, 4), (64, 4, 64))
    outs = eng.verify(pairs, taus)
    _assert_sound(outs, truths, taus)
    for a, b in zip(clean, outs):
        assert a.similar == b.similar and b.certified


def test_fault_injector_spec_parsing():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector("badsite@times=1")
    with pytest.raises(ValueError, match="unknown fault-spec key"):
        FaultInjector("dispatch@nope=1")
    inj = FaultInjector("dispatch@times=2,rung=1;lock")
    inj.check("dispatch", rung=0)                 # rung mismatch: no-op
    with pytest.raises(Exception):
        inj.check("dispatch", rung=1)
    with pytest.raises(Exception):
        inj.check("lock")
    inj.check("lock")                             # budget spent
    assert inj.fired == 2


def test_env_injector_pickup(monkeypatch):
    from repro.ged.faults import get_injector
    monkeypatch.setenv("REPRO_GED_FAULT_INJECT", "host@times=1")
    inj = get_injector()
    assert inj is not None
    monkeypatch.delenv("REPRO_GED_FAULT_INJECT")
    assert get_injector() is None


# ------------------------------------------------------ cache hygiene


def test_timed_out_outcomes_do_not_poison_caches(tmp_path):
    pairs = _pairs(3, seed=7)
    truths = _truths(pairs)
    eng = ged.GedEngine("exact", cache_size=64,
                        shared_cache_dir=str(tmp_path))
    bad = eng.compute(pairs, deadline_s=0.0)
    assert all(o.timed_out for o in bad)
    # Same engine, no deadline: must re-solve, not replay the fallback.
    good = eng.compute(pairs)
    for o, t in zip(good, truths):
        assert o.certified and o.ged == pytest.approx(t)
    # Shared tier never saw the uncertified rows either.
    fresh = ged.GedEngine("exact", cache_size=0,
                          shared_cache_dir=str(tmp_path))
    again = fresh.compute(pairs)
    for o, t in zip(again, truths):
        assert o.ged == pytest.approx(t)


# ------------------------------------------------- lock timeouts (io)


def test_file_lock_timeout_raises_lock_timeout(tmp_path):
    import fcntl
    path = str(tmp_path / "lk")
    held = open(path, "a+")
    fcntl.flock(held.fileno(), fcntl.LOCK_EX)
    try:
        with pytest.raises(LockTimeout):
            with file_lock(path, timeout=0.05, poll_s=0.01):
                pass
    finally:
        fcntl.flock(held.fileno(), fcntl.LOCK_UN)
        held.close()
    with file_lock(path, timeout=0.05):           # released: acquires
        pass


def test_shared_cache_lock_timeout_fails_open(tmp_path):
    from repro.ged.results import GedOutcome
    from repro.store_io.shared_cache import SharedResultCache
    install_injector(FaultInjector("lock@times=1"))
    cache = SharedResultCache(str(tmp_path), lock_timeout_s=0.05)
    key = ("exact", b"q", b"g", False, None, None, "jax")
    out = GedOutcome(ged=2.0, similar=None, certified=True,
                     lower_bound=2.0, upper_bound=2.0, mapping=None,
                     backend="jax", wall_s=0.0)
    assert cache.put(key, out)                    # fail-open write
    assert cache.lock_timeouts == 1
    hit = cache.get(key)
    assert hit is not None and hit.ged == 2.0
    assert cache.stats["lock_timeouts"] == 1.0


def test_engine_surfaces_lock_timeout_stat(tmp_path):
    eng = ged.GedEngine("exact", shared_cache_dir=str(tmp_path),
                        fault_inject="lock@times=1")
    install_injector(FaultInjector("lock@times=1"))
    eng.compute(_pairs(1))
    assert eng.stats["shared_cache_lock_timeouts"] >= 1.0


# ------------------------------------------------------------ serving


def test_admission_control_sheds_and_recovers():
    from repro.serving.ged_service import AdmissionController
    ac = AdmissionController(capacity=4)
    with ac.admit(3):
        with pytest.raises(Overloaded) as ei:
            with ac.admit(2):
                pass
    err = ei.value
    assert err.retry_after_s > 0 and err.capacity == 4
    with ac.admit(2):                             # drained: admits again
        pass
    with ac.admit(100):                           # oversized-but-idle
        pass
    h = ac.health
    assert h["shed"] == 1 and h["queue_depth"] == 0
    assert h["p99_wall_s"] >= h["p50_wall_s"] >= 0


def test_service_deadline_and_health():
    from repro.serving.ged_service import (GedRequest,
                                           GedVerificationService)
    svc = GedVerificationService(batch_size=8, slots=16, capacity=16)
    pairs = _pairs(3, seed=13)
    truths = _truths(pairs)
    reqs = [GedRequest(q, g, tau=1.0, deadline_s=0.0) for q, g in pairs]
    outs = svc.verify(reqs)
    _assert_sound(outs, truths, [1.0] * len(pairs))
    for o in outs:
        assert o.timed_out or o.certified     # cache hits may certify
    h = svc.health()
    assert h["admitted"] == 1 and "p99_wall_s" in h
    assert h["timed_out_pairs"] >= 1


# ----------------------------------------------------------- property


def _bound_property(seed, budget):
    rng = np.random.default_rng(seed)
    pairs = [(random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                           n_vlabels=2, n_elabels=2),
              random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                           n_vlabels=2, n_elabels=2)) for _ in range(3)]
    truths = _truths(pairs)
    eng = ged.GedEngine("auto", deadline_s=budget, **ENGINE_OPTS)
    outs = eng.verify(pairs, [1.0] * len(pairs))
    for o, t in zip(outs, truths):
        if not o.certified:     # see _assert_sound on certified verdicts
            assert o.lower_bound <= t + 1e-9 <= o.upper_bound + 2e-9, \
                (seed, budget, o.lower_bound, t, o.upper_bound)
        assert o.certified or o.timed_out or o.degraded
        assert cheap_lower_bound(*pairs[0]) >= 0


def test_bounds_bracket_truth_under_seeded_deadline_sweep():
    for seed in (0, 1, 2, 3):
        for budget in (0.0, 0.002, 0.02, 3600.0):
            _bound_property(seed, budget)


def test_bounds_bracket_truth_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           budget=st.floats(0.0, 0.05, allow_nan=False))
    def run(seed, budget):
        _bound_property(seed, budget)

    run()
