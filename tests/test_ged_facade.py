"""The ``repro.ged`` facade: backend parity, bucketed compile reuse,
ingestion adapters, streaming, the sharded executor, the engine-level
result cache, and the unified result schema."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ged
from repro.core.engine.api import run_batch_traces
from repro.core.exact.brute import brute_force_ged
from repro.core.exact.graph import Graph
from repro.data.graphs import perturb, random_graph


def _small_pairs(seed, count, nmin=3, nmax=6):
    rng = np.random.default_rng(seed)
    pairs = []
    for _ in range(count):
        q = random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                         density=0.4, n_vlabels=3, n_elabels=2)
        if rng.random() < 0.5:
            g = perturb(rng, q, int(rng.integers(0, 4)),
                        n_vlabels=3, n_elabels=2)
        else:
            g = random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                             density=0.4, n_vlabels=3, n_elabels=2)
        pairs.append((q, g))
    return pairs


# ------------------------------------------------------------------ parity

@pytest.mark.parametrize("backend", ["exact", "jax", "auto"])
def test_backend_matches_brute_force_oracle(backend):
    pairs = _small_pairs(0, 10)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    outs = ged.GedEngine(backend, pool=1024, expand=4,
                         max_iters=1024).compute(pairs)
    for o, t in zip(outs, truth):
        assert o.certified
        assert o.ged == t, (backend, o, t)


def test_exact_and_jax_backends_agree_everywhere():
    pairs = _small_pairs(1, 12)
    a = ged.GedEngine("exact").compute(pairs)
    b = ged.GedEngine("jax", pool=1024, expand=4, max_iters=1024
                      ).compute(pairs)
    for oa, ob in zip(a, b):
        assert ob.certified and oa.ged == ob.ged


def test_verification_parity_across_backends():
    pairs = _small_pairs(2, 8)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    for delta in (-1, 0, 1):
        taus = [max(t + delta, 0) for t in truth]
        for backend in ("exact", "jax", "auto"):
            outs = ged.GedEngine(backend, pool=1024, expand=4,
                                 max_iters=1024).verify(pairs, taus)
            for o, t, tau in zip(outs, truth, taus):
                assert o.certified
                assert o.similar == (t <= tau), (backend, delta, o, t)


# ----------------------------------------------------------- result schema

def test_outcome_schema_and_bounds():
    pairs = _small_pairs(3, 6)
    for backend in ("exact", "jax", "auto"):
        for o in ged.GedEngine(backend, pool=1024).compute(pairs):
            assert o.similar is None and o.ged is not None
            assert o.lower_bound <= o.ged <= o.upper_bound
            assert o.backend.startswith(backend.split("/")[0])
            assert o.wall_s >= 0.0
            if o.certified:
                assert o.lower_bound == o.ged == o.upper_bound
                # a certified computation carries a witness mapping whose
                # image is a valid partial permutation
                assert o.mapping is not None
                img = o.mapping[o.mapping >= 0]
                assert len(set(img.tolist())) == len(img)
        for o in ged.GedEngine(backend, pool=1024).verify(pairs, 3.0):
            assert o.ged is None and o.similar is not None
            assert o.tau == 3.0


def test_mapping_cost_matches_ged():
    """The witness mapping is on the padded (q', g') pair and realises the
    reported distance."""
    from repro.core.exact.graph import editorial_cost, pad_pair
    pairs = _small_pairs(4, 6)
    for backend in ("exact", "jax"):
        outs = ged.GedEngine(backend, pool=1024, expand=4).compute(pairs)
        for (q, g), o in zip(pairs, outs):
            if not o.certified or o.mapping is None:
                continue
            qp, gp, _ = pad_pair(q, g)
            assert editorial_cost(qp, gp, o.mapping) == o.ged


# -------------------------------------------------------------- ingestion

def test_input_adapters_are_equivalent():
    q = Graph.from_edges([0, 1, 1], [(0, 1, 1), (1, 2, 2)])
    g = Graph.from_edges([0, 1, 2], [(0, 1, 1), (0, 2, 1)])
    as_tuple = ([0, 1, 1], [(0, 1, 1), (1, 2, 2)])
    as_dict = {"vlabels": [0, 1, 1], "edges": [(0, 1, 1), (1, 2, 2)]}
    as_adjdict = {"a": (0, [("b", 1)]),
                  "b": (1, [("a", 1), ("c", 2)]),
                  "c": (1, [("b", 2)])}
    want = ged.compute([(q, g)], backend="exact")[0].ged
    for form in (as_tuple, as_dict, as_adjdict):
        assert ged.compute([(form, g)], backend="exact")[0].ged == want


def test_adapter_rejects_garbage():
    with pytest.raises(TypeError):
        ged.compute([(42, 43)], backend="exact")


# -------------------------------------------------------------- streaming

def test_submit_flush_preserves_order_and_modes():
    pairs = _small_pairs(5, 5)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    eng = ged.GedEngine("exact")
    tickets = []
    for i, (q, g) in enumerate(pairs):
        tau = float(truth[i]) if i % 2 else None  # alternate verify/compute
        tickets.append(eng.submit(q, g, tau=tau))
    assert tickets == list(range(len(pairs)))
    outs = eng.flush()
    assert len(outs) == len(pairs)
    for i, (o, t) in enumerate(zip(outs, truth)):
        if i % 2:
            assert o.similar is True and o.tau == t
        else:
            assert o.ged == t
    assert eng.flush() == []  # drained


# ------------------------------------------------- bucketing / compile cache

VOCAB = ((0, 1, 2), (1, 2))


def _sized_pairs(seed, sizes):
    rng = np.random.default_rng(seed)
    out = []
    for n in sizes:
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        out.append((q, perturb(rng, q, 2, n_vlabels=3, n_elabels=2)))
    return out


def test_bucketing_reuses_compilations_across_batches():
    """Mixed-size workloads must compile once per slot bucket, then reuse."""
    eng = ged.GedEngine("jax", vocab=VOCAB, pool=128, expand=2, max_iters=64)
    # sizes 3..4 -> 4-slot bucket, 5..8 -> 8-slot bucket; 4 pairs per bucket
    batch1 = _sized_pairs(7, [3, 4, 5, 6, 4, 3, 7, 8])
    t0 = run_batch_traces()
    outs = eng.compute(batch1)
    assert len(outs) == len(batch1)
    new_traces = run_batch_traces() - t0
    assert new_traces == 2, f"expected one trace per bucket, got {new_traces}"

    # same buckets, different pairs and batch sizes (padded to pow2) -> no
    # new traces at all
    batch2 = _sized_pairs(8, [4, 5, 6, 3, 8, 5, 4])
    t1 = run_batch_traces()
    eng.compute(batch2)
    assert run_batch_traces() - t1 == 0, "same-bucket batch re-traced"
    assert eng.stats["compile_cache_hits"] >= 2


def test_bucketing_results_match_unbucketed():
    pairs = _sized_pairs(9, [3, 5, 8, 4, 6])
    bucketed = ged.GedEngine("jax", pool=512, expand=4).compute(pairs)
    pinned = ged.GedEngine("jax", slots=8, pool=512, expand=4).compute(pairs)
    for a, b in zip(bucketed, pinned):
        assert a.certified == b.certified
        if a.certified:
            assert a.ged == b.ged


def test_slot_bucket_is_pow2_and_monotone():
    assert [ged.slot_bucket(n) for n in (1, 3, 4, 5, 8, 9, 16, 17)] == \
        [4, 4, 4, 8, 8, 16, 16, 32]


# ------------------------------------------------------------- registry

def test_backend_registry_round_trip():
    assert set(ged.available_backends()) >= {"exact", "jax", "pallas",
                                             "sharded", "auto"}
    with pytest.raises(ValueError):
        ged.GedEngine("no-such-backend")

    class EchoBackend:
        name = "echo"

        def run(self, plan, taus, verification, cfg):
            from repro.ged.results import GedOutcome
            return [GedOutcome(ged=0.0, similar=None, certified=False,
                               lower_bound=0.0, upper_bound=0.0,
                               mapping=None, backend=self.name, wall_s=0.0)
                    for _ in plan.pairs]

    ged.register_backend("echo", EchoBackend)
    try:
        outs = ged.GedEngine("echo").compute(_small_pairs(10, 2))
        assert [o.backend for o in outs] == ["echo", "echo"]
    finally:
        from repro.ged import backends as B
        B._REGISTRY.pop("echo", None)


def test_module_level_one_shots():
    pairs = _small_pairs(11, 3)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    outs = ged.compute(pairs, backend="auto")
    assert [o.ged for o in outs] == truth
    vers = ged.verify(pairs, truth, backend="auto")
    assert all(o.similar for o in vers)


# ------------------------------------------------- sharded executor layer

ENGINE_OPTS = dict(pool=256, expand=4, max_iters=256)


def test_sharded_backend_matches_jax_backend():
    """Same policy, different placement => identical outcomes (compute and
    verification), whatever the local device count."""
    pairs = _small_pairs(12, 10)
    a = ged.GedEngine("jax", **ENGINE_OPTS).compute(pairs)
    b = ged.GedEngine("sharded", **ENGINE_OPTS).compute(pairs)
    for oa, ob in zip(a, b):
        assert (oa.ged, oa.certified, oa.lower_bound) == \
            (ob.ged, ob.certified, ob.lower_bound)
        assert ob.backend == "sharded"
    for tau in (2.0, 4.0):
        va = ged.GedEngine("jax", **ENGINE_OPTS).verify(pairs, tau)
        vb = ged.GedEngine("sharded", **ENGINE_OPTS).verify(pairs, tau)
        for oa, ob in zip(va, vb):
            assert (oa.similar, oa.certified) == (ob.similar, ob.certified)


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from repro import ged
    from repro.data.graphs import perturb, random_graph

    assert jax.device_count() == 8
    rng = np.random.default_rng(5)
    pairs = []
    for _ in range(11):     # odd count: padded to 16 (a multiple of 8)
        q = random_graph(rng, int(rng.integers(4, 10)), density=0.4,
                         n_vlabels=3, n_elabels=2)
        pairs.append((q, perturb(rng, q, 3, n_vlabels=3, n_elabels=2)))
    opts = dict(pool=256, expand=4, max_iters=256)

    ref = ged.GedEngine("jax", **opts).compute(pairs)
    eng = ged.GedEngine("sharded", **opts)
    assert eng.batch_multiple == 8
    got = eng.compute(pairs)
    assert [(o.ged, o.certified) for o in got] == \\
        [(o.ged, o.certified) for o in ref]

    vref = ged.GedEngine("jax", **opts).verify(pairs, 4.0)
    vgot = ged.GedEngine("sharded", **opts).verify(pairs, 4.0)
    assert [(o.similar, o.certified) for o in vgot] == \\
        [(o.similar, o.certified) for o in vref]

    # production-shaped 2-D mesh: pairs shard over the batch axes only
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    v2d = ged.GedEngine("sharded", mesh=mesh, **opts).verify(pairs, 4.0)
    assert [(o.similar, o.certified) for o in v2d] == \\
        [(o.similar, o.certified) for o in vref]
    print("OK")
""")


@pytest.mark.slow
def test_sharded_backend_parity_on_8_devices():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_sharded_single_device_fast_path():
    """On a one-shard mesh the sharded executor must skip shard_map (the
    fast path counter fires) and pad to batch_multiple == 1, with outcomes
    identical to the jax backend."""
    import jax
    if jax.device_count() != 1:
        pytest.skip("needs exactly one local device")
    pairs = _small_pairs(21, 8)
    eng = ged.GedEngine("sharded", **ENGINE_OPTS)
    assert eng.batch_multiple == 1              # no shard-multiple padding
    got = eng.compute(pairs)
    ref = ged.GedEngine("jax", **ENGINE_OPTS).compute(pairs)
    assert [(o.ged, o.certified) for o in got] == \
        [(o.ged, o.certified) for o in ref]
    assert eng.stats["executor_single_device_fastpath"] >= 1


@pytest.fixture
def _compile_cache_reset():
    """The persistent compile cache is process-global jax config; point it
    back off after the test so later tests don't write into a deleted
    tmp_path.  The config update alone is not enough — jax latches its
    cache state at first use, so without ``reset_cache()`` every later
    compile in the process keeps writing into the removed directory."""
    yield
    import jax
    from jax.experimental.compilation_cache import compilation_cache

    from repro.ged import exec as gexec
    jax.config.update("jax_compilation_cache_dir", None)
    compilation_cache.reset_cache()
    gexec._PERSISTENT_CACHE["dir"] = None


def test_compile_cache_dir_knob(tmp_path, _compile_cache_reset):
    """GedEngine(compile_cache_dir=...) enables jax's persistent cache:
    executables are serialised into the directory and the stats surface
    the process-wide hit/miss counters."""
    d = str(tmp_path / "cc")
    eng = ged.GedEngine("jax", compile_cache_dir=d, **ENGINE_OPTS)
    assert eng.compile_cache_dir == d
    eng.compute(_small_pairs(22, 2))
    stats = eng.stats
    for key in ("persistent_cache_hits", "persistent_cache_misses",
                "persistent_cache_entries"):
        assert key in stats, stats
    # the engine's compile may have been answered by this process's jit
    # cache (no XLA compile => nothing to persist); force a fresh entry
    if stats["persistent_cache_entries"] == 0:
        import jax
        import jax.numpy as jnp
        jax.jit(lambda x: x * 2 + 19)(jnp.ones(3)).block_until_ready()
    assert len(os.listdir(d)) >= 1


def test_compile_cache_env_default(tmp_path, monkeypatch,
                                   _compile_cache_reset):
    from repro.ged.exec import COMPILE_CACHE_ENV, enable_compile_cache
    d = str(tmp_path / "env_cc")
    monkeypatch.setenv(COMPILE_CACHE_ENV, d)
    assert enable_compile_cache(None) == d
    assert os.path.isdir(d)


def test_shard_padding_round_trip():
    """Buckets padded to shard multiples still answer exactly the real
    pairs, in order, with the same results as unpadded planning."""
    from repro.ged.plan import build_plan, padded_batch

    assert [padded_batch(r, 1) for r in (1, 3, 5, 8)] == [1, 4, 8, 8]
    assert [padded_batch(r, 8) for r in (1, 3, 8, 9)] == [8, 8, 8, 16]
    assert padded_batch(4, 6) == 6 and padded_batch(7, 6) == 12

    pairs = _sized_pairs(14, [3, 5, 8, 4, 6])
    plain = build_plan(pairs)
    padded = build_plan(pairs, batch_multiple=8)
    for plan in (plain, padded):
        covered = sorted(i for b in plan.buckets for i in b.indices)
        assert covered == list(range(len(pairs)))
    assert all(b.packed.batch % 8 == 0 for b in padded.buckets)

    from repro.core.engine.search import EngineConfig
    from repro.ged.backends import EngineBackend
    cfg = EngineConfig(use_kernel=False, **ENGINE_OPTS)
    taus = np.zeros(len(pairs), dtype=np.float32)
    a = EngineBackend().run(plain, taus, False, cfg)
    b = EngineBackend().run(padded, taus, False, cfg)
    assert [(o.ged, o.certified) for o in a] == \
        [(o.ged, o.certified) for o in b]


# ------------------------------------------------------- result caching

def test_result_cache_answers_repeats_without_reexecution():
    eng = ged.GedEngine("jax", **ENGINE_OPTS)
    pairs = _small_pairs(13, 5)
    first = eng.compute(pairs)
    calls = eng.stats["executor_calls"]
    assert eng.stats["result_cache_misses"] == len(pairs)

    t0 = run_batch_traces()
    second = eng.compute(pairs)
    assert run_batch_traces() - t0 == 0, "cached pairs must not re-compile"
    assert eng.stats["executor_calls"] == calls, \
        "cached pairs must not re-execute"
    assert eng.stats["result_cache_hits"] == len(pairs)
    for a, b in zip(first, second):
        assert (a.ged, a.certified) == (b.ged, b.certified)
        assert b.stats.get("cached") and not a.stats.get("cached")


def test_result_cache_dedups_within_one_batch():
    eng = ged.GedEngine("jax", **ENGINE_OPTS)
    (p0, p1) = _small_pairs(15, 2)
    outs = eng.compute([p0, p0, p1, p0])
    assert eng.stats["result_cache_misses"] == 2
    assert eng.stats["result_cache_hits"] == 2
    assert eng.stats["executor_pairs"] == 2     # only the unique pairs ran
    assert outs[0].ged == outs[1].ged == outs[3].ged
    # every position is its own outcome: mutating one entry (stats dict
    # or mapping array) must not leak into duplicates or later cache hits
    outs[1].stats["caller_tag"] = 1
    assert "caller_tag" not in outs[3].stats
    if outs[1].mapping is not None:
        outs[1].mapping[:] = -7
        assert not np.array_equal(outs[3].mapping, outs[1].mapping)
    again = eng.compute([p0])[0]
    assert "caller_tag" not in again.stats
    if again.mapping is not None:
        assert not np.array_equal(again.mapping, outs[1].mapping)


def test_result_cache_is_tau_and_mode_aware():
    eng = ged.GedEngine("jax", **ENGINE_OPTS)
    pairs = _small_pairs(16, 3)
    eng.compute(pairs)
    eng.verify(pairs, 3.0)              # different mode: all misses
    assert eng.stats["result_cache_hits"] == 0
    eng.verify(pairs, 4.0)              # different tau: all misses
    assert eng.stats["result_cache_hits"] == 0
    eng.verify(pairs, 3.0)              # same tau: all hits
    assert eng.stats["result_cache_hits"] == len(pairs)


def test_result_cache_key_is_vocab_independent():
    """The same pair hits the cache even when its batch companions change
    the shared label vocabulary."""
    rng = np.random.default_rng(17)
    q = random_graph(rng, 4, density=0.4, n_vlabels=2, n_elabels=1)
    p0 = (q, perturb(rng, q, 1, n_vlabels=2, n_elabels=1))
    rich = random_graph(rng, 5, density=0.5, n_vlabels=6, n_elabels=3)
    p1 = (rich, perturb(rng, rich, 2, n_vlabels=6, n_elabels=3))
    eng = ged.GedEngine("jax", **ENGINE_OPTS)
    eng.compute([p0])
    eng.compute([p0, p1])               # bigger vocab, same p0
    assert eng.stats["result_cache_hits"] == 1


def test_cache_can_be_disabled():
    eng = ged.GedEngine("jax", cache=False, **ENGINE_OPTS)
    pairs = _small_pairs(18, 3)
    eng.compute(pairs)
    calls = eng.stats["executor_calls"]
    eng.compute(pairs)
    assert "result_cache_hits" not in eng.stats
    assert eng.stats["executor_calls"] == 2 * calls  # repeats re-execute
