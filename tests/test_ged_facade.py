"""The ``repro.ged`` facade: backend parity, bucketed compile reuse,
ingestion adapters, streaming, and the unified result schema."""

import numpy as np
import pytest

from repro import ged
from repro.core.engine.api import run_batch_traces
from repro.core.exact.brute import brute_force_ged
from repro.core.exact.graph import Graph
from repro.data.graphs import perturb, random_graph


def _small_pairs(seed, count, nmin=3, nmax=6):
    rng = np.random.default_rng(seed)
    pairs = []
    for _ in range(count):
        q = random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                         density=0.4, n_vlabels=3, n_elabels=2)
        if rng.random() < 0.5:
            g = perturb(rng, q, int(rng.integers(0, 4)),
                        n_vlabels=3, n_elabels=2)
        else:
            g = random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                             density=0.4, n_vlabels=3, n_elabels=2)
        pairs.append((q, g))
    return pairs


# ------------------------------------------------------------------ parity

@pytest.mark.parametrize("backend", ["exact", "jax", "auto"])
def test_backend_matches_brute_force_oracle(backend):
    pairs = _small_pairs(0, 10)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    outs = ged.GedEngine(backend, pool=1024, expand=4,
                         max_iters=1024).compute(pairs)
    for o, t in zip(outs, truth):
        assert o.certified
        assert o.ged == t, (backend, o, t)


def test_exact_and_jax_backends_agree_everywhere():
    pairs = _small_pairs(1, 12)
    a = ged.GedEngine("exact").compute(pairs)
    b = ged.GedEngine("jax", pool=1024, expand=4, max_iters=1024
                      ).compute(pairs)
    for oa, ob in zip(a, b):
        assert ob.certified and oa.ged == ob.ged


def test_verification_parity_across_backends():
    pairs = _small_pairs(2, 8)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    for delta in (-1, 0, 1):
        taus = [max(t + delta, 0) for t in truth]
        for backend in ("exact", "jax", "auto"):
            outs = ged.GedEngine(backend, pool=1024, expand=4,
                                 max_iters=1024).verify(pairs, taus)
            for o, t, tau in zip(outs, truth, taus):
                assert o.certified
                assert o.similar == (t <= tau), (backend, delta, o, t)


# ----------------------------------------------------------- result schema

def test_outcome_schema_and_bounds():
    pairs = _small_pairs(3, 6)
    for backend in ("exact", "jax", "auto"):
        for o in ged.GedEngine(backend, pool=1024).compute(pairs):
            assert o.similar is None and o.ged is not None
            assert o.lower_bound <= o.ged <= o.upper_bound
            assert o.backend.startswith(backend.split("/")[0])
            assert o.wall_s >= 0.0
            if o.certified:
                assert o.lower_bound == o.ged == o.upper_bound
                # a certified computation carries a witness mapping whose
                # image is a valid partial permutation
                assert o.mapping is not None
                img = o.mapping[o.mapping >= 0]
                assert len(set(img.tolist())) == len(img)
        for o in ged.GedEngine(backend, pool=1024).verify(pairs, 3.0):
            assert o.ged is None and o.similar is not None
            assert o.tau == 3.0


def test_mapping_cost_matches_ged():
    """The witness mapping is on the padded (q', g') pair and realises the
    reported distance."""
    from repro.core.exact.graph import editorial_cost, pad_pair
    pairs = _small_pairs(4, 6)
    for backend in ("exact", "jax"):
        outs = ged.GedEngine(backend, pool=1024, expand=4).compute(pairs)
        for (q, g), o in zip(pairs, outs):
            if not o.certified or o.mapping is None:
                continue
            qp, gp, _ = pad_pair(q, g)
            assert editorial_cost(qp, gp, o.mapping) == o.ged


# -------------------------------------------------------------- ingestion

def test_input_adapters_are_equivalent():
    q = Graph.from_edges([0, 1, 1], [(0, 1, 1), (1, 2, 2)])
    g = Graph.from_edges([0, 1, 2], [(0, 1, 1), (0, 2, 1)])
    as_tuple = ([0, 1, 1], [(0, 1, 1), (1, 2, 2)])
    as_dict = {"vlabels": [0, 1, 1], "edges": [(0, 1, 1), (1, 2, 2)]}
    as_adjdict = {"a": (0, [("b", 1)]),
                  "b": (1, [("a", 1), ("c", 2)]),
                  "c": (1, [("b", 2)])}
    want = ged.compute([(q, g)], backend="exact")[0].ged
    for form in (as_tuple, as_dict, as_adjdict):
        assert ged.compute([(form, g)], backend="exact")[0].ged == want


def test_adapter_rejects_garbage():
    with pytest.raises(TypeError):
        ged.compute([(42, 43)], backend="exact")


# -------------------------------------------------------------- streaming

def test_submit_flush_preserves_order_and_modes():
    pairs = _small_pairs(5, 5)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    eng = ged.GedEngine("exact")
    tickets = []
    for i, (q, g) in enumerate(pairs):
        tau = float(truth[i]) if i % 2 else None  # alternate verify/compute
        tickets.append(eng.submit(q, g, tau=tau))
    assert tickets == list(range(len(pairs)))
    outs = eng.flush()
    assert len(outs) == len(pairs)
    for i, (o, t) in enumerate(zip(outs, truth)):
        if i % 2:
            assert o.similar is True and o.tau == t
        else:
            assert o.ged == t
    assert eng.flush() == []  # drained


# ------------------------------------------------- bucketing / compile cache

VOCAB = ((0, 1, 2), (1, 2))


def _sized_pairs(seed, sizes):
    rng = np.random.default_rng(seed)
    out = []
    for n in sizes:
        q = random_graph(rng, n, density=0.4, n_vlabels=3, n_elabels=2)
        out.append((q, perturb(rng, q, 2, n_vlabels=3, n_elabels=2)))
    return out


def test_bucketing_reuses_compilations_across_batches():
    """Mixed-size workloads must compile once per slot bucket, then reuse."""
    eng = ged.GedEngine("jax", vocab=VOCAB, pool=128, expand=2, max_iters=64)
    # sizes 3..4 -> 4-slot bucket, 5..8 -> 8-slot bucket; 4 pairs per bucket
    batch1 = _sized_pairs(7, [3, 4, 5, 6, 4, 3, 7, 8])
    t0 = run_batch_traces()
    outs = eng.compute(batch1)
    assert len(outs) == len(batch1)
    new_traces = run_batch_traces() - t0
    assert new_traces == 2, f"expected one trace per bucket, got {new_traces}"

    # same buckets, different pairs and batch sizes (padded to pow2) -> no
    # new traces at all
    batch2 = _sized_pairs(8, [4, 5, 6, 3, 8, 5, 4])
    t1 = run_batch_traces()
    eng.compute(batch2)
    assert run_batch_traces() - t1 == 0, "same-bucket batch re-traced"
    assert eng.stats["compile_cache_hits"] >= 2


def test_bucketing_results_match_unbucketed():
    pairs = _sized_pairs(9, [3, 5, 8, 4, 6])
    bucketed = ged.GedEngine("jax", pool=512, expand=4).compute(pairs)
    pinned = ged.GedEngine("jax", slots=8, pool=512, expand=4).compute(pairs)
    for a, b in zip(bucketed, pinned):
        assert a.certified == b.certified
        if a.certified:
            assert a.ged == b.ged


def test_slot_bucket_is_pow2_and_monotone():
    assert [ged.slot_bucket(n) for n in (1, 3, 4, 5, 8, 9, 16, 17)] == \
        [4, 4, 4, 8, 8, 16, 16, 32]


# ------------------------------------------------------------- registry

def test_backend_registry_round_trip():
    assert set(ged.available_backends()) >= {"exact", "jax", "pallas",
                                             "auto"}
    with pytest.raises(ValueError):
        ged.GedEngine("no-such-backend")

    class EchoBackend:
        name = "echo"

        def run(self, plan, taus, verification, cfg):
            from repro.ged.results import GedOutcome
            return [GedOutcome(ged=0.0, similar=None, certified=False,
                               lower_bound=0.0, upper_bound=0.0,
                               mapping=None, backend=self.name, wall_s=0.0)
                    for _ in plan.pairs]

    ged.register_backend("echo", EchoBackend)
    try:
        outs = ged.GedEngine("echo").compute(_small_pairs(10, 2))
        assert [o.backend for o in outs] == ["echo", "echo"]
    finally:
        from repro.ged import backends as B
        B._REGISTRY.pop("echo", None)


def test_module_level_one_shots():
    pairs = _small_pairs(11, 3)
    truth = [brute_force_ged(q, g) for q, g in pairs]
    outs = ged.compute(pairs, backend="auto")
    assert [o.ged for o in outs] == truth
    vers = ged.verify(pairs, truth, backend="auto")
    assert all(o.similar for o in vers)
