"""``ged.GraphStore`` corpus search: brute-force parity for range and
top-k queries, filter soundness (no stage prunes a true hit), the stage-0
bound's admissibility, WL-digest dedup, store stats, and the sharded
corpus scan (8-device subprocess)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ged
from repro.core.engine.corpus import scan_traces, stage0_reference
from repro.core.exact.brute import brute_force_ged
from repro.data.graphs import perturb, random_graph
from repro.ged.exec import Executor, ShardedExecutor, graph_digest, wl_digest
from repro.ged.results import STAGE_BOUND, STAGE_VERIFY

STORE_OPTS = dict(pool=256, expand=4, max_iters=256, batch_size=8)


def _corpus(seed, count, nmin=3, nmax=7, planted=2):
    """Random small graphs plus a few near-duplicates of the first one."""
    rng = np.random.default_rng(seed)
    graphs = [random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                           density=0.4, n_vlabels=3, n_elabels=2)
              for _ in range(count)]
    for _ in range(planted):
        graphs.append(perturb(rng, graphs[0], int(rng.integers(1, 3)),
                              n_vlabels=3, n_elabels=2))
    return graphs


def _permuted(rng, g):
    perm = rng.permutation(g.n)
    return ged.as_graph((g.vlabels[perm].tolist(),
                         [(int(np.where(perm == i)[0][0]),
                           int(np.where(perm == j)[0][0]), a)
                          for i, j, a in g.edges()]))


# ------------------------------------------------------- range parity

def test_range_search_matches_bruteforce_over_all_pairs():
    corpus = _corpus(0, 10)
    query = corpus[0]
    truth = [brute_force_ged(query, g) for g in corpus]
    store = ged.GraphStore(corpus, **STORE_OPTS)
    for tau in (0.0, 1.0, 2.0, 4.0):
        hits = store.range_search(query, tau)
        want = sorted(i for i, t in enumerate(truth) if t <= tau)
        assert sorted(h.graph_id for h in hits) == want, tau
        for h in hits:
            assert h.similar and h.certified
            assert h.stage in (STAGE_BOUND, STAGE_VERIFY)
            assert h.upper_bound <= tau + 1e-6
    # ranked: upper bounds ascend, ids break ties
    ub = [(h.upper_bound, h.graph_id) for h in store.range_search(query, 4.0)]
    assert ub == sorted(ub)


def test_range_search_novel_query_and_labels():
    """A query that is not a corpus member — and carries labels the corpus
    never uses — still gets exact hits."""
    corpus = _corpus(1, 8, planted=0)
    rng = np.random.default_rng(99)
    query = random_graph(rng, 5, density=0.5, n_vlabels=7, n_elabels=3)
    truth = [brute_force_ged(query, g) for g in corpus]
    store = ged.GraphStore(corpus, **STORE_OPTS)
    for tau in (2.0, 5.0):
        got = sorted(h.graph_id for h in store.range_search(query, tau))
        assert got == sorted(i for i, t in enumerate(truth) if t <= tau)


# ------------------------------------------------------- top-k parity

def test_top_k_matches_bruteforce_ranking():
    corpus = _corpus(2, 9)
    query = corpus[3]
    truth = [brute_force_ged(query, g) for g in corpus]
    by_dist = sorted(range(len(corpus)), key=lambda i: (truth[i], i))
    store = ged.GraphStore(corpus, **STORE_OPTS)
    for k in (1, 3, 6, len(corpus) + 5):
        hits = store.top_k(query, k)
        assert [h.graph_id for h in hits] == by_dist[:k]
        assert [h.ged for h in hits] == [truth[i] for i in by_dist[:k]]
        assert all(h.certified for h in hits)
    # the lower-bound walk must have skipped part of the corpus for small k
    s = store.stats
    assert s["topk_verified"] <= s["topk_candidates"]
    assert store.top_k(query, 0) == []


# --------------------------------------------------- filter soundness

def test_stage0_bound_is_admissible():
    """The vectorized stage-0 bound never exceeds the true GED (so stage-0
    pruning can never drop a true hit), and matches its host oracle."""
    rng = np.random.default_rng(3)
    graphs = [random_graph(rng, int(rng.integers(2, 7)), density=0.5,
                           n_vlabels=3, n_elabels=2) for _ in range(12)]
    from repro.ged.filters import FilterIndex
    from repro.ged.plan import graphs_vocab
    idx = FilterIndex(graphs, list(range(len(graphs))),
                      graphs_vocab(graphs), Executor())
    for qi in (0, 5, 11):
        q = graphs[qi]
        lbs = idx.scan_by_id(q)
        for gi, g in enumerate(graphs):
            true = brute_force_ged(q, g)
            assert lbs[gi] <= true + 1e-5, (qi, gi, lbs[gi], true)
            assert lbs[gi] == pytest.approx(stage0_reference(q, g))
        assert lbs[qi] == 0.0


def test_stage0_scan_reuses_compilations():
    """Same-bucket queries must not re-trace the fused scan kernel."""
    rng = np.random.default_rng(30)
    graphs = [random_graph(rng, int(rng.integers(3, 7)), density=0.4,
                           n_vlabels=3, n_elabels=2) for _ in range(8)]
    from repro.ged.filters import FilterIndex
    from repro.ged.plan import graphs_vocab
    idx = FilterIndex(graphs, list(range(len(graphs))),
                      graphs_vocab(graphs), Executor())
    q4 = random_graph(rng, 4, density=0.4, n_vlabels=3, n_elabels=2)
    t0 = scan_traces()
    idx.scan(q4)
    assert scan_traces() - t0 >= 1          # first query compiles
    t1 = scan_traces()
    idx.scan(random_graph(rng, 3, density=0.4, n_vlabels=3, n_elabels=2))
    assert scan_traces() - t1 == 0, "same-bucket query re-traced the scan"


def test_no_stage_prunes_a_true_hit_property():
    """Filter-soundness property sweep: across random corpora, queries and
    thresholds, range_search returns exactly the brute-force hit set."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), tau=st.integers(0, 5))
    def run(seed, tau):
        rng = np.random.default_rng(seed)
        corpus = [random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                               n_vlabels=2, n_elabels=2) for _ in range(6)]
        query = random_graph(rng, int(rng.integers(2, 6)), density=0.5,
                             n_vlabels=2, n_elabels=2)
        store = ged.GraphStore(corpus, **STORE_OPTS)
        got = sorted(h.graph_id for h in store.range_search(query, float(tau)))
        want = sorted(i for i, g in enumerate(corpus)
                      if brute_force_ged(query, g) <= tau)
        assert got == want, (seed, tau, got, want)

    run()


# ------------------------------------------------------- stats contract

def test_store_stats_account_for_every_candidate():
    corpus = _corpus(4, 12)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    store.range_search(corpus[0], 2.0)
    store.range_search(corpus[5], 1.0)
    s = store.stats
    assert s["queries"] == 2
    assert s["candidates"] == 2 * s["dedup_groups"]
    # the funnel sums to |candidates| across every stage, -1 included
    decided = s["index_pruned"] + s["stage0_pruned"] + \
        s["stage1_decided"] + s["stage2_verified"]
    assert decided == s["candidates"]
    assert s["candidates_stage_-1"] == s["candidates"]  # index on: sees all
    assert 0.0 <= s["filter_ratio"] <= 1.0
    assert s["filter_ratio"] == \
        (s["candidates"] - s["stage2_verified"]) / s["candidates"]
    # random corpus: the cheap stages bite before full verification
    assert s["index_pruned"] + s["stage0_pruned"] > 0
    assert s["scan_wall_s"] >= 0.0 and s["index_wall_s"] >= 0.0
    assert "engine_pairs" in s

    flat = ged.GraphStore(corpus, index=None, **STORE_OPTS)
    flat.range_search(corpus[0], 2.0)
    f = flat.stats
    assert f["candidates_stage_-1"] == 0 and f["index_pruned"] == 0
    assert f["stage0_pruned"] + f["stage1_decided"] + \
        f["stage2_verified"] == f["candidates"]


def test_search_batch_tags_query_ids():
    corpus = _corpus(5, 6)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    per_q = store.search_batch([corpus[0], corpus[1]], 2.0)
    assert len(per_q) == 2
    for qi, hits in enumerate(per_q):
        assert all(h.query_id == qi for h in hits)
    assert any(h.graph_id == 0 for h in per_q[0])
    assert any(h.graph_id == 1 for h in per_q[1])


# ------------------------------------------------------------- dedup

def test_wl_digest_is_isomorphism_invariant():
    rng = np.random.default_rng(6)
    for _ in range(5):
        g = random_graph(rng, int(rng.integers(3, 8)), density=0.5,
                         n_vlabels=3, n_elabels=2)
        p = _permuted(rng, g)
        assert wl_digest(g) == wl_digest(p)
        if not np.array_equal(g.vlabels, p.vlabels) or \
                not np.array_equal(g.adj, p.adj):
            assert graph_digest(g) != graph_digest(p)
    a = random_graph(rng, 6, density=0.4, n_vlabels=3, n_elabels=2)
    b = perturb(rng, a, 2, n_vlabels=3, n_elabels=2)
    if brute_force_ged(a, b) > 0:
        assert wl_digest(a) != wl_digest(b)


def test_store_dedups_isomorphic_corpus_entries():
    rng = np.random.default_rng(7)
    corpus = _corpus(7, 6, planted=0)
    corpus.append(_permuted(rng, corpus[2]))      # isomorphic duplicate
    corpus.append(corpus[3].copy())               # identical duplicate
    store = ged.GraphStore(corpus, **STORE_OPTS)
    assert store.stats["dedup_duplicates"] == 2
    assert store.stats["dedup_checks"] >= 1       # wl merge was confirmed
    # routing lookups are byte-exact: an iso rewrite must NOT match
    assert store.member_id(corpus[6]) == 6 or store.member_id(corpus[6]) == 2
    assert store.member_id(_permuted(rng, corpus[2])) is None

    query = corpus[2]
    hits = store.range_search(query, 0.0)         # iso copies: GED 0
    ids = sorted(h.graph_id for h in hits)
    assert 2 in ids and 6 in ids                  # rep + its iso duplicate
    by_id = {h.graph_id: h for h in hits}
    assert by_id[6].outcome.stats.get("dedup")
    assert by_id[6].outcome.mapping is None       # wl dup: mapping dropped

    exact = ged.GraphStore(corpus, digest="exact", **STORE_OPTS)
    assert exact.stats["dedup_duplicates"] == 1   # only the identical copy


def test_wl_collision_between_nonisomorphic_graphs_stays_sound():
    """A 6-cycle and two disjoint triangles are WL-equivalent (2-regular,
    uniform labels) but far apart in GED — the store must keep them in
    separate groups and answer both correctly."""
    cycle = ged.as_graph(([0] * 6, [(i, (i + 1) % 6, 1) for i in range(6)]))
    triangles = ged.as_graph(([0] * 6, [(0, 1, 1), (1, 2, 1), (0, 2, 1),
                                        (3, 4, 1), (4, 5, 1), (3, 5, 1)]))
    assert wl_digest(cycle) == wl_digest(triangles)       # the trap
    assert brute_force_ged(cycle, triangles) > 0

    store = ged.GraphStore([cycle, triangles], **STORE_OPTS)
    assert store.stats["dedup_groups"] == 2               # merge rejected
    assert store.stats["dedup_checks"] == 1
    hits = store.range_search(cycle, 0.5)
    assert [h.graph_id for h in hits] == [0]              # no aliasing
    top = store.top_k(cycle, 2)
    assert [h.graph_id for h in top] == [0, 1]
    assert top[0].ged == 0.0
    assert top[1].ged == brute_force_ged(cycle, triangles)

    # merging is not blocked by a non-isomorphic collider sorting first:
    # a relabelled copy of the triangles still joins the triangles group
    rng = np.random.default_rng(31)
    tri2 = _permuted(rng, triangles)
    three = ged.GraphStore([cycle, triangles, tri2], **STORE_OPTS)
    assert three.stats["dedup_groups"] == 2               # cycle | tris x2
    assert three.stats["dedup_duplicates"] == 1
    assert sorted(h.graph_id for h in three.range_search(triangles, 0.5)) \
        == [1, 2]


def test_verify_members_duplicate_requests_are_independent():
    corpus = _corpus(32, 5, planted=0)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    outs = store.verify_members(corpus[0], [0, 0, 1], [9.0, 9.0, 9.0])
    assert outs[0] is not outs[1]
    assert (outs[0].similar, outs[0].certified) == \
        (outs[1].similar, outs[1].certified)
    outs[0].stats["poison"] = 1
    assert "poison" not in outs[1].stats
    if outs[0].mapping is not None and outs[1].mapping is not None:
        outs[0].mapping[:] = -9
        assert not np.array_equal(outs[1].mapping, outs[0].mapping)


def test_engine_wl_digest_cache_hits_isomorphic_pairs():
    rng = np.random.default_rng(8)
    q = random_graph(rng, 5, density=0.4, n_vlabels=3, n_elabels=2)
    g = perturb(rng, q, 2, n_vlabels=3, n_elabels=2)
    qp, gp = _permuted(rng, q), _permuted(rng, g)

    eng = ged.GedEngine("jax", digest="wl", pool=256, expand=4,
                        max_iters=256)
    first = eng.compute([(q, g)])[0]
    second = eng.compute([(qp, gp)])[0]           # isomorphic rewrite: hit
    assert eng.stats["result_cache_hits"] == 1
    assert second.stats.get("cached") and second.mapping is None
    assert second.ged == first.ged

    plain = ged.GedEngine("jax", pool=256, expand=4, max_iters=256)
    plain.compute([(q, g)])
    plain.compute([(qp, gp)])                     # exact digest: miss
    assert plain.stats["result_cache_hits"] == 0


# ----------------------------------------------- sharded corpus scan

def test_store_with_mesh_uses_sharded_executor():
    import jax
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    corpus = _corpus(9, 5)
    store = ged.GraphStore(corpus, mesh=mesh, **STORE_OPTS)
    assert isinstance(store.executor, ShardedExecutor)
    assert all(b.features.batch % store.executor.batch_multiple == 0
               for b in store._index.buckets)
    plain = ged.GraphStore(corpus, **STORE_OPTS)
    q = corpus[1]
    assert [(h.graph_id, h.similar) for h in store.range_search(q, 2.0)] == \
        [(h.graph_id, h.similar) for h in plain.range_search(q, 2.0)]


SHARDED_STORE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from repro import ged
    from repro.data.graphs import perturb, random_graph
    from repro.ged.exec import ShardedExecutor

    assert jax.device_count() == 8
    rng = np.random.default_rng(10)
    corpus = [random_graph(rng, int(rng.integers(3, 8)), density=0.4,
                           n_vlabels=3, n_elabels=2) for _ in range(13)]
    corpus.append(perturb(rng, corpus[0], 1, n_vlabels=3, n_elabels=2))
    opts = dict(pool=256, expand=4, max_iters=256, batch_size=8)

    plain = ged.GraphStore(corpus, **opts)
    mesh = jax.make_mesh((8,), ("data",))
    store = ged.GraphStore(corpus, mesh=mesh, **opts)
    assert isinstance(store.executor, ShardedExecutor)
    assert store.executor.batch_multiple == 8
    # 14 corpus graphs: feature buckets pad to multiples of 8 shards
    assert all(b.features.batch %% 8 == 0 for b in store._index.buckets)

    q = corpus[0]
    for tau in (1.0, 3.0):
        a = [(h.graph_id, h.similar, h.certified)
             for h in plain.range_search(q, tau)]
        b = [(h.graph_id, h.similar, h.certified)
             for h in store.range_search(q, tau)]
        assert a == b, (tau, a, b)
    assert [h.graph_id for h in store.top_k(q, 4)] == \\
        [h.graph_id for h in plain.top_k(q, 4)]
    s = store.stats
    assert s["index_pruned"] + s["stage0_pruned"] > 0
    print("OK")
""")


@pytest.mark.slow
def test_sharded_corpus_scan_parity_on_8_devices():
    """The PR-2/PR-3 subprocess harness, pointed at the corpus scan: a
    GraphStore whose filter scan and verification rungs shard over a real
    8-device mesh answers exactly like the single-device store."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_STORE_SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
