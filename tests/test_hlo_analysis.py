"""Unit tests for the trip-count-corrected HLO analyzer (no compiles)."""

import textwrap

from repro.launch.hlo_analysis import (analyze_hlo, parse_module,
                                       _shape_bytes)


MODULE = textwrap.dedent("""\
    HloModule jit_step

    %body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
      %p = (s32[], f32[8,64]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,64]{1,0} get-tuple-element(%p), index=1
      %c1 = s32[] constant(1)
      %ni = s32[] add(%i, %c1)
      %w = f32[64,64]{1,0} constant({...})
      %ag = f32[8,64]{0,1} all-gather(%x), channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={1}
      %d = f32[8,64]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %t = (s32[], f32[8,64]{1,0}) tuple(%ni, %d)
    }

    %cond (pc: (s32[], f32[8,64])) -> pred[] {
      %pc = (s32[], f32[8,64]{1,0}) parameter(0)
      %ic = s32[] get-tuple-element(%pc), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%ic, %n), direction=LT
    }

    ENTRY %main (a: f32[8,64]) -> f32[8,64] {
      %a = f32[8,64]{1,0} parameter(0)
      %z = s32[] constant(0)
      %tup = (s32[], f32[8,64]{1,0}) tuple(%z, %a)
      %wh = (s32[], f32[8,64]{1,0}) while(%tup), condition=%cond, body=%body
      ROOT %out = f32[8,64]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_parse_module_structure():
    comps = parse_module(MODULE)
    assert set(comps) == {"body", "cond", "main"}
    assert comps["main"].is_entry
    assert [i.opcode for i in comps["cond"].instrs][-1] == "compare"


def test_trip_count_from_condition_and_flops():
    out = analyze_hlo(MODULE)
    # dot: 2*8*64*64 x 5 trips (condition-parse fallback path), + 5 adds
    # in the body, + 6 compares in the condition (trip + 1 evaluations)
    assert out["flops"] == 2 * 8 * 64 * 64 * 5 + 5 + 6
    assert not out["warnings"]


def test_collective_bytes_trip_multiplied():
    out = analyze_hlo(MODULE)
    assert out["collective_bytes"] == 8 * 64 * 4 * 5
    assert out["collective_by_op"] == {"all-gather": 8 * 64 * 4 * 5}


def test_backend_config_trip_count_preferred():
    mod = MODULE.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}')
    out = analyze_hlo(mod)
    assert out["collective_bytes"] == 8 * 64 * 4 * 7


def test_dcn_attribution():
    out = analyze_hlo(MODULE, pod_boundary=2)   # groups {0,1},{2,3}: intra
    assert out["dcn_bytes"] == 0
    mod = MODULE.replace("replica_groups={{0,1},{2,3}}",
                         "replica_groups={{0,2},{1,3}}")
    out2 = analyze_hlo(mod, pod_boundary=2)     # crosses the boundary
    assert out2["dcn_bytes"] == out2["collective_bytes"] > 0


def test_shape_bytes_tuple_and_dtypes():
    assert _shape_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert _shape_bytes("(s32[], bf16[2,3]{1,0})") == 4 + 12
    assert _shape_bytes("pred[2048]{0}") == 2048
