"""Pallas kernel sweeps: shapes/dtypes vs the pure-jnp oracles in ref.py."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.bma_cost_matrix import bma_cost_matrix_pallas
from repro.kernels.reduced_top2 import reduced_top2_pallas


def _bma_inputs(rng, b, n, le, vl=5, el=3):
    qv = jnp.asarray(rng.integers(0, vl, (b, n)), jnp.int32)
    gv = jnp.asarray(rng.integers(0, vl, (b, n)), jnp.int32)
    iq = jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32)
    ig = jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32)
    qa = jnp.asarray(rng.integers(0, el, (b, n, n)), jnp.int32)
    gc = jnp.asarray(rng.integers(0, el, (b, n, n)), jnp.int32)
    pa = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32)
    return qv, gv, iq, ig, qa, gc, pa


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("n", [8, 16, 32, 64])
@pytest.mark.parametrize("le", [1, 2, 5])
def test_bma_cost_matrix_kernel_sweep(b, n, le):
    rng = np.random.default_rng(b * 100 + n + le)
    args = _bma_inputs(rng, b, n, le)
    got = bma_cost_matrix_pallas(*args, interpret=True)
    want = ref.bma_cost_matrix_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("tile", [(8, 8), (16, 8), (8, 16)])
def test_bma_cost_matrix_kernel_tilings(tile):
    rng = np.random.default_rng(42)
    args = _bma_inputs(rng, 2, 32, 3)
    got = bma_cost_matrix_pallas(*args, tile_v=tile[0], tile_u=tile[1],
                                 interpret=True)
    want = ref.bma_cost_matrix_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("n", [8, 16, 64, 128])
def test_reduced_top2_kernel_sweep(b, n):
    rng = np.random.default_rng(b * 7 + n)
    cost = jnp.asarray(rng.random((b, n, n)), jnp.float32)
    prices = jnp.asarray(rng.random((b, n)) * 3, jnp.float32)
    m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=True)
    w1, wa, w2 = ref.reduced_top2_ref(cost, prices)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(w1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(wa))
    np.testing.assert_allclose(np.asarray(m2), np.asarray(w2), rtol=1e-6)


def test_reduced_top2_with_big_entries():
    """BIG-masked (forbidden) entries must not confuse the top-2."""
    rng = np.random.default_rng(0)
    cost = rng.random((2, 16, 16)).astype(np.float32)
    cost[:, :, ::3] = 1e7
    cost = jnp.asarray(cost)
    prices = jnp.zeros((2, 16), jnp.float32)
    m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=True)
    w1, wa, w2 = ref.reduced_top2_ref(cost, prices)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(w1))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(wa))


def test_ops_wrappers_vmap_and_grad_safety():
    """ops.* must work unbatched, batched, and under vmap."""
    rng = np.random.default_rng(1)
    b, n, le = 3, 16, 2
    qv, gv, iq, ig, qa, gc, pa = _bma_inputs(rng, b, n, le)
    ga = gc  # treat as adjacency; ops gathers internally
    img = jnp.asarray(rng.integers(0, n, (b, n)), jnp.int32)
    full = ops.bma_cost_matrix(qv, gv, iq, ig, qa, ga, img, pa)
    single = ops.bma_cost_matrix(qv[0], gv[0], iq[0], ig[0], qa[0], ga[0],
                                 img[0], pa[0])
    np.testing.assert_allclose(np.asarray(full[0]), np.asarray(single))
    vm = jax.vmap(ops.bma_cost_matrix)(qv, gv, iq, ig, qa, ga, img, pa)
    np.testing.assert_allclose(np.asarray(vm), np.asarray(full))
