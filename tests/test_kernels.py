"""Pallas kernel sweeps: shapes/dtypes vs the pure-jnp oracles in ref.py."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.bma_cost_matrix import bma_cost_matrix_pallas
from repro.kernels.lsa_children import lsa_children_pallas
from repro.kernels.merge_topk import merge_ranks_pallas
from repro.kernels.reduced_top2 import reduced_top2_pallas


def _bma_inputs(rng, b, n, le, vl=5, el=3):
    qv = jnp.asarray(rng.integers(0, vl, (b, n)), jnp.int32)
    gv = jnp.asarray(rng.integers(0, vl, (b, n)), jnp.int32)
    iq = jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32)
    ig = jnp.asarray(rng.integers(0, 4, (b, n, le)), jnp.float32)
    qa = jnp.asarray(rng.integers(0, el, (b, n, n)), jnp.int32)
    gc = jnp.asarray(rng.integers(0, el, (b, n, n)), jnp.int32)
    pa = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32)
    return qv, gv, iq, ig, qa, gc, pa


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("n", [8, 16, 32, 64])
@pytest.mark.parametrize("le", [1, 2, 5])
def test_bma_cost_matrix_kernel_sweep(b, n, le):
    rng = np.random.default_rng(b * 100 + n + le)
    args = _bma_inputs(rng, b, n, le)
    got = bma_cost_matrix_pallas(*args, interpret=True)
    want = ref.bma_cost_matrix_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("tile", [(8, 8), (16, 8), (8, 16)])
def test_bma_cost_matrix_kernel_tilings(tile):
    rng = np.random.default_rng(42)
    args = _bma_inputs(rng, 2, 32, 3)
    got = bma_cost_matrix_pallas(*args, tile_v=tile[0], tile_u=tile[1],
                                 interpret=True)
    want = ref.bma_cost_matrix_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("n", [8, 16, 64, 128])
def test_reduced_top2_kernel_sweep(b, n):
    rng = np.random.default_rng(b * 7 + n)
    cost = jnp.asarray(rng.random((b, n, n)), jnp.float32)
    prices = jnp.asarray(rng.random((b, n)) * 3, jnp.float32)
    m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=True)
    w1, wa, w2 = ref.reduced_top2_ref(cost, prices)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(w1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(wa))
    np.testing.assert_allclose(np.asarray(m2), np.asarray(w2), rtol=1e-6)


def test_reduced_top2_with_big_entries():
    """BIG-masked (forbidden) entries must not confuse the top-2."""
    rng = np.random.default_rng(0)
    cost = rng.random((2, 16, 16)).astype(np.float32)
    cost[:, :, ::3] = 1e7
    cost = jnp.asarray(cost)
    prices = jnp.zeros((2, 16), jnp.float32)
    m1, a1, m2 = reduced_top2_pallas(cost, prices, interpret=True)
    w1, wa, w2 = ref.reduced_top2_ref(cost, prices)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(w1))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(wa))


def test_ops_wrappers_vmap_and_grad_safety():
    """ops.* must work unbatched, batched, and under vmap."""
    rng = np.random.default_rng(1)
    b, n, le = 3, 16, 2
    qv, gv, iq, ig, qa, gc, pa = _bma_inputs(rng, b, n, le)
    ga = gc  # treat as adjacency; ops gathers internally
    img = jnp.asarray(rng.integers(0, n, (b, n)), jnp.int32)
    full = ops.bma_cost_matrix(qv, gv, iq, ig, qa, ga, img, pa)
    single = ops.bma_cost_matrix(qv[0], gv[0], iq[0], ig[0], qa[0], ga[0],
                                 img[0], pa[0])
    np.testing.assert_allclose(np.asarray(full[0]), np.asarray(single))
    vm = jax.vmap(ops.bma_cost_matrix)(qv, gv, iq, ig, qa, ga, img, pa)
    np.testing.assert_allclose(np.asarray(vm), np.asarray(full))


# ----------------------------------------------------------- LSa children

def _lsa_inputs(rng, b, n, le):
    """Random flat operands for the fused LSa kernel (see ref.py docs)."""
    f = lambda *s: jnp.asarray(rng.integers(0, 4, s), jnp.float32)
    return dict(
        base=jnp.asarray(rng.integers(0, 9, (b, n)) * 0.5, jnp.float32),
        free_g=jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32),
        rowhist_g=f(b, n, le),
        a_ju=jnp.asarray(rng.integers(0, le + 1, (b, n, n)), jnp.int32),
        qrow=jnp.asarray(rng.integers(0, le + 1, (b, n)), jnp.int32),
        pos_anch=jnp.asarray(rng.integers(0, 2, (b, n)), jnp.float32),
        cq=f(b, n, le), cg=f(b, n, le),
        base_j=f(b, n), adjb_j=f(b, n),
        hq_i=0.5 * f(b, le), hg_i=0.5 * f(b, le), cq_vi=f(b, le),
    )


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("n", [8, 16, 32, 64])
@pytest.mark.parametrize("le", [1, 2, 5])
def test_lsa_children_kernel_sweep(b, n, le):
    rng = np.random.default_rng(b * 1000 + n * 10 + le)
    args = _lsa_inputs(rng, b, n, le)
    got = lsa_children_pallas(*args.values(), interpret=True)
    want = ref.lsa_children_ref(*args.values())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tile_u", [8, 16, 32])
def test_lsa_children_kernel_tilings(tile_u):
    rng = np.random.default_rng(9)
    args = _lsa_inputs(rng, 2, 32, 3)
    got = lsa_children_pallas(*args.values(), tile_u=tile_u, interpret=True)
    want = ref.lsa_children_ref(*args.values())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lsa_ops_wrapper_unbatched_and_vmap():
    rng = np.random.default_rng(5)
    args = list(_lsa_inputs(rng, 3, 16, 2).values())
    full = ops.lsa_children(*args)
    single = ops.lsa_children(*(x[0] for x in args))
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(single))
    vm = jax.vmap(ops.lsa_children)(*args)
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(full))


def _engine_state(rng, slots, n_graph, level):
    """A real (PairConsts, StateMasks, level, g_cost) engine state."""
    from repro.core.engine import bounds as eb
    from repro.core.engine.tensor_graphs import pack_pairs
    from repro.data.graphs import perturb, random_graph

    q = random_graph(rng, n_graph, density=0.4, n_vlabels=3, n_elabels=2)
    g = perturb(rng, q, int(rng.integers(0, 4)), n_vlabels=3, n_elabels=2)
    t = pack_pairs([(q, g)], slots=slots)
    pc = eb.make_pair_consts(
        jnp.asarray(t.qv[0]), jnp.asarray(t.gv[0]), jnp.asarray(t.qa[0]),
        jnp.asarray(t.ga[0]), jnp.asarray(t.order[0]), jnp.asarray(t.n[0]),
        t.n_vlabels, t.n_elabels)
    n = int(t.n[0])
    level = min(level, n - 1)
    img = np.full(slots, -1, np.int32)
    img[:level] = rng.permutation(n)[:level]
    sm = eb.state_masks(pc, jnp.asarray(img), jnp.int32(level))
    g_cost = jnp.float32(float(rng.integers(0, 7)) * 0.5)
    return pc, sm, jnp.int32(level), g_cost


@pytest.mark.parametrize("slots,n_graph,level",
                         [(8, 5, 0), (8, 8, 3), (16, 6, 1), (16, 12, 7),
                          (32, 9, 4)])
def test_lsa_engine_state_kernel_parity(slots, n_graph, level):
    """bounds.lsa_children kernel path == unfused path, bit for bit, on
    real engine states — PAD slots, bottom labels and masks included."""
    from repro.core.engine import bounds as eb
    rng = np.random.default_rng(slots * 100 + n_graph * 10 + level)
    pc, sm, lvl, g_cost = _engine_state(rng, slots, n_graph, level)
    want = eb.lsa_children(pc, sm, lvl, g_cost, use_kernel=False)
    got = eb.lsa_children(pc, sm, lvl, g_cost, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lsa_engine_state_kernel_parity_hypothesis():
    """Hypothesis sweep over graph sizes / levels / seeds (PAD-heavy slots
    included via the slots draw): the fused kernel must equal the unfused
    bound exactly — small-half float arithmetic leaves no rounding room."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.core.engine import bounds as eb

    @settings(max_examples=25, deadline=None)
    @given(slots=st.sampled_from([8, 16, 32]),
           n_graph=st.integers(3, 12),
           level=st.integers(0, 10),
           seed=st.integers(0, 2 ** 16))
    def check(slots, n_graph, level, seed):
        if n_graph > slots:
            n_graph = slots
        rng = np.random.default_rng(seed)
        pc, sm, lvl, g_cost = _engine_state(rng, slots, n_graph, level)
        want = eb.lsa_children(pc, sm, lvl, g_cost, use_kernel=False)
        got = eb.lsa_children(pc, sm, lvl, g_cost, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    check()


# -------------------------------------------------- merge-path rank counts

def _sorted_runs(rng, b, na, nb, lo=0, hi=6):
    """Key-sorted runs with plenty of ties (small integer keys)."""
    a = np.sort(rng.integers(lo, hi, (b, na)), axis=1).astype(np.float32)
    bb = np.sort(rng.integers(lo, hi, (b, nb)), axis=1).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(bb)


@pytest.mark.parametrize("b,na,nb", [(1, 8, 8), (3, 64, 32), (2, 128, 96),
                                     (1, 1016, 64), (2, 504, 128)])
def test_merge_ranks_kernel_sweep(b, na, nb):
    """Counts match the oracle AND numpy searchsorted on arbitrary run
    lengths (1016 and 504 exercise the gcd tile fallback: gcd(.,128)=8)."""
    rng = np.random.default_rng(b * 1000 + na + nb)
    ka, kb = _sorted_runs(rng, b, na, nb)
    ca, cb = merge_ranks_pallas(ka, kb, interpret=True)
    wa, wb = ref.merge_ranks_ref(ka, kb)
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(cb), np.asarray(wb))
    for i in range(b):
        np.testing.assert_array_equal(
            np.asarray(ca[i]),
            np.searchsorted(np.asarray(kb[i]), np.asarray(ka[i]), "left"))
        np.testing.assert_array_equal(
            np.asarray(cb[i]),
            np.searchsorted(np.asarray(ka[i]), np.asarray(kb[i]), "right"))


@pytest.mark.parametrize("tile", [8, 16, 64])
def test_merge_ranks_kernel_tilings(tile):
    rng = np.random.default_rng(11)
    ka, kb = _sorted_runs(rng, 2, 128, 64)
    got = merge_ranks_pallas(ka, kb, tile_x=tile, interpret=True)
    want = ref.merge_ranks_ref(ka, kb)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_merge_ranks_all_ties_and_infs():
    """Degenerate runs: every key equal, and +inf PAD tails (the pool
    merge pads dead slots with +inf) — strict/non-strict must split them
    exactly as searchsorted left/right does."""
    ka = jnp.asarray([[2.0, 2.0, 2.0, 2.0, jnp.inf, jnp.inf, jnp.inf,
                       jnp.inf]], jnp.float32)
    kb = jnp.asarray([[2.0, 2.0, jnp.inf, jnp.inf, jnp.inf, jnp.inf,
                       jnp.inf, jnp.inf]], jnp.float32)
    ca, cb = merge_ranks_pallas(ka, kb, interpret=True)
    np.testing.assert_array_equal(np.asarray(ca)[0],
                                  [0, 0, 0, 0, 2, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(cb)[0],
                                  [4, 4, 8, 8, 8, 8, 8, 8])


def test_merge_ranks_ops_wrapper_unbatched():
    """ops.merge_ranks accepts unbatched (N,) runs and strips the batch
    axis back off; the ref path (REPRO_DISABLE_PALLAS) agrees."""
    rng = np.random.default_rng(5)
    ka, kb = _sorted_runs(rng, 1, 32, 16)
    ca2, cb2 = ops.merge_ranks(ka, kb)              # batched
    ca1, cb1 = ops.merge_ranks(ka[0], kb[0])        # unbatched
    assert ca1.shape == (32,) and cb1.shape == (16,)
    np.testing.assert_array_equal(np.asarray(ca1), np.asarray(ca2)[0])
    np.testing.assert_array_equal(np.asarray(cb1), np.asarray(cb2)[0])
    wa, wb = ref.merge_ranks_ref(ka, kb)
    np.testing.assert_array_equal(np.asarray(ca2), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(cb2), np.asarray(wb))
