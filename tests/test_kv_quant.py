"""int8 KV-cache quantisation (dense-family decode, beyond-paper)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.params import init_params


def _cfg(kv_quant):
    cfg = reduced(get_arch("qwen3-8b"))
    return dataclasses.replace(cfg, remat="none", compute_dtype="float32",
                               kv_quant=kv_quant)


def test_quantize_roundtrip_bounded(rng):
    kc = jnp.asarray(rng.normal(size=(2, 3, 8, 4, 16)) * 2.0, jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 3, 8, 4, 16)), jnp.float32)
    kq, vq, ks, vs = L.quantize_kv(kc, vc)
    assert kq.dtype == jnp.int8 and ks.shape == (2, 3, 4)
    back = kq.astype(jnp.float32) * np.asarray(ks)[:, :, None, :, None]
    err = np.abs(back - np.asarray(kc))
    # per-(L,B,H) scale bounds the error at scale/2
    bound = np.asarray(ks)[:, :, None, :, None] * 0.5 + 1e-6
    assert np.all(err <= bound)


def test_decode_close_to_bf16_path(rng):
    cfg_q = _cfg(True)
    cfg_f = _cfg(False)
    params = init_params(cfg_f, seed=0)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg_f.vocab, (b, s + 1)), jnp.int32)

    logits_f, caches_f = T.prefill_step(params, tokens[:, :s], cfg_f,
                                        impl="naive")
    logits_q, caches_q = T.prefill_step(params, tokens[:, :s], cfg_q,
                                        impl="naive")
    assert caches_q["k"].dtype == jnp.int8
    # prefill logits identical (quantisation happens after the forward)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_f),
                               atol=1e-5)

    def grow(caches, cfg):
        want = T.cache_shapes(cfg, b, s + 4)
        out = {}
        for k, v in caches.items():
            shape, dt = want[k]
            buf = jnp.zeros(shape, dt)
            sl = tuple(slice(0, min(a, bb)) for a, bb in zip(v.shape, shape))
            out[k] = buf.at[sl].set(v[sl].astype(dt))
        return out

    dq, _ = T.decode_step(params, grow(caches_q, cfg_q), tokens[:, s:s + 1],
                          jnp.int32(s), cfg_q)
    df, _ = T.decode_step(params, grow(caches_f, cfg_f), tokens[:, s:s + 1],
                          jnp.int32(s), cfg_f)
    # int8 cache error is small relative to logit scale
    denom = float(np.abs(np.asarray(df)).max()) + 1e-6
    rel = float(np.abs(np.asarray(dq) - np.asarray(df)).max()) / denom
    assert rel < 0.05, rel
    # greedy tokens agree
    np.testing.assert_array_equal(np.argmax(np.asarray(dq), -1),
                                  np.argmax(np.asarray(df), -1))


def test_cache_shapes_quant_layout():
    cfg = _cfg(True)
    shapes = T.cache_shapes(cfg, 4, 64)
    assert shapes["k"][1] == jnp.int8
    assert shapes["k_scale"][0] == (cfg.n_layers, 4, cfg.n_kv_heads)
    axes = T.cache_axes(cfg)
    assert axes["k_scale"] == (None, "batch", None)
    assert axes["k"] == (None, "batch", "kv_seq", None, None)
