"""Launch layer: shape registry, analytic flops, HLO analyzer, and a
reduced-scale lower+compile of every step kind on an 8-device fake mesh."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.launch.flops import model_flops
from repro.launch.shapes import (GED_SHAPES, SHAPE_ORDER, SHAPES,
                                 cell_skip_reason, input_specs)


def test_grid_is_40_cells():
    assert len(ARCHS) == 10 and len(SHAPE_ORDER) == 4


def test_skip_policy():
    skipped = {(a, s) for a in ARCHS for s in SHAPE_ORDER
               if cell_skip_reason(get_arch(a), SHAPES[s])}
    assert skipped == {(a, "long_500k") for a in ARCHS
                       if not get_arch(a).subquadratic}
    assert {a for a, _ in skipped} == {
        "qwen3-8b", "nemotron-4-15b", "qwen2-72b", "qwen2-vl-2b",
        "moonshot-v1-16b-a3b", "qwen2-moe-a2.7b", "whisper-large-v3"}


def test_input_specs_all_cells():
    for a in ARCHS:
        cfg = get_arch(a)
        for s in SHAPE_ORDER:
            sh = SHAPES[s]
            specs = input_specs(cfg, sh)
            if sh.kind == "decode":
                assert specs["token"].shape == (sh.global_batch, 1)
            else:
                toks = specs["tokens"]
                assert toks.shape[0] == sh.global_batch
                if cfg.vlm is not None:
                    assert (toks.shape[1] + specs["patches"].shape[1]
                            == sh.seq_len)
                else:
                    assert toks.shape[1] == sh.seq_len
            if sh.kind == "train":
                assert "labels" in specs


def test_model_flops_scaling():
    cfg = get_arch("qwen3-8b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_pre = model_flops(cfg, SHAPES["prefill_32k"])
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    # 6ND vs 2ND: train ~ 3x prefill at equal token counts
    tokens_t = f_train["tokens"]
    tokens_p = f_pre["tokens"]
    ratio = (f_train["model_flops"] / tokens_t) / \
        (f_pre["model_flops"] / tokens_p)
    # attention flops/token grow with seq, diluting the 3x at 32k prefill
    assert 1.8 < ratio < 3.2
    # decode processes B tokens, vastly fewer flops
    assert f_dec["model_flops"] < f_pre["model_flops"] / 100
    # 8B arch: ~7e9 matmul params
    assert 5e9 < f_train["n_matmul_params"] < 9e9


def test_moe_flops_count_active_only():
    f = model_flops(get_arch("qwen2-moe-a2.7b"), SHAPES["train_4k"])
    # active ~2.7B nominal (we count matmul params, ~2.3-3.5B incl shared)
    assert 1.5e9 < f["n_active_matmul_params"] < 4.5e9


def test_hlo_analyzer_counts_scan_trips():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        def step(params, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(body, x, params)
            return c.sum()
        L, D = 7, 256
        params = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((16, D), jnp.float32)
        with mesh:
            compiled = jax.jit(step, in_shardings=(
                NamedSharding(mesh, P(None, None, "model")),
                NamedSharding(mesh, P("data", None)))).lower(params, x).compile()
        out = analyze_hlo(compiled.as_text())
        dot_flops = 2 * 8 * 64 * 256 * L          # per device, L trips
        assert dot_flops <= out["flops"] <= dot_flops * 1.2, out
        assert out["collective_bytes"] >= 8 * 64 * 4 * L  # all-gather x L
        assert not out["warnings"], out["warnings"]
        print("OK")
    """) % (os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                         "src")),)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=180)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


BUILD_CELL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import dataclasses, jax
    from repro.configs import get_arch
    from repro.models.config import reduced
    from repro.launch.shapes import ShapeSpec
    from repro.launch.steps import build_cell
    from repro.launch.hlo_analysis import analyze_hlo

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced(get_arch(%r), layers=3, d_model=64, vocab=512,
                  d_ff=128, heads=4)
    cfg = dataclasses.replace(cfg, train_accum=2)
    for spec in (ShapeSpec("t", "train", 64, 8),
                 ShapeSpec("p", "prefill", 64, 8),
                 ShapeSpec("d", "decode", 64, 8)):
        plan = build_cell(cfg, spec, mesh)
        with mesh:
            c = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                        out_shardings=plan.out_shardings,
                        donate_argnums=plan.donate_argnums
                        ).lower(*plan.args).compile()
        a = analyze_hlo(c.as_text(), pod_boundary=4)
        assert a["flops"] > 0
        print(spec.kind, "ok", int(a["flops"]))
    print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen2-moe-a2.7b",
                                  "rwkv6-3b", "zamba2-7b"])
def test_build_cell_compiles_multipod_reduced(arch):
    """All three step kinds lower+compile on a 2x2x2 (pod,data,model) mesh."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", BUILD_CELL % (src, arch)],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
