"""GED verification under real (fake-device) mesh sharding.

The dry-run proves the 512-chip lowering; this test EXECUTES the batched
engine with the pair batch sharded over 8 devices and checks answers are
identical to the single-device run (lockstep vmap semantics are
placement-invariant).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_DISABLE_PALLAS"] = "1"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine.api import verify_batch, _run_batch, _pair_tuple
    from repro.core.engine.search import EngineConfig
    from repro.core.engine.tensor_graphs import pack_pairs
    from repro.data.graphs import perturb, random_graph

    rng = np.random.default_rng(5)
    pairs = []
    for _ in range(16):
        q = random_graph(rng, 10)
        pairs.append((q, perturb(rng, q, 3)))
    packed = pack_pairs(pairs, slots=16)
    cfg = EngineConfig(pool=256, expand=4, max_iters=256, bound="hybrid",
                       strategy="astar", use_kernel=False)
    taus = [4.0] * 16

    # single-device reference
    ref = verify_batch(packed, taus, cfg)

    # sharded execution: pairs over a (4, 2) mesh, all axes
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sh = NamedSharding(mesh, P(("data", "model")))
    import jax.numpy as jnp
    args = [jax.device_put(jnp.asarray(a), NamedSharding(
        mesh, P(("data", "model"), *([None] * (np.asarray(a).ndim - 1)))))
        for a in _pair_tuple(packed)]
    t = jax.device_put(jnp.asarray(np.asarray(taus, np.float32)), sh)
    with mesh:
        out = _run_batch(*args, t, cfg, True, packed.n_vlabels,
                         packed.n_elabels)
    for k in ("similar", "exact"):
        np.testing.assert_array_equal(np.asarray(out[k]), ref[k])
    # outputs stayed sharded (no implicit gather)
    assert len(out["similar"].sharding.device_set) == 8
    print("OK")
""")


@pytest.mark.slow
def test_verify_batch_sharded_matches_single_device():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
