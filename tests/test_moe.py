"""MoE grouped-dispatch invariants (pure CPU, G=1 and simulated G>1)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import moe as moe_lib
from repro.models.config import reduced
from repro.models.params import init_params
from repro.parallel import sharding as sh


def _cfg(capacity_factor=16.0):
    cfg = reduced(get_arch("qwen2-moe-a2.7b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=capacity_factor))


def _params(cfg):
    return init_params(cfg, seed=0)["layers"]["moe"]


def _slice_layer(p):
    return jax.tree.map(lambda a: a[0], p)


def test_router_topk_distinct_and_normalized(rng):
    cfg = _cfg()
    p = _slice_layer(_params(cfg))
    x = jnp.asarray(rng.normal(size=(32, cfg.d_model)), jnp.float32)
    w, ids, probs = moe_lib.router_topk(x, p["router"], cfg)
    assert w.shape == (32, cfg.moe.top_k)
    # distinct experts per token
    ids_np = np.asarray(ids)
    for row in ids_np:
        assert len(set(row.tolist())) == cfg.moe.top_k
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-5)


def test_padded_experts_never_selected(rng):
    cfg = _cfg()
    base = get_arch("qwen2-moe-a2.7b")
    # simulate padding 60 -> 64
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=6,
                                     padded_experts=8))
    p = _slice_layer(_params(cfg))
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)
    _, ids, _ = moe_lib.router_topk(x, p["router"], cfg)
    assert int(np.asarray(ids).max()) < 6


def test_moe_mlp_matches_dense_expert_sum(rng):
    """With no drops, output == sum_k w_k * expert_k(x) computed densely."""
    cfg = _cfg(capacity_factor=64.0)
    p = _slice_layer(_params(cfg))
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.1, jnp.float32)
    out = moe_lib.moe_mlp(x, p, cfg)

    xt = x.reshape(-1, cfg.d_model)
    w, ids, _ = moe_lib.router_topk(xt, p["router"], cfg)
    dense = np.zeros((xt.shape[0], cfg.d_model), np.float32)
    for t in range(xt.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            h = np.asarray(jax.nn.silu(xt[t] @ p["wg"][e])
                           * (xt[t] @ p["wi"][e]))
            dense[t] += float(w[t, j]) * (h @ np.asarray(p["wo"][e]))
    if cfg.moe.shared_experts:
        sh_h = np.asarray(jax.nn.silu(xt @ p["shared_wg"])
                          * (xt @ p["shared_wi"]))
        dense += sh_h @ np.asarray(p["shared_wo"])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               dense, atol=2e-4)


def test_grouped_equals_global_when_capacity_ample(rng):
    """G>1 grouped dispatch == G=1 when capacity admits every token."""
    cfg = _cfg(capacity_factor=64.0)
    p = _slice_layer(_params(cfg))
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)) * 0.1, jnp.float32)
    out_g1 = moe_lib.moe_mlp(x, p, cfg)

    # force 4 groups (as if the batch were 4-way sharded)
    orig = moe_lib._num_groups
    moe_lib._num_groups = lambda b, s: 4
    try:
        out_g4 = moe_lib.moe_mlp(x, p, cfg)
    finally:
        moe_lib._num_groups = orig
    np.testing.assert_allclose(np.asarray(out_g1), np.asarray(out_g4),
                               atol=1e-5)


def test_capacity_drop_is_graceful(rng):
    """Tiny capacity: output stays finite, dropped tokens fall back to
    shared/zero contribution rather than corrupting others."""
    cfg = _cfg(capacity_factor=0.1)
    p = _slice_layer(_params(cfg))
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out = moe_lib.moe_mlp(x, p, cfg)
    assert np.all(np.isfinite(np.asarray(out)))


def test_aux_loss_balanced_is_one():
    cfg = _cfg()
    e = cfg.moe.total_experts
    t = 4 * e
    probs = jnp.full((t, e), 1.0 / e)
    ids = jnp.asarray(np.arange(t * cfg.moe.top_k) % e).reshape(
        t, cfg.moe.top_k)
    val = float(moe_lib.aux_loss(probs, ids, cfg))
    assert abs(val - 1.0) < 1e-4
