"""Property tests for SPMD-friendly op variants (parallel/ops.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.parallel.ops import top_k_sorted


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 5),
    n=st.integers(2, 33),
    k=st.integers(1, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_matches_lax_top_k_values(b, n, k, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    v_ref, _ = jax.lax.top_k(x, k)
    v, idx = top_k_sorted(x, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), atol=0)
    # indices point at the returned values
    picked = np.take_along_axis(np.asarray(x), np.asarray(idx), axis=-1)
    np.testing.assert_allclose(picked, np.asarray(v), atol=0)
    # indices are distinct per row
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == k


def test_descending_and_stable_on_ties():
    x = jnp.asarray([[1.0, 3.0, 3.0, 2.0]])
    v, idx = top_k_sorted(x, 3)
    np.testing.assert_array_equal(np.asarray(v)[0], [3.0, 3.0, 2.0])
    assert list(np.asarray(idx)[0][:2]) == [1, 2]      # stable tie order


def test_router_gradient_pattern():
    """The documented gradient path: stop-grad ids + one-hot einsum
    (models/moe.py) — grad reaches the selected entries only."""
    x = jnp.asarray([[0.3, 2.0, 1.0]])

    def f(x):
        _, idx = top_k_sorted(jax.lax.stop_gradient(x), 2)
        onehot = jax.nn.one_hot(idx, x.shape[-1], dtype=x.dtype)
        v = jnp.einsum("tke,te->tk", onehot, x)
        return jnp.sum(v * jnp.asarray([2.0, 1.0]))

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g)[0], [0.0, 2.0, 1.0])
