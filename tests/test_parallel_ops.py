"""Property tests for SPMD-friendly op variants (parallel/ops.py).

Hypothesis-driven sweeps skip individually when hypothesis is absent;
the deterministic cases always run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.ops import merge_sorted_topk, sort_by_key, top_k_sorted


def test_matches_lax_top_k_values():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        b=st.integers(1, 5),
        n=st.integers(2, 33),
        k=st.integers(1, 8),
        seed=st.integers(0, 2 ** 16),
    )
    def check(b, n, k, seed):
        k = min(k, n)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
        v_ref, _ = jax.lax.top_k(x, k)
        v, idx = top_k_sorted(x, k)
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), atol=0)
        # indices point at the returned values
        picked = np.take_along_axis(np.asarray(x), np.asarray(idx), axis=-1)
        np.testing.assert_allclose(picked, np.asarray(v), atol=0)
        # indices are distinct per row
        for row in np.asarray(idx):
            assert len(set(row.tolist())) == k

    check()


def test_descending_and_stable_on_ties():
    x = jnp.asarray([[1.0, 3.0, 3.0, 2.0]])
    v, idx = top_k_sorted(x, 3)
    np.testing.assert_array_equal(np.asarray(v)[0], [3.0, 3.0, 2.0])
    assert list(np.asarray(idx)[0][:2]) == [1, 2]      # stable tie order


def _merge_oracle(a_keys, b_keys_sorted, a_drop, b_drop_sorted, keep):
    """Stable sort of the concatenation, A-before-B on ties."""
    allk = np.concatenate([a_keys, b_keys_sorted])
    order = np.argsort(allk, kind="stable")
    kept, dropped = order[:keep], order[keep:]
    alld = np.concatenate([a_drop, b_drop_sorted])
    dmin = alld[dropped].min() if len(dropped) else np.inf
    return allk[kept], kept, dmin


def _check_merge_case(na, nb, keep, seed):
    if na + nb < keep:
        keep = na + nb
    rng = np.random.default_rng(seed)
    # small integer keys force plenty of ties (the interesting case)
    a = np.sort(rng.integers(0, 6, na)).astype(np.float32)
    b_raw = rng.integers(0, 6, nb).astype(np.float32)
    pa = np.arange(na, dtype=np.int32)
    pb = 1000 + np.arange(nb, dtype=np.int32)
    da = a + 0.5
    db_raw = b_raw + 0.5

    b_order = np.argsort(b_raw, kind="stable")
    want_k, want_pos, want_dmin = _merge_oracle(
        a, b_raw[b_order], da, db_raw[b_order], keep)
    want_p = np.concatenate([pa, pb[b_order]])[want_pos]

    # payload pre-sorted alongside the keys
    bs, pbs = sort_by_key(jnp.asarray(b_raw), jnp.asarray(pb))
    ko, po, dm = merge_sorted_topk(
        jnp.asarray(a), bs, jnp.asarray(pa), pbs, keep,
        drop_a=jnp.asarray(da), drop_b=jnp.asarray(db_raw[b_order]))
    np.testing.assert_array_equal(np.asarray(ko), want_k)
    np.testing.assert_array_equal(np.asarray(po), want_p)
    assert float(dm) == float(want_dmin)

    # perm_b mode: keys sorted separately, payload/drop in pre-sort order
    bs2, order = sort_by_key(jnp.asarray(b_raw),
                             jnp.arange(nb, dtype=jnp.int32))
    ko2, po2, dm2 = merge_sorted_topk(
        jnp.asarray(a), bs2, jnp.asarray(pa), jnp.asarray(pb), keep,
        drop_a=jnp.asarray(da), drop_b=jnp.asarray(db_raw), perm_b=order)
    np.testing.assert_array_equal(np.asarray(ko2), want_k)
    np.testing.assert_array_equal(np.asarray(po2), want_p)
    assert float(dm2) == float(want_dmin)


@pytest.mark.parametrize("na,nb,keep,seed",
                         [(16, 8, 16, 0), (0, 5, 3, 1), (7, 1, 8, 2),
                          (12, 12, 6, 3), (3, 20, 10, 4), (24, 24, 30, 5)])
def test_merge_sorted_topk_matches_stable_sort(na, nb, keep, seed):
    _check_merge_case(na, nb, keep, seed)


def test_merge_sorted_topk_matches_stable_sort_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(na=st.integers(0, 24), nb=st.integers(1, 24),
           keep=st.integers(1, 30), seed=st.integers(0, 2 ** 16))
    def check(na, nb, keep, seed):
        _check_merge_case(na, nb, keep, seed)

    check()


def test_merge_sorted_topk_prefers_run_a_on_ties():
    """The sorted-pool invariant needs the stable-merge tie rule: existing
    pool entries (run A) outrank equal-keyed fresh children (run B)."""
    a = jnp.asarray([1.0, 2.0])
    b = jnp.asarray([1.0, 2.0])
    _, payload, _ = merge_sorted_topk(
        a, b, jnp.asarray([10, 20]), jnp.asarray([30, 40]), 4)
    assert list(np.asarray(payload)) == [10, 30, 20, 40]


def test_merge_sorted_topk_dropped_min_tracks_floor():
    a = jnp.asarray([0.0, 5.0])
    b = jnp.asarray([1.0, 9.0])
    lb_a = jnp.asarray([0.0, 5.0])
    lb_b = jnp.asarray([1.0, 9.0])
    _, _, dmin = merge_sorted_topk(a, b, a, b, 2, drop_a=lb_a, drop_b=lb_b)
    assert float(dmin) == 5.0                   # min lb among {5.0, 9.0}
    _, _, none_dropped = merge_sorted_topk(a, b, a, b, 4,
                                           drop_a=lb_a, drop_b=lb_b)
    assert np.isinf(float(none_dropped))


def test_merge_sorted_topk_multidim_payload_and_vmap():
    rng = np.random.default_rng(3)
    batch, na, nb, keep, w = 4, 12, 6, 10, 5
    a = jnp.asarray(np.sort(rng.random((batch, na)), axis=1), jnp.float32)
    b = jnp.asarray(np.sort(rng.random((batch, nb)), axis=1), jnp.float32)
    pa = jnp.asarray(rng.integers(0, 9, (batch, na, w)), jnp.int32)
    pb = jnp.asarray(rng.integers(0, 9, (batch, nb, w)), jnp.int32)
    ko, po, dm = jax.vmap(
        lambda a, b, pa, pb: merge_sorted_topk(a, b, pa, pb, keep)
    )(a, b, pa, pb)
    for i in range(batch):
        allk = np.concatenate([np.asarray(a[i]), np.asarray(b[i])])
        allp = np.concatenate([np.asarray(pa[i]), np.asarray(pb[i])])
        order = np.argsort(allk, kind="stable")
        np.testing.assert_array_equal(np.asarray(ko[i]), allk[order[:keep]])
        np.testing.assert_array_equal(np.asarray(po[i]), allp[order[:keep]])


def test_router_gradient_pattern():
    """The documented gradient path: stop-grad ids + one-hot einsum
    (models/moe.py) — grad reaches the selected entries only."""
    x = jnp.asarray([[0.3, 2.0, 1.0]])

    def f(x):
        _, idx = top_k_sorted(jax.lax.stop_gradient(x), 2)
        onehot = jax.nn.one_hot(idx, x.shape[-1], dtype=x.dtype)
        v = jnp.einsum("tke,te->tk", onehot, x)
        return jnp.sum(v * jnp.asarray([2.0, 1.0]))

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g)[0], [0.0, 2.0, 1.0])


def test_merge_sorted_topk_kernel_path_bit_identical():
    """use_kernel=True swaps the searchsorted rank computation for the
    Pallas comparison-matrix kernel; the integer ranks are the same
    numbers, so every output (keys, payload, dropped floor) must be
    byte-identical — including on ties, where rank semantics live."""
    for na, nb, keep, seed in [(16, 8, 16, 0), (12, 12, 6, 3),
                               (24, 24, 30, 5), (32, 16, 20, 7)]:
        rng = np.random.default_rng(seed)
        a = jnp.asarray(np.sort(rng.integers(0, 6, na)), jnp.float32)
        b = jnp.asarray(np.sort(rng.integers(0, 6, nb)), jnp.float32)
        pa = jnp.asarray(np.arange(na), jnp.int32)
        pb = jnp.asarray(1000 + np.arange(nb), jnp.int32)
        da, db = a + 0.5, b + 0.5
        want = merge_sorted_topk(a, b, pa, pb, keep, drop_a=da, drop_b=db)
        got = merge_sorted_topk(a, b, pa, pb, keep, drop_a=da, drop_b=db,
                                use_kernel=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_merge_sorted_topk_kernel_path_vmap():
    """The kernel path under vmap (how the engine actually calls it):
    batched runs, multidim payload, byte-identical to the default path."""
    rng = np.random.default_rng(9)
    batch, na, nb, keep, w = 4, 16, 8, 12, 3
    a = jnp.asarray(np.sort(rng.integers(0, 5, (batch, na)), axis=1),
                    jnp.float32)
    b = jnp.asarray(np.sort(rng.integers(0, 5, (batch, nb)), axis=1),
                    jnp.float32)
    pa = jnp.asarray(rng.integers(0, 9, (batch, na, w)), jnp.int32)
    pb = jnp.asarray(rng.integers(0, 9, (batch, nb, w)), jnp.int32)

    def run(uk):
        return jax.vmap(
            lambda a, b, pa, pb: merge_sorted_topk(a, b, pa, pb, keep,
                                                   use_kernel=uk)
        )(a, b, pa, pb)

    for g, w in zip(run(True), run(False)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
