"""Pipeline-parallel wrapper: correctness vs sequential on fake devices."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply, stack_stages

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    L, D, B = 8, 16, 32
    rng = np.random.default_rng(0)
    layers = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.2, jnp.float32),
              "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def layer(p, a):
        return jnp.tanh(a @ p["w"] + p["b"])

    def stage_fn(stage_params, a):
        def body(c, lp):
            return layer(lp, c), None
        out, _ = jax.lax.scan(body, a, stage_params)
        return out

    # sequential oracle
    def seq(a):
        def body(c, i):
            lp = jax.tree.map(lambda t: t[i], layers)
            return layer(lp, c), None
        out, _ = jax.lax.scan(body, a, jnp.arange(L))
        return out
    want = seq(x)

    staged = stack_stages(layers, 4)
    got = pipeline_apply(stage_fn, staged, x, mesh, axis="pod",
                         microbatches=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)

    # compile check on the production-shaped (pod, data, model) mesh
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    staged2 = stack_stages(layers, 2)
    lowered = jax.jit(lambda p, xx: pipeline_apply(
        stage_fn, p, xx, mesh3, axis="pod", microbatches=4)).lower(staged2, x)
    compiled = lowered.compile()
    txt = compiled.as_text()      # post-SPMD HLO
    assert "collective-permute" in txt, "boundary transfer must be a permute"
    print("OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT % src],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
