"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.exact.assignment import brute_force_assignment, hungarian
from repro.core.exact.bounds import PairContext, remaining_lower_bound
from repro.core.exact.brute import brute_force_ged
from repro.core.exact.graph import Graph, editorial_cost, pad_pair
from repro.core.exact.multiset import hist_edit_distance, multiset_edit_distance
from repro.core.exact.order import matching_order
from repro.core.exact.search import ged, ged_verify


# ------------------------------------------------------------- strategies
@st.composite
def graphs(draw, max_n=6, n_vlabels=3, n_elabels=2):
    n = draw(st.integers(min_value=1, max_value=max_n))
    vlabels = draw(st.lists(st.integers(0, n_vlabels - 1), min_size=n, max_size=n))
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        for j in range(i + 1, n):
            e = draw(st.integers(0, n_elabels))
            adj[i, j] = adj[j, i] = e
    return Graph(np.asarray(vlabels), adj)


small_multisets = st.lists(st.integers(0, 4), min_size=0, max_size=8)


# ---------------------------------------------------------------- multiset
@given(small_multisets, small_multisets)
def test_multiset_edit_distance_is_metric(s1, s2):
    d = multiset_edit_distance(s1, s2)
    assert d >= 0
    assert d == multiset_edit_distance(s2, s1)
    assert (d == 0) == (sorted(s1) == sorted(s2))


@given(small_multisets, small_multisets, small_multisets)
def test_multiset_edit_distance_triangle(s1, s2, s3):
    d12 = multiset_edit_distance(s1, s2)
    d23 = multiset_edit_distance(s2, s3)
    d13 = multiset_edit_distance(s1, s3)
    assert d13 <= d12 + d23


@given(small_multisets, small_multisets, small_multisets, small_multisets)
def test_multiset_union_subadditivity(s1, s2, t1, t2):
    """Lemma A.1: Y(S1 u T1, S2 u T2) <= Y(S1, S2) + Y(T1, T2)."""
    lhs = multiset_edit_distance(s1 + t1, s2 + t2)
    rhs = multiset_edit_distance(s1, s2) + multiset_edit_distance(t1, t2)
    assert lhs <= rhs


@given(small_multisets, small_multisets)
def test_hist_edit_distance_agrees(s1, s2):
    h1 = np.bincount(np.asarray(s1, dtype=np.int64), minlength=5)
    h2 = np.bincount(np.asarray(s2, dtype=np.int64), minlength=5)
    assert hist_edit_distance(h1, h2) == multiset_edit_distance(s1, s2)


# -------------------------------------------------------------- assignment
@given(st.integers(1, 5), st.data())
@settings(max_examples=40, deadline=None)
def test_hungarian_optimal(n, data):
    cost = np.array(
        data.draw(
            st.lists(
                st.lists(st.integers(0, 10), min_size=n, max_size=n),
                min_size=n, max_size=n,
            )
        ),
        dtype=float,
    )
    col, total = hungarian(cost)
    _, bf = brute_force_assignment(cost)
    assert abs(total - bf) < 1e-9
    assert sorted(col.tolist()) == list(range(n))


# ------------------------------------------------------------------ GED
@given(graphs(max_n=4), graphs(max_n=4))
@settings(max_examples=25, deadline=None)
def test_ged_is_metric_like(q, g):
    d_qg = ged(q, g, bound="BMa").ged
    d_gq = ged(g, q, bound="BMa").ged
    assert d_qg == d_gq  # symmetry
    assert d_qg >= 0
    if d_qg == 0:
        # 0 distance -> brute force agrees they are isomorphic
        assert brute_force_ged(q, g) == 0


@given(graphs(max_n=4), graphs(max_n=4), graphs(max_n=4))
@settings(max_examples=15, deadline=None)
def test_ged_triangle_inequality(q, g, h):
    d_qg = ged(q, g, bound="BMa").ged
    d_gh = ged(g, h, bound="BMa").ged
    d_qh = ged(q, h, bound="BMa").ged
    assert d_qh <= d_qg + d_gh


@given(graphs(max_n=5), graphs(max_n=5))
@settings(max_examples=30, deadline=None)
def test_all_bounds_and_strategies_agree(q, g):
    results = set()
    for bound in ("LS", "LSa", "BMa"):
        for strategy in ("astar", "dfs"):
            results.add(ged(q, g, bound=bound, strategy=strategy).ged)
    assert len(results) == 1
    assert results.pop() == brute_force_ged(q, g)


@given(graphs(max_n=5), graphs(max_n=5), st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_verification_consistent_with_ged(q, g, tau):
    d = brute_force_ged(q, g)
    res = ged_verify(q, g, tau=tau, bound="BMa")
    assert res.similar == (d <= tau)


@given(graphs(max_n=5), graphs(max_n=5), st.data())
@settings(max_examples=30, deadline=None)
def test_root_bounds_lower_bound_true_ged(q, g, data):
    """Whole-state bounds at the root must lower-bound the true GED."""
    qp, gp, _ = pad_pair(q, g)
    order = matching_order(qp, gp)
    ctx = PairContext(qp, gp, order)
    d = brute_force_ged(q, g)
    for kind in ("LS", "LSa", "BM", "BMa", "SM", "SMa"):
        lb = remaining_lower_bound(ctx, (), kind)
        assert lb <= d + 1e-9, f"{kind}: {lb} > {d}"


@given(graphs(max_n=5))
@settings(max_examples=20, deadline=None)
def test_self_distance_zero(g):
    assert ged(g, g, bound="BMa").ged == 0
    assert ged(g, g, bound="LS", strategy="dfs").ged == 0


@given(graphs(max_n=5), st.data())
@settings(max_examples=25, deadline=None)
def test_editorial_cost_upper_bounds_ged(g, data):
    q = data.draw(graphs(max_n=5))
    qp, gp, _ = pad_pair(q, g)
    n = gp.n
    perm = data.draw(st.permutations(list(range(n))))
    cost = editorial_cost(qp, gp, np.asarray(perm))
    assert ged(q, g, bound="BMa").ged <= cost
