"""Fault-tolerant loop: injected failures recover with exact replay;
straggler scheduler invariants."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import FaultInjector, GedScheduler, difficulty, train_loop
from repro.runtime.scheduler import ESCALATION_RUNGS


def _toy_problem():
    """Deterministic quadratic: state is a vector, batch is data index."""
    import jax, jax.numpy as jnp

    target = jnp.asarray(np.linspace(-1, 1, 8), jnp.float32)

    @jax.jit
    def step(w, batch):
        x = jnp.asarray(batch, jnp.float32)
        loss = jnp.mean((w - target) ** 2) + 0.0 * x.sum()
        g = 2 * (w - target) / w.size
        w = w - 0.1 * g
        return w, {"loss": loss}

    def make_pipeline(start):
        def gen():
            k = start
            while True:
                yield np.full((2,), k)
                k += 1
        return gen()

    return step, make_pipeline


def _run(tmp_path, faults, steps=30):
    import jax.numpy as jnp
    step, make_pipeline = _toy_problem()
    ckpt = CheckpointManager(tmp_path, async_save=False)
    w0 = jnp.zeros((8,), jnp.float32)
    w, hist = train_loop(step, w0, make_pipeline, ckpt, total_steps=steps,
                         ckpt_every=10, injector=FaultInjector(faults),
                         log_every=1)
    return np.asarray(w), [h["loss"] for h in hist]


def test_fault_recovery_exact_replay(tmp_path):
    w_clean, h_clean = _run(tmp_path / "clean", faults=[])
    w_fault, h_fault = _run(tmp_path / "fault", faults=[15, 25])
    np.testing.assert_array_equal(w_clean, w_fault)
    assert h_clean == h_fault


def test_fault_before_first_checkpoint_raises(tmp_path):
    with pytest.raises(RuntimeError):
        _run(tmp_path, faults=[3])


def test_too_many_faults_raises(tmp_path):
    step, make_pipeline = _toy_problem()
    import jax.numpy as jnp
    ckpt = CheckpointManager(tmp_path, async_save=False)

    class Always(FaultInjector):
        def maybe_fail(self, step):
            if step == 15:
                from repro.runtime import SimulatedFault
                raise SimulatedFault("again")

    with pytest.raises(RuntimeError):
        train_loop(step, jnp.zeros((8,)), make_pipeline, ckpt,
                   total_steps=30, ckpt_every=10, injector=Always([]),
                   max_restarts=3)


# ------------------------------------------------------------- scheduler

def test_lpt_packing_balances_load(rng):
    sched = GedScheduler(batch_size=8)
    diffs = list(rng.lognormal(0, 2.0, size=64))       # heavy tail
    batches = sched.pack(diffs)
    assert sum(len(b.indices) for b in batches) == 64
    assert all(len(b.indices) <= 8 for b in batches)
    seen = sorted(i for b in batches for i in b.indices)
    assert seen == list(range(64))
    loads = [b.predicted for b in batches]
    naive = [sum(diffs[i] for i in range(k, min(k + 8, 64)))
             for k in range(0, 64, 8)]
    assert (max(loads) - min(loads)) <= (max(naive) - min(naive)) + 1e-9


def test_difficulty_monotone_in_size():
    l5 = [0, 1, 2, 3, 4]
    d_small = difficulty(8, 8, 10, 10, l5, l5)
    d_big = difficulty(24, 24, 60, 60, l5, l5)
    assert d_big > d_small


def test_difficulty_easier_when_tau_rejects_cheaply():
    l5 = [0, 1, 2, 3, 4]
    # huge size gap vs tau -> cheap reject -> lower predicted effort
    d_cheap = difficulty(10, 20, 10, 60, l5, l5, tau=2.0)
    d_hard = difficulty(10, 11, 10, 12, l5, l5, tau=12.0)
    assert d_cheap < d_hard


def test_escalation_rungs_grow():
    pools = [r[0] for r in ESCALATION_RUNGS]
    assert pools == sorted(pools) and len(set(pools)) == len(pools)
    sched = GedScheduler(batch_size=4)
    b = sched.pack([1.0] * 4)[0]
    nxt = sched.escalate(b, [0, 2])
    assert nxt.rung == 1 and len(nxt.indices) == 2
    assert sched.engine_params(len(ESCALATION_RUNGS)) is None
