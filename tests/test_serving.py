"""Serving layer: GED verification service correctness, corpus routing,
the similarity-search service, and LM generation."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.exact.search import ged as exact_ged
from repro.data.graphs import perturb, random_graph
from repro.models.config import reduced
from repro.models.params import init_params
from repro.serving import (GedRequest, GedSimilarityService,
                           GedVerificationService, SearchRequest, generate)


@pytest.fixture(scope="module")
def request_set():
    rng = np.random.default_rng(7)
    reqs, truths = [], []
    for _ in range(24):
        q = random_graph(rng, int(rng.integers(6, 11)))
        g = perturb(rng, q, int(rng.integers(1, 6)))
        true_ged = exact_ged(q, g, bound="BMa").ged
        tau = float(rng.integers(1, 7))
        reqs.append(GedRequest(q, g, tau))
        truths.append(true_ged)
    return reqs, truths


def test_verification_matches_exact(request_set):
    reqs, truths = request_set
    svc = GedVerificationService(batch_size=8, slots=16)
    results = svc.verify(reqs)
    assert len(results) == len(reqs)
    for r, req, t in zip(results, reqs, truths):
        assert r.certified
        assert r.similar == (t <= req.tau), (t, req.tau, r)
    assert svc.stats["pairs"] == len(reqs)


def test_computation_matches_exact(request_set):
    reqs, truths = request_set
    svc = GedVerificationService(batch_size=8, slots=16)
    results = svc.compute([(r.q, r.g) for r in reqs[:10]])
    for r, t in zip(results, truths[:10]):
        assert r.certified and r.ged == pytest.approx(t), (r.ged, t)


def test_escalation_path_used_for_hard_pairs():
    """Tiny first-rung budget forces escalation; answers stay exact."""
    rng = np.random.default_rng(11)
    reqs, truths = [], []
    for _ in range(6):
        q = random_graph(rng, 10, density=0.35)
        g = perturb(rng, q, 6)
        truths.append(exact_ged(q, g, bound="BMa").ged)
        reqs.append(GedRequest(q, g, tau=4.0))
    svc = GedVerificationService(batch_size=6, slots=16)
    svc.scheduler.rungs = ((8, 2, 4),)      # absurdly small engine budget
    results = svc.verify(reqs)
    assert svc.stats["escalated"] + svc.stats["host_solved"] > 0
    for r, req, t in zip(results, reqs, truths):
        assert r.certified and r.similar == (t <= req.tau)


def test_verify_routes_registered_corpus_through_store(request_set):
    """With a corpus registered, batch verification against in-corpus
    targets goes through the staged filter — and answers stay identical
    to the plain engine path."""
    reqs, truths = request_set
    corpus = [r.g for r in reqs[:16]]
    svc = GedVerificationService(batch_size=8, slots=16)
    store = svc.register_corpus(corpus)
    assert store.engine is svc.engine          # shared cache + executor

    rng = np.random.default_rng(21)
    stray = GedRequest(reqs[0].q,
                       random_graph(rng, 7), tau=3.0)   # not in the corpus
    # duck-typed query form must survive the corpus-routed path too
    ducky = GedRequest(([0, 1], [(0, 1, 1)]), corpus[0], tau=50.0)
    results = svc.verify(list(reqs[:16]) + [stray, ducky])
    for r, req, t in zip(results[:16], reqs[:16], truths[:16]):
        assert r.certified
        assert r.similar == (t <= req.tau), (t, req.tau, r)
    assert results[16].certified
    assert results[17].certified and results[17].similar
    s = svc.stats
    assert s["store_candidates"] == 17
    assert s["store_index_pruned"] + s["store_stage0_pruned"] + \
        s["store_stage1_decided"] + s["store_stage2_verified"] == 17
    # a shared engine is exclusive with engine-level store options
    with pytest.raises(TypeError):
        svc.register_corpus(corpus, cache=False)


def test_similarity_service_range_and_topk():
    rng = np.random.default_rng(23)
    corpus = [random_graph(rng, int(rng.integers(4, 8)), density=0.4,
                           n_vlabels=3, n_elabels=2) for _ in range(8)]
    svc = GedSimilarityService(corpus, batch_size=8, pool=256, expand=4,
                               max_iters=256)
    q = corpus[2]
    hits = svc.range_search(q, 0.0)
    assert any(h.graph_id == 2 for h in hits)
    answers = svc.search([SearchRequest(q, tau=1.0), SearchRequest(q, k=3)])
    assert len(answers) == 2
    assert all(h.query_id == 0 for h in answers[0])
    assert len(answers[1]) == 3 and answers[1][0].graph_id == 2
    assert svc.stats["queries"] == 3
    with pytest.raises(ValueError):
        svc.search([SearchRequest(q)])          # neither tau nor k


def test_lm_generate_runs():
    cfg = reduced(get_arch("qwen3-8b"))
    cfg = dataclasses.replace(cfg, remat="none", compute_dtype="float32")
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = generate(params, prompt, cfg, max_new=4, impl="naive")
    assert out.shape == (2, 4)
    assert np.all((out >= 0) & (out < cfg.vocab))


def test_lm_generate_ssm_runs():
    cfg = reduced(get_arch("rwkv6-3b"))
    cfg = dataclasses.replace(cfg, remat="none", compute_dtype="float32")
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(1, 8)).astype(np.int32)
    out = generate(params, prompt, cfg, max_new=4, impl="naive")
    assert out.shape == (1, 4)
